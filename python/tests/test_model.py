"""L2 tests: model structure (paper Fig 5/6), shapes, training step."""

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from compile import model as M  # noqa: E402


def _ids(bsz, max_len, seed=0, vocab=100):
    key = jax.random.PRNGKey(seed)
    ids = jax.random.randint(key, (bsz, max_len), 2, vocab)
    # Pad the tail third of each row.
    return ids.at[:, 2 * max_len // 3 :].set(M.PAD_ID)


class TestStructure:
    def test_fig5_conv_ops_structure(self):
        cfg = M.CONFIGS["conv_ops"]
        assert cfg["filters"] == [2] * 6, "Fig 5: six stacked Conv1D, fs=2"
        assert len(cfg["fc"]) == 3, "Fig 5: three FC layers"
        assert cfg["embed"] == 64, "paper: embedding dim 64"

    def test_fig6_conv_full_structure(self):
        cfg = M.CONFIGS["conv_full"]
        assert cfg["filters"] == [16, 16, 8, 8, 2, 1], "Fig 6 filter sizes"
        assert cfg["max_len"] == 4 * M.CONFIGS["conv_ops"]["max_len"], "~4x longer sequences"

    def test_param_manifest_matches_config(self):
        p = M.init_params("conv_ops")
        for i, (k, c) in enumerate(zip([2] * 6, [32] * 6)):
            assert p[f"conv{i}_w"].shape[0] == k
            assert p[f"conv{i}_w"].shape[2] == c
        assert p["embed"].shape == (M.VOCAB_SIZE, 64)

    def test_param_order_is_sorted_and_stable(self):
        p = M.init_params("lstm_ops")
        order = M.param_order(p)
        assert order == sorted(order)
        assert set(order) == set(p.keys())


class TestForward:
    @pytest.mark.parametrize("name", list(M.CONFIGS.keys()))
    def test_forward_shapes(self, name):
        cfg = M.CONFIGS[name]
        p = M.init_params(name)
        ids = _ids(4, cfg["max_len"])
        out = M.forward(name, p, ids)
        assert out.shape == (4,)
        assert np.isfinite(np.asarray(out)).all()

    def test_fc_is_order_invariant(self):
        # Bag-of-tokens: permuting the (unpadded) tokens must not change
        # the prediction.
        p = M.init_params("fc_ops")
        ids = _ids(2, 128, seed=3)
        perm = jax.random.permutation(jax.random.PRNGKey(1), 128 * 2 // 3)
        ids2 = ids.at[:, : len(perm)].set(ids[:, perm])
        a = M.forward("fc_ops", p, ids)
        b = M.forward("fc_ops", p, ids2)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)

    def test_conv_is_order_sensitive(self):
        # The sequence models must NOT be bag-of-tokens.
        p = M.init_params("conv_ops")
        ids = _ids(1, 128, seed=5)
        ids2 = ids.at[0, :8].set(ids[0, :8][::-1])
        a = M.forward("conv_ops", p, ids)
        b = M.forward("conv_ops", p, ids2)
        assert not np.allclose(np.asarray(a), np.asarray(b))

    def test_padding_is_inert(self):
        # Extending pure padding must not change predictions (mask works).
        p = M.init_params("conv_ops")
        ids = _ids(2, 128, seed=7)
        more_pad = ids.at[:, 100:].set(M.PAD_ID)
        a = M.forward("conv_ops", p, more_pad)
        ids3 = more_pad.at[:, 120:].set(M.PAD_ID)  # no-op
        b = M.forward("conv_ops", p, ids3)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))

    def test_pallas_path_matches_ref_path(self):
        p = M.init_params("conv_ops")
        ids = _ids(8, 128, seed=11)
        a = M.forward("conv_ops", p, ids, use_pallas=False)
        b = M.forward("conv_ops", p, ids, use_pallas=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


class TestTraining:
    def test_loss_decreases_on_fixed_batch(self):
        name = "conv_ops"
        p = M.init_params(name, seed=1)
        m, v = M.init_opt(p)
        ids = _ids(16, 128, seed=2)
        targets = jnp.linspace(-1.0, 1.0, 16)
        step = jnp.asarray(0.0)
        step_fn = jax.jit(lambda p, m, v, s: M.train_step(name, p, m, v, s, ids, targets))
        losses = []
        for _ in range(25):
            p, m, v, step, loss = step_fn(p, m, v, step)
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.5, losses

    def test_flat_signatures_roundtrip(self):
        name = "fc_ops"
        p = M.init_params(name)
        order = M.param_order(p)
        ids = _ids(4, 128)
        flat = [p[k] for k in order]
        (pred,) = M.predict_flat(name, order, *flat, ids)
        np.testing.assert_allclose(
            np.asarray(pred), np.asarray(M.forward(name, p, ids)), rtol=1e-6
        )
        m, v = M.init_opt(p)
        args = flat + [m[k] for k in order] + [v[k] for k in order]
        out = M.train_step_flat(
            name, order, *args, jnp.asarray(0.0), ids, jnp.zeros((4,), jnp.float32)
        )
        assert len(out) == 3 * len(order) + 2
        assert np.isfinite(float(out[-1]))

"""L1 correctness: Pallas kernel vs pure-jnp oracle (hypothesis sweep)."""

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from compile.kernels import conv1d as pk  # noqa: E402
from compile.kernels import ref  # noqa: E402


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


def _stack(key, taps, channels, cin):
    ws, bs = [], []
    chans = [cin] + channels
    for i, k in enumerate(taps):
        key, k1, k2 = jax.random.split(key, 3)
        ws.append(_rand(k1, (k, chans[i], chans[i + 1])) * 0.3)
        bs.append(_rand(k2, (chans[i + 1],)) * 0.1)
    return ws, bs


class TestConvRef:
    def test_conv1d_same_matches_manual(self):
        # K=2: out[t] = x[t-1] @ w0 + x[t] @ w1 + b.
        x = jnp.arange(12, dtype=jnp.float32).reshape(1, 4, 3)
        w = jnp.ones((2, 3, 2), jnp.float32)
        b = jnp.zeros((2,), jnp.float32)
        out = ref.conv1d_same(x, w, b)
        assert out.shape == (1, 4, 2)
        # t=0: only current tap (left pad is zero).
        np.testing.assert_allclose(out[0, 0], x[0, 0].sum() * np.ones(2), rtol=1e-6)
        # t=1: x[0] + x[1] contributions.
        np.testing.assert_allclose(
            out[0, 1], (x[0, 0].sum() + x[0, 1].sum()) * np.ones(2), rtol=1e-6
        )

    def test_relu_clamps(self):
        x = -jnp.ones((1, 4, 3), jnp.float32)
        w = jnp.ones((1, 3, 2), jnp.float32)
        b = jnp.zeros((2,), jnp.float32)
        out = ref.conv1d_relu(x, w, b)
        assert (np.asarray(out) >= 0).all()

    def test_maxpool(self):
        x = jnp.array([[[1.0, 5.0], [3.0, 2.0]]])
        np.testing.assert_allclose(ref.global_maxpool(x), [[3.0, 5.0]])


class TestPallasVsRef:
    @settings(max_examples=12, deadline=None)
    @given(
        bsz=st.sampled_from([1, 2, 4, 8]),
        length=st.sampled_from([8, 16, 33]),
        cin=st.sampled_from([4, 8]),
        depth=st.integers(min_value=1, max_value=3),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_stack_pool_matches_ref(self, bsz, length, cin, depth, seed):
        key = jax.random.PRNGKey(seed)
        key, kx = jax.random.split(key)
        x = _rand(kx, (bsz, length, cin))
        taps = [2, 3, 4][:depth]
        channels = [8] * depth
        ws, bs = _stack(key, taps, channels, cin)
        got = pk.conv_stack_pool_pallas(x, ws, bs)
        want = ref.conv_stack_pool(x, ws, bs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)

    def test_paper_fig5_config(self):
        # 6 layers, fs=2, like the ops-only model.
        key = jax.random.PRNGKey(7)
        key, kx = jax.random.split(key)
        x = _rand(kx, (8, 32, 16))
        ws, bs = _stack(key, [2] * 6, [16] * 6, 16)
        got = pk.conv_stack_pool_pallas(x, ws, bs)
        want = ref.conv_stack_pool(x, ws, bs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)

    def test_paper_fig6_filter_sizes(self):
        # fs = 16,16,8,8,2,1 on a longer sequence (ops+operands model).
        key = jax.random.PRNGKey(9)
        key, kx = jax.random.split(key)
        x = _rand(kx, (2, 64, 8))
        ws, bs = _stack(key, [16, 16, 8, 8, 2, 1], [8] * 6, 8)
        got = pk.conv_stack_pool_pallas(x, ws, bs)
        want = ref.conv_stack_pool(x, ws, bs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)

    def test_odd_batch_falls_back_to_block1(self):
        key = jax.random.PRNGKey(3)
        key, kx = jax.random.split(key)
        x = _rand(kx, (3, 16, 4))
        ws, bs = _stack(key, [2, 2], [4, 4], 4)
        got = pk.conv_stack_pool_pallas(x, ws, bs)
        want = ref.conv_stack_pool(x, ws, bs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


class TestPerfModels:
    def test_vmem_footprint_is_sane(self):
        # Fig 5 config at serving shape must fit a ~16 MiB VMEM budget.
        fp = pk.vmem_footprint_bytes(8, 128, [64] + [32] * 6, [2] * 6)
        assert fp < 16 << 20, fp

    def test_mxu_macs_positive_and_scales(self):
        small = pk.mxu_macs(128, [64, 32], [2])
        big = pk.mxu_macs(512, [64, 32], [2])
        assert big == 4 * small > 0

"""AOT export: lower the L2 models once to HLO *text* + a JSON manifest.

Interchange is HLO text, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids that the runtime's xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Outputs, per model variant, under artifacts/:
  predict_<variant>_b<B>.hlo.txt      (ref path, batch size B)
  predict_<variant>_b<B>_pallas.hlo.txt  (conv models: Pallas-kernel path)
  train_step_<variant>_b<B>.hlo.txt
  init_<variant>.npz-like flat f32 blob per param (raw little-endian)
  manifest.json                        (shapes, orders, file inventory)

Run via `make artifacts`. Python never runs after this point.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from compile import model as M  # noqa: E402

PREDICT_BATCHES = (1, 32)
TRAIN_BATCH = 32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def export_variant(name: str, outdir: str, manifest: dict) -> None:
    cfg = M.CONFIGS[name]
    params = M.init_params(name, seed=0)
    order = M.param_order(params)
    param_specs = [spec(params[k].shape) for k in order]
    max_len = cfg["max_len"]

    entry = {
        "config": {k: v for k, v in cfg.items()},
        "param_order": order,
        "param_shapes": {k: list(params[k].shape) for k in order},
        "max_len": max_len,
        "vocab_size": M.VOCAB_SIZE,
        "predict_batches": list(PREDICT_BATCHES),
        "train_batch": TRAIN_BATCH,
        "files": {},
    }

    # Initial parameters: one raw f32 little-endian blob per tensor.
    init_dir = os.path.join(outdir, f"init_{name}")
    os.makedirs(init_dir, exist_ok=True)
    for k in order:
        np.asarray(params[k], dtype=np.float32).tofile(os.path.join(init_dir, f"{k}.f32"))
    entry["files"]["init_dir"] = f"init_{name}"

    # Predict executables.
    for bsz in PREDICT_BATCHES:
        ids_spec = spec((bsz, max_len), jnp.int32)
        fn = functools.partial(M.predict_flat, name, order)
        low = jax.jit(fn).lower(*param_specs, ids_spec)
        path = f"predict_{name}_b{bsz}.hlo.txt"
        with open(os.path.join(outdir, path), "w") as f:
            f.write(to_hlo_text(low))
        entry["files"][f"predict_b{bsz}"] = path
        if cfg["kind"] == "conv":
            fnp = functools.partial(M.predict_flat_pallas, name, order)
            lowp = jax.jit(fnp).lower(*param_specs, ids_spec)
            pathp = f"predict_{name}_b{bsz}_pallas.hlo.txt"
            with open(os.path.join(outdir, pathp), "w") as f:
                f.write(to_hlo_text(lowp))
            entry["files"][f"predict_b{bsz}_pallas"] = pathp

    # Train step executable.
    ids_spec = spec((TRAIN_BATCH, max_len), jnp.int32)
    tgt_spec = spec((TRAIN_BATCH,), jnp.float32)
    step_spec = spec((), jnp.float32)
    fn = functools.partial(M.train_step_flat, name, order)
    low = jax.jit(fn).lower(
        *param_specs, *param_specs, *param_specs, step_spec, ids_spec, tgt_spec
    )
    path = f"train_step_{name}_b{TRAIN_BATCH}.hlo.txt"
    with open(os.path.join(outdir, path), "w") as f:
        f.write(to_hlo_text(low))
    entry["files"]["train_step"] = path

    manifest["models"][name] = entry
    print(f"exported {name}: {len(entry['files'])} artifact files")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))), "artifacts"))
    ap.add_argument("--models", nargs="*", default=list(M.CONFIGS.keys()))
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest = {"version": 1, "vocab_size": M.VOCAB_SIZE, "models": {}}
    for name in args.models:
        export_variant(name, args.out, manifest)
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"manifest written to {args.out}/manifest.json")


if __name__ == "__main__":
    main()

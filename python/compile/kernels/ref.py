"""Pure-jnp oracle for the L1 Pallas kernels.

Everything the Pallas kernel computes is specified here first; pytest
(`python/tests/test_kernels.py`) asserts the two agree to float tolerance
across a hypothesis sweep of shapes. The training path also uses these
reference ops (Pallas interpret-mode has no autodiff rule), so train and
serve are numerically the same function.
"""

from __future__ import annotations

import jax.numpy as jnp


def conv1d_same(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """1-D convolution with left-zero-padded 'same' output length.

    Args:
      x: [B, L, Cin] activations.
      w: [K, Cin, Cout] filter taps.
      b: [Cout] bias.

    Returns:
      [B, L, Cout]: ``out[:, t] = sum_k x[:, t-(K-1-k)] @ w[k] + b`` with
      zero padding on the left — tap K-1 sees the current position.
      Expressing the conv as K channel-contraction matmuls is exactly how
      the Pallas kernel maps it to the MXU.
    """
    bsz, length, cin = x.shape
    k, cin2, cout = w.shape
    assert cin == cin2, f"channel mismatch {cin} vs {cin2}"
    out = jnp.zeros((bsz, length, cout), dtype=x.dtype)
    for tap in range(k):
        shift = k - 1 - tap
        if shift == 0:
            xs = x
        else:
            xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :length, :]
        out = out + xs @ w[tap]
    return out + b


def conv1d_relu(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """conv1d_same followed by ReLU — one stack layer."""
    return jnp.maximum(conv1d_same(x, w, b), 0.0)


def conv_stack(x, taps, biases):
    """The paper's stacked Conv1D feature extractor (Fig 5 / Fig 6)."""
    for w, b in zip(taps, biases):
        x = conv1d_relu(x, w, b)
    return x


def global_maxpool(x: jnp.ndarray) -> jnp.ndarray:
    """MaxPool1D over the full sequence: [B, L, C] -> [B, C]."""
    return jnp.max(x, axis=1)


def conv_stack_pool(x, taps, biases):
    """Stack + pool: the fused region the Pallas kernel implements."""
    return global_maxpool(conv_stack(x, taps, biases))

"""L1 Pallas kernel: fused stacked-Conv1D + global MaxPool.

This is the hot path of the paper's best model (Fig 5: six Conv1D layers →
MaxPool1D → FC). The kernel fuses the whole conv stack and the pooling for
one block of the (batch, sequence) iteration space, so intermediate
activations never leave VMEM.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): a GPU would give each
threadblock a sequence tile and use shared memory; on TPU we instead

  * express each conv tap as a channel-contraction matmul
    ``x_shifted[L, Cin] @ w[tap][Cin, Cout]`` so the inner loop runs on the
    MXU systolic array (bf16/f32 matmul), not as pointwise VPU work;
  * tile the sequence dimension with BlockSpec so one (batch-row, L-block)
    of activations plus all taps fit in VMEM (footprint analysis in
    DESIGN.md §Perf);
  * overlap rows via the Pallas grid — the HBM→VMEM schedule a CUDA kernel
    writes by hand falls out of the BlockSpec index map.

The kernel MUST run with ``interpret=True`` in this image: real-TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
Numerics are validated against ``ref.py`` by pytest/hypothesis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Keep the padded left-halo of the deepest stack bounded; max total shift =
# sum(K_i - 1) over the stack. For the paper's two configs: fs=2 x6 -> 6,
# fs=16,16,8,8,2,1 -> 44.


def _stack_kernel(x_ref, *refs, n_layers: int, taps: tuple[int, ...]):
    """Pallas kernel body: refs = [w_0, b_0, ..., w_{n-1}, b_{n-1}, out].

    x_ref: [BLK_B, L, Cin] block (already left-padded by the caller).
    out:   [BLK_B, C_out] pooled features.
    """
    out_ref = refs[-1]
    x = x_ref[...]
    for layer in range(n_layers):
        w = refs[2 * layer][...]  # [K, Cin, Cout]
        b = refs[2 * layer + 1][...]  # [Cout]
        k = taps[layer]
        length = x.shape[1]
        acc = jnp.zeros((x.shape[0], length, w.shape[2]), dtype=x.dtype)
        for tap in range(k):
            shift = k - 1 - tap
            # Static shift: a roll + zero-mask keeps everything vectorized
            # (dynamic_slice per tap would serialize the MXU pipeline).
            if shift == 0:
                xs = x
            else:
                pad = jnp.zeros((x.shape[0], shift, x.shape[2]), dtype=x.dtype)
                xs = jnp.concatenate([pad, x[:, : length - shift, :]], axis=1)
            # Channel contraction on the MXU: [B, L, Cin] @ [Cin, Cout].
            acc = acc + jax.lax.dot_general(
                xs,
                w[tap],
                dimension_numbers=(((2,), (0,)), ((), ())),
                preferred_element_type=x.dtype,
            )
        x = jnp.maximum(acc + b, 0.0)
    # Global max pool over the sequence axis.
    out_ref[...] = jnp.max(x, axis=1)


def conv_stack_pool_pallas(x, taps_w, taps_b, *, block_b: int = 8):
    """Fused conv-stack + maxpool via pallas_call (interpret mode).

    Args:
      x: [B, L, Cin] embeddings.
      taps_w: list of [K_i, C_in_i, C_out_i] filters.
      taps_b: list of [C_out_i] biases.
      block_b: batch rows per grid step (VMEM tile height).

    Returns:
      [B, C_out_last] pooled features, identical to
      ``ref.conv_stack_pool``.
    """
    bsz, length, _ = x.shape
    n_layers = len(taps_w)
    taps = tuple(int(w.shape[0]) for w in taps_w)
    c_out = int(taps_w[-1].shape[2])
    if bsz % block_b != 0:
        block_b = 1  # degenerate but always valid

    kernel = functools.partial(_stack_kernel, n_layers=n_layers, taps=taps)
    in_specs = [pl.BlockSpec((block_b, length, x.shape[2]), lambda i: (i, 0, 0))]
    operands = [x]
    for w, b in zip(taps_w, taps_b):
        in_specs.append(pl.BlockSpec(w.shape, lambda i: (0,) * w.ndim))
        in_specs.append(pl.BlockSpec(b.shape, lambda i: (0,)))
        operands.extend([w, b])
    return pl.pallas_call(
        kernel,
        grid=(bsz // block_b,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, c_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, c_out), x.dtype),
        interpret=True,
    )(*operands)


def vmem_footprint_bytes(block_b: int, length: int, channels: list[int], taps: list[int],
                         dtype_bytes: int = 4) -> int:
    """Analytic VMEM footprint of one grid step (DESIGN.md §Perf L1).

    Activations double-buffer (current + next layer) plus all filter taps.
    """
    act = 2 * block_b * length * max(channels) * dtype_bytes
    weights = sum(k * cin * cout * dtype_bytes
                  for k, cin, cout in zip(taps, channels[:-1], channels[1:]))
    return act + weights


def mxu_macs(length: int, channels: list[int], taps: list[int]) -> int:
    """MACs per sample routed to the MXU — used for the utilization
    estimate in EXPERIMENTS.md §Perf."""
    total = 0
    for k, cin, cout in zip(taps, channels[:-1], channels[1:]):
        total += k * length * cin * cout
    return total

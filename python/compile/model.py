"""L2: the paper's three regressor families in JAX.

Models (paper §3 "The Actual ML-model"):
  * ``fc``    — bag-of-tokens: embed → masked mean over positions → 3 FC.
  * ``lstm``  — embed → single-layer LSTM → last hidden state → FC head.
  * ``conv``  — embed → stacked Conv1D(+ReLU) → global MaxPool → 3 FC
                (Fig 5; filter sizes [2]*6 for ops-only, Fig 6;
                [16,16,8,8,2,1] for ops+operands).

All parameters live in a flat ``dict[str, jnp.ndarray]``; the AOT boundary
flattens it in sorted-key order (the Rust runtime reconstructs the same
order from the manifest). Python here is build-time only — the functions
get lowered to HLO text once and executed forever from Rust via PJRT.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .kernels import conv1d as pk
from .kernels import ref

PAD_ID = 0

# ---------------------------------------------------------------------------
# Model configurations
# ---------------------------------------------------------------------------

# Paper: embedding dim 64. Channel widths chosen so a few hundred training
# steps are tractable on this CPU-only image; structure matches Fig 5/6.
CONFIGS = {
    # E1 models (ops-only tokenization, seq 128).
    "fc_ops": dict(kind="fc", max_len=128, embed=64, fc=[128, 64, 1]),
    "lstm_ops": dict(kind="lstm", max_len=128, embed=64, hidden=64, fc=[64, 1]),
    "conv_ops": dict(
        kind="conv", max_len=128, embed=64, channels=[32] * 6,
        filters=[2, 2, 2, 2, 2, 2], fc=[64, 32, 1],
    ),
    # E2 model (ops+operands tokenization, ~4x longer sequences, Fig 6
    # filter sizes 16,16,8,8,2,1).
    "conv_full": dict(
        kind="conv", max_len=512, embed=64, channels=[32] * 6,
        filters=[16, 16, 8, 8, 2, 1], fc=[64, 32, 1],
    ),
}

VOCAB_SIZE = 8192  # embedding rows; Rust vocabularies stay well under this


def _dense_init(key, fan_in, fan_out):
    scale = math.sqrt(2.0 / fan_in)
    return jax.random.normal(key, (fan_in, fan_out), jnp.float32) * scale


def init_params(name: str, seed: int = 0) -> dict[str, jnp.ndarray]:
    """Initialize a parameter dict for model config `name`."""
    cfg = CONFIGS[name]
    key = jax.random.PRNGKey(seed)
    keys = iter(jax.random.split(key, 64))
    p: dict[str, jnp.ndarray] = {}
    p["embed"] = jax.random.normal(next(keys), (VOCAB_SIZE, cfg["embed"]), jnp.float32) * 0.1

    if cfg["kind"] == "conv":
        cin = cfg["embed"]
        for i, (k, cout) in enumerate(zip(cfg["filters"], cfg["channels"])):
            p[f"conv{i}_w"] = _dense_init(next(keys), k * cin, cout).reshape(k, cin, cout)
            p[f"conv{i}_b"] = jnp.zeros((cout,), jnp.float32)
            cin = cout
        fan = cin
    elif cfg["kind"] == "lstm":
        h = cfg["hidden"]
        e = cfg["embed"]
        p["lstm_wx"] = _dense_init(next(keys), e, 4 * h)
        p["lstm_wh"] = _dense_init(next(keys), h, 4 * h)
        p["lstm_b"] = jnp.zeros((4 * h,), jnp.float32)
        fan = h
    else:  # fc / bag-of-tokens
        fan = cfg["embed"]

    for i, width in enumerate(cfg["fc"]):
        p[f"fc{i}_w"] = _dense_init(next(keys), fan, width)
        p[f"fc{i}_b"] = jnp.zeros((width,), jnp.float32)
        fan = width
    return p


def param_order(params: dict[str, jnp.ndarray]) -> list[str]:
    """Canonical flattening order shared with the Rust runtime."""
    return sorted(params.keys())


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _fc_head(p, x, n_fc):
    for i in range(n_fc):
        x = x @ p[f"fc{i}_w"] + p[f"fc{i}_b"]
        if i + 1 < n_fc:
            x = jnp.maximum(x, 0.0)
    return x[:, 0]


def forward(name: str, p: dict[str, jnp.ndarray], ids: jnp.ndarray,
            *, use_pallas: bool = False) -> jnp.ndarray:
    """Predict the (normalized) target for a batch of token-id rows.

    ids: [B, max_len] int32, 0 = padding.  Returns [B] float32.
    """
    cfg = CONFIGS[name]
    mask = (ids != PAD_ID).astype(jnp.float32)  # [B, L]
    emb = p["embed"][ids] * mask[:, :, None]  # zero out padding rows

    if cfg["kind"] == "fc":
        # Bag of tokens: masked mean (order-free, exactly the paper's
        # "considers the input token sequence as a bag-of-tokens").
        denom = jnp.maximum(mask.sum(axis=1, keepdims=True), 1.0)
        x = emb.sum(axis=1) / denom
    elif cfg["kind"] == "lstm":
        h = cfg["hidden"]

        def step(carry, xt):
            hprev, cprev = carry
            z = xt @ p["lstm_wx"] + hprev @ p["lstm_wh"] + p["lstm_b"]
            i, f, g, o = jnp.split(z, 4, axis=-1)
            c = jax.nn.sigmoid(f) * cprev + jax.nn.sigmoid(i) * jnp.tanh(g)
            hnew = jax.nn.sigmoid(o) * jnp.tanh(c)
            return (hnew, c), None

        bsz = ids.shape[0]
        h0 = (jnp.zeros((bsz, h), jnp.float32), jnp.zeros((bsz, h), jnp.float32))
        (hlast, _), _ = jax.lax.scan(step, h0, jnp.swapaxes(emb, 0, 1))
        x = hlast
    else:  # conv
        taps = [p[f"conv{i}_w"] for i in range(len(cfg["filters"]))]
        biases = [p[f"conv{i}_b"] for i in range(len(cfg["filters"]))]
        if use_pallas:
            x = pk.conv_stack_pool_pallas(emb, taps, biases)
        else:
            x = ref.conv_stack_pool(emb, taps, biases)

    return _fc_head(p, x, len(cfg["fc"]))


# ---------------------------------------------------------------------------
# Loss + Adam (hand-rolled: no optax at build time either)
# ---------------------------------------------------------------------------


def mse_loss(name, p, ids, targets):
    pred = forward(name, p, ids)
    return jnp.mean((pred - targets) ** 2)


def init_opt(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return zeros, {k: jnp.zeros_like(v) for k, v in params.items()}


def train_step(name, p, m, v, step, ids, targets, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    """One Adam step. Returns (new_p, new_m, new_v, new_step, loss)."""
    loss, grads = jax.value_and_grad(lambda q: mse_loss(name, q, ids, targets))(p)
    step = step + 1.0
    bc1 = 1.0 - b1 ** step
    bc2 = 1.0 - b2 ** step
    new_p, new_m, new_v = {}, {}, {}
    for k in p:
        g = grads[k]
        new_m[k] = b1 * m[k] + (1.0 - b1) * g
        new_v[k] = b2 * v[k] + (1.0 - b2) * g * g
        mhat = new_m[k] / bc1
        vhat = new_v[k] / bc2
        new_p[k] = p[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
    return new_p, new_m, new_v, step, loss


# ---------------------------------------------------------------------------
# AOT-facing flat signatures (params as positional leaves, sorted by key)
# ---------------------------------------------------------------------------


def predict_flat(name: str, order: list[str], *args):
    """args = [*params(sorted), ids] → (pred,)"""
    p = dict(zip(order, args[: len(order)]))
    ids = args[len(order)]
    return (forward(name, p, ids, use_pallas=False),)


def predict_flat_pallas(name: str, order: list[str], *args):
    """Same as predict_flat but through the Pallas kernel (conv models)."""
    p = dict(zip(order, args[: len(order)]))
    ids = args[len(order)]
    return (forward(name, p, ids, use_pallas=True),)


def train_step_flat(name: str, order: list[str], *args):
    """args = [*p, *m, *v, step, ids, targets] →
    (*new_p, *new_m, *new_v, new_step, loss)"""
    n = len(order)
    p = dict(zip(order, args[:n]))
    m = dict(zip(order, args[n : 2 * n]))
    v = dict(zip(order, args[2 * n : 3 * n]))
    step, ids, targets = args[3 * n], args[3 * n + 1], args[3 * n + 2]
    new_p, new_m, new_v, new_step, loss = train_step(name, p, m, v, step, ids, targets)
    out = [new_p[k] for k in order] + [new_m[k] for k in order] + [new_v[k] for k in order]
    return (*out, new_step, loss)

//! Compiler-integration example — the paper's §1 motivating question:
//! *"if we need to unroll a loop should we unroll-by-4 or unroll-by-8? Do
//! we run out of registers when we unroll aggressively?"*
//!
//! A toy pass sweeps unroll factors, asks the ML cost model for the
//! predicted register pressure of each variant, and picks the largest
//! unroll that stays inside the register file — then we check the choice
//! against the real compile+simulate pipeline.
//!
//! Run: `cargo run --release --example compiler_unroll`

use anyhow::Result;
use mlir_cost::graphgen::{generate, Family, GraphSpec};
use mlir_cost::lower::{analyze, lower, CodegenOpts, VREG_CAPACITY};
use mlir_cost::sim::{simulate, XpuConfig};

fn main() -> Result<()> {
    let cfg = XpuConfig::default();
    println!("unroll sweep (register budget = {VREG_CAPACITY} vregs)\n");
    println!(
        "{:<28} {:>7} {:>12} {:>12} {:>10}",
        "graph", "unroll", "regpressure", "cycles", "spills"
    );

    for (i, family) in [Family::Mlp, Family::Bert, Family::Random].into_iter().enumerate() {
        let spec = GraphSpec {
            family,
            structure_seed: 11 + i as u64,
            shape_seed: 23 + i as u64,
        };
        let func = generate(&spec)?;
        let mut best: Option<(u32, u64)> = None;
        for unroll in [1u32, 2, 4, 8, 16] {
            let opts = CodegenOpts { unroll: Some(unroll), ..Default::default() };
            let mut prog = lower(&func, &opts)?;
            let reg = analyze(&prog);
            mlir_cost::lower::apply_spills(&mut prog, &reg);
            let sim = simulate(&prog, &cfg);
            println!(
                "{:<28} {:>7} {:>12} {:>12} {:>10}",
                format!("{}({})", family.name(), func.num_ops()),
                unroll,
                reg.max_live,
                sim.cycles,
                reg.spilled
            );
            // Policy: fastest variant that does not spill.
            if reg.spilled == 0 && best.map_or(true, |(_, c)| sim.cycles < c) {
                best = Some((unroll, sim.cycles));
            }
        }
        match best {
            Some((u, c)) => println!("  -> chose unroll-by-{u} ({c} cycles, no spills)\n"),
            None => println!("  -> every variant spills; chose unroll-by-1\n"),
        }
    }
    println!(
        "(In production the per-variant regpressure comes from the served\n\
         ML model — `mlir-cost serve` — instead of compiling every variant;\n\
         that is precisely the compile-time the paper's model saves.)"
    );
    Ok(())
}

//! Quickstart: generate a dataflow graph, print its MLIR, get the
//! compiler+simulator ground truth, and query the served cost model.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;
use mlir_cost::bundle::Bundle;
use mlir_cost::coordinator::{batcher::BatchPolicy, Service};
use mlir_cost::dataset::TargetStats;
use mlir_cost::graphgen::{generate, Family, GraphSpec};
use mlir_cost::mlir::print_function;
use mlir_cost::runtime::Manifest;
use mlir_cost::sim::{ground_truth_default, Target};
use mlir_cost::tokenizer::{tokenize, Scheme, Vocab};
use std::path::Path;
use std::sync::Arc;

fn main() -> Result<()> {
    // 1. A ResNet-style subgraph from the corpus generator.
    let spec = GraphSpec { family: Family::Resnet, structure_seed: 7, shape_seed: 9 };
    let func = generate(&spec)?;
    let text = print_function(&func);
    println!("--- MLIR ({} ops) ---\n{text}", func.num_ops());

    // 2. Ground truth: what the DL-compiler + xPU simulator measure.
    let labels = ground_truth_default(&func)?;
    println!(
        "--- ground truth ---\nregpressure = {}\nxpuutil     = {:.2}%\ncycles      = {}",
        labels.regpressure, labels.xpu_util, labels.cycles
    );

    // 3. The paper's tokenization (ops-only).
    let toks = tokenize(&func, Scheme::OpsOnly);
    println!("--- tokens ({}) ---\n{}", toks.len(), toks.join(" "));

    // 4. Query the ML cost model through the serving coordinator. Uses a
    //    trained bundle when present (runs/e1/conv_regpressure), otherwise
    //    untrained weights (prediction quality then meaningless, but the
    //    full parse→tokenize→batch→PJRT path is identical).
    let manifest = Arc::new(Manifest::load(Path::new("artifacts"))?);
    let bundle_dir = Path::new("runs/e1/conv_regpressure");
    let bundle = if bundle_dir.join("bundle.json").exists() {
        println!("--- using trained bundle {bundle_dir:?} ---");
        Bundle::load(bundle_dir, &manifest)?
    } else {
        println!("--- no trained bundle found; using untrained weights ---");
        let streams = vec![toks.clone()];
        Bundle::untrained(
            &manifest,
            "conv_ops",
            Target::RegPressure,
            Scheme::OpsOnly,
            Vocab::build(streams.iter(), 1),
            TargetStats { mean: 20.0, std: 8.0, min: 2.0, max: 70.0 },
        )?
    };
    let service = Arc::new(Service::start(
        manifest,
        vec![bundle],
        BatchPolicy::default(),
        true, // Pallas-kernel predict path
    )?);
    let pred = service.predict(Target::RegPressure, &text)?;
    println!(
        "--- model prediction ---\nregpressure ≈ {pred:.2} (truth {})",
        labels.regpressure
    );
    Ok(())
}

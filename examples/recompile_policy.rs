//! Dynamic-runtime example — the paper's abstract: cost models "help
//! dynamic runtimes make decisions on whether to incur the cost of
//! recompilation given changing operator shapes or continue using already
//! compiled code."
//!
//! A runtime holds a kernel compiled for shape S0. Requests arrive with
//! new shapes; running them on the S0 binary means padding to S0 (wasted
//! cycles), recompiling costs a fixed budget. The policy compares the
//! predicted cycles of both options.
//!
//! Run: `cargo run --release --example recompile_policy`

use anyhow::Result;
use mlir_cost::mlir::{Attrs, DType, FuncBuilder, Function, Type, XpuOp};
use mlir_cost::sim::ground_truth_default;

/// An MLP layer at a given batch size (the "operator shape" that changes).
fn kernel(batch: i64) -> Result<Function> {
    let mut b = FuncBuilder::new(&format!("mlp_b{batch}"));
    let x = b.arg(Type::tensor(vec![batch, 512], DType::F32));
    let w1 = b.xpu(
        XpuOp::Const,
        &[],
        Attrs::new()
            .with("shape", mlir_cost::mlir::Attr::IntArray(vec![512, 512]))
            .with("dtype", mlir_cost::mlir::Attr::Str("f32".into())),
    )?;
    let h = b.xpu(XpuOp::MatMul, &[x, w1], Attrs::new())?;
    let r = b.xpu(XpuOp::Relu, &[h], Attrs::new())?;
    let f = b.ret(&[r])?;
    Ok(f)
}

fn main() -> Result<()> {
    const RECOMPILE_COST_CYCLES: f64 = 2_000_000.0; // measured compile time, amortized per use
    let compiled_batch = 64i64;
    let compiled = ground_truth_default(&kernel(compiled_batch)?)?;
    println!(
        "resident binary: batch={compiled_batch}, {} cycles/run\n",
        compiled.cycles
    );
    println!(
        "{:>10} {:>14} {:>14} {:>16}  decision",
        "batch", "pad+reuse cyc", "native cyc", "break-even runs"
    );
    for batch in [8i64, 16, 48, 96, 256] {
        // Reuse: pad to 64-multiples and run ceil(b/64) times.
        let runs = (batch + compiled_batch - 1) / compiled_batch;
        let reuse_cycles = compiled.cycles * runs as f64;
        // Recompile: native shape.
        let native = ground_truth_default(&kernel(batch)?)?;
        let saving = reuse_cycles - native.cycles;
        let break_even = if saving > 0.0 {
            (RECOMPILE_COST_CYCLES / saving).ceil()
        } else {
            f64::INFINITY
        };
        let decide = if saving > 0.0 && break_even <= 100.0 {
            format!("RECOMPILE (pays off after {break_even:.0} runs)")
        } else {
            "reuse padded binary".to_string()
        };
        println!(
            "{:>10} {:>14} {:>14} {:>16}  {}",
            batch,
            reuse_cycles,
            native.cycles,
            if break_even.is_finite() { format!("{break_even:.0}") } else { "-".into() },
            decide
        );
    }
    println!(
        "\n(The runtime never compiles to decide: predicted cycles come from\n\
         the served `cycles` cost model; here we show the same decision with\n\
         simulator ground truth so the example is self-contained.)"
    );
    Ok(())
}

//! End-to-end driver (the repo's E2E validation, EXPERIMENTS.md §E2E):
//! corpus generation → ground-truth labeling → tokenize/encode → train the
//! paper's Conv1D model via the AOT `train_step` on PJRT → evaluate →
//! serve one prediction — all from one Rust process, Python long gone.
//!
//! Budgets are env-tunable: E2E_COUNT (base graphs), E2E_STEPS.
//! Defaults keep the run to a few minutes on one CPU core.
//!
//! Run: `cargo run --release --example end_to_end`

use anyhow::Result;
use mlir_cost::bundle::Bundle;
use mlir_cost::dataset::{Dataset, EncodedSet, TargetStats};
use mlir_cost::runtime::{Manifest, Runtime};
use mlir_cost::sim::Target;
use mlir_cost::tokenizer::{OpIdTable, Scheme, Vocab};
use mlir_cost::train::{metrics, TrainConfig, Trainer};
use std::path::Path;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let count = env_usize("E2E_COUNT", 1500);
    let steps = env_usize("E2E_STEPS", 300);
    let model = std::env::var("E2E_MODEL").unwrap_or_else(|_| "conv_ops".into());
    let target = Target::RegPressure;
    let scheme = Scheme::OpsOnly;

    // 1. Corpus: graphs -> MLIR text -> compile+simulate ground truth.
    let t0 = std::time::Instant::now();
    let ds = Dataset::generate(42, count, 1)?;
    println!(
        "[1/6] corpus: {} labeled samples in {:.1}s",
        ds.len(),
        t0.elapsed().as_secs_f64()
    );
    let (train, test) = ds.split(7, 0.1);

    // 2. Tokenize + encode (vocab on train only; the fused encode pass
    // counts test OOV as a side effect — no second vocabulary sweep).
    let streams_tr = train.token_streams(scheme)?;
    let streams_te = test.token_streams(scheme)?;
    let vocab = Vocab::build(streams_tr.iter(), 2);
    let stats = TargetStats::for_dataset(&train, target);
    let manifest = Manifest::load(Path::new("artifacts"))?;
    let mm = manifest.model(&model)?;
    let enc_tr = EncodedSet::build(&train, &streams_tr, &vocab, mm.max_len, target, &stats);
    let enc_te = EncodedSet::build(&test, &streams_te, &vocab, mm.max_len, target, &stats);
    let total: usize = streams_te.iter().map(Vec::len).sum();
    println!(
        "[2/6] vocab {} tokens; test OOV rate {:.2}% ({} / {})",
        vocab.len(),
        100.0 * enc_te.oov as f64 / total as f64,
        enc_te.oov,
        total
    );

    // 3. Train via the AOT train_step executable.
    let rt = Runtime::cpu()?;
    let mut trainer = Trainer::new(&rt, &manifest, &model)?;
    let cfg = TrainConfig {
        model: model.clone(),
        steps,
        seed: 0,
        eval_every: (steps / 3).max(1),
        log_every: (steps / 10).max(1),
    };
    let report = trainer.run(&cfg, &enc_tr, &enc_te)?;
    println!(
        "[3/6] trained {steps} steps at {:.2} steps/s; loss curve: {:?}",
        report.steps_per_sec,
        report
            .losses
            .iter()
            .map(|(s, l)| format!("{s}:{l:.3}"))
            .collect::<Vec<_>>()
    );

    // 4. Evaluate in paper terms.
    let preds: Vec<f64> = trainer
        .predict_set(&enc_te)?
        .iter()
        .map(|p| stats.denormalize(p.first()))
        .collect();
    let truth: Vec<f64> = test.samples.iter().map(|s| target.of(&s.labels)).collect();
    let rmse_pct = metrics::rmse_pct(&preds, &truth, stats.range());
    println!(
        "[4/6] test: RMSE {:.3} ({:.2}% of range {:.0}), MAE {:.3}, exact {:.1}%",
        metrics::rmse(&preds, &truth),
        rmse_pct,
        stats.range(),
        metrics::mae(&preds, &truth),
        metrics::pct_exact_rounded(&preds, &truth)
    );

    // 5. Persist the serving bundle + show one served prediction.
    let op_ids = OpIdTable::build(&vocab);
    let bundle = Bundle {
        model: model.clone(),
        targets: vec![target],
        scheme,
        max_len: mm.max_len,
        vocab,
        stats: vec![stats],
        hardware: None,
        params: trainer.params().to_vec(),
        op_ids,
    };
    let out = Path::new("runs/e2e_bundle");
    bundle.save(out, &manifest)?;
    // The router serves only queries its variant's max_len covers (no
    // silent truncation), so demo with a sample that fits.
    let sample = test
        .samples
        .iter()
        .find(|s| {
            mlir_cost::mlir::parse_function(&s.mlir_text)
                .map(|f| mlir_cost::tokenizer::token_count(&f, scheme) <= mm.max_len)
                .unwrap_or(false)
        })
        .unwrap_or(&test.samples[0]);
    let service = std::sync::Arc::new(mlir_cost::coordinator::Service::start(
        std::sync::Arc::new(manifest),
        vec![Bundle::load(out, &Manifest::load(Path::new("artifacts"))?)?],
        mlir_cost::coordinator::batcher::BatchPolicy::default(),
        true,
    )?);
    let served = service.predict(target, &sample.mlir_text)?;
    println!(
        "[5/6] bundle {out:?}; served prediction for '{}': {:.2} (truth {})",
        sample.name, served, sample.labels.regpressure
    );

    // 6. Batch API: a compiler pass hands the coordinator a whole probe
    //    set at once — cache hits resolve inline, all misses enter the
    //    batch queue in one shot, duplicates coalesce via single-flight.
    let probe: Vec<&str> =
        test.samples.iter().take(8).map(|s| s.mlir_text.as_str()).collect();
    let many = service.predict_many(target, &probe);
    let ok = many.iter().filter(|r| r.is_ok()).count();
    println!(
        "[6/6] predict_many: {ok}/{} predictions in one call (batch fill {:.2}, {} coalesced, {} cache hits)",
        probe.len(),
        service.stats.batch_fill_ratio(),
        service.cache.coalesced(),
        service.cache.stats().0,
    );
    Ok(())
}

# Convenience targets for the common workflows. Everything here is a
# thin wrapper — the scripts/ entries are the source of truth and run
# fine without make.

.PHONY: build test bench bench-smoke check

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

# Full bench run at real iteration counts: overwrites the committed
# BENCH_*.json results, then self-checks them (schema + regression
# diff against the pre-run baselines).
bench:
	bash scripts/run_benches.sh

# CI's fast twin: every bench must still run end to end under
# MLIR_COST_SMOKE=1; committed results are restored afterwards.
bench-smoke:
	bash scripts/bench_smoke.sh

# The non-cargo checks CI runs (docs, bench schemas, differ smoke).
check:
	python3 scripts/check_doc_links.py
	python3 scripts/check_bench_schema.py
	python3 scripts/bench_compare.py . . --require-both

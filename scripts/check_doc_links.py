#!/usr/bin/env python3
"""Fail CI on broken intra-repo links in README.md and docs/*.md.

Checks every markdown inline link `[text](target)` whose target is not
an external URL or a pure in-page anchor: the referenced file (or
directory) must exist relative to the linking file. Anchor fragments
(`file.md#section`) are checked for file existence only — heading
anchors are best-effort by design.

Usage: python3 scripts/check_doc_links.py  (from the repo root)
"""

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
EXTERNAL = ("http://", "https://", "mailto:")


def doc_files(root: Path):
    files = []
    readme = root / "README.md"
    if readme.exists():
        files.append(readme)
    files.extend(sorted((root / "docs").glob("*.md")))
    return files


def check(root: Path) -> int:
    errors = 0
    files = doc_files(root)
    if not files:
        print("error: no README.md or docs/*.md found — wrong cwd?", file=sys.stderr)
        return 1
    for md in files:
        text = md.read_text(encoding="utf-8")
        in_fence = False
        for lineno, line in enumerate(text.splitlines(), start=1):
            if line.strip().startswith("```"):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for target in LINK_RE.findall(line):
                if target.startswith(EXTERNAL) or target.startswith("#"):
                    continue
                path_part = target.split("#", 1)[0]
                if not path_part:
                    continue
                resolved = (md.parent / path_part).resolve()
                if not resolved.exists():
                    print(
                        f"{md.relative_to(root)}:{lineno}: broken link -> {target}",
                        file=sys.stderr,
                    )
                    errors += 1
    checked = ", ".join(str(f.relative_to(root)) for f in files)
    if errors:
        print(f"{errors} broken link(s) across: {checked}", file=sys.stderr)
    else:
        print(f"links OK: {checked}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(check(Path.cwd()))

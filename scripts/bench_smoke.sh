#!/usr/bin/env bash
# Bench smoke: every bench target must still RUN end to end, not just
# compile. Builds all e1-e10 bench binaries, then — when model artifacts
# are present — runs each one under MLIR_COST_SMOKE=1, which makes
# benchkit clamp every iteration count to a tiny budget so the full
# suite finishes in seconds. Smoke numbers are execution evidence, not
# measurements: any BENCH_*.json the benches write is restored
# afterwards so a smoke run never clobbers committed results.
#
# Usage: bash scripts/bench_smoke.sh   (from anywhere; cds to repo root)
set -euo pipefail

cd "$(dirname "$0")/.."

benches=(
  e1_rmse_table
  e2_fig6
  e3_serving
  e4_model_latency
  e5_ablation
  e6_frontend
  e7_cluster
  e8_router
  e9_incremental
  e10_autotune
  e11_admission
)

echo "== building all bench targets =="
(cd rust && cargo build --release --benches)

if [[ ! -f artifacts/manifest.json ]]; then
  echo "== artifacts/ absent: benches built but not run (model-gated) =="
  exit 0
fi

# Preserve committed bench results across the smoke run.
tmp="$(mktemp -d)"
cp BENCH_*.json "$tmp"/ 2>/dev/null || true
restore() {
  cp "$tmp"/BENCH_*.json . 2>/dev/null || true
  rm -rf "$tmp"
}
trap restore EXIT

for b in "${benches[@]}"; do
  echo "== smoke: $b =="
  (cd rust && MLIR_COST_SMOKE=1 cargo bench --bench "$b")
done

echo "== bench smoke OK (${#benches[@]} benches) =="

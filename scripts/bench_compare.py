#!/usr/bin/env python3
"""Diff a fresh bench run against the committed BENCH_*.json baselines.

Typical use after rerunning benches locally:

    cd rust && cargo bench --bench e6_frontend --bench e9_incremental
    git stash -- ../BENCH_*.json        # committed baselines back in place
    python3 scripts/bench_compare.py /tmp/fresh .   # or any two dirs

For every BENCH_*.json present in BOTH directories, numeric metrics are
compared leaf-by-leaf (objects by key, scenario arrays by index):

- a `null` on either side is skipped — placeholders (schema files whose
  metrics were never measured) never fail the comparison;
- metric *direction* is inferred from the key name: `*_us` / `*_ns` /
  latency-style keys regress when they grow, `*_per_sec` / `*speedup*` /
  `*ratio*` keys regress when they shrink; keys with no inferable
  direction are reported but never fail;
- a regression beyond --threshold (default 25%, i.e. 1.25x the wrong
  way) fails with exit 1. Improvements and in-threshold noise print as
  information only.

A file present on only ONE side (committed baseline with no fresh
counterpart, or a fresh file with no baseline — e.g. a brand-new bench)
compares nothing; both directions warn on stderr, and --require-both
turns either into a failure so CI catches a bench silently dropping out
of the run.

CI runs this self-referentially (`bench_compare.py . .`) as a smoke
test: every committed bench file must parse and identity-compare clean.
"""

import argparse
import json
import sys
from pathlib import Path

SLOWER_IS_WORSE = ("_us", "_ns", "latency", "us_per_edit", "open_us")
BIGGER_IS_BETTER = ("per_sec", "speedup", "ratio", "hit")


def direction(key: str):
    """+1 if bigger is better, -1 if smaller is better, 0 if unknown."""
    k = key.lower()
    if any(tag in k for tag in BIGGER_IS_BETTER):
        return 1
    if any(k.endswith(tag) or tag in k for tag in SLOWER_IS_WORSE):
        return -1
    return 0


def numeric_leaves(value, path=""):
    """Yield (path, leaf_key, number-or-None) for every metric leaf."""
    if isinstance(value, dict):
        for key, child in value.items():
            yield from numeric_leaves(child, f"{path}.{key}" if path else key)
    elif isinstance(value, list):
        for i, child in enumerate(value):
            yield from numeric_leaves(child, f"{path}[{i}]")
    elif isinstance(value, bool) or isinstance(value, str):
        return
    else:  # number or null
        leaf_key = path.rsplit(".", 1)[-1]
        yield path, leaf_key, value


def compare_file(name, base_doc, fresh_doc, threshold):
    """Return (regressions, notes) comparing one bench document."""
    regressions, notes = [], []
    base = {p: (k, v) for p, k, v in numeric_leaves(base_doc)}
    for path, key, fresh_v in numeric_leaves(fresh_doc):
        if path not in base:
            notes.append(f"{name}: {path}: new metric (no baseline)")
            continue
        _, base_v = base.pop(path)
        if base_v is None or fresh_v is None:
            continue  # null-tolerant: unmeasured on either side
        sense = direction(key)
        if sense == 0:
            if base_v != fresh_v:
                notes.append(f"{name}: {path}: {base_v} -> {fresh_v} (direction unknown)")
            continue
        if base_v == 0:
            continue  # ratio undefined; schema check guards the zeros that matter
        ratio = fresh_v / base_v
        # Normalize so `worse > 1` regardless of metric direction.
        worse = ratio if sense < 0 else (1.0 / ratio if ratio else float("inf"))
        if worse > 1.0 + threshold:
            regressions.append(
                f"{name}: {path}: {base_v:.4g} -> {fresh_v:.4g} "
                f"({(worse - 1.0) * 100.0:.0f}% worse than baseline)"
            )
        elif worse < 1.0:
            notes.append(f"{name}: {path}: {base_v:.4g} -> {fresh_v:.4g} (improved)")
    for path, (_, base_v) in sorted(base.items()):
        if base_v is not None:
            regressions.append(f"{name}: {path}: measured metric dropped from the fresh run")
    return regressions, notes


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", type=Path, help="dir with committed BENCH_*.json")
    ap.add_argument("fresh", type=Path, help="dir with freshly produced BENCH_*.json")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.25,
        help="allowed fractional regression per metric (default 0.25 = 25%%)",
    )
    ap.add_argument(
        "--require-both",
        action="store_true",
        help="fail (exit 1) when a BENCH_*.json exists on only one side, "
        "instead of just warning — CI mode",
    )
    args = ap.parse_args()
    base_files = {p.name: p for p in sorted(args.baseline.glob("BENCH_*.json"))}
    fresh_files = {p.name: p for p in sorted(args.fresh.glob("BENCH_*.json"))}
    if not base_files:
        print(f"error: no BENCH_*.json under {args.baseline}", file=sys.stderr)
        return 1
    regressions, notes = [], []
    compared = []
    one_sided = [
        f"{name}: in baseline but missing from the fresh run"
        for name in base_files
        if name not in fresh_files
    ] + [
        f"{name}: in fresh run but has no committed baseline"
        for name in sorted(fresh_files)
        if name not in base_files
    ]
    for line in one_sided:
        print(f"warning: {line} — nothing compared", file=sys.stderr)
    if args.require_both:
        regressions += [f"{line} (--require-both)" for line in one_sided]
    for name, base_path in base_files.items():
        fresh_path = fresh_files.get(name)
        if fresh_path is None:
            continue
        try:
            base_doc = json.loads(base_path.read_text(encoding="utf-8"))
            fresh_doc = json.loads(fresh_path.read_text(encoding="utf-8"))
        except json.JSONDecodeError as e:
            regressions.append(f"{name}: invalid JSON: {e}")
            continue
        r, n = compare_file(name, base_doc, fresh_doc, args.threshold)
        regressions += r
        notes += n
        compared.append(name)
    for line in notes:
        print(f"note: {line}")
    if regressions:
        for line in regressions:
            print(f"REGRESSION: {line}", file=sys.stderr)
        print(f"{len(regressions)} regression(s) across {compared}", file=sys.stderr)
        return 1
    print(f"bench comparison clean: {', '.join(compared) or 'nothing comparable'}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

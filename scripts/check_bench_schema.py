#!/usr/bin/env python3
"""Fail CI when a BENCH_*.json drifts from its expected schema.

Each `BENCH_*.json` at the repo root is either a placeholder schema
(metric values `null`, overwritten by running the bench) or a measured
result. Either way it must stay machine-readable for the dashboards
that diff bench runs across PRs:

- valid JSON, top-level object;
- a `bench` string naming the producing bench (`rust/benches/<bench>.rs`
  must exist) and a human `note` string;
- every leaf value is a number, string, bool, or null — a metric that
  was measured must be a finite number, a metric not yet measured must
  be null (never "", NaN, or a quoted number);
- every `scenarios`-style array holds objects sharing ONE key set, so
  a renamed column cannot silently fork the table's schema.

Usage: python3 scripts/check_bench_schema.py  (from the repo root)
"""

import json
import math
import sys
from pathlib import Path


def leaf_errors(value, path):
    """Yield (path, message) for every malformed leaf under `value`."""
    if isinstance(value, dict):
        for key, child in value.items():
            yield from leaf_errors(child, f"{path}.{key}")
    elif isinstance(value, list):
        rows = [v for v in value if isinstance(v, dict)]
        if rows and len(rows) == len(value):
            first_keys = set(rows[0].keys())
            for i, row in enumerate(rows):
                if set(row.keys()) != first_keys:
                    yield (
                        f"{path}[{i}]",
                        f"row keys {sorted(row.keys())} differ from "
                        f"row 0 {sorted(first_keys)}",
                    )
        for i, child in enumerate(value):
            yield from leaf_errors(child, f"{path}[{i}]")
    elif isinstance(value, float):
        if math.isnan(value) or math.isinf(value):
            yield (path, "non-finite number")
    elif not isinstance(value, (int, str, bool)) and value is not None:
        yield (path, f"unexpected leaf type {type(value).__name__}")


# Column set of the E3e offload table in BENCH_serving.json: the in-loop
# vs offloaded comparison the serving dashboards diff across PRs.
SERVING_OFFLOAD_KEYS = {
    "mode",
    "request_workers",
    "connections",
    "queries",
    "queries_per_sec",
    "p50_us",
    "p99_us",
    "offloaded_misses",
}


def serving_offload_errors(doc, stem):
    """e3_serving-specific: the offload_scenarios table must exist, keep
    its column set, and carry both an in_loop and an offloaded row."""
    rows = doc.get("offload_scenarios")
    if not isinstance(rows, list) or not rows:
        yield (f"{stem}.offload_scenarios", "missing/empty array")
        return
    modes = set()
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            yield (f"{stem}.offload_scenarios[{i}]", "not an object")
            continue
        missing = SERVING_OFFLOAD_KEYS - set(row)
        if missing:
            yield (
                f"{stem}.offload_scenarios[{i}]",
                f"missing keys {sorted(missing)}",
            )
        modes.add(row.get("mode"))
    for mode in ("in_loop", "offloaded"):
        if mode not in modes:
            yield (f"{stem}.offload_scenarios", f"no {mode!r} row")


# Column set of the autotune scenario table in BENCH_autotune.json:
# one row per family x probe mode x algorithm, diffed across PRs for
# search quality (regret) and throughput (probes_per_sec).
AUTOTUNE_KEYS = {
    "family",
    "probe",
    "probe_mode",
    "algo",
    "beam",
    "space_size",
    "fusion_bits",
    "fusion_explored",
    "candidates",
    "probes",
    "delta_probes",
    "search_us",
    "probes_per_sec",
    "chosen",
    "oracle_best",
    "regret",
    "speedup",
    "speedup_per_sec",
}


def autotune_errors(doc, stem):
    """e10_autotune-specific: the scenarios table must exist, keep its
    column set (regret + probes_per_sec included), span >= 3 graph
    families, and carry rows for BOTH probe modes (cold and delta)."""
    rows = doc.get("scenarios")
    if not isinstance(rows, list) or not rows:
        yield (f"{stem}.scenarios", "missing/empty array")
        return
    modes, families = set(), set()
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            yield (f"{stem}.scenarios[{i}]", "not an object")
            continue
        missing = AUTOTUNE_KEYS - set(row)
        if missing:
            yield (f"{stem}.scenarios[{i}]", f"missing keys {sorted(missing)}")
        modes.add(row.get("probe_mode"))
        families.add(row.get("family"))
    for mode in ("cold", "delta"):
        if mode not in modes:
            yield (f"{stem}.scenarios", f"no probe_mode {mode!r} row")
    if len(families) < 3:
        yield (
            f"{stem}.scenarios",
            f"only {len(families)} graph families (need >= 3)",
        )


# Column set of the flood-vs-interactive sweep in BENCH_admission.json:
# one row per pool mode (per-connection FIFO-equivalent vs tenant-tagged
# fair queueing), diffed across PRs for interactive tail latency.
ADMISSION_KEYS = {
    "mode",
    "flood_connections",
    "request_workers",
    "interactive_queries",
    "interactive_p50_us",
    "interactive_p95_us",
    "flood_queries",
    "flood_queries_per_sec",
}


def admission_errors(doc, stem):
    """e11_admission-specific: the scenarios table must exist, keep its
    column set, and carry both the untenanted and tenant-tagged rows."""
    rows = doc.get("scenarios")
    if not isinstance(rows, list) or not rows:
        yield (f"{stem}.scenarios", "missing/empty array")
        return
    modes = set()
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            yield (f"{stem}.scenarios[{i}]", "not an object")
            continue
        missing = ADMISSION_KEYS - set(row)
        if missing:
            yield (f"{stem}.scenarios[{i}]", f"missing keys {sorted(missing)}")
        modes.add(row.get("mode"))
    for mode in ("fifo_untenanted", "fair_tenant_tagged"):
        if mode not in modes:
            yield (f"{stem}.scenarios", f"no {mode!r} row")


def check_file(root: Path, path: Path) -> int:
    rel = path.relative_to(root)
    try:
        doc = json.loads(path.read_text(encoding="utf-8"))
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        print(f"{rel}: invalid JSON: {e}", file=sys.stderr)
        return 1
    errors = 0
    if not isinstance(doc, dict):
        print(f"{rel}: top level must be an object", file=sys.stderr)
        return 1
    for field in ("bench", "note"):
        if not isinstance(doc.get(field), str) or not doc.get(field):
            print(f"{rel}: missing/empty '{field}' string", file=sys.stderr)
            errors += 1
    bench = doc.get("bench")
    if isinstance(bench, str):
        bench_src = root / "rust" / "benches" / f"{bench}.rs"
        if not bench_src.exists():
            print(
                f"{rel}: 'bench' names {bench!r} but "
                f"rust/benches/{bench}.rs does not exist",
                file=sys.stderr,
            )
            errors += 1
    for leaf_path, msg in leaf_errors(doc, path.stem):
        print(f"{rel}: {leaf_path}: {msg}", file=sys.stderr)
        errors += 1
    if bench == "e3_serving":
        for leaf_path, msg in serving_offload_errors(doc, path.stem):
            print(f"{rel}: {leaf_path}: {msg}", file=sys.stderr)
            errors += 1
    if bench == "e10_autotune":
        for leaf_path, msg in autotune_errors(doc, path.stem):
            print(f"{rel}: {leaf_path}: {msg}", file=sys.stderr)
            errors += 1
    if bench == "e11_admission":
        for leaf_path, msg in admission_errors(doc, path.stem):
            print(f"{rel}: {leaf_path}: {msg}", file=sys.stderr)
            errors += 1
    return errors


def check(root: Path) -> int:
    files = sorted(root.glob("BENCH_*.json"))
    if not files:
        print("error: no BENCH_*.json found — wrong cwd?", file=sys.stderr)
        return 1
    errors = sum(check_file(root, f) for f in files)
    checked = ", ".join(str(f.relative_to(root)) for f in files)
    if errors:
        print(f"{errors} schema error(s) across: {checked}", file=sys.stderr)
    else:
        print(f"bench schemas OK: {checked}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(check(Path.cwd()))

#!/usr/bin/env bash
# Full bench run: build and execute every e1-e10 bench target at real
# iteration counts, letting each one OVERWRITE its committed
# BENCH_*.json at the repo root with measured numbers — then self-check
# the fresh results against the pre-run baselines with bench_compare.py
# (--require-both: a bench that stops producing its file is an error).
#
# This is the `make bench` target. The smoke-mode twin that CI runs is
# scripts/bench_smoke.sh (tiny iteration counts, results restored).
#
# Model-gated benches (e1-e5, e7-e8, and the served scenarios of e10)
# need artifacts/; without them this script still runs the front-end
# benches but warns that the rest were skipped.
#
# Usage: bash scripts/run_benches.sh [--threshold 0.25]
#        (from anywhere; cds to repo root; extra args pass through to
#        bench_compare.py)
set -euo pipefail

cd "$(dirname "$0")/.."

benches=(
  e1_rmse_table
  e2_fig6
  e3_serving
  e4_model_latency
  e5_ablation
  e6_frontend
  e7_cluster
  e8_router
  e9_incremental
  e10_autotune
  e11_admission
)

# Benches that refuse to run without model artifacts. The rest measure
# the front end / sim only (e10 falls back to its sim probe backend).
model_gated=(e1_rmse_table e2_fig6 e3_serving e4_model_latency e5_ablation e7_cluster e8_router)

have_artifacts=1
if [[ ! -f artifacts/manifest.json ]]; then
  have_artifacts=0
  echo "== artifacts/ absent: model-gated benches will be skipped =="
fi

echo "== building all bench targets =="
(cd rust && cargo build --release --benches)

# Snapshot the committed baselines so the fresh run can be diffed
# against them after the benches overwrite the real files.
baseline="$(mktemp -d)"
cp BENCH_*.json "$baseline"/ 2>/dev/null || true
cleanup() { rm -rf "$baseline"; }
trap cleanup EXIT

skipped=()
for b in "${benches[@]}"; do
  if [[ $have_artifacts -eq 0 ]] && printf '%s\n' "${model_gated[@]}" | grep -qx "$b"; then
    skipped+=("$b")
    continue
  fi
  echo "== bench: $b =="
  (cd rust && cargo bench --bench "$b")
done

if ((${#skipped[@]})); then
  echo "== skipped (artifacts absent): ${skipped[*]} =="
fi

echo "== schema check on the fresh results =="
python3 scripts/check_bench_schema.py

echo "== fresh run vs pre-run baselines =="
# One-sided files fail only when everything ran; on a partial (artifact-
# less) run the unrefreshed baselines still compare clean against
# themselves because the benches overwrite in place.
python3 scripts/bench_compare.py "$baseline" . --require-both "$@"

echo "== bench run OK (${#benches[@]} targets, ${#skipped[@]} skipped) =="

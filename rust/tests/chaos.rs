//! Chaos/property suite for the epoll front end: seeded random
//! interleavings of connects, pipelined floods, mid-line disconnects,
//! send-and-quit hangups, and slow readers — against a 1- and
//! 2-thread event loop with an offload worker, all artifact-free
//! through the [`LineService`] seam.
//!
//! Three properties must survive every interleaving:
//! - **no stalled connection**: every well-behaved client gets every
//!   response it is owed within a generous timeout;
//! - **no response desync**: responses arrive in request order per
//!   connection, ids matching what was sent;
//! - **conservation**: at quiescence the admission ledger balances —
//!   `admitted == answered + over_quota + shed_deadline + overloaded
//!   + dropped` — no line is lost or double-counted, even for lines
//!   whose connection died before the answer could be written.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use mlir_cost::coordinator::offload::LineService;
use mlir_cost::coordinator::server::{serve_loops, ServerConfig, Stop};
use mlir_cost::coordinator::stats::ServiceStats;
use mlir_cost::json::{parse, Json};
use mlir_cost::rng::Rng;

/// Echo head: lines containing `"slow"` are would-block and sleep 2 ms
/// on the offload pool; everything else answers inline.
struct Echo {
    stats: ServiceStats,
}

impl LineService for Echo {
    fn stats(&self) -> &ServiceStats {
        &self.stats
    }

    fn would_block(&self, line: &str) -> bool {
        line.contains("slow")
    }

    fn handle(&self, line: &str) -> Json {
        if line.contains("slow") {
            std::thread::sleep(Duration::from_millis(2));
        }
        let id = parse(line).ok().and_then(|r| r.get("id").cloned()).unwrap_or(Json::Null);
        Json::obj().with("id", id).with("ok", Json::Bool(true))
    }
}

/// Build request line `id` for one session; `slow` routes it through
/// the offload pool, `fat` pads it to stress read/write buffering.
fn req_line(id: usize, slow: bool, fat: bool) -> String {
    let mut req = Json::obj().with("id", Json::num(id as f64));
    if slow {
        req = req.with("kind", Json::str("slow"));
    }
    if fat {
        req = req.with("pad", Json::str("x".repeat(64 * 1024)));
    }
    format!("{req}\n")
}

/// Read `n` responses and assert they answer requests 0..n in order.
/// The 5-second read timeout set by the caller is the stall detector:
/// a starved connection fails here instead of hanging the suite.
fn read_in_order(reader: &mut impl BufRead, n: usize) {
    for want in 0..n {
        let mut line = String::new();
        let got = reader.read_line(&mut line).expect("read stalled or failed");
        assert!(got > 0, "connection closed {want}/{n} responses in");
        let resp = parse(&line).unwrap();
        assert_eq!(
            resp.get("id").and_then(Json::as_f64),
            Some(want as f64),
            "response desync: expected id {want}, got {line:?}"
        );
    }
}

/// One client session against `addr`, shape picked by the rng. Returns
/// after its connection is finished with (cleanly or abusively).
fn run_session(addr: &str, rng: &mut Rng) {
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    match rng.below(4) {
        // Pipelined burst: up to 24 lines in one write, read all back.
        0 => {
            let n = 1 + rng.below(24) as usize;
            let mut buf = String::new();
            for i in 0..n {
                buf.push_str(&req_line(i, rng.chance(0.2), false));
                if rng.chance(0.1) {
                    buf.push('\n'); // empty line: skipped, no response
                }
            }
            conn.write_all(buf.as_bytes()).unwrap();
            let mut reader = BufReader::new(&conn);
            read_in_order(&mut reader, n);
        }
        // Slow reader: pipeline fat request lines (stressing partial-
        // line reassembly) and let the answers queue in the server's
        // write buffer before draining any of them.
        1 => {
            let n = 2 + rng.below(8) as usize;
            for i in 0..n {
                conn.write_all(req_line(i, false, true).as_bytes()).unwrap();
            }
            std::thread::sleep(Duration::from_millis(10 + rng.below(40)));
            let mut reader = BufReader::new(&conn);
            read_in_order(&mut reader, n);
        }
        // Mid-line disconnect: a complete line (so something is in
        // flight), then a partial line, then hang up.
        2 => {
            let line = req_line(0, rng.chance(0.5), false);
            conn.write_all(line.as_bytes()).unwrap();
            conn.write_all(b"{\"id\": 1, \"trunc").unwrap();
            drop(conn);
        }
        // Send-and-quit: complete lines, never read the answers.
        _ => {
            let n = 1 + rng.below(8) as usize;
            let mut buf = String::new();
            for i in 0..n {
                buf.push_str(&req_line(i, rng.chance(0.3), false));
            }
            conn.write_all(buf.as_bytes()).unwrap();
            drop(conn);
        }
    }
}

/// Run one seeded scenario: an event-loop server (thread count from
/// the seed) flooded by 4 concurrent client threads x 6 sessions of
/// random shape, then checked for ledger conservation at quiescence.
fn run_scenario(seed: u64) {
    let svc = Arc::new(Echo { stats: ServiceStats::default() });
    let config = ServerConfig {
        io_threads: 1 + (seed % 2) as usize,
        request_workers: 1,
        ..Default::default()
    };
    let stop = Stop::new();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = {
        let (svc, stop) = (svc.clone(), stop.clone());
        std::thread::spawn(move || serve_loops(svc, vec![listener], stop, config))
    };

    let clients: Vec<_> = (0..4)
        .map(|c| {
            let addr = addr.clone();
            let mut rng = Rng::new(seed ^ (0x9e37_79b9 + c));
            std::thread::spawn(move || {
                for _ in 0..6 {
                    run_session(&addr, &mut rng);
                }
            })
        })
        .collect();
    for c in clients {
        c.join().unwrap();
    }

    // Give in-flight teardowns (abandoned connections, parked slow
    // jobs whose owner hung up) a beat to settle before the ledger
    // check; shutdown then drains whatever is still parked.
    std::thread::sleep(Duration::from_millis(100));
    stop.trigger();
    server.join().unwrap().unwrap();

    let s = &svc.stats;
    use std::sync::atomic::Ordering::Relaxed;
    assert!(s.lines_admitted.load(Relaxed) > 0, "seed {seed}: scenario admitted nothing");
    assert_eq!(
        s.conservation_debt(),
        0,
        "seed {seed}: ledger out of balance (admitted {}, answered {}, dropped {})",
        s.lines_admitted.load(Relaxed),
        s.lines_answered.load(Relaxed),
        s.lines_dropped.load(Relaxed),
    );
}

#[test]
fn chaos_interleavings_preserve_order_liveness_and_conservation() {
    for seed in 0..6 {
        run_scenario(seed);
    }
}

//! The doc-example test pinning `docs/protocol.md`: every request
//! marked `<!-- verify: ... -->` in the protocol reference is fed
//! VERBATIM through `server::handle_line` against a live 3-variant
//! service, and the documented response shape is asserted.
//!
//! Marker grammar (an HTML comment on the line before a ```json fence):
//!
//!   <!-- verify: ok keys=prediction,variant,us -->   response must be
//!       ok:true and carry every listed key
//!   <!-- verify: error contains=bad json -->         response must be
//!       ok:false with an "error" containing the substring
//!
//! If the doc drifts from the server (a renamed field, a removed
//! command, an example that no longer parses), this test fails — the
//! CI `docs-check` step runs it explicitly.
//!
//! Artifact-gated like every Service test: without `artifacts/` it is
//! skipped.

use mlir_cost::bundle::Bundle;
use mlir_cost::coordinator::batcher::BatchPolicy;
use mlir_cost::coordinator::router::VariantSpec;
use mlir_cost::coordinator::{server, ServeOptions, Service};
use mlir_cost::dataset::TargetStats;
use mlir_cost::json::Json;
use mlir_cost::runtime::Manifest;
use mlir_cost::sim::Target;
use mlir_cost::tokenizer::{Scheme, Vocab};
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().to_path_buf()
}

fn bundle(manifest: &Manifest, model: &str) -> Bundle {
    let vocab = Vocab::build(vec![vec!["xpu.matmul".to_string()]].iter(), 1);
    let stats = TargetStats { mean: 20.0, std: 5.0, min: 4.0, max: 60.0 };
    Bundle::untrained(manifest, model, Target::RegPressure, Scheme::OpsOnly, vocab, stats)
        .unwrap()
}

/// The documented deployment shape: one target behind a 3-variant
/// family, so `variant`-bearing examples exercise real routing.
fn service() -> Option<Service> {
    let adir = repo_root().join("artifacts");
    if !adir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let manifest = Arc::new(Manifest::load(&adir).unwrap());
    let specs = vec![
        VariantSpec { name: "fc_ops".into(), bundle: bundle(&manifest, "fc_ops") },
        VariantSpec { name: "lstm_ops".into(), bundle: bundle(&manifest, "lstm_ops") },
        VariantSpec { name: "conv_full".into(), bundle: bundle(&manifest, "conv_full") },
    ];
    Some(
        Service::start_variants(manifest, specs, BatchPolicy::default(), ServeOptions::default())
            .unwrap(),
    )
}

struct Example {
    line_no: usize,
    mode: Mode,
    request: String,
}

enum Mode {
    Ok { keys: Vec<String> },
    Error { contains: Option<String> },
}

/// Pull every `<!-- verify: ... -->` + following ```json fence out of
/// the doc. Panics on malformed markers — a broken marker must fail
/// loudly, not silently verify nothing.
fn extract(doc: &str) -> Vec<Example> {
    let lines: Vec<&str> = doc.lines().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < lines.len() {
        let line = lines[i].trim();
        if let Some(body) = line.strip_prefix("<!-- verify:") {
            let body = body
                .strip_suffix("-->")
                .unwrap_or_else(|| panic!("line {}: unterminated verify marker", i + 1))
                .trim();
            let (mode_word, rest) = body.split_once(char::is_whitespace).unwrap_or((body, ""));
            let rest = rest.trim();
            let mode = match mode_word {
                "ok" => {
                    let keys = rest
                        .strip_prefix("keys=")
                        .unwrap_or_else(|| panic!("line {}: ok marker needs keys=", i + 1))
                        .split(',')
                        .map(|k| k.trim().to_string())
                        .collect();
                    Mode::Ok { keys }
                }
                "error" => Mode::Error {
                    contains: rest.strip_prefix("contains=").map(|s| s.trim().to_string()),
                },
                other => panic!("line {}: unknown verify mode '{other}'", i + 1),
            };
            // The next non-blank line must open a ```json fence.
            let mut j = i + 1;
            while j < lines.len() && lines[j].trim().is_empty() {
                j += 1;
            }
            assert_eq!(
                lines.get(j).map(|l| l.trim()),
                Some("```json"),
                "line {}: verify marker not followed by a ```json fence",
                i + 1
            );
            let mut body_lines = Vec::new();
            j += 1;
            while j < lines.len() && lines[j].trim() != "```" {
                body_lines.push(lines[j]);
                j += 1;
            }
            assert!(j < lines.len(), "line {}: unterminated fence", i + 1);
            let non_empty: Vec<&str> =
                body_lines.iter().copied().filter(|l| !l.trim().is_empty()).collect();
            assert_eq!(
                non_empty.len(),
                1,
                "line {}: a verified request must be ONE line (the wire protocol \
                 is line-delimited)",
                i + 1
            );
            out.push(Example { line_no: i + 1, mode, request: non_empty[0].to_string() });
            i = j + 1;
        } else {
            i += 1;
        }
    }
    out
}

#[test]
fn every_documented_request_round_trips() {
    let doc_path = repo_root().join("docs/protocol.md");
    let doc = std::fs::read_to_string(&doc_path)
        .unwrap_or_else(|e| panic!("reading {doc_path:?}: {e}"));
    let examples = extract(&doc);
    assert!(
        examples.len() >= 12,
        "only {} verified examples found — did the marker format drift?",
        examples.len()
    );
    let Some(svc) = service() else { return };
    for ex in examples {
        let resp = server::handle_line(&svc, &ex.request);
        let ok = resp.get("ok").and_then(Json::as_bool);
        match &ex.mode {
            Mode::Ok { keys } => {
                assert_eq!(
                    ok,
                    Some(true),
                    "protocol.md:{}: documented request failed: {} -> {}",
                    ex.line_no,
                    ex.request,
                    resp.to_string(),
                );
                for key in keys {
                    assert!(
                        resp.get(key).is_some(),
                        "protocol.md:{}: response missing documented key '{key}': {}",
                        ex.line_no,
                        resp.to_string(),
                    );
                }
            }
            Mode::Error { contains } => {
                assert_eq!(
                    ok,
                    Some(false),
                    "protocol.md:{}: documented error example succeeded: {}",
                    ex.line_no,
                    ex.request,
                );
                let msg = resp
                    .get("error")
                    .and_then(Json::as_str)
                    .unwrap_or_else(|| panic!("protocol.md:{}: no error string", ex.line_no));
                if let Some(needle) = contains {
                    assert!(
                        msg.contains(needle.as_str()),
                        "protocol.md:{}: error '{msg}' does not mention '{needle}'",
                        ex.line_no,
                    );
                }
            }
        }
    }
}

/// The extractor itself is artifact-free: the doc must always parse
/// and contain the expected example count, even where the service
/// cannot start.
#[test]
fn protocol_doc_markers_parse() {
    let doc_path = repo_root().join("docs/protocol.md");
    let doc = std::fs::read_to_string(&doc_path)
        .unwrap_or_else(|e| panic!("reading {doc_path:?}: {e}"));
    let examples = extract(&doc);
    assert!(examples.len() >= 12, "found {}", examples.len());
    // Every documented request that the doc claims is valid JSON-per-
    // line is parseable — except the deliberate bad-json example.
    for ex in &examples {
        if let Mode::Error { contains: Some(c) } = &ex.mode {
            if c == "bad json" {
                continue;
            }
        }
        mlir_cost::json::parse(&ex.request).unwrap_or_else(|e| {
            panic!("protocol.md:{}: example does not parse: {e:#}", ex.line_no)
        });
    }
}

//! The doc-example test pinning `docs/protocol.md`: every request
//! marked `<!-- verify: ... -->` in the protocol reference is fed
//! VERBATIM through `server::handle_line` against a live 3-variant
//! service, and the documented response shape is asserted.
//!
//! Marker grammar (an HTML comment on the line before a ```json fence):
//!
//!   <!-- verify: ok keys=prediction,variant,us -->   response must be
//!       ok:true and carry every listed key
//!   <!-- verify: error contains=bad json -->         response must be
//!       ok:false with an "error" containing the substring
//!   <!-- verify: admission contains=over_quota -->   the request is
//!       replayed against a live admission-enabled mini-server (NOT
//!       `handle_line` — these rejections fire at line admission) and
//!       must be rejected with an error containing the substring
//!
//! If the doc drifts from the server (a renamed field, a removed
//! command, an example that no longer parses), this test fails — the
//! CI `docs-check` step runs it explicitly.
//!
//! Artifact-gated like every Service test — except the admission
//! examples, which run against a fake [`LineService`] and need no
//! artifacts.

use mlir_cost::bundle::Bundle;
use mlir_cost::coordinator::batcher::BatchPolicy;
use mlir_cost::coordinator::offload::LineService;
use mlir_cost::coordinator::router::VariantSpec;
use mlir_cost::coordinator::server::{serve_loops, ServerConfig, Stop};
use mlir_cost::coordinator::stats::ServiceStats;
use mlir_cost::coordinator::{server, ServeOptions, Service};
use mlir_cost::dataset::TargetStats;
use mlir_cost::json::Json;
use mlir_cost::runtime::Manifest;
use mlir_cost::sim::Target;
use mlir_cost::tokenizer::{Scheme, Vocab};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().to_path_buf()
}

fn bundle(manifest: &Manifest, model: &str) -> Bundle {
    let vocab = Vocab::build(vec![vec!["xpu.matmul".to_string()]].iter(), 1);
    let stats = TargetStats { mean: 20.0, std: 5.0, min: 4.0, max: 60.0 };
    Bundle::untrained(manifest, model, Target::RegPressure, Scheme::OpsOnly, vocab, stats)
        .unwrap()
}

/// The documented deployment shape: one target behind a 3-variant
/// family, so `variant`-bearing examples exercise real routing.
fn service() -> Option<Service> {
    let adir = repo_root().join("artifacts");
    if !adir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let manifest = Arc::new(Manifest::load(&adir).unwrap());
    let specs = vec![
        VariantSpec { name: "fc_ops".into(), bundle: bundle(&manifest, "fc_ops") },
        VariantSpec { name: "lstm_ops".into(), bundle: bundle(&manifest, "lstm_ops") },
        VariantSpec { name: "conv_full".into(), bundle: bundle(&manifest, "conv_full") },
    ];
    Some(
        Service::start_variants(manifest, specs, BatchPolicy::default(), ServeOptions::default())
            .unwrap(),
    )
}

struct Example {
    line_no: usize,
    mode: Mode,
    request: String,
}

enum Mode {
    Ok { keys: Vec<String> },
    Error { contains: Option<String> },
    /// Rejection produced at line admission (quota / shedding / tenant
    /// cap) — exercised over a real socket by its own test below.
    Admission { contains: String },
}

/// Pull every `<!-- verify: ... -->` + following ```json fence out of
/// the doc. Panics on malformed markers — a broken marker must fail
/// loudly, not silently verify nothing.
fn extract(doc: &str) -> Vec<Example> {
    let lines: Vec<&str> = doc.lines().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < lines.len() {
        let line = lines[i].trim();
        if let Some(body) = line.strip_prefix("<!-- verify:") {
            let body = body
                .strip_suffix("-->")
                .unwrap_or_else(|| panic!("line {}: unterminated verify marker", i + 1))
                .trim();
            let (mode_word, rest) = body.split_once(char::is_whitespace).unwrap_or((body, ""));
            let rest = rest.trim();
            let mode = match mode_word {
                "ok" => {
                    let keys = rest
                        .strip_prefix("keys=")
                        .unwrap_or_else(|| panic!("line {}: ok marker needs keys=", i + 1))
                        .split(',')
                        .map(|k| k.trim().to_string())
                        .collect();
                    Mode::Ok { keys }
                }
                "error" => Mode::Error {
                    contains: rest.strip_prefix("contains=").map(|s| s.trim().to_string()),
                },
                "admission" => Mode::Admission {
                    contains: rest
                        .strip_prefix("contains=")
                        .unwrap_or_else(|| {
                            panic!("line {}: admission marker needs contains=", i + 1)
                        })
                        .trim()
                        .to_string(),
                },
                other => panic!("line {}: unknown verify mode '{other}'", i + 1),
            };
            // The next non-blank line must open a ```json fence.
            let mut j = i + 1;
            while j < lines.len() && lines[j].trim().is_empty() {
                j += 1;
            }
            assert_eq!(
                lines.get(j).map(|l| l.trim()),
                Some("```json"),
                "line {}: verify marker not followed by a ```json fence",
                i + 1
            );
            let mut body_lines = Vec::new();
            j += 1;
            while j < lines.len() && lines[j].trim() != "```" {
                body_lines.push(lines[j]);
                j += 1;
            }
            assert!(j < lines.len(), "line {}: unterminated fence", i + 1);
            let non_empty: Vec<&str> =
                body_lines.iter().copied().filter(|l| !l.trim().is_empty()).collect();
            assert_eq!(
                non_empty.len(),
                1,
                "line {}: a verified request must be ONE line (the wire protocol \
                 is line-delimited)",
                i + 1
            );
            out.push(Example { line_no: i + 1, mode, request: non_empty[0].to_string() });
            i = j + 1;
        } else {
            i += 1;
        }
    }
    out
}

#[test]
fn every_documented_request_round_trips() {
    let doc_path = repo_root().join("docs/protocol.md");
    let doc = std::fs::read_to_string(&doc_path)
        .unwrap_or_else(|e| panic!("reading {doc_path:?}: {e}"));
    let examples = extract(&doc);
    assert!(
        examples.len() >= 12,
        "only {} verified examples found — did the marker format drift?",
        examples.len()
    );
    let Some(svc) = service() else { return };
    for ex in examples {
        // Admission rejections never reach handle_line; the
        // documented_admission_errors_fire_on_the_wire test below
        // replays those against a live admission-enabled server.
        if matches!(ex.mode, Mode::Admission { .. }) {
            continue;
        }
        let resp = server::handle_line(&svc, &ex.request);
        let ok = resp.get("ok").and_then(Json::as_bool);
        match &ex.mode {
            Mode::Ok { keys } => {
                assert_eq!(
                    ok,
                    Some(true),
                    "protocol.md:{}: documented request failed: {} -> {}",
                    ex.line_no,
                    ex.request,
                    resp.to_string(),
                );
                for key in keys {
                    assert!(
                        resp.get(key).is_some(),
                        "protocol.md:{}: response missing documented key '{key}': {}",
                        ex.line_no,
                        resp.to_string(),
                    );
                }
            }
            Mode::Admission { .. } => unreachable!("skipped above"),
            Mode::Error { contains } => {
                assert_eq!(
                    ok,
                    Some(false),
                    "protocol.md:{}: documented error example succeeded: {}",
                    ex.line_no,
                    ex.request,
                );
                let msg = resp
                    .get("error")
                    .and_then(Json::as_str)
                    .unwrap_or_else(|| panic!("protocol.md:{}: no error string", ex.line_no));
                if let Some(needle) = contains {
                    assert!(
                        msg.contains(needle.as_str()),
                        "protocol.md:{}: error '{msg}' does not mention '{needle}'",
                        ex.line_no,
                    );
                }
            }
        }
    }
}

/// The extractor itself is artifact-free: the doc must always parse
/// and contain the expected example count, even where the service
/// cannot start.
#[test]
fn protocol_doc_markers_parse() {
    let doc_path = repo_root().join("docs/protocol.md");
    let doc = std::fs::read_to_string(&doc_path)
        .unwrap_or_else(|e| panic!("reading {doc_path:?}: {e}"));
    let examples = extract(&doc);
    assert!(examples.len() >= 12, "found {}", examples.len());
    // Every documented request that the doc claims is valid JSON-per-
    // line is parseable — except the deliberate bad-json example.
    for ex in &examples {
        if let Mode::Error { contains: Some(c) } = &ex.mode {
            if c == "bad json" {
                continue;
            }
        }
        mlir_cost::json::parse(&ex.request).unwrap_or_else(|e| {
            panic!("protocol.md:{}: example does not parse: {e:#}", ex.line_no)
        });
    }
}

/// Artifact-free stand-in behind the [`LineService`] seam for the
/// admission examples: every line is would-block (so the tenant
/// in-flight cap is exercisable), `handle` sleeps `delay` then answers
/// ok, and `shed` rejects any `budget_us` below a fixed 1000 us
/// fastest-variant estimate — mirroring the real service's contract.
struct AdmissionFake {
    stats: ServiceStats,
    delay: Duration,
}

impl LineService for AdmissionFake {
    fn stats(&self) -> &ServiceStats {
        &self.stats
    }

    fn would_block(&self, _line: &str) -> bool {
        true
    }

    fn handle(&self, line: &str) -> Json {
        std::thread::sleep(self.delay);
        let id = mlir_cost::json::parse(line)
            .ok()
            .and_then(|r| r.get("id").cloned())
            .unwrap_or(Json::Null);
        Json::obj().with("id", id).with("ok", Json::Bool(true))
    }

    fn shed(&self, line: &str) -> Option<Json> {
        let req = mlir_cost::json::parse(line).ok()?;
        let budget = req
            .get("budget_us")
            .and_then(Json::as_f64)
            .filter(|b| b.is_finite() && *b >= 0.0)?;
        if !mlir_cost::coordinator::deadline_unmeetable(1_000.0, 0, budget) {
            return None;
        }
        Some(
            Json::obj()
                .with("id", req.get("id").cloned().unwrap_or(Json::Null))
                .with("ok", Json::Bool(false))
                .with(
                    "error",
                    Json::str(format!(
                        "shed_deadline: budget_us {budget} unmeetable \
                         (fastest variant ~1000 us, 0 queued)"
                    )),
                ),
        )
    }
}

fn spawn_admission(
    delay: Duration,
    config: ServerConfig,
) -> (String, Arc<Stop>, std::thread::JoinHandle<anyhow::Result<()>>) {
    let svc = Arc::new(AdmissionFake { stats: ServiceStats::default(), delay });
    let stop = Stop::new();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = {
        let stop = stop.clone();
        std::thread::spawn(move || serve_loops(svc, vec![listener], stop, config))
    };
    (addr, stop, server)
}

fn roundtrip(conn: &mut TcpStream, line: &str) -> Json {
    conn.write_all(format!("{line}\n").as_bytes()).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    mlir_cost::json::parse(&resp).unwrap()
}

/// Every `admission` example in protocol.md really is rejected, over
/// a real socket, with the documented error class — against the
/// matching admission configuration. Artifact-free.
#[test]
fn documented_admission_errors_fire_on_the_wire() {
    let doc_path = repo_root().join("docs/protocol.md");
    let doc = std::fs::read_to_string(&doc_path).unwrap();
    let admission: Vec<Example> = extract(&doc)
        .into_iter()
        .filter(|ex| matches!(ex.mode, Mode::Admission { .. }))
        .collect();
    assert_eq!(admission.len(), 3, "expected over_quota/shed_deadline/overloaded examples");
    for ex in admission {
        let Mode::Admission { contains } = &ex.mode else { unreachable!() };
        let rejected = match contains.as_str() {
            "over_quota" => {
                // Burst of 1: the first send passes, the replayed one
                // is over quota.
                let config =
                    ServerConfig { quota: 1.0, quota_burst: 1.0, ..Default::default() };
                let (addr, stop, server) = spawn_admission(Duration::ZERO, config);
                let mut conn = TcpStream::connect(&addr).unwrap();
                let first = roundtrip(&mut conn, &ex.request);
                assert_eq!(first.get("ok").and_then(Json::as_bool), Some(true));
                let second = roundtrip(&mut conn, &ex.request);
                stop.trigger();
                let _ = server.join();
                second
            }
            "shed_deadline" => {
                let config = ServerConfig { shed_deadlines: true, ..Default::default() };
                let (addr, stop, server) = spawn_admission(Duration::ZERO, config);
                let mut conn = TcpStream::connect(&addr).unwrap();
                let resp = roundtrip(&mut conn, &ex.request);
                stop.trigger();
                let _ = server.join();
                resp
            }
            "overloaded" => {
                // One slow job parks the tenant at its in-flight cap
                // of 1; the same tenant's next line (another
                // connection, same `tenant` field) is rejected.
                let config = ServerConfig {
                    request_workers: 1,
                    tenant_inflight: 1,
                    ..Default::default()
                };
                let (addr, stop, server) =
                    spawn_admission(Duration::from_millis(400), config);
                let mut parked = TcpStream::connect(&addr).unwrap();
                parked.write_all(format!("{}\n", ex.request).as_bytes()).unwrap();
                // Let the first line reach the worker before replaying.
                std::thread::sleep(Duration::from_millis(100));
                let mut conn = TcpStream::connect(&addr).unwrap();
                let resp = roundtrip(&mut conn, &ex.request);
                // The parked line still answers ok once its job runs.
                let mut reader = BufReader::new(&parked);
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                assert_eq!(
                    mlir_cost::json::parse(&line).unwrap().get("ok").and_then(Json::as_bool),
                    Some(true)
                );
                stop.trigger();
                let _ = server.join();
                resp
            }
            other => panic!(
                "protocol.md:{}: no admission scenario for '{other}'",
                ex.line_no
            ),
        };
        assert_eq!(
            rejected.get("ok").and_then(Json::as_bool),
            Some(false),
            "protocol.md:{}: admission example was not rejected: {rejected}",
            ex.line_no
        );
        let msg = rejected.get("error").and_then(Json::as_str).unwrap_or("");
        assert!(
            msg.contains(contains.as_str()),
            "protocol.md:{}: error '{msg}' does not mention '{contains}'",
            ex.line_no
        );
        // The rejection echoes the request's id — pipelined clients
        // stay in sync across rejections.
        let want_id = mlir_cost::json::parse(&ex.request).unwrap().get("id").cloned();
        assert_eq!(rejected.get("id").cloned(), want_id);
    }
}

/// The `metrics` export really carries every counter operations.md
/// documents: every backticked name in the first column of the
/// runbook's counter tables appears in the flat text, either as a
/// plain `name value` line or as a dotted `name.…` prefix (objects).
/// Per-variant and `cluster.`-scoped rows are skipped — they live
/// under computed prefixes the doc spells out in prose.
#[test]
fn metrics_exports_every_documented_counter() {
    let ops_path = repo_root().join("docs/operations.md");
    let doc = std::fs::read_to_string(&ops_path)
        .unwrap_or_else(|e| panic!("reading {ops_path:?}: {e}"));
    let mut names: Vec<String> = Vec::new();
    for line in doc.lines() {
        if !line.starts_with("| `") {
            continue;
        }
        let first_cell = line.split('|').nth(1).unwrap_or("");
        if first_cell.contains("(per variant)") {
            continue;
        }
        let mut rest = first_cell;
        while let Some(start) = rest.find('`') {
            let after = &rest[start + 1..];
            let Some(end) = after.find('`') else { break };
            let name = &after[..end];
            rest = &after[end + 1..];
            // Flags (`--quota`), nested cluster keys (`cluster.nodes`),
            // and array-valued rows (`cluster.peers[]`) are not flat
            // counters.
            if name.starts_with('-') || name.contains('.') || name.contains('[') {
                continue;
            }
            names.push(name.to_string());
        }
    }
    assert!(names.len() >= 40, "only {} documented counters found — parser drift?", names.len());
    let Some(svc) = service() else { return };
    let text = svc.metrics_text();
    for name in &names {
        let flat = format!("{name} ");
        let nested = format!("{name}.");
        assert!(
            text.lines().any(|l| l.starts_with(&flat) || l.starts_with(&nested)),
            "operations.md documents counter '{name}' but the metrics export lacks it"
        );
    }
}

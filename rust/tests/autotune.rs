//! Autotune integration tests — the acceptance bar for the search loop:
//!
//! - **Determinism**: a fixed seed + space chooses a byte-identical
//!   schedule on every run, across graph families (artifact-free, sim
//!   probe).
//! - **Bounded regret**: on exhaustively-enumerable spaces, beam >= 2
//!   search lands within 10% of the exhaustive sim oracle (regret
//!   <= 1.10) — artifact-free, and exact (1.0) where the staged beam
//!   provably visits every configuration.
//! - **Delta == cold**: against a running `Service`, the session/delta
//!   probe path returns predictions identical to batched cold probes
//!   for the same candidates, across families — and the `search_*`
//!   stats counters move. Artifact-gated like every Service test.

use mlir_cost::autotune::{
    self as at, Objective, ProbeMode, SearchConfig, SearchSpace, ServiceProbe, SimProbe,
};
use mlir_cost::bundle::Bundle;
use mlir_cost::coordinator::batcher::BatchPolicy;
use mlir_cost::coordinator::Service;
use mlir_cost::dataset::TargetStats;
use mlir_cost::graphgen::{generate, Family, GraphSpec};
use mlir_cost::mlir::{Attrs, DType, FuncBuilder, Function, Type, XpuOp};
use mlir_cost::runtime::Manifest;
use mlir_cost::sim::{Target, XpuConfig};
use mlir_cost::tokenizer::{Scheme, Vocab};
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// The three-family corpus every test below walks (fixed seeds).
fn corpus() -> Vec<(Family, Function)> {
    [Family::Mlp, Family::Resnet, Family::Bert]
        .into_iter()
        .enumerate()
        .map(|(i, family)| {
            let spec = GraphSpec {
                family,
                structure_seed: 9100 + i as u64,
                shape_seed: 9200 + i as u64,
            };
            (family, generate(&spec).expect("graphgen"))
        })
        .collect()
}

/// matmul+relu: exactly one fusable group, so the full space is tiny
/// and the beam-2 staged search provably visits all of it.
fn matmul_relu() -> Function {
    let mut b = FuncBuilder::new("tune");
    let x = b.arg(Type::tensor(vec![64, 64], DType::F32));
    let w = b.arg(Type::tensor(vec![64, 64], DType::F32));
    let m = b.xpu(XpuOp::MatMul, &[x, w], Attrs::new()).unwrap();
    let r = b.xpu(XpuOp::Relu, &[m], Attrs::new()).unwrap();
    b.ret(&[r]).unwrap()
}

#[test]
fn search_is_deterministic_across_runs() {
    let sp = SearchSpace { unrolls: vec![1, 2, 4], tiles: vec![16, 32], fusion: true };
    let cfg = SearchConfig { beam: 2, objective: Objective::minimize(Target::Cycles) };
    for (family, base) in corpus() {
        let run = || at::search(&base, &sp, &cfg, &mut SimProbe::new()).expect("search");
        let (a, b) = (run(), run());
        assert_eq!(
            a.best.candidate.text,
            b.best.candidate.text,
            "{}: chosen schedule text must be byte-identical across runs",
            family.name()
        );
        assert_eq!(a.best.candidate.knobs, b.best.candidate.knobs, "{}", family.name());
        // The whole probe sequence replays identically, not just the
        // winner.
        let keys = |o: &at::SearchOutcome| {
            o.evaluated.iter().map(|s| s.candidate.knobs.key()).collect::<Vec<_>>()
        };
        assert_eq!(keys(&a), keys(&b), "{}: probe order drifted", family.name());
        assert_eq!(a.probes, a.candidates);
        assert_eq!(a.delta_probes, 0, "sim probe never rides the delta path");
    }
}

/// Acceptance bar: seeded small space, beam >= 2, regret <= 1.10 vs the
/// exhaustive sim oracle — artifact-free. The spaces are shaped so the
/// staged beam visits every configuration (single-point tile dimension,
/// or beam = |unrolls|), which with the perfect sim probe pins regret
/// at exactly 1.0; the 1.10 assertion is the bar the issue names.
#[test]
fn beam_search_regret_is_bounded_on_enumerable_spaces() {
    let xcfg = XpuConfig::default();
    let objective = Objective::minimize(Target::Cycles);

    // One fusable group, fusion explored: 3 unrolls x 1 tile x 2 masks.
    let base = matmul_relu();
    let sp = SearchSpace { unrolls: vec![1, 2, 4], tiles: vec![32], fusion: true };
    let cfg = SearchConfig { beam: 2, objective: objective.clone() };
    let outcome = at::search(&base, &sp, &cfg, &mut SimProbe::new()).unwrap();
    let report = at::regret(&base, &sp, &objective, &outcome, &xcfg).unwrap();
    assert_eq!(report.space_size, 6);
    assert_eq!(outcome.candidates as usize, report.space_size, "beam 2 must cover this space");
    assert!(report.regret <= 1.10, "regret {} > 1.10", report.regret);
    assert!((report.regret - 1.0).abs() < 1e-12, "full coverage => exact optimum");
    assert!(report.speedup_per_sec.is_finite());

    // Every family, fusion fixed: unroll stage scores the whole unroll
    // axis, beam = |unrolls| carries all of it into the tile stage, so
    // all |unrolls| x |tiles| configurations are probed.
    for (family, base) in corpus() {
        let sp = SearchSpace { unrolls: vec![1, 2, 4], tiles: vec![16, 32, 64], fusion: false };
        let cfg = SearchConfig { beam: 3, objective: objective.clone() };
        let outcome = at::search(&base, &sp, &cfg, &mut SimProbe::new()).unwrap();
        let report = at::regret(&base, &sp, &objective, &outcome, &xcfg).unwrap();
        assert_eq!(report.space_size, 9, "{}", family.name());
        assert_eq!(outcome.candidates, 9, "{}: beam 3 must cover the 3x3 grid", family.name());
        assert!(
            report.regret <= 1.10,
            "{}: regret {} > 1.10 (chosen {:?}, oracle {:?})",
            family.name(),
            report.regret,
            report.chosen_knobs,
            report.oracle_knobs
        );
        assert!(report.chosen_measured >= report.oracle_measured - 1e-9, "{}", family.name());
    }
}

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("artifacts")
}

/// One conv_full variant (max_len 512 — covers every family graph here)
/// serving Cycles, untrained weights: predictions are garbage but
/// deterministic, which is exactly what probe-path equality needs.
fn service() -> Option<Arc<Service>> {
    let adir = artifacts_dir();
    if !adir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let manifest = Arc::new(Manifest::load(&adir).unwrap());
    let vocab = Vocab::build(vec![vec!["xpu.relu".to_string()]].iter(), 1);
    let stats = TargetStats { mean: 20.0, std: 5.0, min: 4.0, max: 60.0 };
    let bundle =
        Bundle::untrained(&manifest, "conv_full", Target::Cycles, Scheme::OpsOnly, vocab, stats)
            .unwrap();
    Some(Arc::new(Service::start(manifest, vec![bundle], BatchPolicy::default(), true).unwrap()))
}

/// Delta probes must predict exactly what cold probes predict: the
/// spliced id rows are byte-identical to the full pipeline (pinned by
/// tests/incremental.rs), so the model sees the same input either way.
#[test]
fn delta_probes_match_cold_probes_across_families() {
    let Some(svc) = service() else { return };
    let sp = SearchSpace { unrolls: vec![1, 2, 4], tiles: vec![16, 32], fusion: true };
    let cfg = SearchConfig { beam: 2, objective: Objective::minimize(Target::Cycles) };
    for (family, base) in corpus() {
        let run = |mode: ProbeMode| {
            let mut probe = ServiceProbe::new(svc.clone(), mode);
            let outcome = at::search(&base, &sp, &cfg, &mut probe).expect("served search");
            probe.finish();
            outcome
        };
        let cold = run(ProbeMode::Cold);
        let delta = run(ProbeMode::Delta);

        assert_eq!(cold.delta_probes, 0, "{}", family.name());
        assert_eq!(
            delta.delta_probes,
            delta.probes - 1,
            "{}: every probe after session_open rides mlir_delta",
            family.name()
        );
        assert_eq!(cold.probes, delta.probes, "{}: same space, same probe count", family.name());

        // Identical predictions candidate-by-candidate, and therefore
        // an identical chosen schedule.
        assert_eq!(cold.evaluated.len(), delta.evaluated.len(), "{}", family.name());
        for (c, d) in cold.evaluated.iter().zip(&delta.evaluated) {
            assert_eq!(c.candidate.knobs, d.candidate.knobs, "{}", family.name());
            assert_eq!(
                c.values,
                d.values,
                "{} {}: delta prediction diverged from cold",
                family.name(),
                c.candidate.knobs.key()
            );
        }
        assert_eq!(
            cold.best.candidate.text,
            delta.best.candidate.text,
            "{}: probe mode changed the chosen schedule",
            family.name()
        );
    }

    // The search counters moved: every probe of every search above.
    assert!(svc.stats.search_candidates.load(Ordering::Relaxed) > 0);
    assert!(svc.stats.search_delta_probes.load(Ordering::Relaxed) > 0);
    assert!(
        svc.stats.search_probes.load(Ordering::Relaxed)
            >= svc.stats.search_delta_probes.load(Ordering::Relaxed)
    );
    assert!(svc.stats.search_ns.load(Ordering::Relaxed) > 0);
}

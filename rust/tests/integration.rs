//! Cross-module integration tests over the public API: the exact
//! pipelines a downstream user composes.

use mlir_cost::dataset::{Dataset, EncodedSet, TargetStats};
use mlir_cost::graphgen::{corpus_specs, generate, Family, GraphSpec};
use mlir_cost::lower::{analyze, lower, CodegenOpts};
use mlir_cost::mlir::{parse_function, print_function, verify_function, Function};
use mlir_cost::sim::{ground_truth_default, simulate, Target, XpuConfig};
use mlir_cost::tokenizer::{
    count_oov, encode, encode_function, tokenize, OpIdTable, Scheme, Vocab, PAD_ID,
};

/// Generator → printer → parser → verifier → lowering → regalloc →
/// simulator: the full ground-truth path over every family.
#[test]
fn full_label_pipeline_over_all_families() {
    for (i, family) in Family::ALL.into_iter().enumerate() {
        let spec = GraphSpec { family, structure_seed: 90 + i as u64, shape_seed: 17 };
        let f = generate(&spec).unwrap();
        let text = print_function(&f);
        let f2 = parse_function(&text).unwrap();
        verify_function(&f2).unwrap();
        // Labels computed from the re-parsed text must equal labels from
        // the in-memory graph (text is the source of truth).
        let a = ground_truth_default(&f).unwrap();
        let b = ground_truth_default(&f2).unwrap();
        assert_eq!(a, b, "{family:?}: text round-trip changed labels");
        assert!(a.regpressure > 0.0 && a.cycles > 0.0);
    }
}

/// Dataset → tokenize → vocab → encode: shapes, padding and determinism.
#[test]
fn dataset_to_encoded_batches() {
    let ds = Dataset::generate(1234, 24, 1).unwrap();
    assert_eq!(ds.len(), 48);
    let (train, test) = ds.split(9, 0.25);
    let streams = train.token_streams(Scheme::OpsOnly).unwrap();
    let vocab = Vocab::build(streams.iter(), 1);
    let stats = TargetStats::for_dataset(&train, Target::XpuUtil);
    let enc = EncodedSet::build(&train, &streams, &vocab, 128, Target::XpuUtil, &stats);
    assert_eq!(enc.ids.len(), train.len() * 128);
    // Every row ends in padding or is full; no id exceeds the AOT cap.
    assert!(enc.ids.iter().all(|&i| (i as u32) < mlir_cost::tokenizer::EMBED_VOCAB_CAP));
    // Test-set streams tokenize under the train vocab without panicking.
    let test_streams = test.token_streams(Scheme::OpsOnly).unwrap();
    for s in &test_streams {
        let ids = encode(s, &vocab, 128);
        assert_eq!(ids.len(), 128);
    }
}

/// Compiler-knob coherence: fusion and unroll move cycles/pressure in the
/// directions the §1 use-cases rely on, across a corpus (not one graph).
#[test]
fn compiler_knobs_move_labels_coherently() {
    let cfg = XpuConfig::default();
    let mut fusion_wins = 0;
    let mut pressure_grows = 0;
    let specs = corpus_specs(555, 20, 0);
    for spec in &specs {
        let f = generate(spec).unwrap();
        let fused = ground_truth_default(&f).unwrap();
        let unfused = mlir_cost::sim::ground_truth(
            &f,
            &CodegenOpts { fuse: false, ..Default::default() },
            &cfg,
        )
        .unwrap();
        if fused.cycles <= unfused.cycles {
            fusion_wins += 1;
        }
        let p1 = analyze(&lower(&f, &CodegenOpts { unroll: Some(1), ..Default::default() }).unwrap());
        let p8 = analyze(&lower(&f, &CodegenOpts { unroll: Some(8), ..Default::default() }).unwrap());
        if p8.max_live >= p1.max_live {
            pressure_grows += 1;
        }
    }
    assert!(fusion_wins >= 19, "fusion should ~never hurt: {fusion_wins}/20");
    assert!(pressure_grows >= 18, "unroll should ~never shrink pressure: {pressure_grows}/20");
}

/// Tokenization schemes line up with the labels the CSV stores.
#[test]
fn csv_roundtrip_preserves_everything() {
    let ds = Dataset::generate(77, 10, 0).unwrap();
    let dir = std::env::temp_dir().join("mlir_cost_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ds.csv");
    ds.save_csv(&path).unwrap();
    let ds2 = Dataset::load_csv(&path).unwrap();
    for (a, b) in ds.samples.iter().zip(&ds2.samples) {
        // Integer-valued labels survive exactly; xpu_util is stored with
        // 6 decimals in the CSV.
        assert_eq!(a.labels.regpressure, b.labels.regpressure);
        assert_eq!(a.labels.cycles, b.labels.cycles);
        assert_eq!(a.labels.spills, b.labels.spills);
        assert_eq!(a.labels.dyn_instrs, b.labels.dyn_instrs);
        assert!((a.labels.xpu_util - b.labels.xpu_util).abs() < 1e-5);
        let fa = parse_function(&a.mlir_text).unwrap();
        let fb = parse_function(&b.mlir_text).unwrap();
        assert_eq!(tokenize(&fa, Scheme::OpsOperands), tokenize(&fb, Scheme::OpsOperands));
    }
    std::fs::remove_file(path).ok();
}

/// Simulated machine sanity: identical programs → identical reports;
/// beefier machine → fewer cycles.
#[test]
fn machine_model_monotonicity() {
    let spec = GraphSpec { family: Family::Bert, structure_seed: 3, shape_seed: 4 };
    let f = generate(&spec).unwrap();
    let prog = lower(&f, &CodegenOpts::default()).unwrap();
    let base = simulate(&prog, &XpuConfig::default());
    let again = simulate(&prog, &XpuConfig::default());
    assert_eq!(base, again, "simulator must be deterministic");
    let fast = XpuConfig {
        issue_width: 4,
        dma_bytes_per_cycle: 256,
        ..XpuConfig::default()
    };
    let faster = simulate(&prog, &fast);
    assert!(faster.cycles <= base.cycles, "{} vs {}", faster.cycles, base.cycles);
}

/// Padding ids are PAD everywhere the encoder promises.
#[test]
fn encode_padding_contract() {
    let toks: Vec<String> = vec!["func".into(), "xpu.relu".into()];
    let vocab = Vocab::build([toks.clone()].iter(), 1);
    let ids = encode(&toks, &vocab, 8);
    assert_eq!(&ids[2..], &[PAD_ID; 6][..]);
}

/// Equivalence property for the serving fast path: the fused id-direct
/// sink must produce byte-identical ids (and the same whole-stream OOV
/// count) as the two-phase `encode(&tokenize(...))` string pipeline —
/// across all 7 graphgen families × both schemes × the affine-lowered
/// form, under a vocab that leaves real OOV tokens, at truncating and
/// padding max_lens.
#[test]
fn id_direct_sink_matches_string_pipeline_everywhere() {
    // Corpus: every family, xpu form + affine-lowered form.
    let mut funcs: Vec<Function> = Vec::new();
    for (i, family) in Family::ALL.into_iter().enumerate() {
        let spec = GraphSpec { family, structure_seed: 40 + i as u64, shape_seed: 7 + i as u64 };
        let f = generate(&spec).unwrap();
        let affine = mlir_cost::lower::affine::lower_to_affine(&f).unwrap();
        funcs.push(f);
        funcs.push(affine);
    }
    // Train-like vocab from a *subset* of the streams with min_count 2,
    // so unseen shapes/%values genuinely encode as OOV.
    let mut vocab_streams: Vec<Vec<String>> = Vec::new();
    for f in funcs.iter().step_by(3) {
        vocab_streams.push(tokenize(f, Scheme::OpsOnly));
        vocab_streams.push(tokenize(f, Scheme::OpsOperands));
    }
    let vocab = Vocab::build(vocab_streams.iter(), 2);
    let table = OpIdTable::build(&vocab);

    let mut checked = 0usize;
    let mut saw_oov = false;
    for scheme in [Scheme::OpsOnly, Scheme::OpsOperands] {
        for f in &funcs {
            let toks = tokenize(f, scheme);
            let want_oov = count_oov(&toks, &vocab);
            saw_oov |= want_oov > 0;
            // One truncating, one exact-ish, one padding max_len.
            for max_len in [8, toks.len(), toks.len() + 33] {
                let want = encode(&toks, &vocab, max_len);
                let (got, got_oov) = encode_function(f, scheme, &vocab, &table, max_len);
                assert_eq!(
                    got, want,
                    "id mismatch: {} {scheme:?} max_len={max_len}",
                    f.name
                );
                assert_eq!(got_oov, want_oov, "oov mismatch: {} {scheme:?}", f.name);
                checked += 1;
            }
        }
    }
    assert_eq!(checked, 2 * funcs.len() * 3);
    assert!(saw_oov, "test vocab too permissive — OOV path never exercised");
}

//! Wire-level admission-control suite: quotas, per-connection
//! fallback buckets, flood-vs-interactive tenancy, and deterministic
//! deadline shedding — all artifact-free through the [`LineService`]
//! seam (`serve_loops` over a fake model head, no `artifacts/`
//! needed), so the suite runs on every CI machine.
//!
//! Pinned behavior (the issue's acceptance bar):
//! - a tenant over its token-bucket quota gets a typed `over_quota`
//!   error, and is admitted again once the bucket refills;
//! - untagged traffic draws from per-connection buckets — one
//!   connection's exhaustion never throttles a sibling;
//! - a flooding tenant cannot starve an interactive tenant: the
//!   interactive one's requests all answer correctly while the abuser
//!   accumulates `over_quota` rejections;
//! - weighted-fair queueing on the offload pool bounds how long one
//!   tenant's backlog can delay another tenant's single job;
//! - `shed_deadline` fires deterministically from seeded latency
//!   estimates, and NEVER fires when no `budget_us` is supplied;
//! - with every knob at its default the admission layer is off and
//!   responses are byte-identical to a direct `handle()` render;
//! - at quiescence the conservation ledger balances:
//!   `admitted == answered + over_quota + shed_deadline + overloaded
//!   + dropped`.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use mlir_cost::coordinator::deadline_unmeetable;
use mlir_cost::coordinator::offload::LineService;
use mlir_cost::coordinator::server::{serve_loops, ServerConfig, Stop};
use mlir_cost::coordinator::stats::{LatencyEwma, ServiceStats};
use mlir_cost::json::{parse, Json};

/// Artifact-free model head: echoes every line back; lines containing
/// `"slow"` are classified would-block and sleep `delay_ms` (the
/// stand-in for a cache-miss model execution on the offload pool).
/// `shed` mirrors the real service's contract — consulted only for
/// requests that carry a `budget_us`, against a seeded latency EWMA —
/// so the shedding tests are deterministic without artifacts.
struct Echo {
    stats: ServiceStats,
    delay: Duration,
    /// Seeded fastest-variant latency estimate for `shed`; 0 = the
    /// fake has no estimate and never sheds (like a cold router).
    est: LatencyEwma,
}

impl Echo {
    fn new(delay_ms: u64) -> Arc<Echo> {
        Arc::new(Echo {
            stats: ServiceStats::default(),
            delay: Duration::from_millis(delay_ms),
            est: LatencyEwma::default(),
        })
    }
}

impl LineService for Echo {
    fn stats(&self) -> &ServiceStats {
        &self.stats
    }

    fn would_block(&self, line: &str) -> bool {
        line.contains("slow")
    }

    fn handle(&self, line: &str) -> Json {
        if line.contains("slow") {
            std::thread::sleep(self.delay);
        }
        let id = parse(line).ok().and_then(|r| r.get("id").cloned()).unwrap_or(Json::Null);
        Json::obj()
            .with("id", id)
            .with("ok", Json::Bool(true))
            .with("echo", Json::str(line))
    }

    fn shed(&self, line: &str) -> Option<Json> {
        let req = parse(line).ok()?;
        let budget = req
            .get("budget_us")
            .and_then(Json::as_f64)
            .filter(|b| b.is_finite() && *b >= 0.0)?;
        let est = self.est.get();
        if est <= 0.0 || !deadline_unmeetable(est, 0, budget) {
            return None;
        }
        let id = req.get("id").cloned().unwrap_or(Json::Null);
        Some(
            Json::obj()
                .with("id", id)
                .with("ok", Json::Bool(false))
                .with("error", Json::str(format!("shed_deadline: budget_us {budget} unmeetable"))),
        )
    }
}

/// Spawn `serve_loops` over a fake on port 0; (addr, stop, join).
fn spawn(
    svc: Arc<dyn LineService>,
    config: ServerConfig,
) -> (String, Arc<Stop>, std::thread::JoinHandle<anyhow::Result<()>>) {
    let stop = Stop::new();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = {
        let stop = stop.clone();
        std::thread::spawn(move || serve_loops(svc, vec![listener], stop, config))
    };
    (addr, stop, server)
}

/// Write `n` request lines in one burst, tagged with `tenant` when
/// given, then read `n` responses back. Returns (ok, over_quota)
/// counts and asserts every response answers its request in order.
fn burst(conn: &mut TcpStream, tenant: Option<&str>, n: usize) -> (usize, usize) {
    let mut buf = String::new();
    for i in 0..n {
        let mut req = Json::obj().with("id", Json::num(i as f64));
        if let Some(t) = tenant {
            req = req.with("tenant", Json::str(t));
        }
        buf.push_str(&req.to_string());
        buf.push('\n');
    }
    conn.write_all(buf.as_bytes()).unwrap();
    let mut reader = BufReader::new(conn);
    let (mut ok, mut over) = (0, 0);
    for i in 0..n {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = parse(&line).unwrap();
        assert_eq!(
            resp.get("id").and_then(Json::as_f64),
            Some(i as f64),
            "response desync at line {i}: {line:?}"
        );
        if resp.get("ok").and_then(Json::as_bool) == Some(true) {
            ok += 1;
        } else {
            let err = resp.req_str("error").unwrap();
            assert!(err.starts_with("over_quota"), "unexpected error form: {err}");
            over += 1;
        }
    }
    (ok, over)
}

/// Quota exhaustion returns the typed `over_quota` error — and the
/// tenant is admitted again once the bucket refills at `quota`/s.
#[test]
fn quota_exhaustion_returns_over_quota_and_recovers_after_refill() {
    let svc = Echo::new(0);
    let config = ServerConfig { quota: 2.0, quota_burst: 2.0, ..Default::default() };
    let (addr, stop, server) = spawn(svc.clone(), config);

    let mut conn = TcpStream::connect(&addr).unwrap();
    let (ok, over) = burst(&mut conn, Some("tuner-a"), 10);
    // A fresh bucket holds exactly the burst; the 10-line burst lands
    // well inside one refill interval, so at most a token's worth of
    // slack beyond it can be admitted.
    assert!((2..=3).contains(&ok), "burst of 2 admitted {ok} of 10");
    assert!(over >= 7, "expected >= 7 over_quota rejections, got {over}");
    // Refill: at 2 tokens/s, 700 ms banks at least one token.
    std::thread::sleep(Duration::from_millis(700));
    let mut conn2 = TcpStream::connect(&addr).unwrap();
    let (ok2, _) = burst(&mut conn2, Some("tuner-a"), 1);
    assert_eq!(ok2, 1, "tenant not re-admitted after refill");

    stop.trigger();
    let _ = server.join();
    assert!(svc.stats.over_quota.load(Ordering::Relaxed) >= 7);
    assert_eq!(svc.stats.conservation_debt(), 0, "admission ledger out of balance");
}

/// Untagged traffic falls back to one bucket per connection: one
/// connection burning its burst must not throttle a sibling.
#[test]
fn untagged_connections_get_independent_buckets() {
    let svc = Echo::new(0);
    let config = ServerConfig { quota: 1.0, quota_burst: 1.0, ..Default::default() };
    let (addr, stop, server) = spawn(svc.clone(), config);

    let mut a = TcpStream::connect(&addr).unwrap();
    let mut b = TcpStream::connect(&addr).unwrap();
    let (ok_a, over_a) = burst(&mut a, None, 2);
    let (ok_b, over_b) = burst(&mut b, None, 2);
    // Each connection gets its own burst of 1 — the first line passes
    // on BOTH connections, the immediate second is rejected on both.
    assert_eq!((ok_a, over_a), (1, 1));
    assert_eq!((ok_b, over_b), (1, 1));

    stop.trigger();
    let _ = server.join();
    assert_eq!(svc.stats.over_quota.load(Ordering::Relaxed), 2);
    assert_eq!(svc.stats.conservation_debt(), 0, "admission ledger out of balance");
}

/// The flood bar: one io thread, an abusive tenant pipelining a large
/// burst, an interactive tenant doing paced request/response — the
/// interactive tenant's requests ALL answer correctly while the
/// abuser accumulates `over_quota` rejections, and the event loop's
/// round-robin records fairness deferrals against the flooder.
#[test]
fn flooding_tenant_cannot_starve_interactive_tenant() {
    let svc = Echo::new(0);
    let config = ServerConfig {
        io_threads: 1,
        quota: 200.0,
        quota_burst: 16.0,
        ..Default::default()
    };
    let (addr, stop, server) = spawn(svc.clone(), config);

    let flood_n = 512;
    let mut abuser = TcpStream::connect(&addr).unwrap();
    let flooder = std::thread::spawn(move || burst(&mut abuser, Some("abuser"), flood_n));

    let mut ui = TcpStream::connect(&addr).unwrap();
    ui.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut reader = BufReader::new(ui.try_clone().unwrap());
    let mut worst = Duration::ZERO;
    for i in 0..20 {
        let req = Json::obj().with("id", Json::num(i as f64)).with("tenant", Json::str("ui"));
        let t0 = Instant::now();
        ui.write_all(format!("{req}\n").as_bytes()).unwrap();
        let mut line = String::new();
        // A read timeout here (empty line) fails the id assert below —
        // that IS the starvation detector.
        reader.read_line(&mut line).unwrap();
        let resp = parse(&line).unwrap();
        assert_eq!(resp.get("id").and_then(Json::as_f64), Some(i as f64), "ui desync: {line:?}");
        // Any rejection fails the test: the paced tenant stays far
        // inside its own quota no matter what the abuser does.
        assert_eq!(
            resp.get("ok").and_then(Json::as_bool),
            Some(true),
            "interactive request rejected: {line:?}"
        );
        worst = worst.max(t0.elapsed());
        std::thread::sleep(Duration::from_millis(5));
    }
    let (flood_ok, flood_over) = flooder.join().unwrap();
    // The abuser burned its burst and little more; the bulk of the
    // flood was rejected without model work.
    assert!(flood_ok < flood_n / 2, "flood mostly admitted: {flood_ok}/{flood_n}");
    assert!(flood_over > flood_n / 2, "expected most of the flood rejected, got {flood_over}");
    // Interactive latency stayed sane (generous CI bound — the point
    // is "not behind a 512-line flood", not a precise percentile).
    assert!(worst < Duration::from_secs(2), "interactive tenant stalled {worst:?} behind flood");

    stop.trigger();
    let _ = server.join();
    assert!(svc.stats.over_quota.load(Ordering::Relaxed) >= flood_over as u64);
    assert_eq!(svc.stats.shed_deadline.load(Ordering::Relaxed), 0);
    assert_eq!(svc.stats.conservation_debt(), 0, "admission ledger out of balance");
}

/// Weighted-fair queueing on the offload pool: a tenant with a deep
/// backlog of slow jobs cannot make another tenant's single job wait
/// behind the whole backlog — round-robin interleaves tenants, so the
/// single job is served after at most ~one job's service time.
#[test]
fn fair_queueing_bounds_cross_tenant_offload_delay() {
    let svc = Echo::new(8);
    // Quota far above the traffic: admission exists (so tenant labels
    // reach the pool's fair queues) but never rejects.
    let config = ServerConfig {
        io_threads: 1,
        request_workers: 1,
        quota: 100_000.0,
        ..Default::default()
    };
    let (addr, stop, server) = spawn(svc.clone(), config);

    // 48 slow jobs x 8 ms = a ~380 ms backlog for the abuser tenant
    // (safely under the pool's 64-slot bound, so nothing falls back to
    // an inline answer on the io thread).
    let backlog = 48;
    let mut abuser = TcpStream::connect(&addr).unwrap();
    let mut buf = String::new();
    for i in 0..backlog {
        let req = Json::obj()
            .with("id", Json::num(i as f64))
            .with("tenant", Json::str("abuser"))
            .with("kind", Json::str("slow"));
        buf.push_str(&req.to_string());
        buf.push('\n');
    }
    abuser.write_all(buf.as_bytes()).unwrap();
    // Let the loop admit the backlog into the pool's abuser queue.
    std::thread::sleep(Duration::from_millis(60));

    let mut ui = TcpStream::connect(&addr).unwrap();
    let req = Json::obj()
        .with("id", Json::num(0.0))
        .with("tenant", Json::str("ui"))
        .with("kind", Json::str("slow"));
    let t0 = Instant::now();
    ui.write_all(format!("{req}\n").as_bytes()).unwrap();
    let mut reader = BufReader::new(&ui);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let waited = t0.elapsed();
    assert!(parse(&line).unwrap().get("ok").and_then(Json::as_bool) == Some(true));
    // FIFO would serve the ui job after the remaining ~300+ ms of
    // abuser backlog; fair queueing serves it after at most a couple
    // of service times. Generous CI bound.
    assert!(waited < Duration::from_millis(150), "ui job waited {waited:?} behind a FIFO backlog");

    // Drain the abuser's responses so teardown sees a quiet server.
    let mut reader = BufReader::new(&abuser);
    for _ in 0..backlog {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
    }
    stop.trigger();
    let _ = server.join();
    assert_eq!(svc.stats.conservation_debt(), 0, "admission ledger out of balance");
}

/// Deadline shedding is deterministic against seeded estimates: an
/// unmeetable `budget_us` is rejected with the typed `shed_deadline`
/// error, a generous budget passes, and a request WITHOUT a budget is
/// never shed — even with the estimate seeded sky-high.
#[test]
fn shed_deadline_is_deterministic_from_seeded_estimates() {
    let svc = Echo::new(0);
    svc.est.set(1_000.0); // "fastest variant takes ~1000 us"
    let config = ServerConfig { shed_deadlines: true, ..Default::default() };
    let (addr, stop, server) = spawn(svc.clone(), config);

    let mut conn = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut send = |req: Json| -> Json {
        conn.write_all(format!("{req}\n").as_bytes()).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        parse(&line).unwrap()
    };

    // budget 100 us < 1000 us estimate: shed, typed error.
    let resp = send(Json::obj().with("id", Json::num(1.0)).with("budget_us", Json::num(100.0)));
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    assert!(resp.req_str("error").unwrap().starts_with("shed_deadline"));
    // budget 10000 us: meetable, handled normally.
    let resp = send(Json::obj().with("id", Json::num(2.0)).with("budget_us", Json::num(10_000.0)));
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
    // No budget at all: NEVER shed, whatever the estimate says.
    let resp = send(Json::obj().with("id", Json::num(3.0)));
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));

    stop.trigger();
    let _ = server.join();
    assert_eq!(svc.stats.shed_deadline.load(Ordering::Relaxed), 1);
    assert_eq!(svc.stats.conservation_debt(), 0, "admission ledger out of balance");
}

/// The off switch IS off: with every admission knob at its default the
/// wire responses are byte-identical to a direct `handle()` render and
/// the admission-only counters stay untouched except the ledger pair.
#[test]
fn default_config_is_byte_identical_to_direct_handles() {
    let svc = Echo::new(0);
    let (addr, stop, server) = spawn(svc.clone(), ServerConfig::default());

    let lines = [
        r#"{"id": 1}"#,
        r#"{"id": 2, "tenant": "ignored-when-off"}"#,
        r#"{"id": 3, "budget_us": 0.001}"#,
        r#"{"id": 4, "payload": "xyz"}"#,
    ];
    let mut conn = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    for line in lines {
        conn.write_all(format!("{line}\n").as_bytes()).unwrap();
        let mut got = String::new();
        reader.read_line(&mut got).unwrap();
        let want = format!("{}\n", svc.handle(line));
        assert_eq!(got, want, "wire response diverged from direct handle for {line}");
    }

    stop.trigger();
    let _ = server.join();
    assert_eq!(svc.stats.over_quota.load(Ordering::Relaxed), 0);
    assert_eq!(svc.stats.shed_deadline.load(Ordering::Relaxed), 0);
    assert_eq!(svc.stats.rejected_overloaded.load(Ordering::Relaxed), 0);
    assert_eq!(svc.stats.lines_admitted.load(Ordering::Relaxed), lines.len() as u64);
    assert_eq!(svc.stats.lines_answered.load(Ordering::Relaxed), lines.len() as u64);
    assert_eq!(svc.stats.conservation_debt(), 0, "admission ledger out of balance");
}

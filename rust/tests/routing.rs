//! Routing-tier integration tests: a 3-variant `Service` (fc_ops +
//! lstm_ops at max_len 128, conv_full at max_len 512, all serving
//! RegPressure) exercised through the PUBLIC api and the wire protocol.
//!
//! Pinned behavior (the issue's acceptance bar):
//! - queries route to the cheapest variant whose `max_len` covers their
//!   token length, and the response's `variant` field names it;
//! - `budget_us` downgrades to a smaller/faster variant when the
//!   preferred one's latency EWMA would blow the budget — and an
//!   unsatisfiable budget keeps the smallest COVERING variant;
//! - a query longer than every variant fails cleanly
//!   (`no_covering_variant`), whole-service state intact;
//! - an `mlir_batch` spanning variants returns rows in input order;
//! - `routed_by_variant` / `budget_downgrades` / `no_covering_variant`
//!   are visible over the `stats` wire command.
//!
//! Artifact-gated like every Service test: without `artifacts/` the
//! tests are skipped.

use mlir_cost::bundle::Bundle;
use mlir_cost::coordinator::batcher::BatchPolicy;
use mlir_cost::coordinator::router::VariantSpec;
use mlir_cost::coordinator::{server, ServeOptions, Service};
use mlir_cost::dataset::TargetStats;
use mlir_cost::json::Json;
use mlir_cost::mlir::{print_function, Attrs, DType, FuncBuilder, Type, XpuOp};
use mlir_cost::runtime::Manifest;
use mlir_cost::sim::Target;
use mlir_cost::tokenizer::{Scheme, Vocab};
use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("artifacts")
}

fn bundle(manifest: &Manifest, model: &str) -> Bundle {
    let vocab = Vocab::build(vec![vec!["xpu.relu".to_string()]].iter(), 1);
    let stats = TargetStats { mean: 20.0, std: 5.0, min: 4.0, max: 60.0 };
    Bundle::untrained(manifest, model, Target::RegPressure, Scheme::OpsOnly, vocab, stats)
        .unwrap()
}

/// fc_ops + lstm_ops (128) and conv_full (512) behind one target.
fn service() -> Option<Arc<Service>> {
    let adir = artifacts_dir();
    if !adir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let manifest = Arc::new(Manifest::load(&adir).unwrap());
    let specs = vec![
        VariantSpec { name: "fc_ops".into(), bundle: bundle(&manifest, "fc_ops") },
        VariantSpec { name: "lstm_ops".into(), bundle: bundle(&manifest, "lstm_ops") },
        VariantSpec { name: "conv_full".into(), bundle: bundle(&manifest, "conv_full") },
    ];
    Some(Arc::new(
        Service::start_variants(
            manifest,
            specs,
            BatchPolicy::default(),
            ServeOptions::default(),
        )
        .unwrap(),
    ))
}

/// A relu chain of `n_ops` ops = `n_ops + 5` ops-only tokens, so each
/// test dials token lengths precisely. `tag` varies the arg shape so
/// different tests never share cache keys.
fn chain_text(n_ops: usize, tag: i64) -> String {
    let mut b = FuncBuilder::new("chain");
    let mut v = b.arg(Type::tensor(vec![2 + tag, 8], DType::F32));
    for _ in 0..n_ops {
        v = b.xpu(XpuOp::Relu, &[v], Attrs::new()).unwrap();
    }
    print_function(&b.ret(&[v]).unwrap())
}

fn seed_ewmas(svc: &Service) {
    svc.set_variant_ewma_us(Target::RegPressure, "fc_ops", 300.0).unwrap();
    svc.set_variant_ewma_us(Target::RegPressure, "lstm_ops", 900.0).unwrap();
    svc.set_variant_ewma_us(Target::RegPressure, "conv_full", 5_000.0).unwrap();
}

/// The acceptance scenario end to end over TCP: route by length, honor
/// `budget_us` downgrades, report per-variant counters over `stats`.
#[test]
fn three_variant_service_routes_and_honors_budgets_over_the_wire() {
    let Some(svc) = service() else { return };
    let stop = server::Stop::new();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let join = {
        let svc = svc.clone();
        let stop = stop.clone();
        std::thread::spawn(move || server::serve_on(svc, listener, stop))
    };
    let mut client = server::Client::connect(&addr).unwrap();

    // Short query → the cheapest covering variant (fc_ops, 128).
    let (v, variant) =
        client.predict_routed(Target::RegPressure, &chain_text(10, 1), None).unwrap();
    assert!(v.is_finite());
    assert_eq!(variant, "fc_ops");

    // Long query (155 tokens) → only conv_full (512) covers.
    let (_, variant) =
        client.predict_routed(Target::RegPressure, &chain_text(150, 1), None).unwrap();
    assert_eq!(variant, "conv_full");

    // Budget downgrade: conv_full's 5000us estimate blows a 1000us
    // budget, lstm_ops (900us) is the largest fitting smaller variant.
    seed_ewmas(&svc);
    let (_, variant) = client
        .predict_routed(Target::RegPressure, &chain_text(152, 1), Some(1_000))
        .unwrap();
    assert_eq!(variant, "lstm_ops");

    // Unsatisfiable budget: the smallest COVERING variant serves.
    seed_ewmas(&svc);
    let (_, variant) = client
        .predict_routed(Target::RegPressure, &chain_text(153, 1), Some(10))
        .unwrap();
    assert_eq!(variant, "conv_full");

    // The stats wire view carries the per-variant routing counters.
    let stats = client.stats().unwrap();
    let routed = stats.get("routed_by_variant").expect("routed_by_variant missing");
    assert!(routed.req_f64("regpressure/fc_ops").unwrap() >= 1.0);
    assert!(routed.req_f64("regpressure/lstm_ops").unwrap() >= 1.0);
    assert!(routed.req_f64("regpressure/conv_full").unwrap() >= 2.0);
    assert_eq!(stats.req_f64("budget_downgrades").unwrap(), 1.0);
    assert_eq!(stats.req_f64("no_covering_variant").unwrap(), 0.0);
    let variants = stats.get("variants").expect("variants missing");
    assert_eq!(
        variants.get("regpressure/lstm_ops").unwrap().req_f64("budget_downgrades").unwrap(),
        1.0
    );

    stop.trigger();
    let _ = join.join().unwrap();
}

/// Uncovered queries fail cleanly over the wire — per entry in a batch,
/// whole-request for a single predict — and the counter moves.
#[test]
fn uncovered_query_is_a_clean_wire_error() {
    let Some(svc) = service() else { return };
    // 605 ops-only tokens: longer than conv_full's 512.
    let huge = chain_text(600, 2);
    let req = Json::obj()
        .with("id", Json::num(1.0))
        .with("target", Json::str("regpressure"))
        .with("mlir", Json::str(huge.as_str()));
    let resp = server::handle_line(&svc, &req.to_string());
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    assert!(resp.req_str("error").unwrap().contains("covers token length"));
    assert_eq!(svc.stats.no_covering_variant.load(std::sync::atomic::Ordering::Relaxed), 1);
    // In a batch the failure is per-entry: sibling rows still serve.
    let breq = Json::obj()
        .with("id", Json::num(2.0))
        .with("target", Json::str("regpressure"))
        .with(
            "mlir_batch",
            Json::Arr(vec![Json::str(chain_text(5, 2).as_str()), Json::str(huge.as_str())]),
        );
    let bresp = server::handle_line(&svc, &breq.to_string());
    assert_eq!(bresp.get("ok").and_then(Json::as_bool), Some(true));
    let rows = bresp.req_arr("predictions").unwrap();
    assert_eq!(rows[0].get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(rows[0].req_str("variant").unwrap(), "fc_ops");
    assert_eq!(rows[1].get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(svc.stats.no_covering_variant.load(std::sync::atomic::Ordering::Relaxed), 2);
}

/// An `mlir_batch` spanning variants comes back in input order, every
/// row tagged with the variant that served it.
#[test]
fn batch_spanning_variants_preserves_input_order_on_the_wire() {
    let Some(svc) = service() else { return };
    let short_a = chain_text(5, 3);
    let long = chain_text(200, 3);
    let short_b = chain_text(7, 3);
    let req = Json::obj()
        .with("id", Json::num(1.0))
        .with("target", Json::str("regpressure"))
        .with(
            "mlir_batch",
            Json::Arr(vec![
                Json::str(short_a.as_str()),
                Json::str(long.as_str()),
                Json::str(short_b.as_str()),
                Json::str(long.as_str()),
            ]),
        );
    let resp = server::handle_line(&svc, &req.to_string());
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
    let rows = resp.req_arr("predictions").unwrap();
    assert_eq!(rows.len(), 4);
    for (i, expect) in ["fc_ops", "conv_full", "fc_ops", "conv_full"].iter().enumerate() {
        assert_eq!(rows[i].get("ok").and_then(Json::as_bool), Some(true), "row {i} failed");
        assert_eq!(rows[i].req_str("variant").unwrap(), *expect, "row {i} misrouted");
    }
    // Duplicate long entries coalesce to one value...
    assert_eq!(
        rows[1].req_f64("prediction").unwrap(),
        rows[3].req_f64("prediction").unwrap()
    );
    // ...and each row matches a fresh single predict of the same text
    // (now a cache hit), proving rows were not permuted.
    for (text, row) in [&short_a, &long, &short_b, &long].iter().zip(rows) {
        assert_eq!(
            svc.predict(Target::RegPressure, text).unwrap(),
            row.req_f64("prediction").unwrap()
        );
    }
}

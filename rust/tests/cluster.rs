//! Cluster-tier integration tests: 2–3 real coordinator nodes in one
//! process, each with its own `Service`, epoll front end on an ephemeral
//! port, and consistent-hash `Cluster` over the shared membership.
//!
//! Pinned behavior (the issue's acceptance bar):
//! - a key computed on its owner node is a `remote_hit` when another
//!   node later misses on it;
//! - values computed off-owner are written back to the owner
//!   asynchronously, so third nodes hit them remotely;
//! - killing a node degrades its keys to local compute
//!   (`degraded_fallbacks` > 0, peer health flips Down) and NO request
//!   ever errors because a peer is down;
//! - ring ownership is deterministic across nodes.
//!
//! Artifact-gated like every Service test: without `artifacts/` the
//! tests are skipped.

use mlir_cost::bundle::Bundle;
use mlir_cost::cluster::{Cluster, ClusterConfig, PeerHealth};
use mlir_cost::coordinator::batcher::BatchPolicy;
use mlir_cost::coordinator::cache::{cache_key, cache_namespace};
use mlir_cost::coordinator::{server, Service};
use mlir_cost::dataset::TargetStats;
use mlir_cost::graphgen::{generate, Family, GraphSpec};
use mlir_cost::mlir::{parse_function, print_function};
use mlir_cost::runtime::Manifest;
use mlir_cost::sim::Target;
use mlir_cost::tokenizer::{Scheme, Vocab};
use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("artifacts")
}

/// Every node (and the test's key-probe) uses an identical bundle, so
/// encodings — and therefore cache keys — agree across the cluster.
fn mk_bundle(manifest: &Manifest) -> Bundle {
    let vocab = Vocab::build(vec![vec!["xpu.matmul".to_string()]].iter(), 1);
    let stats = TargetStats { mean: 20.0, std: 5.0, min: 4.0, max: 60.0 };
    Bundle::untrained(manifest, "fc_ops", Target::RegPressure, Scheme::OpsOnly, vocab, stats)
        .unwrap()
}

/// A bundle declaring TWO characteristics, for the vector wire tests.
fn mk_multi_bundle(manifest: &Manifest) -> Bundle {
    let vocab = Vocab::build(vec![vec!["xpu.matmul".to_string()]].iter(), 1);
    Bundle::untrained_multi(
        manifest,
        "fc_ops",
        &[Target::Cycles, Target::XpuUtil],
        Scheme::OpsOnly,
        vocab,
        vec![
            TargetStats { mean: 900.0, std: 200.0, min: 100.0, max: 4000.0 },
            TargetStats { mean: 40.0, std: 10.0, min: 0.0, max: 100.0 },
        ],
        Some("xpu-v1".to_string()),
    )
    .unwrap()
}

struct Node {
    svc: Arc<Service>,
    addr: String,
    stop: Arc<server::Stop>,
    join: std::thread::JoinHandle<()>,
}

/// Spin up `n` clustered nodes on ephemeral ports. Returns `None` (skip)
/// when the artifacts are not built.
fn spawn_cluster(n: usize) -> Option<(Vec<Node>, Bundle)> {
    spawn_cluster_with(n, mk_bundle)
}

fn spawn_cluster_with(
    n: usize,
    mk: fn(&Manifest) -> Bundle,
) -> Option<(Vec<Node>, Bundle)> {
    let adir = artifacts_dir();
    if !adir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let manifest = Arc::new(Manifest::load(&adir).unwrap());
    let listeners: Vec<TcpListener> =
        (0..n).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
    let addrs: Vec<String> =
        listeners.iter().map(|l| l.local_addr().unwrap().to_string()).collect();
    let members = addrs.join(",");
    let mut nodes = Vec::new();
    for (i, listener) in listeners.into_iter().enumerate() {
        let mut svc = Service::start(
            manifest.clone(),
            vec![mk(&manifest)],
            BatchPolicy::default(),
            false,
        )
        .unwrap();
        let cfg = ClusterConfig::new(&members, &addrs[i]).unwrap();
        svc.set_cluster(Arc::new(Cluster::new(&cfg).unwrap()));
        let svc = Arc::new(svc);
        let stop = server::Stop::new();
        let join = {
            let svc = svc.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                if let Err(e) = server::serve_on(svc, listener, stop) {
                    eprintln!("[cluster test] node exited with error: {e:#}");
                }
            })
        };
        nodes.push(Node { svc, addr: addrs[i].clone(), stop, join });
    }
    Some((nodes, mk(&manifest)))
}

fn teardown(nodes: Vec<Node>) {
    for n in &nodes {
        n.stop.trigger();
    }
    for n in nodes {
        let _ = n.join.join();
    }
}

fn graph_text(structure_seed: u64, shape_seed: u64) -> String {
    let spec = GraphSpec { family: Family::Mlp, structure_seed, shape_seed };
    print_function(&generate(&spec).unwrap())
}

/// The cache key a clustered service will derive for `text`. Keys are
/// namespaced per `(target, variant, model)`; a bundle served via
/// `Service::start` registers as the sole variant of its target, named
/// after its model — every node derives the identical namespace.
fn probe_key(bundle: &Bundle, text: &str) -> u64 {
    let func = parse_function(text).unwrap();
    let (ids, _oov) = bundle.encode_ids(&func);
    let ns = cache_namespace(bundle.primary_target().name(), &bundle.model, &bundle.model);
    cache_key(&ns, &ids)
}

/// Find `count` graph texts with pairwise-distinct cache keys all owned
/// by `owner_addr` according to `cluster`'s ring. Seeds are offset by
/// `base` so different tests never share cache keys.
fn texts_owned_by(
    bundle: &Bundle,
    cluster: &Cluster,
    owner_addr: &str,
    count: usize,
    base: u64,
) -> Vec<(String, u64)> {
    let mut found: Vec<(String, u64)> = Vec::new();
    for seed in 0..512u64 {
        let text = graph_text(base + seed, base + 1000 + seed);
        let key = probe_key(bundle, &text);
        if cluster.ring().owner(key) == owner_addr
            && !found.iter().any(|&(_, k)| k == key)
        {
            found.push((text, key));
            if found.len() == count {
                return found;
            }
        }
    }
    panic!("could not find {count} keys owned by {owner_addr} in 512 candidates");
}

/// (a) A key cached at its owner is a `remote_hit` for every other node.
#[test]
fn key_computed_on_owner_is_remote_hit_elsewhere() {
    let Some((nodes, bundle)) = spawn_cluster(3) else { return };
    let cluster0 = nodes[0].svc.cluster().unwrap();
    let (text, _key) = texts_owned_by(&bundle, cluster0, &nodes[0].addr, 1, 10_000)
        .pop()
        .unwrap();
    // Owner computes locally: no forwarding involved.
    let v0 = nodes[0].svc.predict(Target::RegPressure, &text).unwrap();
    assert_eq!(nodes[0].svc.stats.forwarded_gets.load(Ordering::Relaxed), 0);
    // Another node missing locally probes the owner and hits.
    let v1 = nodes[1].svc.predict(Target::RegPressure, &text).unwrap();
    assert_eq!(v0, v1, "remote hit returned a different value");
    assert_eq!(nodes[1].svc.stats.remote_hits.load(Ordering::Relaxed), 1);
    assert_eq!(nodes[1].svc.stats.forwarded_gets.load(Ordering::Relaxed), 1);
    assert_eq!(nodes[1].svc.stats.degraded_fallbacks.load(Ordering::Relaxed), 0);
    // The remote hit also populated node 1's local LRU: a repeat stays
    // local (no second forward).
    let v1b = nodes[1].svc.predict(Target::RegPressure, &text).unwrap();
    assert_eq!(v1, v1b);
    assert_eq!(nodes[1].svc.stats.forwarded_gets.load(Ordering::Relaxed), 1);
    // The stats wire view carries the cluster object on every node.
    let j = nodes[1].svc.stats_json();
    let cl = j.get("cluster").expect("clustered stats must carry the peer view");
    assert_eq!(cl.req_f64("nodes").unwrap(), 3.0);
    assert_eq!(cl.req_arr("peers").unwrap().len(), 2);
    teardown(nodes);
}

/// Off-owner computes are written back to the owner asynchronously, so
/// a third node's probe hits the owner remotely.
#[test]
fn computed_value_is_written_back_to_owner() {
    let Some((nodes, bundle)) = spawn_cluster(3) else { return };
    let cluster0 = nodes[0].svc.cluster().unwrap();
    let (text, key) = texts_owned_by(&bundle, cluster0, &nodes[1].addr, 1, 20_000)
        .pop()
        .unwrap();
    // Node 0 does not own the key: probe misses at the owner, compute
    // locally, write back.
    let v0 = nodes[0].svc.predict(Target::RegPressure, &text).unwrap();
    assert_eq!(nodes[0].svc.stats.forwarded_gets.load(Ordering::Relaxed), 1);
    assert_eq!(nodes[0].svc.stats.remote_hits.load(Ordering::Relaxed), 0);
    assert_eq!(nodes[0].svc.stats.forwarded_puts.load(Ordering::Relaxed), 1);
    // The write-back is fire-and-forget: poll the owner's cache.
    let t0 = Instant::now();
    loop {
        if let Some(v) = nodes[1].svc.cache.get(key) {
            assert_eq!(v.first(), v0, "write-back stored a different value");
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "write-back never reached the owner");
        std::thread::sleep(Duration::from_millis(10));
    }
    // A third node now remote-hits the owner: computed once, visible
    // everywhere.
    let v2 = nodes[2].svc.predict(Target::RegPressure, &text).unwrap();
    assert_eq!(v0, v2);
    assert_eq!(nodes[2].svc.stats.remote_hits.load(Ordering::Relaxed), 1);
    teardown(nodes);
}

/// (b) Killing a node flips its peer state Down and its keys degrade to
/// local compute — counted, and never an error.
#[test]
fn dead_owner_degrades_to_local_compute() {
    let Some((mut nodes, bundle)) = spawn_cluster(3) else { return };
    let victim_addr = nodes[2].addr.clone();
    let texts = {
        let cluster0 = nodes[0].svc.cluster().unwrap();
        texts_owned_by(&bundle, cluster0, &victim_addr, 5, 30_000)
    };
    // Kill node 2: server down, listener closed, service torn down.
    let victim = nodes.pop().unwrap();
    victim.stop.trigger();
    let _ = victim.join.join();
    drop(victim.svc);
    // Every query for a victim-owned key still succeeds on node 0.
    for (text, _key) in &texts {
        let v = nodes[0]
            .svc
            .predict(Target::RegPressure, text)
            .expect("a dead peer must never fail a request");
        assert!(v.is_finite());
    }
    let stats = &nodes[0].svc.stats;
    assert!(
        stats.degraded_fallbacks.load(Ordering::Relaxed) >= texts.len() as u64,
        "every victim-owned probe must be counted as a degraded fallback"
    );
    assert!(stats.peer_failures.load(Ordering::Relaxed) >= 1);
    assert_eq!(stats.remote_hits.load(Ordering::Relaxed), 0);
    // The peer's health flipped (Degraded after the first failures, Down
    // once they accumulate; 5 sequential failures pass the threshold).
    let peer = nodes[0]
        .svc
        .cluster()
        .unwrap()
        .peers()
        .find(|p| p.addr() == victim_addr)
        .expect("victim must be a peer of node 0");
    assert_eq!(peer.health(), PeerHealth::Down, "ring entry for the dead node must flip");
    // ...and the flip is visible over the stats wire view.
    let j = nodes[0].svc.stats_json();
    let peers = j.get("cluster").unwrap().req_arr("peers").unwrap();
    let down = peers
        .iter()
        .find(|p| p.req_str("addr").unwrap() == victim_addr)
        .expect("victim missing from stats peers");
    assert_eq!(down.req_str("state").unwrap(), "down");
    teardown(nodes);
}

/// (c) Ring ownership is deterministic across nodes: every node routes
/// every key to the same owner.
#[test]
fn ring_ownership_is_deterministic_across_nodes() {
    let Some((nodes, bundle)) = spawn_cluster(3) else { return };
    // Real keys (graph encodings) and synthetic ones both agree.
    let mut keys: Vec<u64> = (0..64u64)
        .map(|i| probe_key(&bundle, &graph_text(40_000 + i, 41_000 + i)))
        .collect();
    keys.extend([0u64, 1, u64::MAX, 0x8000_0000_0000_0000]);
    for key in keys {
        let owner0 = nodes[0].svc.cluster().unwrap().ring().owner(key).to_string();
        for node in &nodes[1..] {
            assert_eq!(
                node.svc.cluster().unwrap().ring().owner(key),
                owner0,
                "nodes disagree on the owner of {key:#x}"
            );
        }
        // Exactly one node claims local ownership.
        let owners: usize = nodes
            .iter()
            .map(|n| n.svc.cluster().unwrap().owns(key) as usize)
            .sum();
        assert_eq!(owners, 1, "key {key:#x} claimed by {owners} nodes");
    }
    teardown(nodes);
}

/// The batch API forwards too: a predict_many over remote-owned keys on
/// a non-owner node overlaps its owner probes and write-backs.
#[test]
fn predict_many_forwards_and_writes_back() {
    let Some((nodes, bundle)) = spawn_cluster(2) else { return };
    let cluster0 = nodes[0].svc.cluster().unwrap();
    let owned_by_1 = texts_owned_by(&bundle, cluster0, &nodes[1].addr, 3, 50_000);
    // Warm one of them at the owner so the batch sees a remote hit AND
    // remote misses in the same call.
    let v_warm = nodes[1].svc.predict(Target::RegPressure, &owned_by_1[0].0).unwrap();
    let texts: Vec<&str> = owned_by_1.iter().map(|(t, _)| t.as_str()).collect();
    let out = nodes[0].svc.predict_many(Target::RegPressure, &texts);
    assert!(out.iter().all(|r| r.is_ok()), "batch entries failed: {out:?}");
    assert_eq!(*out[0].as_ref().unwrap(), v_warm, "remote hit diverged in batch");
    let stats = &nodes[0].svc.stats;
    assert_eq!(stats.forwarded_gets.load(Ordering::Relaxed), 3);
    assert_eq!(stats.remote_hits.load(Ordering::Relaxed), 1);
    assert_eq!(stats.forwarded_puts.load(Ordering::Relaxed), 2);
    assert_eq!(stats.degraded_fallbacks.load(Ordering::Relaxed), 0);
    teardown(nodes);
}

/// Multi-output values survive the cluster wire intact: a prediction
/// VECTOR computed off-owner is written back to the owner as a JSON
/// array, and a third node's remote hit reads the whole vector back —
/// every characteristic, not just the primary scalar.
#[test]
fn vector_values_round_trip_across_three_nodes() {
    let Some((nodes, bundle)) = spawn_cluster_with(3, mk_multi_bundle) else { return };
    let required = [Target::Cycles, Target::XpuUtil];
    let cluster0 = nodes[0].svc.cluster().unwrap();
    let (text, key) = texts_owned_by(&bundle, cluster0, &nodes[1].addr, 1, 60_000)
        .pop()
        .unwrap();
    // Node 0 (non-owner) computes the full vector and writes it back.
    let r0 = nodes[0]
        .svc
        .predict_full(Target::Cycles, &text, None, &required)
        .unwrap();
    assert_eq!(r0.value.len(), 2, "multi bundle must answer both characteristics");
    assert!(r0.value.iter().all(|v| v.is_finite()));
    assert_eq!(nodes[0].svc.stats.forwarded_puts.load(Ordering::Relaxed), 1);
    // The async write-back lands the ENTIRE vector at the owner.
    let t0 = Instant::now();
    let stored = loop {
        if let Some(v) = nodes[1].svc.cache.get(key) {
            break v;
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "write-back never reached the owner");
        std::thread::sleep(Duration::from_millis(10));
    };
    assert_eq!(stored, r0.value, "vector mangled by the cache_put wire encoding");
    // A third node remote-hits the owner and reads the full vector back
    // through cache_get — both characteristics identical to the origin.
    let r2 = nodes[2]
        .svc
        .predict_full(Target::Cycles, &text, None, &required)
        .unwrap();
    assert_eq!(r2.value, r0.value, "vector mangled by the cache_get wire decoding");
    assert_eq!(r2.value_for(Target::XpuUtil), r0.value_for(Target::XpuUtil));
    assert_eq!(nodes[2].svc.stats.remote_hits.load(Ordering::Relaxed), 1);
    teardown(nodes);
}

//! Incremental-tier property test: delta-spliced id rows must be
//! byte-identical to the full tokenize→encode pipeline — across every
//! graph family (plus affine-lowered forms), both tokenization schemes,
//! and the edit kinds an autotuner produces (replace a line at the
//! first/middle/last segment, whitespace-only change, insert a line via
//! a byte-range splice, delete a line), at max_lens that pad AND
//! truncate (so edits land before, at, and past the padding boundary).
//!
//! Needs no artifacts: this exercises the text→ids half only
//! (`coordinator::session` + `tokenizer::span`), the exact code the
//! serving path's `session_open`/`mlir_delta` run.

use mlir_cost::coordinator::session::{
    apply_splices, index_lines, indexed_token_len, reindex_lines, Splice,
};
use mlir_cost::graphgen::{generate, Family, GraphSpec};
use mlir_cost::lower::affine::lower_to_affine;
use mlir_cost::mlir::{parse_function, print_function};
use mlir_cost::tokenizer::span::{line_span, splice_ids, tail_span, IdSpan};
use mlir_cost::tokenizer::{encode_function, token_count, tokenize, OpIdTable, Scheme, Vocab};
use std::collections::HashMap;

/// All seven families, with an affine-lowered sibling for every third —
/// the lowered texts carry the loop-nest line forms (affine.for /
/// load / store / yield) the per-line grammar must handle.
fn corpus() -> Vec<String> {
    let mut texts = Vec::new();
    for i in 0..Family::ALL.len() {
        let spec = GraphSpec {
            family: Family::ALL[i],
            structure_seed: 4000 + i as u64,
            shape_seed: 5000 + i as u64,
        };
        let f = generate(&spec).expect("graphgen");
        texts.push(print_function(&f));
        if i % 3 == 0 {
            texts.push(print_function(&lower_to_affine(&f).expect("affine lowering")));
        }
    }
    texts
}

/// Edit cases for one base text: `(tag, session base, edited text,
/// lines that must be re-lexed)`. Every edited text stays parseable
/// (comment/whitespace edits are invisible to the lexer), so the full
/// pipeline can adjudicate the spliced row.
fn edit_cases(base: &str) -> Vec<(&'static str, String, String, usize)> {
    let lines: Vec<&str> = base.lines().collect();
    let n = lines.len();
    assert!(n >= 3, "generated function too small to edit");
    let mid = n / 2;
    let with_edit = |at: usize, f: &dyn Fn(&str) -> String| -> String {
        lines
            .iter()
            .enumerate()
            .map(|(i, l)| if i == at { f(l) } else { l.to_string() })
            .collect::<Vec<_>>()
            .join("\n")
    };
    // Insert a comment-only line after `mid` through the byte-range
    // splice path (offsets into the base, exactly as the wire form).
    let insert_at: usize = lines.iter().take(mid + 1).map(|l| l.len() + 1).sum();
    let inserted = apply_splices(
        base,
        &[Splice { start: insert_at, end: insert_at, text: "// inserted\n".into() }],
    )
    .expect("insert splice");
    vec![
        ("replace-first", base.into(), with_edit(0, &|l| format!("{l} // edited")), 1),
        ("replace-mid", base.into(), with_edit(mid, &|l| format!("{l} // edited")), 1),
        ("replace-last", base.into(), with_edit(n - 1, &|l| format!("{l} // edited")), 1),
        ("replace-whitespace", base.into(), with_edit(mid, &|l| format!("  {l}")), 1),
        ("insert-line", base.into(), inserted.clone(), 1),
        // Delete: open on the longer text, delta back to the base — the
        // removed line's neighbors all splice, nothing re-lexes.
        ("delete-line", inserted, base.into(), 0),
    ]
}

#[test]
fn delta_spliced_ids_match_full_pipeline() {
    for text in corpus() {
        for scheme in [Scheme::OpsOnly, Scheme::OpsOperands] {
            // Per-text vocab, as a trained bundle would carry.
            let streams = vec![tokenize(&parse_function(&text).expect("parse base"), scheme)];
            let vocab = Vocab::build(streams.iter(), 1);
            let ops = OpIdTable::build(&vocab);
            let tail = tail_span(&vocab);
            for (tag, old_text, new_text, want_relexed) in edit_cases(&text) {
                let ctx = || format!("{tag} scheme={}", scheme.name());
                let old_lines = index_lines(&old_text, scheme)
                    .unwrap_or_else(|e| panic!("{}: index base: {e:#}", ctx()));
                // Warm span table = what session_open leaves behind.
                let mut table: HashMap<u64, IdSpan> = HashMap::new();
                for l in &old_lines {
                    table.insert(
                        l.hash,
                        line_span(&l.text, scheme, &vocab, &ops)
                            .unwrap_or_else(|e| panic!("{}: base span: {e:#}", ctx())),
                    );
                }
                let (new_lines, _changed) = reindex_lines(&old_lines, &new_text, scheme)
                    .unwrap_or_else(|e| panic!("{}: reindex: {e:#}", ctx()));
                // Splice with hit/miss accounting — the serving path's
                // encode_query, minus the sharded table.
                let mut relexed = 0usize;
                let mut spans: Vec<IdSpan> = Vec::with_capacity(new_lines.len());
                for l in &new_lines {
                    let span = table.get(&l.hash).cloned().unwrap_or_else(|| {
                        relexed += 1;
                        line_span(&l.text, scheme, &vocab, &ops)
                            .unwrap_or_else(|e| panic!("{}: edited span: {e:#}", ctx()))
                    });
                    spans.push(span);
                }
                assert_eq!(relexed, want_relexed, "{}: wrong re-lex count", ctx());
                let func = parse_function(&new_text)
                    .unwrap_or_else(|e| panic!("{}: edited text must parse: {e:#}", ctx()));
                // Routing's length: cached line sums == full tokenizer.
                assert_eq!(
                    indexed_token_len(&new_lines),
                    token_count(&func, scheme),
                    "{}: token length drifted",
                    ctx()
                );
                // max_len 16 truncates every text (edits land past the
                // boundary), 512 pads; both must agree byte-for-byte.
                for max_len in [16usize, 64, 512] {
                    let (ids, _oov) =
                        splice_ids(spans.iter().chain(std::iter::once(&tail)), max_len);
                    let (want, _oov) = encode_function(&func, scheme, &vocab, &ops, max_len);
                    assert_eq!(ids, want, "{} max_len={max_len}: ids diverged", ctx());
                }
            }
        }
    }
}

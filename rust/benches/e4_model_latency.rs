//! E4 — paper §5 claim: the Conv1D+MaxPool model is "extremely fast ...
//! compared to the likes of LSTM". Measures predict-executable latency per
//! model family at batch 1 and 32, ref vs Pallas-kernel lowering for conv.

use mlir_cost::benchkit;
use mlir_cost::runtime::{Manifest, Runtime, Tensor};

fn repo_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().to_path_buf()
}


fn main() {
    benchkit::section("E4: predict-path latency per model (PJRT CPU)");
    let manifest = Manifest::load(&repo_root().join("artifacts")).expect("artifacts built");
    let rt = Runtime::cpu().expect("PJRT client");

    for (model, keys) in [
        ("fc_ops", vec!["predict_b1", "predict_b32"]),
        ("lstm_ops", vec!["predict_b1", "predict_b32"]),
        ("conv_ops", vec!["predict_b1", "predict_b32", "predict_b32_pallas"]),
        ("conv_full", vec!["predict_b32", "predict_b32_pallas"]),
    ] {
        let mm = manifest.model(model).unwrap();
        let params = manifest.load_init_params(model).unwrap();
        for key in keys {
            let Ok(file) = mm.file(key) else { continue };
            let exe = rt.load(&manifest.path_of(file)).unwrap();
            let batch: i64 = key
                .trim_start_matches("predict_b")
                .trim_end_matches("_pallas")
                .parse()
                .unwrap();
            let ids = Tensor::i32(
                vec![batch, mm.max_len as i64],
                (0..batch * mm.max_len as i64).map(|i| 2 + (i % 64) as i32).collect(),
            )
            .unwrap();
            let mut inputs = params.clone();
            inputs.push(ids);
            let iters = if model == "conv_full" { 10 } else { 25 };
            let s = benchkit::bench(&format!("{model:<10} {key}"), 2, iters, || {
                let _ = exe.run(&inputs).unwrap();
            });
            println!("{}  ({:.1} us/graph)", s.row(), s.mean_us / batch as f64);
        }
    }
    benchkit::kv(
        "paper-shape: conv per-graph latency << lstm at equal seq len",
        "compare conv_ops vs lstm_ops b32 rows",
    );
}

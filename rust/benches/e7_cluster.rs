//! E7 — cluster-tier benchmark: duplicate-heavy autotuning traffic
//! against 1 node, a 3-node consistent-hash cluster, and a 3-node
//! cluster with one node killed mid-flight. Numbers are recorded to
//! `BENCH_cluster.json` at the repo root.
//!
//! The workload is the paper's probe shape (many clients re-evaluating
//! the same small candidate set), spread across nodes — exactly the
//! setup where independent per-node caches each pay for every distinct
//! probe, while the cluster tier computes each probe once *anywhere* and
//! serves the rest as local or remote cache hits. The killed-node
//! scenario measures the degradation floor: traffic must keep flowing
//! (local compute fallback), not error.

use mlir_cost::benchkit;
use mlir_cost::bundle::Bundle;
use mlir_cost::cluster::{Cluster, ClusterConfig};
use mlir_cost::coordinator::{batcher::BatchPolicy, server, Service};
use mlir_cost::dataset::TargetStats;
use mlir_cost::graphgen::{generate, Family, GraphSpec};
use mlir_cost::json::Json;
use mlir_cost::mlir::print_function;
use mlir_cost::runtime::Manifest;
use mlir_cost::sim::Target;
use mlir_cost::tokenizer::{token_count, Scheme, Vocab};
use std::net::TcpListener;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

const CLIENT_THREADS: usize = 8;
const QUERIES_PER_CLIENT: usize = 48;

fn repo_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().to_path_buf()
}

/// `n` distinct graphs; seeds whose graph exceeds the served bundle's
/// ops-only `max_len` (conv_ops: 128) are skipped — the router rejects
/// over-long queries cleanly instead of truncating them.
fn corpus_at(n: usize, base: u64) -> Vec<String> {
    let mut texts = Vec::with_capacity(n);
    let mut attempt = 0u64;
    while texts.len() < n {
        let spec = GraphSpec {
            family: Family::ALL[(attempt % 7) as usize],
            structure_seed: base + attempt,
            shape_seed: base + 1000 + attempt,
        };
        attempt += 1;
        let f = generate(&spec).unwrap();
        if token_count(&f, Scheme::OpsOnly) <= 128 {
            texts.push(print_function(&f));
        }
    }
    texts
}

struct BenchNode {
    svc: Arc<Service>,
    addr: String,
    stop: Arc<server::Stop>,
    join: std::thread::JoinHandle<()>,
}

/// Spin up `n` serving nodes on ephemeral ports; `n > 1` wires them into
/// one consistent-hash cluster.
fn spawn_nodes(manifest: &Arc<Manifest>, n: usize) -> Vec<BenchNode> {
    let listeners: Vec<TcpListener> =
        (0..n).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
    let addrs: Vec<String> =
        listeners.iter().map(|l| l.local_addr().unwrap().to_string()).collect();
    let members = addrs.join(",");
    let mut nodes = Vec::new();
    for (i, listener) in listeners.into_iter().enumerate() {
        let vocab = Vocab::build(vec![vec!["x".to_string()]].iter(), 1);
        let stats = TargetStats { mean: 20.0, std: 8.0, min: 2.0, max: 70.0 };
        let bundle = Bundle::untrained(
            manifest,
            "conv_ops",
            Target::RegPressure,
            Scheme::OpsOnly,
            vocab,
            stats,
        )
        .unwrap();
        let mut svc = Service::start(
            manifest.clone(),
            vec![bundle],
            BatchPolicy::default(),
            true,
        )
        .unwrap();
        if n > 1 {
            let cfg = ClusterConfig::new(&members, &addrs[i]).unwrap();
            svc.set_cluster(Arc::new(Cluster::new(&cfg).unwrap()));
        }
        let svc = Arc::new(svc);
        let stop = server::Stop::new();
        let join = {
            let svc = svc.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                if let Err(e) = server::serve_on(svc, listener, stop) {
                    eprintln!("[bench] node exited with error: {e:#}");
                }
            })
        };
        nodes.push(BenchNode { svc, addr: addrs[i].clone(), stop, join });
    }
    nodes
}

/// Push the duplicate-heavy corpus through the live nodes from
/// CLIENT_THREADS TCP clients (round-robin over nodes). Returns
/// (queries/s, seconds, total queries).
fn drive(live: &[&BenchNode], texts: &[String]) -> (f64, f64, usize) {
    let total = CLIENT_THREADS * QUERIES_PER_CLIENT;
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..CLIENT_THREADS {
            let addr = live[t % live.len()].addr.clone();
            s.spawn(move || {
                let mut client = server::Client::connect(&addr).unwrap();
                for i in 0..QUERIES_PER_CLIENT {
                    let text = &texts[(t + i) % texts.len()];
                    client.predict(Target::RegPressure, text).unwrap();
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    (total as f64 / dt.max(1e-9), dt, total)
}

/// One scenario cell: `n` nodes, optionally killing the last before the
/// measured traffic. Returns the JSON row for BENCH_cluster.json.
fn scenario(manifest: &Arc<Manifest>, label: &str, n: usize, kill_one: bool, base: u64) -> Json {
    let mut nodes = spawn_nodes(manifest, n);
    if kill_one {
        let victim = nodes.pop().unwrap();
        victim.stop.trigger();
        let _ = victim.join.join();
        // Leak the victim's service: PJRT teardown while siblings run
        // can wedge xla_extension 0.5.1 on single-core images (same
        // note as e3_serving).
        std::mem::forget(victim.svc);
    }
    let texts = corpus_at(16, base);
    let live: Vec<&BenchNode> = nodes.iter().collect();
    let (qps, dt, total) = drive(&live, &texts);
    let sum = |f: &dyn Fn(&BenchNode) -> u64| nodes.iter().map(|x| f(x)).sum::<u64>();
    let remote_hits = sum(&|x| x.svc.stats.remote_hits.load(Ordering::Relaxed));
    let degraded = sum(&|x| x.svc.stats.degraded_fallbacks.load(Ordering::Relaxed));
    let forwarded_gets = sum(&|x| x.svc.stats.forwarded_gets.load(Ordering::Relaxed));
    let forwarded_puts = sum(&|x| x.svc.stats.forwarded_puts.load(Ordering::Relaxed));
    let computed = sum(&|x| x.svc.stats.batched_queries.load(Ordering::Relaxed));
    benchkit::kv(
        &format!("{label} ({} live node(s))", live.len()),
        format!(
            "{qps:.0} pred/s ({dt:.2}s, {total} queries; {computed} computed, \
             {remote_hits} remote hits, {degraded} degraded fallbacks)"
        ),
    );
    for node in nodes {
        node.stop.trigger();
        let _ = node.join.join();
        std::mem::forget(node.svc);
    }
    Json::obj()
        .with("scenario", Json::str(label))
        .with("nodes", Json::num(n as f64))
        .with("live_nodes", Json::num((if kill_one { n - 1 } else { n }) as f64))
        .with("queries", Json::num(total as f64))
        .with("queries_per_sec", Json::num(qps))
        .with("model_invocations", Json::num(computed as f64))
        .with("remote_hits", Json::num(remote_hits as f64))
        .with("forwarded_gets", Json::num(forwarded_gets as f64))
        .with("forwarded_puts", Json::num(forwarded_puts as f64))
        .with("degraded_fallbacks", Json::num(degraded as f64))
}

fn main() {
    benchkit::section("E7: cluster tier (consistent-hash remote cache shards)");
    let manifest =
        Arc::new(Manifest::load(&repo_root().join("artifacts")).expect("artifacts built"));
    let scenarios = vec![
        scenario(&manifest, "1_node", 1, false, 100_000),
        scenario(&manifest, "3_node", 3, false, 200_000),
        scenario(&manifest, "3_node_one_killed", 3, true, 300_000),
    ];
    let doc = Json::obj()
        .with("bench", Json::str("e7_cluster"))
        .with(
            "note",
            Json::str(
                "Duplicate-heavy probe mix (16 distinct graphs, 8 clients x 48 queries, \
                 clients round-robin over live nodes) against one node, a 3-node \
                 consistent-hash cluster, and the same cluster with one node killed before \
                 traffic. Run `cargo bench --bench e7_cluster` from rust/ to overwrite with \
                 measured numbers.",
            ),
        )
        .with("duplicate_corpus_texts", Json::num(16.0))
        .with("client_threads", Json::num(CLIENT_THREADS as f64))
        .with("queries_per_client", Json::num(QUERIES_PER_CLIENT as f64))
        .with("scenarios", Json::Arr(scenarios))
        .with(
            "acceptance",
            Json::str(
                "3_node remote_hits > 0 (cluster-wide dedup observable; concurrent cross-node \
                 probes racing a write-back may still double-compute a key) and \
                 3_node_one_killed completes with degraded_fallbacks > 0 and zero request errors",
            ),
        );
    let out = repo_root().join("BENCH_cluster.json");
    match std::fs::write(&out, doc.to_string()) {
        Ok(()) => benchkit::kv("cluster sweep recorded", out.display()),
        Err(e) => eprintln!("\ncould not write {out:?}: {e}"),
    }
}

//! E11 — admission & tenancy benchmark: what tenant tagging buys an
//! interactive client sharing the offload pool with a flood. A fleet
//! of flooding connections keeps the request-worker pool saturated
//! with slow jobs while ONE interactive connection does sequential
//! request/response; per-connection fair queueing (untenanted — the
//! pre-tenancy behavior, each connection its own queue key) gives the
//! interactive client a 1-of-N share, while tagging the whole flood
//! with one `tenant` label collapses it to a single fair-queue lane
//! and the interactive client's latency stops scaling with flood
//! width. Artifact-free: the model head is a fake behind the
//! [`LineService`] seam, so the numbers isolate the serving plane.
//! Results go to `BENCH_admission.json` at the repo root.

use mlir_cost::benchkit;
use mlir_cost::coordinator::offload::LineService;
use mlir_cost::coordinator::server::{serve_loops, ServerConfig, Stop};
use mlir_cost::coordinator::stats::ServiceStats;
use mlir_cost::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

fn repo_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().to_path_buf()
}

/// Per-job service time of the fake model head. Long enough that queue
/// position, not syscall noise, dominates the interactive latency.
const JOB_MS: u64 = 3;

/// Fake model head: every line is a would-block job taking [`JOB_MS`].
struct SlowHead {
    stats: ServiceStats,
}

impl LineService for SlowHead {
    fn stats(&self) -> &ServiceStats {
        &self.stats
    }

    fn would_block(&self, _line: &str) -> bool {
        true
    }

    fn handle(&self, line: &str) -> Json {
        std::thread::sleep(std::time::Duration::from_millis(JOB_MS));
        let id = mlir_cost::json::parse(line)
            .ok()
            .and_then(|r| r.get("id").cloned())
            .unwrap_or(Json::Null);
        Json::obj().with("id", id).with("ok", Json::Bool(true))
    }
}

/// One request/response over a raw socket.
fn roundtrip(conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &Json) {
    conn.write_all(format!("{req}\n").as_bytes()).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\": true") || line.contains("\"ok\":true"), "rejected: {line}");
}

fn request(id: usize, tenant: Option<&str>) -> Json {
    let mut req = Json::obj().with("id", Json::num(id as f64));
    if let Some(t) = tenant {
        req = req.with("tenant", Json::str(t));
    }
    req
}

/// One sweep cell: `flood_conns` ping-pong flooders + 1 interactive
/// connection through a 1-loop, 1-worker server. `tagged` = the flood
/// shares one `tenant` label and the interactive client another (the
/// fair pool aggregates the flood); untagged = per-connection queue
/// keys (the flood holds `flood_conns` lanes). Returns the interactive
/// (p50 us, p95 us) over `interactive_n` queries plus the flood's
/// completed-query throughput while the interactive client ran.
fn run_cell(tagged: bool, flood_conns: usize, interactive_n: usize) -> (u64, u64, usize, f64) {
    let svc = Arc::new(SlowHead { stats: ServiceStats::default() });
    let config = ServerConfig {
        io_threads: 1,
        request_workers: 1,
        // Tenant labels only flow to the pool's fair queues when some
        // admission knob is on; an unreachable quota enables the
        // tagged plumbing without ever rejecting.
        quota: if tagged { 1e9 } else { 0.0 },
        ..Default::default()
    };
    let stop = Stop::new();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = {
        let (svc, stop) = (svc.clone(), stop.clone());
        std::thread::spawn(move || serve_loops(svc, vec![listener], stop, config))
    };

    let done = Arc::new(AtomicBool::new(false));
    let mut lats: Vec<u64> = Vec::with_capacity(interactive_n);
    let mut flood_total = 0usize;
    let t0 = Instant::now();
    std::thread::scope(|s| {
        let mut flooders = Vec::with_capacity(flood_conns);
        for _ in 0..flood_conns {
            let (addr, done) = (addr.clone(), done.clone());
            let tenant = tagged.then_some("flood");
            flooders.push(s.spawn(move || {
                let mut conn = TcpStream::connect(&addr).unwrap();
                let mut reader = BufReader::new(conn.try_clone().unwrap());
                let mut n = 0usize;
                // Ping-pong: per-connection order already caps each
                // connection at one in-flight job, so this keeps the
                // pool exactly `flood_conns` deep without overflowing
                // its bounded queue.
                while !done.load(Ordering::Relaxed) {
                    roundtrip(&mut conn, &mut reader, &request(n, tenant));
                    n += 1;
                }
                n
            }));
        }
        // Let the flood fill the pool before measuring.
        std::thread::sleep(std::time::Duration::from_millis(50));
        let mut conn = TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let tenant = tagged.then_some("ui");
        for i in 0..interactive_n {
            let q0 = Instant::now();
            roundtrip(&mut conn, &mut reader, &request(i, tenant));
            lats.push(q0.elapsed().as_micros() as u64);
        }
        done.store(true, Ordering::Relaxed);
        for f in flooders {
            flood_total += f.join().unwrap();
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    stop.trigger();
    let _ = server.join();
    assert_eq!(svc.stats.conservation_debt(), 0, "admission ledger out of balance");
    lats.sort_unstable();
    let pct = |p: f64| lats[((lats.len() as f64 * p) as usize).min(lats.len() - 1)];
    (pct(0.50), pct(0.95), flood_total, flood_total as f64 / dt.max(1e-9))
}

fn main() {
    benchkit::section("E11: admission & tenancy (flood vs interactive, fair pool)");
    let flood_conns = 16usize;
    let interactive_n = benchkit::clamp_iters(64);

    let mut scenarios: Vec<Json> = Vec::new();
    for (mode, tagged) in [("fifo_untenanted", false), ("fair_tenant_tagged", true)] {
        let (p50, p95, flood_q, flood_qps) = run_cell(tagged, flood_conns, interactive_n);
        benchkit::kv(
            &format!("{mode} @ {flood_conns} flood conns"),
            format!(
                "interactive p50 {p50} us, p95 {p95} us ({interactive_n} queries; \
                 flood {flood_q} done, {flood_qps:.0}/s)"
            ),
        );
        scenarios.push(
            Json::obj()
                .with("mode", Json::str(mode))
                .with("flood_connections", Json::num(flood_conns as f64))
                .with("request_workers", Json::num(1.0))
                .with("interactive_queries", Json::num(interactive_n as f64))
                .with("interactive_p50_us", Json::num(p50 as f64))
                .with("interactive_p95_us", Json::num(p95 as f64))
                .with("flood_queries", Json::num(flood_q as f64))
                .with("flood_queries_per_sec", Json::num(flood_qps)),
        );
    }

    let doc = Json::obj()
        .with("bench", Json::str("e11_admission"))
        .with(
            "note",
            Json::str(
                "Flood-vs-interactive sweep through one io loop and one request worker, \
                 artifact-free (fake 3 ms model head): 16 ping-pong flood connections \
                 saturate the offload pool while 1 interactive connection measures latency. \
                 fifo_untenanted keys the fair pool per connection (the flood holds 16 \
                 lanes); fair_tenant_tagged collapses the flood to one `tenant` lane. Run \
                 `cargo bench --bench e11_admission` from rust/ to overwrite with measured \
                 numbers.",
            ),
        )
        .with("job_ms", Json::num(JOB_MS as f64))
        .with("scenarios", Json::Arr(scenarios))
        .with(
            "acceptance",
            Json::str("fair_tenant_tagged interactive_p50_us < fifo_untenanted interactive_p50_us"),
        );
    let out = repo_root().join("BENCH_admission.json");
    match std::fs::write(&out, doc.to_string()) {
        Ok(()) => benchkit::kv("sweep recorded", out.display()),
        Err(e) => eprintln!("\ncould not write {out:?}: {e}"),
    }
}

//! E1 — regenerates the paper §4 accuracy table: RMSE (as % of target
//! range) for FC vs LSTM vs Conv1D on both targets, ops-only tokenization.
//!
//! Reads the metric JSONs produced by `make experiments` from `runs/e1/`;
//! any missing cell is trained here with a reduced budget so the table is
//! always complete (reduced cells are marked `*`).
//!
//! Paper claims to reproduce (shape, not absolutes): FC worst, LSTM
//! middle, Conv1D best; best-model RMSE in the 5-7%-of-range ballpark.

use mlir_cost::benchkit;
use mlir_cost::bundle::Bundle;
use mlir_cost::dataset::{Dataset, EncodedSet, TargetStats};
use mlir_cost::json;
use mlir_cost::runtime::{Manifest, Runtime};
use mlir_cost::sim::Target;
use mlir_cost::tokenizer::{Scheme, Vocab};
use mlir_cost::train::{metrics, TrainConfig, Trainer};
use std::path::Path;

fn repo_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().to_path_buf()
}


struct Cell {
    rmse_pct: f64,
    exact: f64,
    reduced: bool,
}

fn load_cell(path: &Path) -> Option<Cell> {
    let doc = json::parse(&std::fs::read_to_string(path).ok()?).ok()?;
    Some(Cell {
        rmse_pct: doc.req_f64("rmse_pct_of_range").ok()?,
        exact: doc.req_f64("pct_exact").ok()?,
        reduced: false,
    })
}

fn train_reduced(model: &str, target: Target) -> anyhow::Result<Cell> {
    let manifest = Manifest::load(&repo_root().join("artifacts"))?;
    let rt = Runtime::cpu()?;
    let ds = Dataset::generate(4242, 300, 0)?;
    let (train, test) = ds.split(3, 0.2);
    let scheme = Scheme::OpsOnly;
    let streams_tr = train.token_streams(scheme)?;
    let streams_te = test.token_streams(scheme)?;
    let vocab = Vocab::build(streams_tr.iter(), 1);
    let stats = TargetStats::for_dataset(&train, target);
    let max_len = manifest.model(model)?.max_len;
    let enc_tr = EncodedSet::build(&train, &streams_tr, &vocab, max_len, target, &stats);
    let enc_te = EncodedSet::build(&test, &streams_te, &vocab, max_len, target, &stats);
    let mut trainer = Trainer::new(&rt, &manifest, model)?;
    let steps = if model == "lstm_ops" { 60 } else { 120 };
    let cfg = TrainConfig { model: model.into(), steps, seed: 0, eval_every: 0, log_every: 0 };
    trainer.run(&cfg, &enc_tr, &enc_te)?;
    let preds: Vec<f64> =
        trainer.predict_set(&enc_te)?.iter().map(|p| stats.denormalize(p.first())).collect();
    let truth: Vec<f64> = test.samples.iter().map(|s| target.of(&s.labels)).collect();
    let _ = Bundle::untrained; // bundle type exercised elsewhere
    Ok(Cell {
        rmse_pct: metrics::rmse_pct(&preds, &truth, stats.range()),
        exact: metrics::pct_exact_rounded(&preds, &truth),
        reduced: true,
    })
}

fn main() {
    benchkit::section("E1: paper §4 accuracy table (ops-only tokenization)");
    println!(
        "{:<10} {:<14} {:>16} {:>12}",
        "model", "target", "RMSE (% range)", "exact %"
    );
    let mut cells: Vec<(String, Target, Cell)> = Vec::new();
    for model in ["fc_ops", "lstm_ops", "conv_ops"] {
        for target in [Target::RegPressure, Target::XpuUtil] {
            let short = model.trim_end_matches("_ops");
            let path = repo_root().join(format!("runs/e1/{short}_{}.json", target.name()));
            let cell = load_cell(&path).or_else(|| {
                eprintln!("[e1] {path:?} missing; training reduced-budget cell");
                train_reduced(model, target).ok()
            });
            if let Some(c) = cell {
                println!(
                    "{:<10} {:<14} {:>15.2}{} {:>11.1}%",
                    short,
                    target.name(),
                    c.rmse_pct,
                    if c.reduced { "*" } else { " " },
                    c.exact
                );
                cells.push((short.to_string(), target, c));
            } else {
                println!("{short:<10} {:<14} {:>16} {:>12}", target.name(), "FAILED", "-");
            }
        }
    }
    println!("(* = reduced in-bench budget; run `make experiments` for full cells)");

    // Shape checks vs the paper.
    for target in [Target::RegPressure, Target::XpuUtil] {
        let get = |m: &str| {
            cells
                .iter()
                .find(|(name, t, _)| name == m && *t == target)
                .map(|(_, _, c)| c.rmse_pct)
        };
        if let (Some(fc), Some(conv)) = (get("fc"), get("conv")) {
            benchkit::kv(
                &format!("paper-shape[{}]: Conv1D beats FC", target.name()),
                if conv <= fc { "OK" } else { "VIOLATED" },
            );
        }
        if let Some(conv) = get("conv") {
            benchkit::kv(
                &format!("paper-shape[{}]: best RMSE vs 5-7% band", target.name()),
                format!("{conv:.2}%"),
            );
        }
    }
}

//! E2 — regenerates Fig 6: operator+operand modelling.
//!
//! Paper claims (shape): (a) sequences ~4x longer than ops-only;
//! (b) training slower; (c) accuracy improves vs ops-only — ~75% of
//! register-pressure predictions exact; (d) unseen %argk/%k tokens are the
//! OOV hazard. (a) and (d) are measured directly here; (c) reads the
//! metric JSONs from `make experiments` (runs/e2/) next to the ops-only
//! baseline (runs/e1/conv_regpressure.json).

use mlir_cost::benchkit;
use mlir_cost::dataset::Dataset;
use mlir_cost::json;
use mlir_cost::tokenizer::{Scheme, Vocab, OOV_ID};

fn repo_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().to_path_buf()
}


fn main() {
    benchkit::section("E2 / Fig 6: ops+operands modelling");

    // (a) sequence-length ratio + (d) OOV from %k value tokens.
    let ds = Dataset::generate(777, 400, 0).expect("corpus");
    let (train, test) = ds.split(5, 0.25);
    let tr_ops = train.token_streams(Scheme::OpsOnly).unwrap();
    let tr_full = train.token_streams(Scheme::OpsOperands).unwrap();
    let te_full = test.token_streams(Scheme::OpsOperands).unwrap();
    let len_ops: usize = tr_ops.iter().map(Vec::len).sum();
    let len_full: usize = tr_full.iter().map(Vec::len).sum();
    let ratio = len_full as f64 / len_ops as f64;
    benchkit::kv("mean sequence-length ratio (paper: ~4x)", format!("{ratio:.2}x"));

    // One vocabulary pass produces both the OOV total and its %-value
    // split (paper: "Unseen %argk or %k cause bad vector mapping") — the
    // old shape did a count_oov sweep plus a second id_of sweep.
    let vocab_full = Vocab::build(tr_full.iter(), 1);
    let total: usize = te_full.iter().map(Vec::len).sum();
    let mut oov_value_tokens = 0usize;
    let mut oov_other = 0usize;
    for s in &te_full {
        for t in s {
            if vocab_full.id_of(t) == OOV_ID {
                if t.starts_with('%') {
                    oov_value_tokens += 1;
                } else {
                    oov_other += 1;
                }
            }
        }
    }
    let oov = oov_value_tokens + oov_other;
    benchkit::kv(
        "test OOV rate under ops+operands (Fig 6 hazard)",
        format!("{:.2}% ({oov}/{total})", 100.0 * oov as f64 / total as f64),
    );
    benchkit::kv(
        "OOV split: %value-tokens vs other",
        format!("{oov_value_tokens} vs {oov_other}"),
    );

    // (b)+(c): trained results from `make experiments`.
    let root = repo_root();
    let ops_only = root.join("runs/e1/conv_regpressure.json");
    let full = root.join("runs/e2/convfull_regpressure.json");
    match (
        std::fs::read_to_string(&ops_only).ok().and_then(|t| json::parse(&t).ok()),
        std::fs::read_to_string(&full).ok().and_then(|t| json::parse(&t).ok()),
    ) {
        (Some(a), Some(b)) => {
            let (ra, rb) = (
                a.req_f64("rmse_pct_of_range").unwrap_or(f64::NAN),
                b.req_f64("rmse_pct_of_range").unwrap_or(f64::NAN),
            );
            let (ea, eb) = (
                a.req_f64("pct_exact").unwrap_or(f64::NAN),
                b.req_f64("pct_exact").unwrap_or(f64::NAN),
            );
            let (sa, sb) = (
                a.req_f64("steps_per_sec").unwrap_or(f64::NAN),
                b.req_f64("steps_per_sec").unwrap_or(f64::NAN),
            );
            benchkit::kv("RMSE%: ops-only -> ops+operands", format!("{ra:.2}% -> {rb:.2}%"));
            benchkit::kv("exact%: ops-only -> ops+operands (paper: ~75%)", format!("{ea:.1}% -> {eb:.1}%"));
            benchkit::kv(
                "training speed (steps/s), ops-only vs full (paper: slower)",
                format!("{sa:.2} vs {sb:.2}"),
            );
            if let Ok(hist) = b.req_arr("abs_error_histogram") {
                let bars: Vec<String> = hist
                    .iter()
                    .enumerate()
                    .map(|(i, h)| format!("|e|={i}: {}", h.as_u64().unwrap_or(0)))
                    .collect();
                benchkit::kv("Fig 6 error histogram (rounded)", bars.join("  "));
            }
            benchkit::kv(
                "paper-shape: ops+operands more accurate",
                if rb <= ra { "OK" } else { "VIOLATED" },
            );
        }
        _ => {
            println!(
                "  trained E2 metrics not found ({ops_only:?}, {full:?});\n  \
                 run `make experiments` to fill in accuracy/speed rows"
            );
        }
    }
}

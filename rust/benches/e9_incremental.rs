//! E9 — incremental delta encoding: the session tier's text→ids path
//! (PR 7) against the full re-encode every query otherwise pays.
//!
//! Per scenario (one graph family × form, sizes from ~tens to
//! ~hundreds of lines), the same stream of 1-line edits runs through
//! two pipelines:
//!
//!   full   — parse → fused encode, the cold front end a session-less
//!            client pays for every probe
//!   delta  — line-diff against the base + span-table splice: only the
//!            edited line is re-lexed, everything else is a hash
//!            lookup (`coordinator::session` + `tokenizer::span`,
//!            exactly what `mlir_delta` runs)
//!
//! Every edit is unique (fresh line hash), so the delta path re-lexes
//! exactly one line per probe — the steady-state autotuner shape. The
//! one-time `session_open` cost (index + span warm-up) is measured
//! separately to show where amortization starts.
//!
//! Results print as a table and are recorded to
//! `BENCH_incremental.json` at the repo root. No model artifacts are
//! needed — this measures the front end only.

use mlir_cost::benchkit;
use mlir_cost::coordinator::session::{index_lines, reindex_lines};
use mlir_cost::graphgen::{generate, Family, GraphSpec};
use mlir_cost::json::Json;
use mlir_cost::lower::affine::lower_to_affine;
use mlir_cost::mlir::{parse_function, print_function};
use mlir_cost::tokenizer::span::{line_span, splice_ids, tail_span, IdSpan};
use mlir_cost::tokenizer::{encode_function, token_count, tokenize, OpIdTable, Scheme, Vocab};
use std::collections::HashMap;

fn repo_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().to_path_buf()
}

const MAX_LEN: usize = 512;
const WARMUP: usize = 2;
const ITERS: usize = 20;
/// Edits per timed iteration — one edit is microseconds, so each
/// sample aggregates a small burst for stable timing.
const EDITS_PER_ITER: usize = 8;

fn main() {
    benchkit::section("E9 / incremental delta encoding vs full re-encode");
    let scheme = Scheme::OpsOperands;

    // Scenario corpus: fc / conv / attention families, each in the
    // compact xpu form and (for the structured ones) the affine-lowered
    // loop-nest form — the "hundreds of lines" end of the size axis.
    let scenarios: Vec<(&str, String)> = {
        let gen = |family, i: u64| {
            generate(&GraphSpec { family, structure_seed: 7000 + i, shape_seed: 8000 + i })
                .expect("graphgen")
        };
        let mlp = gen(Family::Mlp, 0);
        let resnet = gen(Family::Resnet, 1);
        let bert = gen(Family::Bert, 2);
        vec![
            ("mlp/xpu", print_function(&mlp)),
            ("resnet/xpu", print_function(&resnet)),
            ("bert/xpu", print_function(&bert)),
            ("resnet/affine", print_function(&lower_to_affine(&resnet).expect("lower"))),
            ("bert/affine", print_function(&lower_to_affine(&bert).expect("lower"))),
        ]
    };

    let mut rows: Vec<Json> = Vec::new();
    let mut speedups: Vec<f64> = Vec::new();
    for (name, base) in &scenarios {
        let func = parse_function(base).expect("parse base");
        let streams = vec![tokenize(&func, scheme)];
        let vocab = Vocab::build(streams.iter(), 1);
        let ops = OpIdTable::build(&vocab);
        let tail = tail_span(&vocab);
        let n_lines = base.lines().count();
        let n_tokens = token_count(&func, scheme);
        benchkit::section(&format!("scenario {name}: {n_lines} lines, {n_tokens} tokens"));

        // Pre-built pool of 1-line edits (comment-append keeps every
        // variant parseable); unique suffixes give every edit a fresh
        // line hash, so nothing is accidentally warm across probes.
        let pool: Vec<String> = (0..(WARMUP + ITERS) * EDITS_PER_ITER)
            .map(|j| {
                let at = (j * 7 + 3) % n_lines;
                base.lines()
                    .enumerate()
                    .map(|(i, l)| if i == at { format!("{l} // tune {j}") } else { l.to_string() })
                    .collect::<Vec<_>>()
                    .join("\n")
            })
            .collect();

        // One-time session_open work: index the base + warm the spans.
        let s_open = benchkit::bench("session_open (index + span warm-up)", WARMUP, ITERS, || {
            let lines = index_lines(base, scheme).expect("index base");
            let mut table: HashMap<u64, IdSpan> = HashMap::with_capacity(lines.len());
            for l in &lines {
                table
                    .entry(l.hash)
                    .or_insert_with(|| line_span(&l.text, scheme, &vocab, &ops).expect("span"));
            }
            std::hint::black_box(table.len());
        });
        println!("{}", s_open.row());

        // Full re-encode: what every probe costs without a session.
        let mut kf = 0usize;
        let s_full = benchkit::bench("full re-encode (parse + encode)", WARMUP, ITERS, || {
            for _ in 0..EDITS_PER_ITER {
                let text = &pool[kf % pool.len()];
                kf += 1;
                let f = parse_function(text).expect("parse edit");
                let (ids, _oov) = encode_function(&f, scheme, &vocab, &ops, MAX_LEN);
                std::hint::black_box(ids);
            }
        });
        println!("{}", s_full.row());

        // Delta splice: diff against the base, splice cached spans,
        // re-lex only the edited line — the serving path's
        // `encode_query`, minus the sharded table.
        let base_lines = index_lines(base, scheme).expect("index base");
        let mut table: HashMap<u64, IdSpan> = HashMap::with_capacity(base_lines.len());
        for l in &base_lines {
            table
                .entry(l.hash)
                .or_insert_with(|| line_span(&l.text, scheme, &vocab, &ops).expect("span"));
        }
        let mut kd = 0usize;
        let mut relexed = 0usize;
        let s_delta = benchkit::bench("delta splice (1-line re-lex)", WARMUP, ITERS, || {
            for _ in 0..EDITS_PER_ITER {
                let text = &pool[kd];
                kd += 1;
                let (new_lines, _changed) =
                    reindex_lines(&base_lines, text, scheme).expect("reindex");
                let mut spans: Vec<IdSpan> = Vec::with_capacity(new_lines.len());
                for l in &new_lines {
                    match table.get(&l.hash) {
                        Some(s) => spans.push(s.clone()),
                        None => {
                            relexed += 1;
                            let s = line_span(&l.text, scheme, &vocab, &ops).expect("span");
                            table.insert(l.hash, s.clone());
                            spans.push(s);
                        }
                    }
                }
                let (ids, _oov) = splice_ids(spans.iter().chain(std::iter::once(&tail)), MAX_LEN);
                std::hint::black_box(ids);
            }
        });
        println!("{}", s_delta.row());
        assert_eq!(
            relexed,
            (WARMUP + ITERS) * EDITS_PER_ITER,
            "every probe must re-lex exactly its one edited line"
        );

        let full_us = s_full.mean_us / EDITS_PER_ITER as f64;
        let delta_us = s_delta.mean_us / EDITS_PER_ITER as f64;
        let speedup = full_us / delta_us;
        speedups.push(speedup);
        benchkit::kv(
            "per-edit",
            format!("full {full_us:.1} us, delta {delta_us:.1} us ({speedup:.2}x)"),
        );
        rows.push(
            Json::obj()
                .with("scenario", Json::str(*name))
                .with("lines", Json::num(n_lines as f64))
                .with("tokens", Json::num(n_tokens as f64))
                .with("open_us", Json::num(s_open.mean_us))
                .with("full_us_per_edit", Json::num(full_us))
                .with("delta_us_per_edit", Json::num(delta_us))
                .with("delta_speedup", Json::num(speedup)),
        );
    }

    let min = speedups.iter().copied().fold(f64::INFINITY, f64::min);
    let max = speedups.iter().copied().fold(0.0f64, f64::max);
    benchkit::section("E9 summary");
    benchkit::kv("delta speedup range", format!("{min:.2}x .. {max:.2}x"));
    benchkit::kv(
        "speedup >1x on every scenario (acceptance)",
        if min > 1.0 { "OK" } else { "VIOLATED" },
    );

    let doc = Json::obj()
        .with("bench", Json::str("e9_incremental"))
        .with(
            "note",
            Json::str(
                "1-line edits per scenario: full parse+encode vs session-tier delta \
                 splice (re-lex only the edited line). Run `cargo bench --bench \
                 e9_incremental` from rust/ to refresh.",
            ),
        )
        .with("scheme", Json::str(scheme.name()))
        .with("max_len", Json::num(MAX_LEN as f64))
        .with("edits_per_iter", Json::num(EDITS_PER_ITER as f64))
        .with("scenarios", Json::Arr(rows))
        .with("delta_speedup_min", Json::num(min))
        .with("delta_speedup_max", Json::num(max));
    let out = repo_root().join("BENCH_incremental.json");
    match std::fs::write(&out, doc.to_string()) {
        Ok(()) => println!("\nrecorded {out:?}"),
        Err(e) => eprintln!("\ncould not write {out:?}: {e}"),
    }
}

//! E3 — serving-path benchmark (DESIGN.md E5): latency/throughput of the
//! coordinator a DL-compiler queries, comparing batching policies, the
//! prediction cache, the single-flight duplicate-heavy path, the
//! `predict_many` batch API, and (E3d) the thread-per-connection
//! baseline vs the epoll event loop across connection counts — the
//! sweep's numbers are recorded to `BENCH_serving.json` at the repo
//! root.

use mlir_cost::benchkit;
use mlir_cost::bundle::Bundle;
use mlir_cost::coordinator::{batcher::BatchPolicy, server, Service};
use mlir_cost::dataset::TargetStats;
use mlir_cost::graphgen::{generate, Family, GraphSpec};
use mlir_cost::json::Json;
use mlir_cost::mlir::print_function;
use mlir_cost::runtime::Manifest;
use mlir_cost::sim::Target;
use mlir_cost::tokenizer::{token_count, Scheme, Vocab};
use std::net::TcpListener;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn repo_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().to_path_buf()
}

fn make_service(max_batch: usize, max_wait_us: u64) -> Arc<Service> {
    let manifest = Arc::new(Manifest::load(&repo_root().join("artifacts")).expect("artifacts built"));
    let vocab = Vocab::build(vec![vec!["x".to_string()]].iter(), 1);
    let stats = TargetStats { mean: 20.0, std: 8.0, min: 2.0, max: 70.0 };
    let bundle = Bundle::untrained(
        &manifest,
        "conv_ops",
        Target::RegPressure,
        Scheme::OpsOnly,
        vocab,
        stats,
    )
    .unwrap();
    Arc::new(
        Service::start(
            manifest,
            vec![bundle],
            BatchPolicy { max_batch, max_wait: Duration::from_micros(max_wait_us) },
            true,
        )
        .unwrap(),
    )
}

/// The served bundle's ops-only `max_len` (conv_ops in the artifact
/// manifest). The router rejects over-long queries cleanly instead of
/// truncating them, so every corpus text must fit.
const SERVE_MAX_LEN: usize = 128;

/// `n` distinct graphs with seeds offset by `base` so scenarios never
/// share cache keys; seeds whose graph exceeds [`SERVE_MAX_LEN`]
/// ops-only tokens are skipped (the Random family can run long).
fn corpus_at(n: usize, base: u64) -> Vec<String> {
    let mut texts = Vec::with_capacity(n);
    let mut attempt = 0u64;
    while texts.len() < n {
        let spec = GraphSpec {
            family: Family::ALL[(attempt % 7) as usize],
            structure_seed: base + attempt,
            shape_seed: base + 1000 + attempt,
        };
        attempt += 1;
        let f = generate(&spec).unwrap();
        if token_count(&f, Scheme::OpsOnly) <= SERVE_MAX_LEN {
            texts.push(print_function(&f));
        }
    }
    texts
}

fn throughput(svc: &Arc<Service>, texts: &[String], threads: usize) -> (f64, f64) {
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for chunk in texts.chunks(texts.len().div_ceil(threads)) {
            let svc = svc.clone();
            s.spawn(move || {
                for t in chunk {
                    svc.predict(Target::RegPressure, t).unwrap();
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    (texts.len() as f64 / dt, dt)
}

fn main() {
    benchkit::section("E3: serving coordinator (compiler query path)");
    let texts = corpus_at(192, 0);

    // Single-query latency (no batching benefit, cold-ish cache).
    let svc1 = make_service(1, 100);
    let mut idx = 0usize;
    let lat = benchkit::bench("predict latency (b=1, cold-ish cache)", 3, 40, || {
        let t = &texts[idx % texts.len()];
        idx += 1;
        let _ = svc1.predict(Target::RegPressure, t).unwrap();
    });
    println!("{}", lat.row());
    std::mem::forget(svc1);

    // Batched throughput under concurrency; capture the per-query unique
    // baseline for the later comparisons.
    let mut unique_qps = 0.0;
    for (max_batch, wait_us) in [(1usize, 100u64), (8, 2000), (32, 2000)] {
        let svc = make_service(max_batch, wait_us);
        let (qps, dt) = throughput(&svc, &texts, 8);
        benchkit::kv(
            &format!("throughput max_batch={max_batch} wait={wait_us}us (8 client threads)"),
            format!(
                "{qps:.0} pred/s ({dt:.2}s, mean batch {:.1}, fill {:.2})",
                svc.stats.mean_batch_size(),
                svc.stats.batch_fill_ratio()
            ),
        );
        if max_batch == 32 {
            unique_qps = qps;
        }
        // Leak the service: tearing down a PJRT client while the next
        // policy's client spins up can wedge xla_extension 0.5.1 on this
        // single-core image; the process exits right after anyway.
        std::mem::forget(svc);
    }

    // Cache effect: re-query the same 192 graphs.
    let svc = make_service(32, 2000);
    let (cold_qps, _) = throughput(&svc, &texts, 8);
    let (warm_qps, _) = throughput(&svc, &texts, 8);
    let (hits, misses) = svc.cache.stats();
    benchkit::kv("cold pass", format!("{cold_qps:.0} pred/s"));
    benchkit::kv(
        "warm pass (prediction cache)",
        format!("{warm_qps:.0} pred/s ({hits} hits / {misses} misses)"),
    );
    std::mem::forget(svc);

    // Duplicate-heavy concurrent workload: every thread walks the SAME
    // small set of fresh graphs in the same order, released together —
    // the autotuning-probe shape from the paper, where near-identical
    // candidates are re-evaluated by the thousands. Concurrent identical
    // misses must coalesce onto one model invocation (single-flight), and
    // repeats come out of the sharded cache.
    benchkit::section("E3b: duplicate-heavy workload (single-flight + sharded cache)");
    let dup_texts = corpus_at(16, 50_000);
    let svc = make_service(32, 2000);
    let (dup_qps, dup_dt) = benchkit::concurrent_throughput(8, 48, |_t, i| {
        let text = &dup_texts[i % dup_texts.len()];
        svc.predict(Target::RegPressure, text).unwrap();
    });
    let coalesced = svc.cache.coalesced();
    let contended = svc.cache.contended();
    benchkit::kv(
        "duplicate-heavy (8 threads x 48 over 16 graphs)",
        format!("{dup_qps:.0} pred/s ({dup_dt:.2}s)"),
    );
    benchkit::kv(
        "single-flight",
        format!("{coalesced} coalesced queries, {contended} contended shard locks"),
    );
    benchkit::kv(
        "vs per-query unique path",
        format!("{dup_qps:.0} vs {unique_qps:.0} pred/s ({:.1}x)", dup_qps / unique_qps.max(1e-9)),
    );
    assert!(
        coalesced > 0,
        "duplicate-heavy concurrency must exercise single-flight coalescing"
    );
    assert!(
        dup_qps > unique_qps,
        "duplicate-heavy workload should beat the per-query unique path"
    );
    std::mem::forget(svc);

    // Batch API: the whole compiler probe set travels in predict_many
    // calls — all misses enter the batch queue in one shot instead of one
    // submit (and one potential wakeup) per query.
    benchkit::section("E3c: batch API (predict_many)");
    let batch_texts = corpus_at(192, 70_000);
    let svc = make_service(32, 2000);
    let t0 = Instant::now();
    let mut ok = 0usize;
    for chunk in batch_texts.chunks(32) {
        let refs: Vec<&str> = chunk.iter().map(String::as_str).collect();
        ok += svc
            .predict_many(Target::RegPressure, &refs)
            .iter()
            .filter(|r| r.is_ok())
            .count();
    }
    let dt = t0.elapsed().as_secs_f64();
    let batch_qps = batch_texts.len() as f64 / dt.max(1e-9);
    benchkit::kv(
        "predict_many (192 queries in 6 calls of 32)",
        format!("{batch_qps:.0} pred/s ({dt:.2}s, {ok}/192 ok)"),
    );
    benchkit::kv(
        "batch packing",
        format!(
            "fill {:.2}, {} padded slots, mean batch {:.1}",
            svc.stats.batch_fill_ratio(),
            svc.stats.padded_slots.load(std::sync::atomic::Ordering::Relaxed),
            svc.stats.mean_batch_size()
        ),
    );
    benchkit::kv(
        "vs per-query unique path",
        format!(
            "{batch_qps:.0} vs {unique_qps:.0} pred/s ({:.1}x)",
            batch_qps / unique_qps.max(1e-9)
        ),
    );
    std::mem::forget(svc);

    benchkit::kv(
        "paper-shape: batching + dedup help concurrent compiler queries",
        "see throughput rows above",
    );

    // Connection-count sweep: the same duplicate-heavy probe mix pushed
    // through the legacy thread-per-connection front end and the epoll
    // event loop, at 4 / 64 / 256 concurrent clients. At the high end
    // the baseline pays one OS thread + a 200 ms-timeout wakeup cycle
    // per connection; the event loop holds all of them in one thread.
    benchkit::section("E3d: connection sweep (thread-per-conn vs event loop)");
    let sweep_texts = corpus_at(16, 90_000);
    let svc = make_service(32, 2000);
    // Warm the prediction cache so the sweep measures the serving plane
    // itself, not first-touch model latency.
    for t in &sweep_texts {
        svc.predict(Target::RegPressure, t).unwrap();
    }
    let mut scenarios: Vec<Json> = Vec::new();
    for conns in [4usize, 64, 256] {
        for frontend in ["thread_per_conn", "event_loop"] {
            let (qps, p50, p99, total) = sweep_frontend(&svc, frontend, conns, &sweep_texts);
            benchkit::kv(
                &format!("{frontend} @ {conns} conns"),
                format!("{qps:.0} pred/s (p50 {p50} us, p99 {p99} us, {total} queries)"),
            );
            scenarios.push(
                Json::obj()
                    .with("frontend", Json::str(frontend))
                    .with("connections", Json::num(conns as f64))
                    .with("queries", Json::num(total as f64))
                    .with("queries_per_sec", Json::num(qps))
                    .with("p50_us", Json::num(p50 as f64))
                    .with("p99_us", Json::num(p99 as f64)),
            );
        }
    }
    // Offload pool: a miss-heavy mix (fresh graphs, cold cache) through
    // ONE io loop, with model execution in-loop (--request-workers 0)
    // vs handed to the request-worker pool. Same event loop, same
    // handle_line path either way; the offloaded cell keeps the io
    // thread parsing/flushing its other connections while pool workers
    // wait on the model.
    benchkit::section("E3e: miss-heavy in-loop vs offloaded (--request-workers)");
    let mut offload_scenarios: Vec<Json> = Vec::new();
    for (mode, request_workers, seed_base) in
        [("in_loop", 0usize, 110_000u64), ("offloaded", 2, 130_000)]
    {
        // A fresh corpus per cell: both cells pay the same cold-cache
        // miss work instead of the second riding the first's cache.
        let miss_texts = corpus_at(32, seed_base);
        let svc = make_service(32, 2000);
        let (qps, p50, p99, total) = sweep_offload(&svc, request_workers, 16, &miss_texts);
        let offloaded =
            svc.stats.offloaded_misses.load(std::sync::atomic::Ordering::Relaxed);
        benchkit::kv(
            &format!("{mode} (request_workers={request_workers}) @ 16 conns"),
            format!(
                "{qps:.0} pred/s (p50 {p50} us, p99 {p99} us, {total} queries, \
                 {offloaded} offloaded)"
            ),
        );
        offload_scenarios.push(
            Json::obj()
                .with("mode", Json::str(mode))
                .with("request_workers", Json::num(request_workers as f64))
                .with("connections", Json::num(16.0))
                .with("queries", Json::num(total as f64))
                .with("queries_per_sec", Json::num(qps))
                .with("p50_us", Json::num(p50 as f64))
                .with("p99_us", Json::num(p99 as f64))
                .with("offloaded_misses", Json::num(offloaded as f64)),
        );
        std::mem::forget(svc);
    }

    let doc = Json::obj()
        .with("bench", Json::str("e3_serving"))
        .with(
            "note",
            Json::str(
                "Connection-count sweep: duplicate-heavy probe mix (16 distinct graphs, warm \
                 cache) through the legacy thread-per-connection front end vs the epoll event \
                 loop (--io-threads 1). The E3e offload_scenarios push a miss-heavy mix (32 \
                 fresh graphs, cold cache, 16 connections) through one io loop with model \
                 execution in-loop (request_workers 0) vs handed to the request-worker pool. \
                 Run `cargo bench --bench e3_serving` from rust/ to overwrite with measured \
                 numbers.",
            ),
        )
        .with("duplicate_corpus_texts", Json::num(sweep_texts.len() as f64))
        .with("io_threads", Json::num(1.0))
        .with("scenarios", Json::Arr(scenarios))
        .with("offload_scenarios", Json::Arr(offload_scenarios))
        .with(
            "acceptance",
            Json::str("event_loop queries_per_sec >= thread_per_conn at 256 connections"),
        );
    let out = repo_root().join("BENCH_serving.json");
    match std::fs::write(&out, doc.to_string()) {
        Ok(()) => benchkit::kv("sweep recorded", out.display()),
        Err(e) => eprintln!("\ncould not write {out:?}: {e}"),
    }
    std::mem::forget(svc);
}

/// Run one sweep cell: `conns` concurrent clients, each issuing its
/// share of a fixed total query budget over the duplicate-heavy corpus.
/// Returns (queries/sec, p50 us, p99 us, total queries).
fn sweep_frontend(
    svc: &Arc<Service>,
    frontend: &str,
    conns: usize,
    texts: &[String],
) -> (f64, u64, u64, usize) {
    let stop = server::Stop::new();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server_thread = {
        let svc = svc.clone();
        let stop = stop.clone();
        let event_loop = frontend == "event_loop";
        std::thread::spawn(move || {
            let res = if event_loop {
                server::serve_on(svc, listener, stop)
            } else {
                server::serve_on_threaded(svc, listener, stop)
            };
            if let Err(e) = res {
                eprintln!("[bench] server exited with error: {e:#}");
            }
        })
    };
    // Fixed total work so cells are comparable across connection counts.
    let per_conn = benchkit::clamp_iters((2048 / conns).max(4));
    let out = drive_clients(&addr, conns, per_conn, texts);
    stop.trigger();
    let _ = server_thread.join();
    out
}

/// One offload cell: `conns` concurrent clients through the event loop
/// with `request_workers` pool workers (0 = in-loop execution). Returns
/// (queries/sec, p50 us, p99 us, total queries).
fn sweep_offload(
    svc: &Arc<Service>,
    request_workers: usize,
    conns: usize,
    texts: &[String],
) -> (f64, u64, u64, usize) {
    let stop = server::Stop::new();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server_thread = {
        let svc = svc.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let config = server::ServerConfig {
                io_threads: 1,
                request_workers,
                ..Default::default()
            };
            if let Err(e) = server::serve_on_with(svc, listener, stop, config) {
                eprintln!("[bench] server exited with error: {e:#}");
            }
        })
    };
    let per_conn = benchkit::clamp_iters((512 / conns).max(4));
    let out = drive_clients(&addr, conns, per_conn, texts);
    stop.trigger();
    let _ = server_thread.join();
    out
}

/// Drive `conns` concurrent clients, `per_conn` queries each, against a
/// running front end; returns (queries/sec, p50 us, p99 us, total).
fn drive_clients(
    addr: &str,
    conns: usize,
    per_conn: usize,
    texts: &[String],
) -> (f64, u64, u64, usize) {
    let mut latencies: Vec<u64> = Vec::with_capacity(conns * per_conn);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(conns);
        for c in 0..conns {
            handles.push(s.spawn(move || {
                let mut client = server::Client::connect(addr).unwrap();
                let mut lats = Vec::with_capacity(per_conn);
                for i in 0..per_conn {
                    let text = &texts[(c + i) % texts.len()];
                    let q0 = Instant::now();
                    client.predict(Target::RegPressure, text).unwrap();
                    lats.push(q0.elapsed().as_micros() as u64);
                }
                lats
            }));
        }
        for h in handles {
            latencies.extend(h.join().unwrap());
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    latencies.sort_unstable();
    let pct = |p: f64| latencies[((latencies.len() as f64 * p) as usize).min(latencies.len() - 1)];
    (latencies.len() as f64 / dt.max(1e-9), pct(0.50), pct(0.99), latencies.len())
}

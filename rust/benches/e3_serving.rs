//! E3 — serving-path benchmark (DESIGN.md E5): latency/throughput of the
//! coordinator a DL-compiler queries, comparing batching policies and the
//! effect of the prediction cache.

use mlir_cost::benchkit;
use mlir_cost::bundle::Bundle;
use mlir_cost::coordinator::{batcher::BatchPolicy, Service};
use mlir_cost::dataset::TargetStats;
use mlir_cost::graphgen::{generate, Family, GraphSpec};
use mlir_cost::mlir::print_function;
use mlir_cost::runtime::Manifest;
use mlir_cost::sim::Target;
use mlir_cost::tokenizer::{Scheme, Vocab};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn repo_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().to_path_buf()
}


fn make_service(max_batch: usize, max_wait_us: u64) -> Arc<Service> {
    let manifest = Arc::new(Manifest::load(&repo_root().join("artifacts")).expect("artifacts built"));
    let vocab = Vocab::build(vec![vec!["x".to_string()]].iter(), 1);
    let stats = TargetStats { mean: 20.0, std: 8.0, min: 2.0, max: 70.0 };
    let bundle = Bundle::untrained(
        &manifest,
        "conv_ops",
        Target::RegPressure,
        Scheme::OpsOnly,
        vocab,
        stats,
    )
    .unwrap();
    Arc::new(
        Service::start(
            manifest,
            vec![bundle],
            BatchPolicy { max_batch, max_wait: Duration::from_micros(max_wait_us) },
            true,
        )
        .unwrap(),
    )
}

fn corpus(n: usize) -> Vec<String> {
    (0..n)
        .map(|i| {
            let spec = GraphSpec {
                family: Family::ALL[i % 7],
                structure_seed: i as u64,
                shape_seed: 9000 + i as u64,
            };
            print_function(&generate(&spec).unwrap())
        })
        .collect()
}

fn throughput(svc: &Arc<Service>, texts: &[String], threads: usize) -> (f64, f64) {
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for chunk in texts.chunks(texts.len().div_ceil(threads)) {
            let svc = svc.clone();
            s.spawn(move || {
                for t in chunk {
                    svc.predict(Target::RegPressure, t).unwrap();
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    (texts.len() as f64 / dt, dt)
}

fn main() {
    benchkit::section("E3: serving coordinator (compiler query path)");
    let texts = corpus(192);

    // Single-query latency (no batching benefit, cold cache).
    let svc1 = make_service(1, 100);
    let mut idx = 0usize;
    let lat = benchkit::bench("predict latency (b=1, cold-ish cache)", 3, 40, || {
        let t = &texts[idx % texts.len()];
        idx += 1;
        let _ = svc1.predict(Target::RegPressure, t).unwrap();
    });
    println!("{}", lat.row());
    std::mem::forget(svc1);

    // Batched throughput under concurrency.
    for (max_batch, wait_us) in [(1usize, 100u64), (8, 2000), (32, 2000)] {
        let svc = make_service(max_batch, wait_us);
        let (qps, dt) = throughput(&svc, &texts, 8);
        benchkit::kv(
            &format!("throughput max_batch={max_batch} wait={wait_us}us (8 client threads)"),
            format!("{qps:.0} pred/s ({dt:.2}s, mean batch {:.1})", svc.stats.mean_batch_size()),
        );
        // Leak the service: tearing down a PJRT client while the next
        // policy's client spins up can wedge xla_extension 0.5.1 on this
        // single-core image; the process exits right after anyway.
        std::mem::forget(svc);
    }

    // Cache effect: re-query the same 192 graphs.
    let svc = make_service(32, 2000);
    let (cold_qps, _) = throughput(&svc, &texts, 8);
    let (warm_qps, _) = throughput(&svc, &texts, 8);
    let (hits, misses) = svc.cache.stats();
    benchkit::kv("cold pass", format!("{cold_qps:.0} pred/s"));
    benchkit::kv(
        "warm pass (prediction cache)",
        format!("{warm_qps:.0} pred/s ({hits} hits / {misses} misses)"),
    );
    std::mem::forget(svc);
    benchkit::kv(
        "paper-shape: batching helps concurrent compiler queries",
        "see throughput rows above",
    );
}

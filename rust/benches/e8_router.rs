//! E8 — routing tier: what multi-variant serving buys on mixed-length
//! traffic, and what the router itself costs.
//!
//! Two services over the same corpus (short probes that fit a
//! `max_len=128` model + long probes that need `max_len=512`):
//!
//!   single  — one conv_full (max_len 512) variant serves everything:
//!             every short probe pays the big model
//!   routed  — fc_ops + lstm_ops (128) and conv_full (512) behind the
//!             router: short probes pay the small FC model, long probes
//!             the conv stack, by token length
//!
//! Phases per service: cold sweep (every query is a model invocation),
//! then a warm duplicate-heavy sweep (memo + cache hits — measures the
//! router's per-query overhead: one length-memo probe + one choose()).
//! Results (qps, per-variant routing shares) print as a table and are
//! recorded to `BENCH_router.json` at the repo root. Artifact-gated:
//! without `artifacts/` a placeholder is kept.

use mlir_cost::benchkit;
use mlir_cost::bundle::Bundle;
use mlir_cost::coordinator::batcher::BatchPolicy;
use mlir_cost::coordinator::router::VariantSpec;
use mlir_cost::coordinator::{ServeOptions, Service};
use mlir_cost::dataset::TargetStats;
use mlir_cost::json::Json;
use mlir_cost::mlir::{print_function, Attrs, DType, FuncBuilder, Type, XpuOp};
use mlir_cost::runtime::Manifest;
use mlir_cost::sim::Target;
use mlir_cost::tokenizer::{Scheme, Vocab};
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().to_path_buf()
}

fn bundle(manifest: &Manifest, model: &str) -> Bundle {
    let vocab = Vocab::build(vec![vec!["xpu.relu".to_string()]].iter(), 1);
    let stats = TargetStats { mean: 20.0, std: 5.0, min: 4.0, max: 60.0 };
    Bundle::untrained(manifest, model, Target::RegPressure, Scheme::OpsOnly, vocab, stats)
        .expect("bundle")
}

/// Relu chain: `n_ops + 5` ops-only tokens; `tag` splits cache keys.
fn chain_text(n_ops: usize, tag: i64) -> String {
    let mut b = FuncBuilder::new("chain");
    let mut v = b.arg(Type::tensor(vec![2 + tag, 8], DType::F32));
    for _ in 0..n_ops {
        v = b.xpu(XpuOp::Relu, &[v], Attrs::new()).unwrap();
    }
    print_function(&b.ret(&[v]).unwrap())
}

/// 3 short probes for every long one — the autotuning mix routing is
/// built for. 32 distinct texts, repeated `dup` times each.
fn corpus(dup: usize) -> Vec<String> {
    let mut texts = Vec::new();
    for i in 0..32i64 {
        let n_ops = if i % 4 == 3 { 150 + i as usize } else { 10 + i as usize };
        texts.push(chain_text(n_ops, i));
    }
    let distinct = texts.clone();
    for _ in 1..dup {
        texts.extend(distinct.iter().cloned());
    }
    texts
}

fn sweep(svc: &Arc<Service>, texts: &[String], label: &str) -> f64 {
    let refs: Vec<&str> = texts.iter().map(String::as_str).collect();
    let t0 = std::time::Instant::now();
    let out = svc.predict_many(Target::RegPressure, &refs);
    let dt = t0.elapsed().as_secs_f64();
    let ok = out.iter().filter(|r| r.is_ok()).count();
    assert_eq!(ok, texts.len(), "{label}: {ok}/{} queries failed", texts.len());
    texts.len() as f64 / dt
}

fn main() {
    benchkit::section("E8 / routing tier: single variant vs routed family");
    let adir = repo_root().join("artifacts");
    if !adir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (placeholder BENCH_router.json kept)");
        return;
    }
    let manifest = Arc::new(Manifest::load(&adir).expect("manifest"));
    let policy = BatchPolicy::default();

    let single = Arc::new(
        Service::start_variants(
            manifest.clone(),
            vec![VariantSpec { name: "conv_full".into(), bundle: bundle(&manifest, "conv_full") }],
            policy.clone(),
            ServeOptions::default(),
        )
        .expect("single-variant service"),
    );
    let routed = Arc::new(
        Service::start_variants(
            manifest.clone(),
            vec![
                VariantSpec { name: "fc_ops".into(), bundle: bundle(&manifest, "fc_ops") },
                VariantSpec { name: "lstm_ops".into(), bundle: bundle(&manifest, "lstm_ops") },
                VariantSpec { name: "conv_full".into(), bundle: bundle(&manifest, "conv_full") },
            ],
            policy,
            ServeOptions::default(),
        )
        .expect("routed service"),
    );

    let cold = corpus(1);
    let warm = corpus(8);
    benchkit::kv("corpus", format!("{} distinct texts, warm sweep {}", cold.len(), warm.len()));

    let single_cold = sweep(&single, &cold, "single/cold");
    let single_warm = sweep(&single, &warm, "single/warm");
    let routed_cold = sweep(&routed, &cold, "routed/cold");
    let routed_warm = sweep(&routed, &warm, "routed/warm");

    benchkit::kv("single-variant cold", format!("{single_cold:.0} q/s"));
    benchkit::kv("routed cold", format!("{routed_cold:.0} q/s ({:.2}x)", routed_cold / single_cold));
    benchkit::kv("single-variant warm", format!("{single_warm:.0} q/s"));
    benchkit::kv("routed warm", format!("{routed_warm:.0} q/s ({:.2}x)", routed_warm / single_warm));

    let j = routed.stats_json();
    let shares = j.get("routed_by_variant").expect("routed_by_variant").clone();
    benchkit::kv("routed_by_variant", shares.to_string());

    let doc = Json::obj()
        .with("bench", Json::str("e8_router"))
        .with("corpus_distinct", Json::num(cold.len() as f64))
        .with("corpus_warm", Json::num(warm.len() as f64))
        .with(
            "single_variant",
            Json::obj()
                .with("cold_qps", Json::num(single_cold))
                .with("warm_qps", Json::num(single_warm)),
        )
        .with(
            "routed",
            Json::obj()
                .with("cold_qps", Json::num(routed_cold))
                .with("warm_qps", Json::num(routed_warm))
                .with("routed_by_variant", shares),
        )
        .with("cold_speedup_routed_vs_single", Json::num(routed_cold / single_cold))
        .with("warm_speedup_routed_vs_single", Json::num(routed_warm / single_warm));
    let out = repo_root().join("BENCH_router.json");
    match std::fs::write(&out, doc.to_string()) {
        Ok(()) => println!("\nrecorded {out:?}"),
        Err(e) => eprintln!("\ncould not write {out:?}: {e}"),
    }
}

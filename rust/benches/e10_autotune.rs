//! E10 — the autotune loop closed end to end: cost-model-guided
//! schedule search (greedy vs beam) with a measured regret oracle.
//!
//! Per scenario (graph family × probe backend × probe mode × algorithm)
//! the staged search runs over a small schedule space (unroll × MXU
//! tile × per-group fusion when the graph exposes few enough bits to
//! score exhaustively), then the sim oracle exhaustively scores the
//! SAME space and reports **measured regret** — sim-measured cost of
//! the model-chosen schedule over the true optimum — plus throughput
//! (probes/sec) and speedup found per second of search.
//!
//! Probe backends:
//!   service — an in-process untrained `Service` (conv_full, Cycles):
//!             the real serving path, cold (`mlir_batch`) and delta
//!             (`session_open` + `mlir_delta`) probe modes. Model
//!             artifacts required.
//!   sim     — the simulator itself as the cost model (perfect probe,
//!             regret 1.0 wherever the beam covers the space). Used
//!             for both probe-mode rows when artifacts are absent so
//!             the recorded doc keeps its shape — the `probe` column
//!             says which backend actually answered.
//!
//! Results print as a table and are recorded to `BENCH_autotune.json`
//! at the repo root.

use mlir_cost::autotune::{
    self as at, Objective, ProbeMode, SearchConfig, SearchSpace, ServiceProbe, SimProbe,
};
use mlir_cost::benchkit;
use mlir_cost::bundle::Bundle;
use mlir_cost::coordinator::batcher::BatchPolicy;
use mlir_cost::coordinator::Service;
use mlir_cost::dataset::TargetStats;
use mlir_cost::graphgen::{generate, Family, GraphSpec};
use mlir_cost::json::Json;
use mlir_cost::mlir::Function;
use mlir_cost::runtime::Manifest;
use mlir_cost::sim::{Target, XpuConfig};
use mlir_cost::tokenizer::{Scheme, Vocab};
use std::sync::Arc;

fn repo_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().to_path_buf()
}

const WARMUP: usize = 1;
const ITERS: usize = 5;
/// Past this many per-group fusion bits the exhaustive oracle would
/// blow up, so the space drops the fusion dimension (and says so).
const MAX_FUSION_BITS: usize = 6;

/// The real serving path as the search's cost model, when artifacts
/// exist: one untrained conv_full variant (max_len 512 covers every
/// family graph here) serving Cycles.
fn service() -> Option<Arc<Service>> {
    let adir = repo_root().join("artifacts");
    if !adir.join("manifest.json").exists() {
        return None;
    }
    let manifest = Arc::new(Manifest::load(&adir).expect("artifacts load"));
    let vocab = Vocab::build(vec![vec!["xpu.relu".to_string()]].iter(), 1);
    let stats = TargetStats { mean: 20.0, std: 5.0, min: 4.0, max: 60.0 };
    let bundle =
        Bundle::untrained(&manifest, "conv_full", Target::Cycles, Scheme::OpsOnly, vocab, stats)
            .expect("untrained bundle");
    Some(Arc::new(Service::start(manifest, vec![bundle], BatchPolicy::default(), true).unwrap()))
}

fn run_search(
    base: &Function,
    space: &SearchSpace,
    cfg: &SearchConfig,
    svc: &Option<Arc<Service>>,
    mode: ProbeMode,
) -> at::SearchOutcome {
    match svc {
        Some(svc) => {
            let mut probe = ServiceProbe::new(svc.clone(), mode);
            let out = at::search(base, space, cfg, &mut probe).expect("served search");
            probe.finish();
            out
        }
        None => at::search(base, space, cfg, &mut SimProbe::new()).expect("sim search"),
    }
}

fn main() {
    benchkit::section("E10 / autotune: guided schedule search + measured regret");
    let xcfg = XpuConfig::default();
    let objective = Objective::minimize(Target::Cycles);
    let svc = service();
    let probe_name = if svc.is_some() { "service" } else { "sim" };
    if svc.is_none() {
        benchkit::kv("probe backend", "sim (artifacts absent — served probes skipped)");
    }

    let families = [(Family::Mlp, 0u64), (Family::Resnet, 1), (Family::Bert, 2)];
    let algos: [(&str, usize); 2] = [("greedy", 1), ("beam4", 4)];

    let mut rows: Vec<Json> = Vec::new();
    let mut worst_regret = 0.0f64;
    for (family, i) in families {
        let spec = GraphSpec { family, structure_seed: 9300 + i, shape_seed: 9400 + i };
        let base = generate(&spec).expect("graphgen");
        let bits = at::fusable_count(&base);
        let fusion = bits <= MAX_FUSION_BITS;
        let space = SearchSpace { unrolls: vec![1, 2, 4], tiles: vec![16, 32, 64], fusion };
        benchkit::section(&format!(
            "family {}: {} fusable groups, space {}{}",
            family.name(),
            bits,
            space.size(&base),
            if fusion { "" } else { " (fusion dimension dropped: too many bits)" }
        ));

        for mode in [ProbeMode::Cold, ProbeMode::Delta] {
            for (algo, beam) in algos {
                let cfg = SearchConfig { beam, objective: objective.clone() };
                let label = format!("{}/{}/{}/{}", family.name(), probe_name, mode.name(), algo);
                let mut last: Option<at::SearchOutcome> = None;
                let s = benchkit::bench(&label, WARMUP, ITERS, || {
                    last = Some(run_search(&base, &space, &cfg, &svc, mode));
                });
                println!("{}", s.row());
                let outcome = last.expect("at least one timed run");
                let report =
                    at::regret(&base, &space, &objective, &outcome, &xcfg).expect("oracle");
                worst_regret = worst_regret.max(report.regret);
                let search_sec = (s.mean_us / 1e6).max(1e-9);
                let probes_per_sec = outcome.probes as f64 / search_sec;
                benchkit::kv(
                    "regret",
                    format!(
                        "{:.4} ({} probes, {} delta, {:.0} probes/s, speedup {:.3}x)",
                        report.regret,
                        outcome.probes,
                        outcome.delta_probes,
                        probes_per_sec,
                        report.speedup
                    ),
                );
                rows.push(
                    Json::obj()
                        .with("family", Json::str(family.name()))
                        .with("probe", Json::str(probe_name))
                        .with("probe_mode", Json::str(mode.name()))
                        .with("algo", Json::str(algo))
                        .with("beam", Json::num(beam as f64))
                        .with("space_size", Json::num(report.space_size as f64))
                        .with("fusion_bits", Json::num(bits as f64))
                        .with("fusion_explored", Json::Bool(fusion))
                        .with("candidates", Json::num(outcome.candidates as f64))
                        .with("probes", Json::num(outcome.probes as f64))
                        .with("delta_probes", Json::num(outcome.delta_probes as f64))
                        .with("search_us", Json::num(s.mean_us))
                        .with("probes_per_sec", Json::num(probes_per_sec))
                        .with("chosen", Json::num(report.chosen_measured))
                        .with("oracle_best", Json::num(report.oracle_measured))
                        .with("regret", Json::num(report.regret))
                        .with("speedup", Json::num(report.speedup))
                        .with("speedup_per_sec", Json::num((report.speedup - 1.0) / search_sec)),
                );
            }
        }
    }

    benchkit::section("E10 summary");
    benchkit::kv("worst regret", format!("{worst_regret:.4}"));
    benchkit::kv(
        "sim-probe regret == 1.0 wherever the beam covers the space",
        if probe_name == "sim" { "expected" } else { "n/a (served probes)" },
    );

    let doc = Json::obj()
        .with("bench", Json::str("e10_autotune"))
        .with(
            "note",
            Json::str(
                "Guided schedule search (greedy vs beam) over unroll x tile x fusion \
                 spaces, scored by cold and delta probes, with the sim oracle \
                 exhaustively scoring each space for measured regret. `probe` names \
                 the backend that answered (service needs artifacts/). Run `cargo \
                 bench --bench e10_autotune` from rust/ to refresh.",
            ),
        )
        .with("objective", Json::str(objective.to_string()))
        .with("served", Json::Bool(svc.is_some()))
        .with("iters", Json::num(ITERS as f64))
        .with("scenarios", Json::Arr(rows))
        .with("worst_regret", Json::num(worst_regret));
    let out = repo_root().join("BENCH_autotune.json");
    match std::fs::write(&out, doc.to_string()) {
        Ok(()) => println!("\nrecorded {out:?}"),
        Err(e) => eprintln!("\ncould not write {out:?}: {e}"),
    }
}

//! E6 — front-end throughput: the text→ids pipeline the serving
//! coordinator runs on *every* query (PR 2's zero-allocation rebuild).
//!
//! Three pipelines over the same corpus, so one run produces the
//! baseline-vs-after comparison directly:
//!
//!   string  — the pre-PR shape: parse → tokenize to `Vec<String>` →
//!             encode (second vocabulary pass) → cache_key
//!   fused   — zero-copy parse → id-direct sink (no `Vec<String>`,
//!             fused OOV) → cache_key
//!   memo    — duplicate-heavy traffic against the text-level memo:
//!             a warm repeat costs one FxHash of the text + one shard
//!             lookup
//!
//! Results (tokens/s, queries/s, speedups) print as a table and are
//! recorded to `BENCH_frontend.json` at the repo root. No model
//! artifacts are needed — this measures the front end only.

use mlir_cost::benchkit;
use mlir_cost::coordinator::cache::cache_key;
use mlir_cost::coordinator::frontend::{CachedEncode, FrontendMemo};
use mlir_cost::graphgen::{generate, Family, GraphSpec};
use mlir_cost::json::Json;
use mlir_cost::lower::affine::lower_to_affine;
use mlir_cost::mlir::{parse_function, print_function};
use mlir_cost::tokenizer::{encode, encode_function, tokenize, OpIdTable, Scheme, Vocab};
use std::sync::Arc;

fn repo_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().to_path_buf()
}

const TARGET: &str = "regpressure";
const MODEL: &str = "conv_ops";
const MAX_LEN: usize = 512;

fn main() {
    benchkit::section("E6 / front end: parse + tokenize + encode");

    // Corpus: all families, xpu + affine-lowered forms (the affine texts
    // are the "thousands of tokens" worst case the paper calls out).
    let mut texts: Vec<String> = Vec::new();
    for i in 0..14usize {
        let spec = GraphSpec {
            family: Family::ALL[i % 7],
            structure_seed: 900 + i as u64,
            shape_seed: 1900 + i as u64,
        };
        let f = generate(&spec).expect("graphgen");
        texts.push(print_function(&f));
        if i % 2 == 0 {
            texts.push(print_function(&lower_to_affine(&f).expect("affine lowering")));
        }
    }
    let scheme = Scheme::OpsOperands;
    let streams: Vec<Vec<String>> = texts
        .iter()
        .map(|t| tokenize(&parse_function(t).expect("parse"), scheme))
        .collect();
    let vocab = Vocab::build(streams.iter(), 1);
    let table = OpIdTable::build(&vocab);
    let total_tokens: usize = streams.iter().map(Vec::len).sum();
    let total_bytes: usize = texts.iter().map(String::len).sum();
    benchkit::kv(
        "corpus",
        format!("{} texts, {total_tokens} tokens, {total_bytes} bytes", texts.len()),
    );

    // --- baseline: the pre-PR string pipeline -------------------------
    let s_string = benchkit::bench("string pipeline (tokenize->Vec<String>->encode)", 3, 30, || {
        for t in &texts {
            let f = parse_function(t).expect("parse");
            let toks = tokenize(&f, scheme);
            let ids = encode(&toks, &vocab, MAX_LEN);
            std::hint::black_box(cache_key(MODEL, &ids));
        }
    });
    println!("{}", s_string.row());

    // --- fused id-direct sink (cold path of the new front end) --------
    let s_fused = benchkit::bench("fused id-direct sink (no string stream)", 3, 30, || {
        for t in &texts {
            let f = parse_function(t).expect("parse");
            let (ids, _oov) = encode_function(&f, scheme, &vocab, &table, MAX_LEN);
            std::hint::black_box(cache_key(MODEL, &ids));
        }
    });
    println!("{}", s_fused.row());

    // --- memo hits (duplicate-heavy autotuning traffic) ---------------
    let memo = FrontendMemo::new(4096);
    for t in &texts {
        let f = parse_function(t).expect("parse");
        let (ids, _) = encode_function(&f, scheme, &vocab, &table, MAX_LEN);
        let key = cache_key(MODEL, &ids);
        // Variant dimension = the model name here: a single-variant
        // service registers each bundle under its model's name.
        let tk = FrontendMemo::text_key(TARGET, MODEL, MODEL, t);
        memo.insert(tk, CachedEncode { ids: Arc::new(ids), key });
    }
    let s_memo = benchkit::bench("memo hit (hash + shard lookup)", 3, 30, || {
        for t in &texts {
            let tk = FrontendMemo::text_key(TARGET, MODEL, MODEL, t);
            let enc = memo.get(tk).expect("warm memo");
            std::hint::black_box(enc.key);
        }
    });
    println!("{}", s_memo.row());

    let queries_per_iter = texts.len() as f64;
    let qps = |mean_us: f64| queries_per_iter / (mean_us * 1e-6);
    let tps = |mean_us: f64| total_tokens as f64 / (mean_us * 1e-6);
    let fused_speedup = s_string.mean_us / s_fused.mean_us;
    let memo_speedup = s_string.mean_us / s_memo.mean_us;

    benchkit::section("E6 summary");
    benchkit::kv(
        "string pipeline",
        format!("{:.0} q/s, {:.0} tok/s", qps(s_string.mean_us), tps(s_string.mean_us)),
    );
    benchkit::kv(
        "fused id-direct",
        format!(
            "{:.0} q/s, {:.0} tok/s ({fused_speedup:.2}x)",
            qps(s_fused.mean_us),
            tps(s_fused.mean_us)
        ),
    );
    benchkit::kv("memo hit", format!("{:.0} q/s ({memo_speedup:.1}x)", qps(s_memo.mean_us)));
    benchkit::kv(
        "duplicate-heavy >=5x target (acceptance)",
        if memo_speedup >= 5.0 { "OK" } else { "VIOLATED" },
    );

    // Record baseline-vs-after for BENCH_frontend.json.
    let entry = |s: &mlir_cost::benchkit::Summary| {
        Json::obj()
            .with("mean_us_per_sweep", Json::num(s.mean_us))
            .with("p50_us", Json::num(s.p50_us))
            .with("p95_us", Json::num(s.p95_us))
            .with("queries_per_sec", Json::num(qps(s.mean_us)))
            .with("tokens_per_sec", Json::num(tps(s.mean_us)))
    };
    let doc = Json::obj()
        .with("bench", Json::str("e6_frontend"))
        .with("scheme", Json::str(scheme.name()))
        .with("max_len", Json::num(MAX_LEN as f64))
        .with("corpus_texts", Json::num(texts.len() as f64))
        .with("corpus_tokens", Json::num(total_tokens as f64))
        .with("baseline_string_pipeline", entry(&s_string))
        .with("after_fused_id_direct", entry(&s_fused))
        .with("after_memo_hit", entry(&s_memo))
        .with("fused_speedup_vs_baseline", Json::num(fused_speedup))
        .with("memo_hit_speedup_vs_baseline", Json::num(memo_speedup));
    let out = repo_root().join("BENCH_frontend.json");
    match std::fs::write(&out, doc.to_string()) {
        Ok(()) => println!("\nrecorded {out:?}"),
        Err(e) => eprintln!("\ncould not write {out:?}: {e}"),
    }
}

//! E5 — ablations over the DESIGN.md design choices in the ground-truth
//! substrate: fusion on/off, unroll factor, tokenization cost, and the
//! label-generation pipeline's own speed (it must label 20k+ graphs).

use mlir_cost::benchkit;
use mlir_cost::dataset::Dataset;
use mlir_cost::graphgen::{corpus_specs, generate};
use mlir_cost::lower::{analyze, lower, CodegenOpts};
use mlir_cost::mlir::{parse_function, print_function};
use mlir_cost::sim::{ground_truth, simulate, XpuConfig};
use mlir_cost::tokenizer::{tokenize, Scheme};

fn main() {
    benchkit::section("E5: substrate ablations");
    let cfg = XpuConfig::default();
    let funcs: Vec<_> = corpus_specs(31337, 60, 0)
        .iter()
        .map(|s| generate(s).unwrap())
        .collect();

    // Fusion ablation: cycles + pressure with/without operator fusion.
    let mut fused_cycles = 0.0;
    let mut unfused_cycles = 0.0;
    let mut fused_rp = 0.0;
    let mut unfused_rp = 0.0;
    for f in &funcs {
        let a = ground_truth(f, &CodegenOpts::default(), &cfg).unwrap();
        let b = ground_truth(f, &CodegenOpts { fuse: false, ..Default::default() }, &cfg).unwrap();
        fused_cycles += a.cycles;
        unfused_cycles += b.cycles;
        fused_rp += a.regpressure;
        unfused_rp += b.regpressure;
    }
    benchkit::kv(
        "fusion speedup (gecycles, 60 graphs)",
        format!("{:.2}x", unfused_cycles / fused_cycles),
    );
    benchkit::kv(
        "mean regpressure fused vs unfused",
        format!("{:.1} vs {:.1}", fused_rp / 60.0, unfused_rp / 60.0),
    );

    // Unroll sweep: pressure growth (the trade-off the model must learn).
    print!("  unroll sweep mean regpressure:");
    for u in [1u32, 2, 4, 8] {
        let mut rp = 0.0;
        for f in &funcs {
            let prog = lower(f, &CodegenOpts { unroll: Some(u), ..Default::default() }).unwrap();
            rp += analyze(&prog).max_live as f64;
        }
        print!("  u{u}={:.1}", rp / funcs.len() as f64);
    }
    println!();

    // Hot-path micro-benchmarks (the perf-pass targets).
    let texts: Vec<String> = funcs.iter().map(print_function).collect();
    let mut k = 0usize;
    let s = benchkit::bench("parse MLIR text", 3, 200, || {
        let _ = parse_function(&texts[k % texts.len()]).unwrap();
        k += 1;
    });
    println!("{}", s.row());
    let s = benchkit::bench("tokenize ops-only", 3, 500, || {
        let _ = tokenize(&funcs[k % funcs.len()], Scheme::OpsOnly);
        k += 1;
    });
    println!("{}", s.row());
    let s = benchkit::bench("tokenize ops+operands", 3, 500, || {
        let _ = tokenize(&funcs[k % funcs.len()], Scheme::OpsOperands);
        k += 1;
    });
    println!("{}", s.row());
    let s = benchkit::bench("ground-truth (lower+regalloc+simulate)", 2, 100, || {
        let f = &funcs[k % funcs.len()];
        let _ = ground_truth(f, &CodegenOpts::default(), &cfg).unwrap();
        k += 1;
    });
    println!("{}", s.row());
    let s = benchkit::bench("simulate only", 2, 100, || {
        let f = &funcs[k % funcs.len()];
        let prog = lower(f, &CodegenOpts::default()).unwrap();
        let _ = simulate(&prog, &cfg);
        k += 1;
    });
    println!("{}", s.row());

    // Dataset-generation throughput (labels 20k+ graphs in the paper).
    let t0 = std::time::Instant::now();
    let ds = Dataset::generate(99, 200, 0).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    benchkit::kv(
        "dataset generation throughput",
        format!("{:.0} samples/s ({} samples in {dt:.2}s)", ds.len() as f64 / dt, ds.len()),
    );
}

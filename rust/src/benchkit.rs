//! Mini benchmarking harness (criterion is not vendored in this image).
//!
//! Used by the `benches/` targets (`harness = false`): warmup, timed
//! iterations, mean/stddev/percentiles, and aligned table printing for the
//! paper-table regeneration benches.

use std::time::Instant;

/// Timing summary over N iterations.
#[derive(Debug, Clone)]
pub struct Summary {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub stddev_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
}

impl Summary {
    pub fn row(&self) -> String {
        format!(
            "{:<42} {:>8} iters  mean {:>10.1} us  sd {:>9.1}  p50 {:>10.1}  p95 {:>10.1}",
            self.name, self.iters, self.mean_us, self.stddev_us, self.p50_us, self.p95_us
        )
    }
}

/// CI smoke mode: `MLIR_COST_SMOKE=1` clamps every iteration count the
/// harness sees (see [`clamp_iters`]) so `scripts/bench_smoke.sh` can
/// prove each bench still runs end-to-end in seconds. Smoke numbers are
/// execution evidence, not measurements.
pub fn smoke() -> bool {
    std::env::var("MLIR_COST_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// Iteration ceiling under smoke mode.
const SMOKE_ITERS: usize = 2;

fn clamp_with(n: usize, smoke: bool) -> usize {
    if smoke {
        n.min(SMOKE_ITERS)
    } else {
        n
    }
}

/// Clamp an iteration count to the smoke budget when `MLIR_COST_SMOKE=1`
/// (identity otherwise). [`bench`] and [`concurrent_throughput`] apply
/// this themselves; benches with hand-rolled loops should route their
/// counts through it too.
pub fn clamp_iters(n: usize) -> usize {
    clamp_with(n, smoke())
}

/// Benchmark a closure: `warmup` untimed runs then `iters` timed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Summary {
    let warmup = clamp_iters(warmup);
    let iters = clamp_iters(iters).max(1);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    summarize(name, &samples)
}

/// Summarize raw microsecond samples.
pub fn summarize(name: &str, samples: &[f64]) -> Summary {
    let n = samples.len().max(1) as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
    let mut sorted = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    let pct = |p: f64| -> f64 {
        if sorted.is_empty() {
            0.0
        } else {
            sorted[((sorted.len() as f64 * p) as usize).min(sorted.len() - 1)]
        }
    };
    Summary {
        name: name.to_string(),
        iters: samples.len(),
        mean_us: mean,
        stddev_us: var.sqrt(),
        p50_us: pct(0.50),
        p95_us: pct(0.95),
    }
}

/// Drive a closure from `threads` OS threads (`per_thread` invocations
/// each, all threads released together through a barrier — concurrency
/// benches need simultaneous arrival to exercise batching/coalescing) and
/// return `(ops_per_sec, elapsed_secs)`. The closure receives
/// `(thread_idx, iter_idx)`.
pub fn concurrent_throughput<F>(threads: usize, per_thread: usize, f: F) -> (f64, f64)
where
    F: Fn(usize, usize) + Sync,
{
    // Smoke mode clamps the per-thread count but keeps the thread
    // count: the concurrency shape IS what the bench exercises.
    let per_thread = clamp_iters(per_thread).max(1);
    let barrier = std::sync::Barrier::new(threads);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..threads {
            let barrier = &barrier;
            let f = &f;
            s.spawn(move || {
                barrier.wait();
                for i in 0..per_thread {
                    f(t, i);
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    ((threads * per_thread) as f64 / dt.max(1e-9), dt)
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print an aligned key/value table row.
pub fn kv(key: &str, value: impl std::fmt::Display) {
    println!("  {key:<44} {value}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let s = bench("noop", 2, 50, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.iters, 50);
        assert!(s.mean_us < 1000.0);
        assert!(s.p50_us <= s.p95_us);
    }

    #[test]
    fn concurrent_throughput_runs_everything() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let count = AtomicUsize::new(0);
        let seen_threads = AtomicUsize::new(0);
        let (qps, dt) = concurrent_throughput(4, 25, |t, _i| {
            count.fetch_add(1, Ordering::Relaxed);
            seen_threads.fetch_max(t + 1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 100);
        assert_eq!(seen_threads.load(Ordering::Relaxed), 4);
        assert!(qps > 0.0 && dt >= 0.0);
    }

    #[test]
    fn smoke_clamp_is_identity_unless_enabled() {
        assert_eq!(clamp_with(1000, false), 1000);
        assert_eq!(clamp_with(0, false), 0);
        assert_eq!(clamp_with(1000, true), SMOKE_ITERS);
        assert_eq!(clamp_with(1, true), 1);
        assert_eq!(clamp_with(0, true), 0);
    }

    #[test]
    fn summarize_percentiles() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = summarize("x", &samples);
        assert!((s.mean_us - 50.5).abs() < 1e-9);
        assert_eq!(s.p50_us, 51.0);
        assert_eq!(s.p95_us, 96.0);
    }
}

//! SSD-style multi-scale detection-head subgraphs (paper corpus family #4).

use super::common::{pick_batch, pick_dtype, NetBuilder};
use crate::mlir::{Function, ValueId, XpuOp};
use crate::rng::Rng;
use anyhow::Result;

/// Per-scale prediction head: loc (4 coords) + conf (classes) convs,
/// flattened to [B, boxes*k].
fn scale_head(
    nb: &mut NetBuilder,
    feat: ValueId,
    anchors: i64,
    classes: i64,
) -> Result<(ValueId, ValueId)> {
    let shape = nb.shape(feat);
    let (b, hgt, wid) = (shape[0], shape[2], shape[3]);
    let loc = nb.conv2d(feat, anchors * 4, 3, 1, 1)?;
    let conf = nb.conv2d(feat, anchors * classes, 3, 1, 1)?;
    let loc_flat = nb.reshape(loc, vec![b, anchors * 4 * hgt * wid])?;
    let conf_flat = nb.reshape(conf, vec![b, anchors * classes * hgt * wid])?;
    Ok((loc_flat, conf_flat))
}

/// Build an SSD subgraph: a short conv backbone producing 2–4 feature
/// scales, per-scale heads, cross-scale concat, softmax over scores.
pub fn build(s: &mut Rng, h: &mut Rng, name: &str) -> Result<Function> {
    let dtype = pick_dtype(h);
    let batch = pick_batch(h);
    let n_scales = s.range(2, 4) as usize;
    let backbone_per_scale = s.range(1, 2) as usize;
    let anchors = s.range(2, 6);
    let classes = *h.pick(&[2i64, 4, 8, 21]);
    let base_ch = *h.pick(&[32i64, 64, 128]);
    let spatial = (*h.pick(&[38i64, 64, 75])).max(1 << (n_scales + 2));

    let mut nb = NetBuilder::new(name, dtype);
    let mut x = nb.input(vec![batch, base_ch, spatial, spatial]);

    let mut locs = Vec::new();
    let mut confs = Vec::new();
    let mut ch = base_ch;
    for scale in 0..n_scales {
        for _ in 0..backbone_per_scale {
            x = nb.conv_bn_act(x, ch, 3, 1, XpuOp::Relu)?;
        }
        let (l, c) = scale_head(&mut nb, x, anchors, classes)?;
        locs.push(l);
        confs.push(c);
        if scale + 1 < n_scales {
            // Stride-2 conv to the next scale.
            ch *= 2;
            x = nb.conv_bn_act(x, ch, 3, 2, XpuOp::Relu)?;
        }
    }
    let all_loc = nb.concat(&locs, 1)?;
    let all_conf = nb.concat(&confs, 1)?;
    let scores = nb.softmax(all_conf, 1)?;
    nb.finish(&[all_loc, scores])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mlir::verify_function;

    #[test]
    fn generates_valid_functions() {
        let mut root = Rng::new(400);
        for i in 0..30 {
            let mut sf = root.fork(i);
            let mut hf = root.fork(4000 + i);
            let f = build(&mut sf, &mut hf, &format!("ssd_{i}")).unwrap();
            verify_function(&f).unwrap();
            assert_eq!(f.ret.len(), 2, "loc + scores outputs");
            assert!(f.xpu_ops().contains(&XpuOp::Concat));
        }
    }
}

//! ResNet-style residual subgraphs (paper corpus family #1).

use super::common::{pick_batch, pick_dtype, NetBuilder};
use crate::mlir::{Function, XpuOp};
use crate::rng::Rng;
use anyhow::Result;

/// One residual basic/bottleneck block ending in `add` + `relu`.
fn block(nb: &mut NetBuilder, x: crate::mlir::ValueId, bottleneck: bool, downsample: bool)
    -> Result<crate::mlir::ValueId> {
    let c = nb.channels(x);
    let stride = if downsample { 2 } else { 1 };
    let out_c = if downsample { c * 2 } else { c };
    let main = if bottleneck {
        let mid = (out_c / 4).max(8);
        let a = nb.conv_bn_act(x, mid, 1, stride, XpuOp::Relu)?;
        let b = nb.conv_bn_act(a, mid, 3, 1, XpuOp::Relu)?;
        let v = nb.conv2d(b, out_c, 1, 1, 0)?;
        nb.batchnorm(v)?
    } else {
        let a = nb.conv_bn_act(x, out_c, 3, stride, XpuOp::Relu)?;
        let v = nb.conv2d(a, out_c, 3, 1, 1)?;
        nb.batchnorm(v)?
    };
    let skip = if downsample {
        let p = nb.conv2d(x, out_c, 1, stride, 0)?;
        nb.batchnorm(p)?
    } else {
        x
    };
    let sum = nb.binary(XpuOp::Add, main, skip)?;
    nb.relu(sum)
}

/// Build a ResNet subgraph: optional stem, 1–4 residual blocks, optional
/// classifier head. `s` drives structure, `h` drives shapes (augmentation
/// re-rolls `h` only).
pub fn build(s: &mut Rng, h: &mut Rng, name: &str) -> Result<Function> {
    let dtype = pick_dtype(h);
    let batch = pick_batch(h);
    let channels = *h.pick(&[32i64, 64, 64, 128, 256]);
    let spatial = *h.pick(&[7i64, 14, 28, 56, 56, 112]);

    // Structure decisions come only from `s` so that augmentation
    // (re-rolling `h`) preserves the op sequence exactly.
    let with_stem = s.chance(0.3);
    let bottleneck = s.chance(0.4);
    let n_blocks = s.range(1, 4) as usize;
    let with_head = s.chance(0.3);
    let down_flags: Vec<bool> = (0..n_blocks).map(|i| i > 0 && s.chance(0.35)).collect();

    let mut nb = NetBuilder::new(name, dtype);
    let mut x = if with_stem {
        let img = nb.input(vec![batch, 3, spatial * 4, spatial * 4]);
        let c = nb.conv_bn_act(img, channels, 7, 2, XpuOp::Relu)?;
        nb.maxpool(c, 3, 2, 1)?
    } else {
        nb.input(vec![batch, channels, spatial, spatial])
    };
    for &down in &down_flags {
        x = block(&mut nb, x, bottleneck, down)?;
    }
    if with_head {
        let pooled = nb.unary(XpuOp::GlobalAvgPool, x)?;
        let logits = nb.linear(pooled, *h.pick(&[10i64, 100, 1000]), true)?;
        let probs = nb.softmax(logits, 1)?;
        return nb.finish(&[probs]);
    }
    nb.finish(&[x])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mlir::verify_function;

    #[test]
    fn generates_valid_functions() {
        let mut s = Rng::new(100);
        for i in 0..40 {
            let mut sf = s.fork(i);
            let mut hf = s.fork(1000 + i);
            let f = build(&mut sf, &mut hf, &format!("resnet_{i}")).unwrap();
            verify_function(&f).unwrap();
            assert!(f.num_ops() >= 5, "too small: {}", f.num_ops());
            assert!(f.xpu_ops().contains(&XpuOp::Add), "residual add missing");
        }
    }

    #[test]
    fn structure_seed_fixes_op_sequence() {
        // Same structure seed + different shape seed → same op sequence
        // (this is what makes augmentation honest).
        let f1 = build(&mut Rng::new(7), &mut Rng::new(1), "a").unwrap();
        let f2 = build(&mut Rng::new(7), &mut Rng::new(2), "b").unwrap();
        let shrink = |f: &Function| f.xpu_ops();
        assert_eq!(shrink(&f1), shrink(&f2));
    }
}

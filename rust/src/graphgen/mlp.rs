//! Plain MLP / elementwise-chain subgraphs — the small fry that DL
//! compilers see constantly (corpus filler family, also the smallest
//! graphs in the length distribution).

use super::common::{pick_dtype, NetBuilder};
use crate::mlir::{Function, XpuOp};
use crate::rng::Rng;
use anyhow::Result;

/// Build an MLP subgraph: 1–6 linear layers with mixed activations,
/// optionally ending in softmax, optionally with an elementwise epilogue.
pub fn build(s: &mut Rng, h: &mut Rng, name: &str) -> Result<Function> {
    let dtype = pick_dtype(h);
    let batch = *h.pick(&[1i64, 8, 32, 64, 128]);
    let mut dim = *h.pick(&[64i64, 128, 256, 512, 784, 1024]);

    let n_layers = s.range(1, 6) as usize;
    let acts = [XpuOp::Relu, XpuOp::Gelu, XpuOp::Tanh, XpuOp::Sigmoid];
    let layer_acts: Vec<XpuOp> = (0..n_layers).map(|_| *s.pick(&acts)).collect();
    let with_softmax = s.chance(0.4);
    let with_epilogue = s.chance(0.3);

    let mut nb = NetBuilder::new(name, dtype);
    let mut x = nb.input(vec![batch, dim]);
    for &act in &layer_acts {
        // Halve or keep width per layer (structure-driven).
        dim = (dim / 2).max(16);
        x = nb.linear(x, dim, true)?;
        x = nb.unary(act, x)?;
    }
    if with_epilogue {
        let scale = nb.weight(vec![dim])?;
        x = nb.binary(XpuOp::Mult, x, scale)?;
        let shift = nb.weight(vec![dim])?;
        x = nb.binary(XpuOp::Add, x, shift)?;
    }
    if with_softmax {
        x = nb.softmax(x, 1)?;
    }
    nb.finish(&[x])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mlir::verify_function;

    #[test]
    fn generates_valid_functions() {
        let mut root = Rng::new(600);
        for i in 0..40 {
            let mut sf = root.fork(i);
            let mut hf = root.fork(60 + i);
            let f = build(&mut sf, &mut hf, &format!("mlp_{i}")).unwrap();
            verify_function(&f).unwrap();
            assert!(f.xpu_ops().contains(&XpuOp::MatMul));
        }
    }

    #[test]
    fn sizes_vary() {
        let mut root = Rng::new(601);
        let sizes: Vec<usize> = (0..20)
            .map(|i| {
                let mut sf = root.fork(i);
                let mut hf = root.fork(i + 999);
                build(&mut sf, &mut hf, "m").unwrap().num_ops()
            })
            .collect();
        let min = sizes.iter().min().unwrap();
        let max = sizes.iter().max().unwrap();
        assert!(max > min, "no size diversity: {sizes:?}");
    }
}

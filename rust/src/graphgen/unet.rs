//! UNet-style encoder/decoder subgraphs with skip connections
//! (paper corpus family #3).

use super::common::{pick_batch, pick_dtype, NetBuilder};
use crate::mlir::{Function, ValueId, XpuOp};
use crate::rng::Rng;
use anyhow::Result;

/// Double conv block: (conv3x3 → bn → relu) × 2.
fn double_conv(nb: &mut NetBuilder, x: ValueId, out_ch: i64) -> Result<ValueId> {
    let a = nb.conv_bn_act(x, out_ch, 3, 1, XpuOp::Relu)?;
    nb.conv_bn_act(a, out_ch, 3, 1, XpuOp::Relu)
}

/// Build a UNet subgraph: `depth` down levels, bottleneck, matching up
/// levels with skip concats, and a 1x1 head.
pub fn build(s: &mut Rng, h: &mut Rng, name: &str) -> Result<Function> {
    let dtype = pick_dtype(h);
    let batch = pick_batch(h);
    let base = *h.pick(&[16i64, 32, 32, 64]);
    // Spatial must survive `depth` halvings.
    let depth = s.range(1, 3) as usize;
    let spatial = (*h.pick(&[32i64, 64, 64, 128])).max((1 << depth) * 8);
    let with_head = s.chance(0.6);

    let mut nb = NetBuilder::new(name, dtype);
    let mut x = nb.input(vec![batch, *h.pick(&[1i64, 3]), spatial, spatial]);

    // Encoder: keep skip tensors.
    let mut skips: Vec<ValueId> = Vec::new();
    let mut ch = base;
    for _ in 0..depth {
        let f = double_conv(&mut nb, x, ch)?;
        skips.push(f);
        x = nb.maxpool(f, 2, 2, 0)?;
        ch *= 2;
    }
    // Bottleneck.
    x = double_conv(&mut nb, x, ch)?;
    // Decoder.
    for skip in skips.into_iter().rev() {
        ch /= 2;
        let up = nb.upsample(x, 2)?;
        let reduced = nb.conv2d(up, ch, 1, 1, 0)?;
        let cat = nb.concat(&[reduced, skip], 1)?;
        x = double_conv(&mut nb, cat, ch)?;
    }
    if with_head {
        let classes = *h.pick(&[1i64, 2, 4, 8]);
        let logits = nb.conv2d(x, classes, 1, 1, 0)?;
        let probs = nb.unary(XpuOp::Sigmoid, logits)?;
        return nb.finish(&[probs]);
    }
    nb.finish(&[x])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mlir::verify_function;

    #[test]
    fn generates_valid_functions() {
        let mut root = Rng::new(300);
        for i in 0..30 {
            let mut sf = root.fork(i);
            let mut hf = root.fork(9000 + i);
            let f = build(&mut sf, &mut hf, &format!("unet_{i}")).unwrap();
            verify_function(&f).unwrap();
            let ops = f.xpu_ops();
            assert!(ops.contains(&XpuOp::Concat), "skip concat missing");
            assert!(ops.contains(&XpuOp::Upsample), "decoder upsample missing");
        }
    }

    #[test]
    fn output_spatial_matches_input() {
        // Encoder/decoder symmetry: without a head the output spatial dims
        // equal the input's.
        let f = build(&mut Rng::new(4), &mut Rng::new(4), "u").unwrap();
        let in_shape = f.value_type(crate::mlir::ValueId(0)).as_tensor().unwrap().shape.clone();
        let out = f.ret[0];
        let out_shape = f.value_type(out).as_tensor().unwrap().shape.clone();
        assert_eq!(in_shape[2..], out_shape[2..]);
    }
}

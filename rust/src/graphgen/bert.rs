//! BERT-style transformer-encoder subgraphs (paper corpus family #2).

use super::common::{pick_dtype, NetBuilder};
use crate::mlir::{Function, ValueId, XpuOp};
use crate::rng::Rng;
use anyhow::Result;

/// Multi-head self-attention on `x: [B, S, D]`.
fn attention(nb: &mut NetBuilder, x: ValueId, heads: i64) -> Result<ValueId> {
    let shape = nb.shape(x);
    let (b, s, d) = (shape[0], shape[1], shape[2]);
    let dh = d / heads;
    let q = nb.linear(x, d, true)?;
    let k = nb.linear(x, d, true)?;
    let v = nb.linear(x, d, true)?;
    // [B,S,D] -> [B,H,S,dh]
    let split = |nb: &mut NetBuilder, t: ValueId| -> Result<ValueId> {
        let r = nb.reshape(t, vec![b, s, heads, dh])?;
        nb.transpose(r, vec![0, 2, 1, 3])
    };
    let qh = split(nb, q)?;
    let kh = split(nb, k)?;
    let vh = split(nb, v)?;
    // scores = q @ k^T / sqrt(dh)
    let kt = nb.transpose(kh, vec![0, 1, 3, 2])?;
    let scores = nb.binary(XpuOp::MatMul, qh, kt)?;
    let scale = nb.weight(vec![1])?;
    let scaled = nb.binary(XpuOp::Mult, scores, scale)?;
    let probs = nb.softmax(scaled, 3)?;
    let ctx = nb.binary(XpuOp::MatMul, probs, vh)?;
    // [B,H,S,dh] -> [B,S,D]
    let back = nb.transpose(ctx, vec![0, 2, 1, 3])?;
    let merged = nb.reshape(back, vec![b, s, d])?;
    nb.linear(merged, d, true)
}

/// Feed-forward block: linear → gelu → linear.
fn ffn(nb: &mut NetBuilder, x: ValueId, expand: i64) -> Result<ValueId> {
    let d = *nb.shape(x).last().unwrap();
    let h = nb.linear(x, d * expand, true)?;
    let g = nb.unary(XpuOp::Gelu, h)?;
    nb.linear(g, d, true)
}

/// Build a BERT subgraph: optional embedding front-end, 1–2 encoder
/// layers, optional pooler head.
pub fn build(s: &mut Rng, h: &mut Rng, name: &str) -> Result<Function> {
    let dtype = pick_dtype(h);
    let batch = *h.pick(&[1i64, 2, 4]);
    let seq = *h.pick(&[64i64, 128, 128, 256, 512]);
    let (hidden, heads) = *h.pick(&[(256i64, 4i64), (512, 8), (768, 12), (1024, 16)]);

    let with_embedding = s.chance(0.3);
    let n_layers = s.range(1, 2) as usize;
    let expand = if s.chance(0.8) { 4 } else { 2 };
    let with_pooler = s.chance(0.25);

    let mut nb = NetBuilder::new(name, dtype);
    let mut x = if with_embedding {
        let ids = nb.input_ids(vec![batch, seq]);
        let table = nb.weight(vec![30522, hidden])?;
        let tok = nb.b.xpu(XpuOp::Embedding, &[ids, table], crate::mlir::Attrs::new())?;
        let pos = nb.weight(vec![seq, hidden])?;
        let summed = nb.binary(XpuOp::Add, tok, pos)?;
        nb.layernorm(summed)?
    } else {
        nb.input(vec![batch, seq, hidden])
    };
    for _ in 0..n_layers {
        let att = attention(&mut nb, x, heads)?;
        let res1 = nb.binary(XpuOp::Add, x, att)?;
        let ln1 = nb.layernorm(res1)?;
        let ff = ffn(&mut nb, ln1, expand)?;
        let res2 = nb.binary(XpuOp::Add, ln1, ff)?;
        x = nb.layernorm(res2)?;
    }
    if with_pooler {
        let d = *nb.shape(x).last().unwrap();
        let pooled = nb.linear(x, d, true)?;
        let out = nb.unary(XpuOp::Tanh, pooled)?;
        return nb.finish(&[out]);
    }
    nb.finish(&[x])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mlir::verify_function;

    #[test]
    fn generates_valid_functions() {
        let mut root = Rng::new(200);
        for i in 0..40 {
            let mut sf = root.fork(i);
            let mut hf = root.fork(5000 + i);
            let f = build(&mut sf, &mut hf, &format!("bert_{i}")).unwrap();
            verify_function(&f).unwrap();
            let ops = f.xpu_ops();
            assert!(ops.contains(&XpuOp::Softmax), "attention softmax missing");
            assert!(ops.contains(&XpuOp::Gelu), "ffn gelu missing");
        }
    }

    #[test]
    fn augmentation_preserves_structure() {
        let f1 = build(&mut Rng::new(7), &mut Rng::new(1), "a").unwrap();
        let f2 = build(&mut Rng::new(7), &mut Rng::new(9), "b").unwrap();
        assert_eq!(f1.xpu_ops(), f2.xpu_ops());
    }
}

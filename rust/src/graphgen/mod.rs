//! Synthetic dataflow-graph corpus generators.
//!
//! The paper trains on "MLIR representations of dataflow graphs extracted
//! from popular neural-net architectures like Resnet, BERT, Unet, SSD and
//! Yolo" (20k+ files, plus augmentation). That corpus is proprietary, so
//! this module regenerates its statistical shape: parameterized subgraph
//! generators per family, each split into a *structure* seed (which ops,
//! how many) and a *shape* seed (tensor dims). Augmentation re-rolls only
//! the shape seed — same op sequence, new shapes — which is exactly the
//! kind of augmentation the paper's shape-as-token scheme benefits from.

pub mod bert;
pub mod common;
pub mod mlp;
pub mod random;
pub mod resnet;
pub mod ssd;
pub mod unet;
pub mod yolo;

use crate::mlir::Function;
use crate::rng::Rng;
use anyhow::Result;

/// Corpus family (paper §3 lists the first five).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Family {
    Resnet,
    Bert,
    Unet,
    Ssd,
    Yolo,
    Mlp,
    Random,
}

impl Family {
    pub const ALL: [Family; 7] = [
        Family::Resnet,
        Family::Bert,
        Family::Unet,
        Family::Ssd,
        Family::Yolo,
        Family::Mlp,
        Family::Random,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Family::Resnet => "resnet",
            Family::Bert => "bert",
            Family::Unet => "unet",
            Family::Ssd => "ssd",
            Family::Yolo => "yolo",
            Family::Mlp => "mlp",
            Family::Random => "random",
        }
    }

    pub fn parse(s: &str) -> Option<Family> {
        Family::ALL.iter().copied().find(|f| f.name() == s)
    }

    /// Corpus mixture weight (CNN-ish families dominate real zoos).
    fn weight(self) -> f64 {
        match self {
            Family::Resnet => 0.22,
            Family::Bert => 0.18,
            Family::Unet => 0.12,
            Family::Ssd => 0.12,
            Family::Yolo => 0.12,
            Family::Mlp => 0.12,
            Family::Random => 0.12,
        }
    }
}

/// Everything needed to regenerate one graph deterministically.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GraphSpec {
    pub family: Family,
    pub structure_seed: u64,
    pub shape_seed: u64,
}

impl GraphSpec {
    /// Deterministic function name encoding the spec.
    pub fn func_name(&self) -> String {
        format!("{}_s{}_h{}", self.family.name(), self.structure_seed, self.shape_seed)
    }

    /// The augmented sibling: same structure, shifted shape seed.
    pub fn augmented(&self, k: u64) -> GraphSpec {
        GraphSpec { shape_seed: self.shape_seed.wrapping_add(0x5851_F42D + k), ..*self }
    }
}

/// Generate one graph from a spec.
pub fn generate(spec: &GraphSpec) -> Result<Function> {
    let mut s = Rng::new(spec.structure_seed);
    let mut h = Rng::new(spec.shape_seed);
    let name = spec.func_name();
    match spec.family {
        Family::Resnet => resnet::build(&mut s, &mut h, &name),
        Family::Bert => bert::build(&mut s, &mut h, &name),
        Family::Unet => unet::build(&mut s, &mut h, &name),
        Family::Ssd => ssd::build(&mut s, &mut h, &name),
        Family::Yolo => yolo::build(&mut s, &mut h, &name),
        Family::Mlp => mlp::build(&mut s, &mut h, &name),
        Family::Random => random::build(&mut s, &mut h, &name),
    }
}

/// Draw `count` specs from the corpus mixture, then append `augment` shape
/// re-rolls per spec (paper: "we use augmentation to create a larger
/// training set").
pub fn corpus_specs(seed: u64, count: usize, augment: usize) -> Vec<GraphSpec> {
    let mut rng = Rng::new(seed);
    let weights: Vec<f64> = Family::ALL.iter().map(|f| f.weight()).collect();
    let mut specs = Vec::with_capacity(count * (1 + augment));
    for i in 0..count {
        let family = Family::ALL[rng.weighted(&weights)];
        let spec = GraphSpec {
            family,
            structure_seed: rng.next_u64() ^ i as u64,
            shape_seed: rng.next_u64(),
        };
        specs.push(spec);
        for k in 0..augment {
            specs.push(spec.augmented(k as u64));
        }
    }
    specs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mlir::{parse_function, print_function, verify_function};

    #[test]
    fn all_families_generate_and_roundtrip() {
        for (i, family) in Family::ALL.into_iter().enumerate() {
            let spec = GraphSpec { family, structure_seed: 11 + i as u64, shape_seed: 77 };
            let f = generate(&spec).unwrap();
            verify_function(&f).unwrap();
            let text = print_function(&f);
            let f2 = parse_function(&text).unwrap();
            verify_function(&f2).unwrap();
            assert_eq!(print_function(&f2), text, "{family:?} round-trip");
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let spec = GraphSpec { family: Family::Bert, structure_seed: 5, shape_seed: 6 };
        let a = print_function(&generate(&spec).unwrap());
        let b = print_function(&generate(&spec).unwrap());
        assert_eq!(a, b);
    }

    #[test]
    fn augmented_specs_share_op_sequence() {
        let spec = GraphSpec { family: Family::Resnet, structure_seed: 9, shape_seed: 1 };
        let base = generate(&spec).unwrap();
        let aug = generate(&spec.augmented(0)).unwrap();
        assert_eq!(base.xpu_ops(), aug.xpu_ops());
    }

    #[test]
    fn corpus_mixture_covers_all_families() {
        let specs = corpus_specs(42, 200, 1);
        assert_eq!(specs.len(), 400);
        for family in Family::ALL {
            assert!(
                specs.iter().any(|s| s.family == family),
                "family {family:?} missing from corpus"
            );
        }
        // All specs generate.
        for spec in specs.iter().take(50) {
            verify_function(&generate(spec).unwrap()).unwrap();
        }
    }

    #[test]
    fn family_name_roundtrip() {
        for f in Family::ALL {
            assert_eq!(Family::parse(f.name()), Some(f));
        }
        assert_eq!(Family::parse("alexnet"), None);
    }
}

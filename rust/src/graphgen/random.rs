//! Random DAG generator: type-guided random composition over the whole
//! `xpu` op set. This family stresses the tokenizer/vocab (rare shapes,
//! OOV pressure) and the verifier, and pads the corpus length
//! distribution's tail.

use super::common::{pick_dtype, NetBuilder};
use crate::mlir::{Attr, Attrs, Function, ValueId, XpuOp};
use crate::rng::Rng;
use anyhow::Result;

/// Round `v` up/down to nearby "hardware-friendly" sizes sometimes, to
/// mimic the paper's observation that a handful of tensor shapes dominate.
fn friendly_dim(h: &mut Rng) -> i64 {
    if h.chance(0.8) {
        *h.pick(&[8i64, 16, 32, 64, 128, 256])
    } else {
        h.range(3, 200)
    }
}

/// Build a random dataflow graph: a pool of live tensors is extended op
/// by op, always type-correct by construction.
pub fn build(s: &mut Rng, h: &mut Rng, name: &str) -> Result<Function> {
    let dtype = pick_dtype(h);
    let n_ops_target = s.range(4, 60) as usize;

    let mut nb = NetBuilder::new(name, dtype);
    // Seed pool: 1–3 inputs of rank 2–4.
    let n_inputs = s.range(1, 3);
    let mut pool: Vec<ValueId> = Vec::new();
    for _ in 0..n_inputs {
        let rank = s.range(2, 4);
        let shape: Vec<i64> = match rank {
            2 => vec![*h.pick(&[1i64, 4, 16, 64]), friendly_dim(h)],
            3 => vec![*h.pick(&[1i64, 2, 4]), friendly_dim(h), friendly_dim(h)],
            _ => vec![
                *h.pick(&[1i64, 2]),
                *h.pick(&[8i64, 16, 32, 64]),
                *h.pick(&[8i64, 14, 28, 56]),
                *h.pick(&[8i64, 14, 28, 56]),
            ],
        };
        pool.push(nb.input(shape));
    }

    let unary_ops = [
        XpuOp::Relu,
        XpuOp::Gelu,
        XpuOp::Sigmoid,
        XpuOp::Tanh,
        XpuOp::Erf,
        XpuOp::Exp,
        XpuOp::Sqrt,
        XpuOp::Rsqrt,
        XpuOp::Neg,
    ];
    let binary_ops = [XpuOp::Add, XpuOp::Sub, XpuOp::Mult, XpuOp::Div, XpuOp::Maximum, XpuOp::Minimum];

    let mut emitted = 0usize;
    let mut guard = 0usize;
    while emitted < n_ops_target && guard < n_ops_target * 20 {
        guard += 1;
        let x = *s.pick(&pool);
        let shape = nb.shape(x);
        // Weighted menu of applicable ops for this operand.
        let choice = s.below(10);
        let result = match choice {
            0..=2 => nb.unary(*s.pick(&unary_ops), x),
            3..=4 => {
                // Same-shape binary: pair with a const of equal shape so it
                // is always well-typed.
                let w = nb.weight(shape.clone())?;
                emitted += 1; // the const counts as an op
                nb.binary(*s.pick(&binary_ops), x, w)
            }
            5 => {
                // Linear on the last dim.
                nb.linear(x, friendly_dim(h), s.chance(0.5))
            }
            6 if shape.len() == 4 && shape[2] >= 4 && shape[3] >= 4 => {
                nb.conv2d(x, friendly_dim(h).min(256), 3, 1, 1)
            }
            7 if shape.len() == 4 && shape[2] >= 4 && shape[3] >= 4 => {
                nb.maxpool(x, 2, 2, 0)
            }
            8 if shape.len() >= 2 => {
                let mut perm: Vec<i64> = (0..shape.len() as i64).collect();
                let a = s.below(shape.len() as u64) as usize;
                let b = s.below(shape.len() as u64) as usize;
                perm.swap(a, b);
                nb.transpose(x, perm)
            }
            _ => {
                let axes = vec![(shape.len() as i64) - 1];
                nb.b.xpu(
                    XpuOp::ReduceSum,
                    &[x],
                    Attrs::new()
                        .with("axes", Attr::IntArray(axes))
                        .with("keepdims", Attr::Bool(true)),
                )
            }
        };
        if let Ok(v) = result {
            pool.push(v);
            emitted += 1;
            // Keep the pool bounded and biased towards recent values.
            if pool.len() > 12 {
                pool.remove(0);
            }
        }
    }
    // Return the most recent value.
    let out = *pool.last().expect("non-empty pool");
    nb.finish(&[out])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mlir::verify_function;

    #[test]
    fn generates_valid_functions() {
        let mut root = Rng::new(700);
        for i in 0..60 {
            let mut sf = root.fork(i);
            let mut hf = root.fork(i * 31 + 7);
            let f = build(&mut sf, &mut hf, &format!("rand_{i}")).unwrap();
            verify_function(&f).unwrap();
            assert!(f.num_ops() >= 2);
        }
    }

    #[test]
    fn covers_a_wide_op_set() {
        use std::collections::HashSet;
        let mut root = Rng::new(701);
        let mut seen: HashSet<XpuOp> = HashSet::new();
        for i in 0..100 {
            let mut sf = root.fork(i);
            let mut hf = root.fork(i + 13);
            let f = build(&mut sf, &mut hf, "r").unwrap();
            seen.extend(f.xpu_ops());
        }
        assert!(seen.len() >= 15, "only {} distinct ops: {seen:?}", seen.len());
    }
}

//! YOLO/Darknet-style subgraphs: conv-bn-leaky stacks, residual shortcuts,
//! FPN-style upsample+concat across scales (paper corpus family #5).

use super::common::{pick_batch, pick_dtype, NetBuilder};
use crate::mlir::{Attr, Attrs, Function, ValueId, XpuOp};
use crate::rng::Rng;
use anyhow::Result;

/// "Leaky relu" spelled with the xpu primitive set: max(x, 0.1*x).
fn leaky(nb: &mut NetBuilder, x: ValueId) -> Result<ValueId> {
    let slope = nb.weight(vec![1])?;
    let scaled = nb.binary(XpuOp::Mult, x, slope)?;
    nb.binary(XpuOp::Maximum, x, scaled)
}

fn conv_bn_leaky(nb: &mut NetBuilder, x: ValueId, oc: i64, k: i64, stride: i64) -> Result<ValueId> {
    let pad = (k - 1) / 2;
    let c = nb.conv2d(x, oc, k, stride, pad)?;
    let n = nb.batchnorm(c)?;
    leaky(nb, n)
}

/// Darknet residual unit: 1x1 halve channels, 3x3 restore, shortcut add.
fn dark_block(nb: &mut NetBuilder, x: ValueId) -> Result<ValueId> {
    let c = nb.channels(x);
    let a = conv_bn_leaky(nb, x, (c / 2).max(8), 1, 1)?;
    let b = conv_bn_leaky(nb, a, c, 3, 1)?;
    nb.binary(XpuOp::Add, x, b)
}

/// Detection head: 1x1 conv to anchors*(5+classes), reshape to
/// [B, A, 5+classes, H*W], sigmoid objectness-style activation.
fn detect_head(nb: &mut NetBuilder, x: ValueId, anchors: i64, classes: i64) -> Result<ValueId> {
    let shape = nb.shape(x);
    let (b, hgt, wid) = (shape[0], shape[2], shape[3]);
    let per = 5 + classes;
    let raw = nb.conv2d(x, anchors * per, 1, 1, 0)?;
    let re = nb.reshape(raw, vec![b, anchors, per, hgt * wid])?;
    nb.unary(XpuOp::Sigmoid, re)
}

/// Build a YOLO subgraph: residual backbone chunk, optional second scale
/// with upsample + route-concat, detection heads.
pub fn build(s: &mut Rng, h: &mut Rng, name: &str) -> Result<Function> {
    let dtype = pick_dtype(h);
    let batch = pick_batch(h);
    let ch = *h.pick(&[64i64, 128, 256]);
    let spatial = *h.pick(&[16i64, 26, 32, 52]);
    let n_blocks = s.range(1, 3) as usize;
    let two_scale = s.chance(0.5);
    let anchors = 3;
    let classes = *h.pick(&[4i64, 20, 80]);

    let mut nb = NetBuilder::new(name, dtype);
    let mut x = nb.input(vec![batch, ch, spatial, spatial]);
    for _ in 0..n_blocks {
        x = dark_block(&mut nb, x)?;
    }
    if two_scale {
        // Downsample branch, head there, then FPN back up.
        let deep = conv_bn_leaky(&mut nb, x, ch * 2, 3, 2)?;
        let deep2 = dark_block(&mut nb, deep)?;
        let head_deep = detect_head(&mut nb, deep2, anchors, classes)?;
        let lat = conv_bn_leaky(&mut nb, deep2, ch / 2, 1, 1)?;
        let up = nb.upsample(lat, 2)?;
        let cat = nb.concat(&[up, x], 1)?;
        let fused = conv_bn_leaky(&mut nb, cat, ch, 3, 1)?;
        let head_shallow = detect_head(&mut nb, fused, anchors, classes)?;
        nb.finish(&[head_deep, head_shallow])
    } else {
        let head = detect_head(&mut nb, x, anchors, classes)?;
        nb.finish(&[head])
    }
}

/// A tiny constant so the module exercises `Attrs` directly from here too
/// (kept for doc parity with other families).
#[allow(dead_code)]
fn scale_attr(v: i64) -> Attrs {
    Attrs::new().with("scale", Attr::Int(v))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mlir::verify_function;

    #[test]
    fn generates_valid_functions() {
        let mut root = Rng::new(500);
        for i in 0..30 {
            let mut sf = root.fork(i);
            let mut hf = root.fork(700 + i);
            let f = build(&mut sf, &mut hf, &format!("yolo_{i}")).unwrap();
            verify_function(&f).unwrap();
            assert!(f.xpu_ops().contains(&XpuOp::Sigmoid), "head sigmoid missing");
            assert!(f.xpu_ops().contains(&XpuOp::Maximum), "leaky relu missing");
        }
    }
}

//! Shared building blocks for the synthetic dataflow-graph generators.
//!
//! `NetBuilder` wraps [`FuncBuilder`] with NN-layer-granularity helpers
//! (conv+bn+relu, linear, attention pieces). Weights/constants are emitted
//! as `xpu.const` ops so function arguments stay the true graph inputs —
//! matching the paper's Fig 2 where the function embodies the (sub)graph.

use crate::mlir::{Attr, Attrs, DType, FuncBuilder, Function, Type, ValueId, XpuOp};
use crate::rng::Rng;
use anyhow::Result;

/// Builder with layer-granularity helpers; `dtype` applies to the whole
/// graph (mixed-dtype graphs are not in the paper's corpus).
pub struct NetBuilder {
    pub b: FuncBuilder,
    pub dtype: DType,
}

impl NetBuilder {
    pub fn new(name: &str, dtype: DType) -> Self {
        NetBuilder { b: FuncBuilder::new(name), dtype }
    }

    /// Declare a true graph input.
    pub fn input(&mut self, shape: Vec<i64>) -> ValueId {
        self.b.arg(Type::tensor(shape, self.dtype))
    }

    /// Integer-typed input (token ids for embedding lookups).
    pub fn input_ids(&mut self, shape: Vec<i64>) -> ValueId {
        self.b.arg(Type::tensor(shape, DType::I32))
    }

    /// Current shape of a tensor value (panics on non-tensor: generator bug).
    pub fn shape(&self, x: ValueId) -> Vec<i64> {
        self.b.value_type(x).as_tensor().expect("tensor value").shape.clone()
    }

    /// NCHW channel count.
    pub fn channels(&self, x: ValueId) -> i64 {
        self.shape(x)[1]
    }

    /// Materialize a weight/parameter tensor as `xpu.const`.
    pub fn weight(&mut self, shape: Vec<i64>) -> Result<ValueId> {
        self.b.xpu(
            XpuOp::Const,
            &[],
            Attrs::new()
                .with("shape", Attr::IntArray(shape))
                .with("dtype", Attr::Str(self.dtype.mlir_name().into())),
        )
    }

    /// 2-D convolution with fresh weights.
    pub fn conv2d(
        &mut self,
        x: ValueId,
        out_ch: i64,
        k: i64,
        stride: i64,
        pad: i64,
    ) -> Result<ValueId> {
        let in_ch = self.channels(x);
        let w = self.weight(vec![out_ch, in_ch, k, k])?;
        self.b.xpu(
            XpuOp::Conv2d,
            &[x, w],
            Attrs::new()
                .with("strides", Attr::IntArray(vec![stride, stride]))
                .with("padding", Attr::IntArray(vec![pad, pad])),
        )
    }

    /// Depthwise 3x3 convolution.
    pub fn depthwise(&mut self, x: ValueId, stride: i64) -> Result<ValueId> {
        let c = self.channels(x);
        let w = self.weight(vec![c, 1, 3, 3])?;
        self.b.xpu(
            XpuOp::DepthwiseConv2d,
            &[x, w],
            Attrs::new()
                .with("strides", Attr::IntArray(vec![stride, stride]))
                .with("padding", Attr::IntArray(vec![1, 1])),
        )
    }

    /// Inference-mode batchnorm (scale/bias/mean/var consts).
    pub fn batchnorm(&mut self, x: ValueId) -> Result<ValueId> {
        let c = self.channels(x);
        let scale = self.weight(vec![c])?;
        let bias = self.weight(vec![c])?;
        let mean = self.weight(vec![c])?;
        let var = self.weight(vec![c])?;
        self.b.xpu(XpuOp::BatchNorm, &[x, scale, bias, mean, var], Attrs::new())
    }

    /// Layernorm over the last dim.
    pub fn layernorm(&mut self, x: ValueId) -> Result<ValueId> {
        let d = *self.shape(x).last().expect("layernorm on rank>=1");
        let scale = self.weight(vec![d])?;
        let bias = self.weight(vec![d])?;
        self.b.xpu(XpuOp::LayerNorm, &[x, scale, bias], Attrs::new())
    }

    /// Dense layer: `x @ W (+ b)`.
    pub fn linear(&mut self, x: ValueId, out_dim: i64, bias: bool) -> Result<ValueId> {
        let in_dim = *self.shape(x).last().expect("linear on rank>=1");
        let w = self.weight(vec![in_dim, out_dim])?;
        let y = self.b.xpu(XpuOp::MatMul, &[x, w], Attrs::new())?;
        if bias {
            let b = self.weight(vec![out_dim])?;
            self.b.xpu(XpuOp::Add, &[y, b], Attrs::new())
        } else {
            Ok(y)
        }
    }

    pub fn unary(&mut self, op: XpuOp, x: ValueId) -> Result<ValueId> {
        self.b.xpu(op, &[x], Attrs::new())
    }

    pub fn binary(&mut self, op: XpuOp, a: ValueId, b: ValueId) -> Result<ValueId> {
        self.b.xpu(op, &[a, b], Attrs::new())
    }

    pub fn relu(&mut self, x: ValueId) -> Result<ValueId> {
        self.unary(XpuOp::Relu, x)
    }

    /// conv → bn → activation, the CNN workhorse.
    pub fn conv_bn_act(
        &mut self,
        x: ValueId,
        out_ch: i64,
        k: i64,
        stride: i64,
        act: XpuOp,
    ) -> Result<ValueId> {
        let pad = (k - 1) / 2;
        let c = self.conv2d(x, out_ch, k, stride, pad)?;
        let n = self.batchnorm(c)?;
        self.unary(act, n)
    }

    pub fn maxpool(&mut self, x: ValueId, k: i64, stride: i64, pad: i64) -> Result<ValueId> {
        self.b.xpu(
            XpuOp::MaxPool2d,
            &[x],
            Attrs::new()
                .with("kernel", Attr::IntArray(vec![k, k]))
                .with("strides", Attr::IntArray(vec![stride, stride]))
                .with("padding", Attr::IntArray(vec![pad, pad])),
        )
    }

    pub fn upsample(&mut self, x: ValueId, scale: i64) -> Result<ValueId> {
        self.b.xpu(XpuOp::Upsample, &[x], Attrs::new().with("scale", Attr::Int(scale)))
    }

    pub fn concat(&mut self, xs: &[ValueId], axis: i64) -> Result<ValueId> {
        self.b.xpu(XpuOp::Concat, xs, Attrs::new().with("axis", Attr::Int(axis)))
    }

    pub fn reshape(&mut self, x: ValueId, shape: Vec<i64>) -> Result<ValueId> {
        self.b.xpu(XpuOp::Reshape, &[x], Attrs::new().with("shape", Attr::IntArray(shape)))
    }

    pub fn transpose(&mut self, x: ValueId, perm: Vec<i64>) -> Result<ValueId> {
        self.b.xpu(XpuOp::Transpose, &[x], Attrs::new().with("perm", Attr::IntArray(perm)))
    }

    pub fn softmax(&mut self, x: ValueId, axis: i64) -> Result<ValueId> {
        self.b.xpu(XpuOp::Softmax, &[x], Attrs::new().with("axis", Attr::Int(axis)))
    }

    /// Terminate the function.
    pub fn finish(self, outputs: &[ValueId]) -> Result<Function> {
        self.b.ret(outputs)
    }
}

/// Pick a batch size (paper's corpora are inference graphs: small batches).
pub fn pick_batch(h: &mut Rng) -> i64 {
    *h.pick(&[1, 1, 2, 4, 8])
}

/// Pick a graph dtype (mostly f32, some bf16 as on AI accelerators).
pub fn pick_dtype(h: &mut Rng) -> DType {
    if h.chance(0.25) {
        DType::BF16
    } else {
        DType::F32
    }
}

//! # mlir-cost — ML-driven Hardware Cost Model for MLIR
//!
//! A full reproduction of Das & Mannarswamy, *"ML-driven Hardware Cost
//! Model for MLIR"* (cs.LG 2023): predict hardware characteristics
//! (register pressure, vector-ALU utilization, cycles) of high-level MLIR
//! dataflow graphs by treating the IR as text and training NLP-style
//! sequence regressors. Predictions are multi-output end to end: a
//! bundle declares an ordered list of targets and one forward pass
//! returns a [`pred::PredVec`] — a fixed-order vector of all declared
//! characteristics — through cache, cluster wire, and line protocol.
//!
//! The stack has three layers:
//! - **L3 (this crate)** — MLIR substrate, corpus generators, the
//!   DL-compiler lowering pipeline + xPU simulator that produce ground
//!   truth, the tokenizer/dataset pipeline, the PJRT runtime that executes
//!   AOT-compiled models, the training orchestrator, and the serving
//!   coordinator a compiler queries. The coordinator is built for the
//!   paper's traffic shape — concurrent, heavily duplicated probe streams
//!   from autotuning passes: an N-way-sharded single-flight LRU
//!   prediction cache (duplicate concurrent misses coalesce onto one
//!   model invocation), a dynamic batcher, a batch API
//!   (`Service::predict_many` / the `mlir_batch` wire request) that moves
//!   whole probe sets through the pipeline in one call, and
//!   batching-health metrics (fill ratio, padded slots, coalesced
//!   queries, shard contention) over the `stats` command. The serving
//!   plane is event-driven: an epoll front end over the vendored
//!   `minipoll` bindings (no mio/tokio) where one — or `--io-threads N` —
//!   event-loop thread(s) own every connection as a nonblocking socket
//!   with buffered partial-line reassembly, `EPOLLOUT` write
//!   backpressure, and an eventfd shutdown doorbell, so hundreds of idle
//!   probe connections cost zero CPU. Between the front end and the
//!   compute sits the routing tier (`coordinator/router.rs`): every
//!   target is served by a *family* of registered model variants (e.g.
//!   a `max_len=128` FC model next to a `max_len=512` conv stack), and
//!   each query's token length picks the cheapest variant that covers
//!   it — with an optional per-request `budget_us` that reroutes to a
//!   faster variant when the preferred one's latency EWMA would blow
//!   the budget (`routed_by_variant` / `budget_downgrades` /
//!   `no_covering_variant` in the stats). On the compute side each
//!   variant runs a `--workers-per-head` pool draining its shared batch
//!   queue, every worker compiles the manifest's full predict
//!   batch-size ladder, and each drained chunk executes on the smallest
//!   rung that covers it (`exec_by_batch` / `padded_slots` make the
//!   saved padding observable). The text→ids
//!   front end is zero-allocation: a borrowed-slice lexer, a sink-based
//!   tokenizer whose id-direct sink maps tokens straight to vocabulary
//!   ids (per-`OpKind` id tables, one reusable scratch buffer), a
//!   text-level encode memo so duplicate autotuning probes skip
//!   parse/tokenize/encode entirely (one FxHash + one shard lookup), and
//!   FxHash on every vocab/cache/memo probe — instrumented via the
//!   `frontend_memo_hits` / `encode_ns` counters. Above the single node
//!   sits the cluster tier (`cluster/`): a consistent-hash ring (FxHash,
//!   64 virtual nodes per peer, static `--peers` membership) assigns
//!   every cache key an owner node so a fleet of coordinators shares one
//!   logical prediction cache — remote-owned misses probe the owner's
//!   cache over new `cache_get`/`cache_put` line-protocol commands
//!   (executed by per-peer worker pools with health states, reconnect
//!   and backoff — never by an IO thread) and write computed values back
//!   to the owner asynchronously, so a duplicated autotuning probe is
//!   computed once per cluster; a Down peer degrades its keys to
//!   local-compute-plus-local-cache (`degraded_fallbacks`), never an
//!   error. The event loop itself schedules buffered request lines
//!   round-robin with a per-wakeup per-connection budget, so one
//!   pipelining client cannot monopolize an IO thread
//!   (`fairness_deferrals`). Python is never on the
//!   request path.
//! - **L2 (JAX, build-time)** — the FC / LSTM / Conv1D regressors in
//!   `python/compile/model.py`, AOT-lowered to HLO text.
//! - **L1 (Pallas, build-time)** — the stacked Conv1D+MaxPool hot path in
//!   `python/compile/kernels/`, verified against a pure-jnp oracle.

pub mod autotune;
pub mod bundle;
pub mod cluster;
pub mod coordinator;
pub mod dataset;
pub mod graphgen;
pub mod json;
pub mod lower;
pub mod mlir;
pub mod pred;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod tokenizer;
pub mod train;
pub mod benchkit;

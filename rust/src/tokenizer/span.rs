//! Segment-level incremental tokenization — the substrate of the
//! session/delta tier.
//!
//! Autotuning traffic is thousands of near-duplicate probes: one-line
//! edits to a registered base function. The full pipeline re-lexes the
//! whole text per probe; this module tokenizes each *text line* into an
//! independent [`IdSpan`] so an edited probe re-lexes only its changed
//! lines and splices cached spans for the rest.
//!
//! Correctness contract: concatenating every line's span in text order,
//! appending [`tail_span`], then truncating/padding to `max_len`
//! ([`splice_ids`]) yields ids **byte-identical** to the fused
//! [`super::encode_function`] pipeline on the same text. This holds
//! because the printed form ([`crate::mlir::printer`]) is one op per
//! line and [`super::tokenize_into`]'s walk is pre-order — i.e. textual
//! line order — with exactly three non-local emissions, each handled
//! here explicitly:
//!
//! - the header's `->` token is emitted even when the text has no
//!   `-> R` clause (zero-return functions);
//! - `return` **lines** emit nothing — the single trailing `"return"`
//!   token is position-independent and becomes the fixed [`tail_span`];
//! - `affine.for`'s `step` attribute token is always emitted, default 1,
//!   even when the printed line elides ` step 1`.
//!
//! Per-line tokenization is *context-free*: every token a line
//! contributes is derivable from that line's own bytes (result shapes
//! come from the line's type annotation; a load's scalar result dtype
//! from its `: memref<..xD>` suffix). Operand *names* are tokens but
//! operand *types* are not, so a one-line edit never invalidates
//! neighbouring spans. Lines are validated against the same grammar as
//! [`crate::mlir::parser`]; cross-line semantic errors (an operand name
//! no other line defines) are the one class the full parser rejects
//! that the splice path cannot see.

use super::{
    CountSink, OpIdTable, Scheme, TokenSink, Vocab, EMBED_VOCAB_CAP, OOV_ID, PAD_ID,
};
use crate::mlir::parser::{lex, parse_type_lit, Tok};
use crate::mlir::{AffineOp, Attr, Attrs, DType, MemRefOp, OpKind, Type};
use anyhow::{anyhow, bail, ensure, Context, Result};
use fxhash::FxHasher;
use std::fmt::Write as _;
use std::hash::{Hash, Hasher};

/// One line's cached contribution to the id row: the vocabulary ids
/// (already [`EMBED_VOCAB_CAP`]-clamped, **not** truncated or padded)
/// plus how many of them were OOV pre-clamp — exactly the two facts
/// [`splice_ids`] needs to reproduce `IdSink` semantics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IdSpan {
    pub ids: Vec<u32>,
    pub oov: u32,
}

impl IdSpan {
    /// Memory the cached span retains (the `SpanTable` capacity unit).
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }
}

/// Id-direct sink for one line: same push semantics as
/// [`super::IdSink`] (OOV counted pre-clamp, ids clamped to
/// [`EMBED_VOCAB_CAP`]) but *unbounded* — truncation to `max_len`
/// happens once at splice time, not per span.
pub struct SpanSink<'v> {
    vocab: &'v Vocab,
    ops: &'v OpIdTable,
    ids: Vec<u32>,
    oov: u32,
}

impl<'v> SpanSink<'v> {
    pub fn new(vocab: &'v Vocab, ops: &'v OpIdTable) -> SpanSink<'v> {
        SpanSink { vocab, ops, ids: Vec::new(), oov: 0 }
    }

    #[inline]
    fn push(&mut self, id: u32) {
        if id == OOV_ID {
            self.oov += 1;
        }
        self.ids.push(id.min(EMBED_VOCAB_CAP - 1));
    }

    pub fn finish(self) -> IdSpan {
        IdSpan { ids: self.ids, oov: self.oov }
    }
}

impl TokenSink for SpanSink<'_> {
    fn token(&mut self, tok: &str) {
        let id = self.vocab.id_of(tok);
        self.push(id);
    }

    fn op(&mut self, kind: &OpKind) {
        let id = self.ops.id(kind);
        self.push(id);
    }
}

/// FxHash of one line's bytes — the `SpanTable` key. Scheme and vocab
/// are *not* part of the key because every span table is owned by one
/// serving variant (fixed scheme, vocab, op table).
pub fn line_hash(line: &str) -> u64 {
    let mut h = FxHasher::default();
    line.hash(&mut h);
    h.finish()
}

/// The fixed trailing span: [`super::tokenize_into`] emits one
/// `"return"` token after the walk regardless of where `return` lines
/// sit in the text.
pub fn tail_span(vocab: &Vocab) -> IdSpan {
    let id = vocab.id_of("return");
    IdSpan {
        ids: vec![id.min(EMBED_VOCAB_CAP - 1)],
        oov: u32::from(id == OOV_ID),
    }
}

/// Token count the trailing `"return"` contributes (pairs with
/// [`line_token_count`] sums the way [`tail_span`] pairs with
/// [`line_span`]).
pub const TAIL_TOKEN_COUNT: usize = 1;

/// Concatenate spans in text order into the padded `[max_len]` id row
/// plus the whole-stream OOV count — `IdSink` semantics exactly: OOV
/// sums over *all* spans (pre-truncation), ids stop at `max_len`, the
/// remainder pads with [`PAD_ID`]. The caller chains [`tail_span`] as
/// the final element.
pub fn splice_ids<'a>(
    spans: impl IntoIterator<Item = &'a IdSpan>,
    max_len: usize,
) -> (Vec<u32>, usize) {
    let mut ids: Vec<u32> = Vec::with_capacity(max_len);
    let mut oov = 0usize;
    for span in spans {
        oov += span.oov as usize;
        if ids.len() < max_len {
            let take = (max_len - ids.len()).min(span.ids.len());
            ids.extend_from_slice(&span.ids[..take]);
        }
    }
    ids.resize(max_len, PAD_ID);
    (ids, oov)
}

/// Tokenize one text line into `sink`. Empty result for blank /
/// comment-only / closing-`}` / `return` lines. Errors on any line that
/// does not match the printed grammar — the session tier treats that as
/// "not spliceable", never as "emit something close".
pub fn line_tokens_into<S: TokenSink>(line: &str, scheme: Scheme, sink: &mut S) -> Result<()> {
    let toks = lex(line).with_context(|| format!("lexing line {line:?}"))?;
    let mut c = Cursor { toks: &toks, pos: 0 };
    let mut scratch = String::new();
    match c.peek().copied() {
        None => Ok(()), // blank or comment-only line
        Some(Tok::RBrace) => {
            c.next()?;
            c.done()
        }
        Some(Tok::Ident("func.func")) => c.header(sink, &mut scratch),
        Some(Tok::Ident("return")) => c.ret(),
        Some(Tok::Ident("affine.for")) => c.affine_for(scheme, sink, &mut scratch),
        Some(Tok::Ident("affine.yield")) => {
            c.next()?;
            sink.op(&OpKind::Affine(AffineOp::Yield));
            c.done()
        }
        Some(Tok::Ident(kw @ ("affine.store" | "affine.vector_store"))) => {
            c.next()?;
            c.store(kw, scheme, sink, &mut scratch)
        }
        Some(Tok::Value(_)) => c.assignment(scheme, sink, &mut scratch),
        got => bail!("unrecognized line start: {got:?}"),
    }
}

/// Unpadded token count one line contributes under `scheme` — what the
/// router's length-based variant choice sums (plus
/// [`TAIL_TOKEN_COUNT`]) without touching any vocabulary.
pub fn line_token_count(line: &str, scheme: Scheme) -> Result<usize> {
    let mut sink = CountSink::default();
    line_tokens_into(line, scheme, &mut sink)?;
    Ok(sink.0)
}

/// Lex one line into its cached [`IdSpan`] under a variant's
/// vocab/op-table.
pub fn line_span(line: &str, scheme: Scheme, vocab: &Vocab, ops: &OpIdTable) -> Result<IdSpan> {
    let mut sink = SpanSink::new(vocab, ops);
    line_tokens_into(line, scheme, &mut sink)?;
    Ok(sink.finish())
}

/// Full line-by-line encode of `text`: every line through
/// [`line_span`], spliced with [`tail_span`]. This is the cold path the
/// session tier pays once at `session_open` (and per *changed* line on
/// deltas); it exists standalone so tests can assert byte-identity
/// against [`super::encode_function`] without any session plumbing.
pub fn encode_lines(
    text: &str,
    scheme: Scheme,
    vocab: &Vocab,
    ops: &OpIdTable,
    max_len: usize,
) -> Result<(Vec<u32>, usize)> {
    let mut spans = Vec::new();
    for line in text.lines() {
        spans.push(line_span(line, scheme, vocab, ops)?);
    }
    let tail = tail_span(vocab);
    Ok(splice_ids(spans.iter().chain(std::iter::once(&tail)), max_len))
}

/// Line-by-line token count of `text` (tail included) — must equal
/// [`super::token_count`] of the parsed function.
pub fn token_count_lines(text: &str, scheme: Scheme) -> Result<usize> {
    let mut n = TAIL_TOKEN_COUNT;
    for line in text.lines() {
        n += line_token_count(line, scheme)?;
    }
    Ok(n)
}

// ---------------------------------------------------------------------------
// Per-line grammar
// ---------------------------------------------------------------------------

/// Cursor over one line's borrowed token slice — the same
/// recursive-descent helpers as [`crate::mlir::parser`]'s `Parser`,
/// minus symbol state (a line is tokenized context-free).
struct Cursor<'t, 'a> {
    toks: &'t [Tok<'a>],
    pos: usize,
}

impl<'t, 'a> Cursor<'t, 'a> {
    fn peek(&self) -> Option<&Tok<'a>> {
        self.toks.get(self.pos)
    }

    fn next(&mut self) -> Result<Tok<'a>> {
        let t = self
            .toks
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of line"))?;
        self.pos += 1;
        Ok(t)
    }

    fn expect(&mut self, t: Tok<'a>) -> Result<()> {
        let got = self.next()?;
        ensure!(got == t, "expected {t:?}, got {got:?}");
        Ok(())
    }

    fn eat(&mut self, t: Tok<'a>) -> bool {
        if self.peek() == Some(&t) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn done(&self) -> Result<()> {
        ensure!(self.peek().is_none(), "trailing input on line: {:?}", self.peek());
        Ok(())
    }

    fn expect_ident(&mut self, kw: &str) -> Result<()> {
        match self.next()? {
            Tok::Ident(s) if s == kw => Ok(()),
            got => bail!("expected '{kw}', got {got:?}"),
        }
    }

    fn value_name(&mut self) -> Result<&'a str> {
        match self.next()? {
            Tok::Value(s) => Ok(s),
            got => bail!("expected %value, got {got:?}"),
        }
    }

    fn int(&mut self) -> Result<i64> {
        match self.next()? {
            Tok::Number(s) => s.parse::<i64>().with_context(|| format!("bad integer '{s}'")),
            got => bail!("expected integer, got {got:?}"),
        }
    }

    fn parse_type(&mut self) -> Result<Type> {
        match self.next()? {
            Tok::TypeLit(lit) => parse_type_lit(lit),
            Tok::Ident("index") => Ok(Type::Index),
            Tok::Ident(s) => DType::parse(s)
                .map(Type::Scalar)
                .ok_or_else(|| anyhow!("unknown type '{s}'")),
            got => bail!("expected a type, got {got:?}"),
        }
    }

    /// Same value grammar as the full parser's `parse_attr_value`, so a
    /// re-formatted attr token (`Attr`'s `Display`) is byte-identical
    /// to what the walk emits for the parsed op.
    fn parse_attr_value(&mut self) -> Result<Attr> {
        match self.next()? {
            Tok::Number(s) => {
                if s.contains('.') || s.contains('e') || s.contains('E') {
                    Ok(Attr::Float(s.parse::<f64>().with_context(|| format!("bad float '{s}'"))?))
                } else {
                    Ok(Attr::Int(s.parse::<i64>().with_context(|| format!("bad int '{s}'"))?))
                }
            }
            Tok::Str(s) => Ok(Attr::Str(s.to_string())),
            Tok::Ident("true") => Ok(Attr::Bool(true)),
            Tok::Ident("false") => Ok(Attr::Bool(false)),
            Tok::LBracket => {
                let mut v = Vec::new();
                if !self.eat(Tok::RBracket) {
                    loop {
                        v.push(self.int()?);
                        if !self.eat(Tok::Comma) {
                            break;
                        }
                    }
                    self.expect(Tok::RBracket)?;
                }
                Ok(Attr::IntArray(v))
            }
            got => bail!("expected attribute value, got {got:?}"),
        }
    }

    fn parse_attrs(&mut self) -> Result<Attrs> {
        let mut attrs = Attrs::new();
        if !self.eat(Tok::LBrace) {
            return Ok(attrs);
        }
        if self.eat(Tok::RBrace) {
            return Ok(attrs);
        }
        loop {
            let key = match self.next()? {
                Tok::Ident(s) => s,
                got => bail!("expected attribute key, got {got:?}"),
            };
            self.expect(Tok::Eq)?;
            let value = self.parse_attr_value()?;
            attrs.set(key, value);
            if !self.eat(Tok::Comma) {
                break;
            }
        }
        self.expect(Tok::RBrace)?;
        Ok(attrs)
    }

    /// `[%i, %j]` — returns the index value names in order.
    fn index_names(&mut self) -> Result<Vec<&'a str>> {
        self.expect(Tok::LBracket)?;
        let mut names = Vec::new();
        if !self.eat(Tok::RBracket) {
            loop {
                names.push(self.value_name()?);
                if !self.eat(Tok::Comma) {
                    break;
                }
            }
            self.expect(Tok::RBracket)?;
        }
        Ok(names)
    }

    // -- line forms ---------------------------------------------------------

    /// `func.func @name(%a: T, ...) [-> R | -> (R, ...)] {`
    fn header<S: TokenSink>(&mut self, sink: &mut S, scratch: &mut String) -> Result<()> {
        self.expect_ident("func.func")?;
        match self.next()? {
            Tok::Symbol(_) => {}
            got => bail!("expected @name, got {got:?}"),
        }
        sink.token("func");
        self.expect(Tok::LParen)?;
        if !self.eat(Tok::RParen) {
            loop {
                self.value_name()?;
                self.expect(Tok::Colon)?;
                let ty = self.parse_type()?;
                sink.token(shape_token(&ty, scratch));
                if !self.eat(Tok::Comma) {
                    break;
                }
            }
            self.expect(Tok::RParen)?;
        }
        // The walk emits "->" unconditionally; the printed header omits
        // the arrow clause entirely for zero-return functions.
        sink.token("->");
        if self.eat(Tok::Arrow) {
            if self.eat(Tok::LParen) {
                loop {
                    let ty = self.parse_type()?;
                    sink.token(shape_token(&ty, scratch));
                    if !self.eat(Tok::Comma) {
                        break;
                    }
                }
                self.expect(Tok::RParen)?;
            } else {
                let ty = self.parse_type()?;
                sink.token(shape_token(&ty, scratch));
            }
        }
        self.expect(Tok::LBrace)?;
        self.done()
    }

    /// `return` / `return %a, %b : T, T` — zero tokens (see [`tail_span`]),
    /// but the line is still validated.
    fn ret(&mut self) -> Result<()> {
        self.expect_ident("return")?;
        if matches!(self.peek(), Some(Tok::Value(_))) {
            let mut n = 0usize;
            loop {
                self.value_name()?;
                n += 1;
                if !self.eat(Tok::Comma) {
                    break;
                }
            }
            self.expect(Tok::Colon)?;
            for i in 0..n {
                if i > 0 {
                    self.expect(Tok::Comma)?;
                }
                self.parse_type()?;
            }
        }
        self.done()
    }

    /// `affine.for %iv = LB to UB [step S] {` — the induction variable
    /// is a region argument, never a token; the parser always sets all
    /// three bound attrs (step defaults to 1 when elided).
    fn affine_for<S: TokenSink>(
        &mut self,
        scheme: Scheme,
        sink: &mut S,
        scratch: &mut String,
    ) -> Result<()> {
        self.expect_ident("affine.for")?;
        self.value_name()?;
        self.expect(Tok::Eq)?;
        let lb = self.int()?;
        self.expect_ident("to")?;
        let ub = self.int()?;
        let step = if matches!(self.peek(), Some(Tok::Ident(s)) if *s == "step") {
            self.next()?;
            self.int()?
        } else {
            1
        };
        self.expect(Tok::LBrace)?;
        self.done()?;
        sink.op(&OpKind::Affine(AffineOp::For));
        if scheme == Scheme::OpsOperands {
            let attrs = Attrs::new()
                .with("lb", Attr::Int(lb))
                .with("ub", Attr::Int(ub))
                .with("step", Attr::Int(step));
            emit_attrs(&attrs, sink, scratch);
        }
        Ok(())
    }

    /// `affine.store %v, %m[%i, ...] [{attrs}] : memref<...>`
    fn store<S: TokenSink>(
        &mut self,
        kw: &str,
        scheme: Scheme,
        sink: &mut S,
        scratch: &mut String,
    ) -> Result<()> {
        let value = self.value_name()?;
        self.expect(Tok::Comma)?;
        let memref = self.value_name()?;
        let indices = self.index_names()?;
        let attrs = self.parse_attrs()?;
        self.expect(Tok::Colon)?;
        self.parse_type()?;
        self.done()?;
        let op = if kw == "affine.store" { AffineOp::Store } else { AffineOp::VectorStore };
        sink.op(&OpKind::Affine(op));
        if scheme == Scheme::OpsOperands {
            sink.token(value_token(value, scratch));
            sink.token(value_token(memref, scratch));
            for ix in &indices {
                sink.token(value_token(ix, scratch));
            }
            emit_attrs(&attrs, sink, scratch);
        }
        Ok(())
    }

    /// `%r = <load | alloc | generic op>` lines.
    fn assignment<S: TokenSink>(
        &mut self,
        scheme: Scheme,
        sink: &mut S,
        scratch: &mut String,
    ) -> Result<()> {
        let result = self.value_name()?;
        self.expect(Tok::Eq)?;
        match self.next()? {
            // `%r = affine.load %m[%i, ...] [{attrs}] : memref<..xD>` —
            // the result type is Scalar(D) for load AND vector_load,
            // recoverable from the line's own memref annotation.
            Tok::Ident(kw @ ("affine.load" | "affine.vector_load")) => {
                let memref = self.value_name()?;
                let indices = self.index_names()?;
                let attrs = self.parse_attrs()?;
                self.expect(Tok::Colon)?;
                let mem_ty = self.parse_type()?;
                self.done()?;
                let dtype = match &mem_ty {
                    Type::MemRef(t) => t.dtype,
                    _ => bail!("{kw}: annotation is not a memref type"),
                };
                let op = if kw == "affine.load" { AffineOp::Load } else { AffineOp::VectorLoad };
                sink.op(&OpKind::Affine(op));
                if scheme == Scheme::OpsOperands {
                    sink.token(value_token(memref, scratch));
                    for ix in &indices {
                        sink.token(value_token(ix, scratch));
                    }
                    sink.token(value_token(result, scratch));
                    sink.token(shape_token(&Type::Scalar(dtype), scratch));
                    emit_attrs(&attrs, sink, scratch);
                }
                Ok(())
            }
            Tok::Ident("memref.alloc") => {
                self.expect(Tok::LParen)?;
                self.expect(Tok::RParen)?;
                self.expect(Tok::Colon)?;
                let ty = self.parse_type()?;
                self.done()?;
                ensure!(matches!(ty, Type::MemRef(_)), "memref.alloc must yield a memref");
                sink.op(&OpKind::MemRef(MemRefOp::Alloc));
                if scheme == Scheme::OpsOperands {
                    sink.token(value_token(result, scratch));
                    sink.token(shape_token(&ty, scratch));
                }
                Ok(())
            }
            // generic: `%r = "dialect.op"(%a, %b) [{attrs}] : (T, T) -> U`
            Tok::Str(opname) => {
                let kind = OpKind::parse_name(opname)
                    .ok_or_else(|| anyhow!("unknown op \"{opname}\""))?;
                self.expect(Tok::LParen)?;
                let mut operands = Vec::new();
                if !self.eat(Tok::RParen) {
                    loop {
                        operands.push(self.value_name()?);
                        if !self.eat(Tok::Comma) {
                            break;
                        }
                    }
                    self.expect(Tok::RParen)?;
                }
                let attrs = self.parse_attrs()?;
                self.expect(Tok::Colon)?;
                self.expect(Tok::LParen)?;
                for i in 0..operands.len() {
                    if i > 0 {
                        self.expect(Tok::Comma)?;
                    }
                    self.parse_type()?;
                }
                self.expect(Tok::RParen)?;
                self.expect(Tok::Arrow)?;
                let result_ty = self.parse_type()?;
                self.done()?;
                sink.op(&kind);
                if scheme == Scheme::OpsOperands {
                    for o in &operands {
                        sink.token(value_token(o, scratch));
                    }
                    sink.token(value_token(result, scratch));
                    sink.token(shape_token(&result_ty, scratch));
                    emit_attrs(&attrs, sink, scratch);
                }
                Ok(())
            }
            got => bail!("unexpected token after '%{result} =': {got:?}"),
        }
    }
}

/// Shape token for an already-parsed type — mirrors the walk's
/// `shape_token_into`, which reads the type off the function's value
/// table; here the type comes straight from the line's annotation.
fn shape_token<'s>(ty: &Type, scratch: &'s mut String) -> &'s str {
    scratch.clear();
    match ty {
        Type::Tensor(t) | Type::MemRef(t) => t.write_shape_token(scratch),
        Type::Index => scratch.push_str("index"),
        Type::Scalar(d) => {
            let _ = write!(scratch, "scalar_{d}");
        }
    }
    scratch
}

fn value_token<'s>(name: &str, scratch: &'s mut String) -> &'s str {
    scratch.clear();
    scratch.push('%');
    scratch.push_str(name);
    scratch
}

/// Emit `{k}={v}` tokens in dictionary order, exactly as the walk does
/// for the parsed op's attrs.
fn emit_attrs<S: TokenSink>(attrs: &Attrs, sink: &mut S, scratch: &mut String) {
    for (k, v) in &attrs.0 {
        scratch.clear();
        let _ = write!(scratch, "{k}={v}");
        sink.token(scratch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphgen::{generate, Family, GraphSpec};
    use crate::mlir::{parse_function, print_function};
    use crate::tokenizer::{encode_function, token_count, tokenize};

    fn corpus() -> Vec<String> {
        let mut texts = Vec::new();
        for i in 0..12u64 {
            let spec = GraphSpec {
                family: Family::ALL[(i % Family::ALL.len() as u64) as usize],
                structure_seed: i,
                shape_seed: i + 31,
            };
            let f = generate(&spec).unwrap();
            texts.push(print_function(&f));
            if i % 3 == 0 {
                let a = crate::lower::affine::lower_to_affine(&f).unwrap();
                texts.push(print_function(&a));
            }
        }
        texts
    }

    #[test]
    fn line_concat_matches_full_pipeline() {
        for text in corpus() {
            let f = parse_function(&text).unwrap();
            for scheme in [Scheme::OpsOnly, Scheme::OpsOperands] {
                let toks = tokenize(&f, scheme);
                let vocab = Vocab::build([toks].iter(), 1);
                let table = OpIdTable::build(&vocab);
                for max_len in [8, 64, 512] {
                    let full = encode_function(&f, scheme, &vocab, &table, max_len);
                    let spliced = encode_lines(&text, scheme, &vocab, &table, max_len).unwrap();
                    assert_eq!(spliced, full, "{scheme:?}/{max_len}\n{text}");
                }
            }
        }
    }

    #[test]
    fn line_counts_match_full_pipeline() {
        for text in corpus() {
            let f = parse_function(&text).unwrap();
            for scheme in [Scheme::OpsOnly, Scheme::OpsOperands] {
                assert_eq!(
                    token_count_lines(&text, scheme).unwrap(),
                    token_count(&f, scheme),
                    "{scheme:?}\n{text}"
                );
            }
        }
    }

    #[test]
    fn structural_lines_are_empty_spans() {
        let vocab = Vocab::build([vec!["func".to_string()]].iter(), 1);
        let table = OpIdTable::build(&vocab);
        for line in ["", "   ", "// comment", "}", "  }", "  return %0 : tensor<1xf32>"] {
            for scheme in [Scheme::OpsOnly, Scheme::OpsOperands] {
                let span = line_span(line, scheme, &vocab, &table).unwrap();
                assert!(span.is_empty(), "{line:?} under {scheme:?} produced {:?}", span.ids);
                assert_eq!(line_token_count(line, scheme).unwrap(), 0);
            }
        }
    }

    #[test]
    fn zero_return_header_still_emits_arrow() {
        // The printer omits `-> R` entirely when a function returns
        // nothing, but the token stream always carries "->".
        let vocab = Vocab::build([vec!["->".to_string()]].iter(), 1);
        let table = OpIdTable::build(&vocab);
        let span =
            line_span("func.func @f() {", Scheme::OpsOnly, &vocab, &table).unwrap();
        assert_eq!(span.ids.len(), 2); // "func", "->"
        assert_eq!(span.ids[1], vocab.id_of("->").min(EMBED_VOCAB_CAP - 1));
    }

    #[test]
    fn elided_step_still_emits_step_attr() {
        let n = line_token_count("affine.for %1 = 0 to 8 {", Scheme::OpsOperands).unwrap();
        assert_eq!(n, 4, "affine.for + lb= + ub= + step=");
        let m = line_token_count("affine.for %1 = 0 to 8 step 2 {", Scheme::OpsOperands).unwrap();
        assert_eq!(m, 4);
        assert_eq!(line_token_count("affine.for %1 = 0 to 8 {", Scheme::OpsOnly).unwrap(), 1);
    }

    #[test]
    fn malformed_lines_error_instead_of_guessing() {
        for line in [
            "%0 = \"xpu.bogus\"() : () -> tensor<1xf32>", // unknown op
            "func.func @f(%a: tensor<1xf32>",              // truncated header
            "%0 = affine.load %m[%i] : tensor<4xf32>",     // load needs a memref annotation
            "affine.for %i = 0 to {",                      // missing bound
            "wat",                                          // not a line form at all
        ] {
            assert!(
                line_tokens_into(line, Scheme::OpsOperands, &mut CountSink::default()).is_err(),
                "{line:?} should not tokenize"
            );
        }
    }

    #[test]
    fn splice_truncates_and_pads_like_idsink() {
        let a = IdSpan { ids: vec![1, 2, 3], oov: 1 };
        let b = IdSpan { ids: vec![4, 5], oov: 2 };
        let (ids, oov) = splice_ids([&a, &b], 4);
        assert_eq!(ids, vec![1, 2, 3, 4]);
        assert_eq!(oov, 3, "OOV counts the whole stream, past truncation");
        let (ids, _) = splice_ids([&a, &b], 8);
        assert_eq!(ids, vec![1, 2, 3, 4, 5, PAD_ID, PAD_ID, PAD_ID]);
    }

    #[test]
    fn line_hash_distinguishes_lines() {
        assert_eq!(line_hash("a"), line_hash("a"));
        assert_ne!(line_hash("affine.yield"), line_hash("affine.yield "));
    }
}

//! Vocabulary: token string ↔ id, built on the training split only.
//!
//! Id 0 is PAD, id 1 is OOV. The paper leans on the observation that "in
//! DL subgraphs many of the tensor sizes appear frequently across multiple
//! models, [so] the probability of OOV tokens remains low" — `min_count`
//! trims the long tail to keep that honest, and builtin op tokens are
//! always present.

use crate::json::{parse, Json};
use anyhow::{anyhow, Result};
use fxhash::{FxBuildHasher, FxHashMap};
use std::collections::HashMap;

pub const PAD_ID: u32 = 0;
pub const OOV_ID: u32 = 1;

/// Token vocabulary. `to_id` is FxHash-keyed: `id_of` runs once per token
/// on the serving hot path, and these short internal keys don't need
/// SipHash's DoS resistance.
#[derive(Debug, Clone)]
pub struct Vocab {
    to_id: FxHashMap<String, u32>,
    to_token: Vec<String>,
}

impl Vocab {
    /// Build from an iterator of token streams. Tokens seen fewer than
    /// `min_count` times are dropped (they will encode as OOV). Builtin
    /// op/keyword tokens are always included.
    pub fn build<'a, I>(streams: I, min_count: usize) -> Vocab
    where
        I: Iterator<Item = &'a Vec<String>>,
    {
        let mut counts: FxHashMap<&str, usize> = FxHashMap::default();
        let mut order: Vec<&str> = Vec::new();
        for stream in streams {
            for tok in stream {
                let c = counts.entry(tok.as_str()).or_insert(0);
                if *c == 0 {
                    order.push(tok.as_str());
                }
                *c += 1;
            }
        }
        let mut to_token: Vec<String> = vec!["<pad>".to_string(), "<oov>".to_string()];
        let mut to_id: FxHashMap<String, u32> = FxHashMap::default();
        to_id.insert("<pad>".into(), PAD_ID);
        to_id.insert("<oov>".into(), OOV_ID);
        let mut add = |tok: &str| {
            if !to_id.contains_key(tok) {
                let id = to_token.len() as u32;
                to_token.push(tok.to_string());
                to_id.insert(tok.to_string(), id);
            }
        };
        for tok in super::builtin_tokens() {
            add(&tok);
        }
        for tok in order {
            if counts[tok] >= min_count {
                add(tok);
            }
        }
        Vocab { to_id, to_token }
    }

    pub fn id_of(&self, token: &str) -> u32 {
        self.to_id.get(token).copied().unwrap_or(OOV_ID)
    }

    pub fn token_of(&self, id: u32) -> Option<&str> {
        self.to_token.get(id as usize).map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.to_token.len()
    }

    /// True when the vocabulary carries no real tokens — only the
    /// always-present `<pad>` + `<oov>` sentinels.
    pub fn is_empty(&self) -> bool {
        self.to_token.len() <= 2
    }

    /// Serialize to JSON (`{"tokens": [...]}`, index = id).
    pub fn to_json(&self) -> Json {
        Json::obj().with(
            "tokens",
            Json::Arr(self.to_token.iter().map(|t| Json::str(t.clone())).collect()),
        )
    }

    /// Load from the JSON produced by [`Vocab::to_json`].
    pub fn from_json(src: &str) -> Result<Vocab> {
        let v = parse(src)?;
        let toks = v.req_arr("tokens")?;
        let mut to_token = Vec::with_capacity(toks.len());
        let mut to_id = HashMap::with_capacity_and_hasher(toks.len(), FxBuildHasher::default());
        for (i, t) in toks.iter().enumerate() {
            let s = t.as_str().ok_or_else(|| anyhow!("non-string token at {i}"))?;
            to_token.push(s.to_string());
            to_id.insert(s.to_string(), i as u32);
        }
        anyhow::ensure!(to_token.len() >= 2, "vocab must include pad+oov");
        Ok(Vocab { to_id, to_token })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<Vocab> {
        Vocab::from_json(&std::fs::read_to_string(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn streams(data: &[&[&str]]) -> Vec<Vec<String>> {
        data.iter().map(|s| s.iter().map(|t| t.to_string()).collect()).collect()
    }

    #[test]
    fn build_and_lookup() {
        let s = streams(&[&["a", "b", "a"], &["a", "c"]]);
        let v = Vocab::build(s.iter(), 1);
        assert_ne!(v.id_of("a"), OOV_ID);
        assert_ne!(v.id_of("b"), OOV_ID);
        assert_eq!(v.id_of("zzz"), OOV_ID);
        assert_eq!(v.token_of(PAD_ID), Some("<pad>"));
        assert_eq!(v.token_of(OOV_ID), Some("<oov>"));
    }

    #[test]
    fn min_count_trims_tail() {
        let s = streams(&[&["common", "common", "rare"]]);
        let v = Vocab::build(s.iter(), 2);
        assert_ne!(v.id_of("common"), OOV_ID);
        assert_eq!(v.id_of("rare"), OOV_ID);
    }

    #[test]
    fn builtins_always_present() {
        let s = streams(&[&["x"]]);
        let v = Vocab::build(s.iter(), 1);
        assert_ne!(v.id_of("xpu.matmul"), OOV_ID);
        assert_ne!(v.id_of("affine.for"), OOV_ID);
        assert_ne!(v.id_of("arith.fma"), OOV_ID);
    }

    #[test]
    fn json_roundtrip() {
        let s = streams(&[&["1x128xf32", "%arg0", "xpu.mult"]]);
        let v = Vocab::build(s.iter(), 1);
        let text = v.to_json().to_string();
        let v2 = Vocab::from_json(&text).unwrap();
        assert_eq!(v.len(), v2.len());
        assert_eq!(v.id_of("1x128xf32"), v2.id_of("1x128xf32"));
        assert_eq!(v2.id_of("<pad>"), PAD_ID);
    }

    #[test]
    fn is_empty_reflects_real_tokens() {
        // Regression: this used to be hardcoded `false`. A vocab holding
        // only the pad+oov sentinels IS empty.
        let v = Vocab::from_json(r#"{"tokens": ["<pad>", "<oov>"]}"#).unwrap();
        assert!(v.is_empty());
        assert_eq!(v.len(), 2);
        // Any built vocab carries the builtin op tokens → non-empty.
        let s = streams(&[&["x"]]);
        let v2 = Vocab::build(s.iter(), 1);
        assert!(!v2.is_empty());
    }

    #[test]
    fn ids_are_stable_and_dense() {
        let s = streams(&[&["t1", "t2", "t3"]]);
        let v = Vocab::build(s.iter(), 1);
        for id in 0..v.len() as u32 {
            let tok = v.token_of(id).unwrap();
            assert_eq!(v.id_of(tok), id);
        }
    }
}

//! MLIR-as-text tokenization — the paper's §3 "Tokenization and
//! Embedding" stage, both schemes:
//!
//! 1. **Ops-only** (`Scheme::OpsOnly`): the `xpu.op` mnemonic sequence,
//!    with the function's input/output tensor shapes each tokenized *as a
//!    single entity* (`1x128x768xf32` is one token). Operand information
//!    is dropped — no data-dependence tracking (paper Fig 4).
//! 2. **Ops+operands** (`Scheme::OpsOperands`): ops *and* their operands
//!    (`%arg0`, `%3`, ... are vocabulary tokens — unseen `%argk`/`%k` are
//!    exactly the paper's Fig 6 OOV hazard) plus result shape tokens.
//!    Sequences run ~4× longer (paper Fig 6).

pub mod vocab;

pub use vocab::{Vocab, OOV_ID, PAD_ID};

use crate::mlir::{Function, OpKind, XpuOp};

/// Tokenization scheme (paper §3 describes both).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    OpsOnly,
    OpsOperands,
}

impl Scheme {
    pub fn name(self) -> &'static str {
        match self {
            Scheme::OpsOnly => "ops_only",
            Scheme::OpsOperands => "ops_operands",
        }
    }

    pub fn parse(s: &str) -> Option<Scheme> {
        match s {
            "ops_only" => Some(Scheme::OpsOnly),
            "ops_operands" => Some(Scheme::OpsOperands),
            _ => None,
        }
    }

    /// Default max sequence length (ops+operands runs ~4x longer).
    pub fn default_max_len(self) -> usize {
        match self {
            Scheme::OpsOnly => 128,
            Scheme::OpsOperands => 512,
        }
    }
}

/// Tokenize a function per Fig 4: (1) func header, (2) input/output
/// shapes as single-entity tokens, (3) the op sequence, (4) return.
pub fn tokenize(f: &Function, scheme: Scheme) -> Vec<String> {
    let mut toks: Vec<String> = Vec::new();
    // (1) header
    toks.push("func".to_string());
    // (2) input and output tensor shapes, each one token
    for id in f.arg_ids() {
        toks.push(shape_token(f, id));
    }
    toks.push("->".to_string());
    for &r in &f.ret {
        toks.push(shape_token(f, r));
    }
    // (3) the op sequence
    f.walk(&mut |op, _| {
        if matches!(op.kind, OpKind::Return) {
            return;
        }
        toks.push(op.kind.full_name());
        if scheme == Scheme::OpsOperands {
            for &o in &op.operands {
                toks.push(format!("%{}", f.value_name(o)));
            }
            for &r in &op.results {
                toks.push(format!("%{}", f.value_name(r)));
                toks.push(shape_token(f, r));
            }
            // Structure-bearing attrs become tokens too (loop bounds,
            // strides): they carry the cost signal at the affine level.
            for (k, v) in &op.attrs.0 {
                toks.push(format!("{k}={v}"));
            }
        }
    });
    // (4) terminator
    toks.push("return".to_string());
    toks
}

fn shape_token(f: &Function, id: crate::mlir::ValueId) -> String {
    match f.value_type(id) {
        crate::mlir::Type::Tensor(t) | crate::mlir::Type::MemRef(t) => t.shape_token(),
        crate::mlir::Type::Index => "index".to_string(),
        crate::mlir::Type::Scalar(d) => format!("scalar_{d}"),
    }
}

/// Embedding-table rows baked into the AOT models (`aot.py VOCAB_SIZE`).
/// Tokens past this id (the rarest tail of a very large vocabulary) are
/// clamped to the last row — functionally extra OOV aliasing, and it
/// keeps every id a valid gather index for the fixed-shape executables.
pub const EMBED_VOCAB_CAP: u32 = 8192;

/// Encode a token stream to ids, padding/truncating to `max_len`.
pub fn encode(tokens: &[String], vocab: &Vocab, max_len: usize) -> Vec<u32> {
    let mut ids: Vec<u32> = tokens
        .iter()
        .take(max_len)
        .map(|t| vocab.id_of(t).min(EMBED_VOCAB_CAP - 1))
        .collect();
    ids.resize(max_len, PAD_ID);
    ids
}

/// Count how many tokens would map to OOV under `vocab`.
pub fn count_oov(tokens: &[String], vocab: &Vocab) -> usize {
    tokens.iter().filter(|t| vocab.id_of(t) == OOV_ID).count()
}

/// All a-priori-known tokens (op names, keywords): seeded into every
/// vocabulary so op coverage never depends on corpus luck.
pub fn builtin_tokens() -> Vec<String> {
    let mut v: Vec<String> = vec!["func".into(), "->".into(), "return".into()];
    for op in XpuOp::ALL {
        v.push(format!("xpu.{}", op.mnemonic()));
    }
    for name in [
        "affine.for",
        "affine.yield",
        "affine.load",
        "affine.store",
        "affine.vector_load",
        "affine.vector_store",
        "memref.alloc",
    ] {
        v.push(name.to_string());
    }
    for name in [
        "constant", "addf", "subf", "mulf", "divf", "maxf", "minf", "fma", "expf", "tanhf",
        "erff", "sqrtf", "rsqrtf", "negf",
    ] {
        v.push(format!("arith.{name}"));
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphgen::{generate, Family, GraphSpec};
    use crate::mlir::{Attrs, DType, FuncBuilder, Type};

    fn mini() -> Function {
        let mut b = FuncBuilder::new("mini");
        let x = b.arg(Type::tensor(vec![4, 8], DType::F32));
        let w = b.arg(Type::tensor(vec![8, 16], DType::F32));
        let m = b.xpu(XpuOp::MatMul, &[x, w], Attrs::new()).unwrap();
        let r = b.xpu(XpuOp::Relu, &[m], Attrs::new()).unwrap();
        b.ret(&[r]).unwrap()
    }

    #[test]
    fn ops_only_matches_fig4_structure() {
        let f = mini();
        let toks = tokenize(&f, Scheme::OpsOnly);
        assert_eq!(
            toks,
            vec![
                "func", "4x8xf32", "8x16xf32", "->", "4x16xf32", "xpu.matmul", "xpu.relu",
                "return"
            ]
        );
    }

    #[test]
    fn ops_operands_includes_values_and_shapes() {
        let f = mini();
        let toks = tokenize(&f, Scheme::OpsOperands);
        assert!(toks.contains(&"%arg0".to_string()));
        assert!(toks.contains(&"%0".to_string()));
        assert!(toks.iter().filter(|t| *t == "4x16xf32").count() >= 2); // result shapes
        // Tiny 2-op function still gets meaningfully longer; the ~4x ratio
        // is asserted on real corpus graphs below.
        assert!(toks.len() as f64 > tokenize(&f, Scheme::OpsOnly).len() as f64 * 1.5);
    }

    #[test]
    fn operand_sequences_are_about_4x_longer() {
        // Paper Fig 6: "sequences are on average 4x longer".
        let mut total_ratio = 0.0;
        let mut n = 0;
        for i in 0..20u64 {
            let spec = GraphSpec {
                family: Family::ALL[(i % 7) as usize],
                structure_seed: i,
                shape_seed: i + 100,
            };
            let f = generate(&spec).unwrap();
            let a = tokenize(&f, Scheme::OpsOnly).len() as f64;
            let b = tokenize(&f, Scheme::OpsOperands).len() as f64;
            total_ratio += b / a;
            n += 1;
        }
        let mean = total_ratio / n as f64;
        assert!((2.5..=8.0).contains(&mean), "mean ratio {mean}");
    }

    #[test]
    fn encode_pads_and_truncates() {
        let f = mini();
        let toks = tokenize(&f, Scheme::OpsOnly);
        let vocab = Vocab::build([toks.clone()].iter(), 1);
        let ids = encode(&toks, &vocab, 12);
        assert_eq!(ids.len(), 12);
        assert_eq!(&ids[toks.len()..], &[PAD_ID; 4][..]);
        let short = encode(&toks, &vocab, 3);
        assert_eq!(short.len(), 3);
        assert!(short.iter().all(|&i| i != PAD_ID));
    }

    #[test]
    fn oov_detection() {
        let f = mini();
        let toks = tokenize(&f, Scheme::OpsOnly);
        let vocab = Vocab::build([vec!["func".to_string()]].iter(), 1);
        // Everything except "func" and builtins is OOV.
        let oov = count_oov(&toks, &vocab);
        assert!(oov >= 3, "expected shape tokens OOV, got {oov}");
    }

    #[test]
    fn affine_functions_tokenize() {
        let spec = GraphSpec { family: Family::Mlp, structure_seed: 1, shape_seed: 2 };
        let f = generate(&spec).unwrap();
        let a = crate::lower::affine::lower_to_affine(&f).unwrap();
        let toks = tokenize(&a, Scheme::OpsOnly);
        assert!(toks.iter().any(|t| t == "affine.for"));
        // Affine form is much longer than the xpu form (paper §5).
        assert!(toks.len() > tokenize(&f, Scheme::OpsOnly).len() * 2);
    }
}

//! MLIR-as-text tokenization — the paper's §3 "Tokenization and
//! Embedding" stage, both schemes:
//!
//! 1. **Ops-only** (`Scheme::OpsOnly`): the `xpu.op` mnemonic sequence,
//!    with the function's input/output tensor shapes each tokenized *as a
//!    single entity* (`1x128x768xf32` is one token). Operand information
//!    is dropped — no data-dependence tracking (paper Fig 4).
//! 2. **Ops+operands** (`Scheme::OpsOperands`): ops *and* their operands
//!    (`%arg0`, `%3`, ... are vocabulary tokens — unseen `%argk`/`%k` are
//!    exactly the paper's Fig 6 OOV hazard) plus result shape tokens.
//!    Sequences run ~4× longer (paper Fig 6).

//! The tokenizer is sink-based: [`tokenize_into`] walks the function once
//! and emits each token as a borrowed `&str` (formatted tokens go through
//! a single reusable scratch buffer). Sinks choose the materialization:
//! `Vec<String>` keeps the string stream (vocab building, OOV analysis),
//! while [`IdSink`] maps tokens straight to vocabulary ids — the serving
//! hot path never builds a `Vec<String>` at all.

pub mod span;
pub mod vocab;

pub use vocab::{Vocab, OOV_ID, PAD_ID};

use crate::mlir::{Function, OpKind, XpuOp};
use std::fmt::Write as _;

/// Tokenization scheme (paper §3 describes both).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    OpsOnly,
    OpsOperands,
}

impl Scheme {
    pub fn name(self) -> &'static str {
        match self {
            Scheme::OpsOnly => "ops_only",
            Scheme::OpsOperands => "ops_operands",
        }
    }

    pub fn parse(s: &str) -> Option<Scheme> {
        match s {
            "ops_only" => Some(Scheme::OpsOnly),
            "ops_operands" => Some(Scheme::OpsOperands),
            _ => None,
        }
    }

    /// Default max sequence length (ops+operands runs ~4x longer).
    pub fn default_max_len(self) -> usize {
        match self {
            Scheme::OpsOnly => 128,
            Scheme::OpsOperands => 512,
        }
    }
}

/// Receives the token stream emitted by [`tokenize_into`]. Tokens arrive
/// as `&str` borrows (of a static literal, the function's name table, or
/// the walker's scratch buffer — never valid beyond the call), so a sink
/// decides per token whether to copy, map to an id, or count.
pub trait TokenSink {
    /// One token. The slice is only valid for the duration of this call.
    fn token(&mut self, tok: &str);

    /// An operation-name token (`xpu.matmul`, `affine.for`, ...). The
    /// default formats the full name; id-direct sinks override this with
    /// a precomputed per-[`OpKind`] table lookup so the hot path never
    /// formats op names at all.
    fn op(&mut self, kind: &OpKind) {
        self.token(&kind.full_name());
    }
}

/// The string-stream sink: preserves the historical `Vec<String>` view
/// used for vocab building and OOV analysis.
impl TokenSink for Vec<String> {
    fn token(&mut self, tok: &str) {
        self.push(tok.to_string());
    }

    fn op(&mut self, kind: &OpKind) {
        self.push(kind.full_name());
    }
}

/// Tokenize a function per Fig 4 into `sink`: (1) func header, (2)
/// input/output shapes as single-entity tokens, (3) the op sequence, (4)
/// return. One reusable scratch buffer backs every formatted token —
/// after its first few growths this walk performs zero heap allocation.
pub fn tokenize_into<S: TokenSink>(f: &Function, scheme: Scheme, sink: &mut S) {
    let mut scratch = String::new();
    // (1) header
    sink.token("func");
    // (2) input and output tensor shapes, each one token
    for id in f.arg_ids() {
        sink.token(shape_token_into(f, id, &mut scratch));
    }
    sink.token("->");
    for &r in &f.ret {
        sink.token(shape_token_into(f, r, &mut scratch));
    }
    // (3) the op sequence
    f.walk(&mut |op, _| {
        if matches!(op.kind, OpKind::Return) {
            return;
        }
        sink.op(&op.kind);
        if scheme == Scheme::OpsOperands {
            for &o in &op.operands {
                scratch.clear();
                scratch.push('%');
                scratch.push_str(f.value_name(o));
                sink.token(&scratch);
            }
            for &r in &op.results {
                scratch.clear();
                scratch.push('%');
                scratch.push_str(f.value_name(r));
                sink.token(&scratch);
                sink.token(shape_token_into(f, r, &mut scratch));
            }
            // Structure-bearing attrs become tokens too (loop bounds,
            // strides): they carry the cost signal at the affine level.
            for (k, v) in &op.attrs.0 {
                scratch.clear();
                let _ = write!(scratch, "{k}={v}");
                sink.token(&scratch);
            }
        }
    });
    // (4) terminator
    sink.token("return");
}

/// Tokenize to an owned string stream (vocab building, analysis paths).
pub fn tokenize(f: &Function, scheme: Scheme) -> Vec<String> {
    let mut toks: Vec<String> = Vec::new();
    tokenize_into(f, scheme, &mut toks);
    toks
}

fn shape_token_into<'a>(
    f: &Function,
    id: crate::mlir::ValueId,
    scratch: &'a mut String,
) -> &'a str {
    scratch.clear();
    match f.value_type(id) {
        crate::mlir::Type::Tensor(t) | crate::mlir::Type::MemRef(t) => {
            t.write_shape_token(scratch)
        }
        crate::mlir::Type::Index => scratch.push_str("index"),
        crate::mlir::Type::Scalar(d) => {
            let _ = write!(scratch, "scalar_{d}");
        }
    }
    scratch
}

/// Embedding-table rows baked into the AOT models (`aot.py VOCAB_SIZE`).
/// Tokens past this id (the rarest tail of a very large vocabulary) are
/// clamped to the last row — functionally extra OOV aliasing, and it
/// keeps every id a valid gather index for the fixed-shape executables.
pub const EMBED_VOCAB_CAP: u32 = 8192;

/// Encode a token stream to ids, padding/truncating to `max_len`.
pub fn encode(tokens: &[String], vocab: &Vocab, max_len: usize) -> Vec<u32> {
    let mut ids: Vec<u32> = tokens
        .iter()
        .take(max_len)
        .map(|t| vocab.id_of(t).min(EMBED_VOCAB_CAP - 1))
        .collect();
    ids.resize(max_len, PAD_ID);
    ids
}

/// Encode + count OOV in ONE pass: the id row is truncated/padded to
/// `max_len` exactly like [`encode`], while the OOV count covers the
/// *whole* stream (matching [`count_oov`]'s contract) — one vocabulary
/// hash lookup per token instead of two.
pub fn encode_with_oov(tokens: &[String], vocab: &Vocab, max_len: usize) -> (Vec<u32>, usize) {
    let mut ids: Vec<u32> = Vec::with_capacity(max_len);
    let mut oov = 0usize;
    for t in tokens {
        let id = vocab.id_of(t);
        if id == OOV_ID {
            oov += 1;
        }
        if ids.len() < max_len {
            ids.push(id.min(EMBED_VOCAB_CAP - 1));
        }
    }
    ids.resize(max_len, PAD_ID);
    (ids, oov)
}

/// Count how many tokens would map to OOV under `vocab` (thin wrapper
/// over the fused [`encode_with_oov`] pass).
pub fn count_oov(tokens: &[String], vocab: &Vocab) -> usize {
    encode_with_oov(tokens, vocab, 0).1
}

/// Precomputed `OpKind` → vocabulary-id table, built once per vocab (the
/// serving coordinator caches it on `Bundle` load). Op-name tokens are the
/// single most frequent token class, and with this table the hot path
/// resolves them by array index — no `format!("xpu.{...}")`, no hash.
#[derive(Debug, Clone)]
pub struct OpIdTable {
    ids: Vec<u32>,
}

impl OpIdTable {
    pub fn build(vocab: &Vocab) -> OpIdTable {
        let mut ids = vec![OOV_ID; OpKind::TABLE_LEN];
        for kind in OpKind::all() {
            ids[kind.table_index()] = vocab.id_of(&kind.full_name());
        }
        OpIdTable { ids }
    }

    #[inline]
    pub fn id(&self, kind: &OpKind) -> u32 {
        self.ids[kind.table_index()]
    }
}

/// Id-direct sink: maps each emitted token straight to its vocabulary id
/// (with the [`EMBED_VOCAB_CAP`] clamp) and counts whole-stream OOV on
/// the side. Produces ids byte-identical to
/// `encode(&tokenize(f, scheme), vocab, max_len)` without ever
/// materializing the string stream.
pub struct IdSink<'v> {
    vocab: &'v Vocab,
    ops: &'v OpIdTable,
    max_len: usize,
    ids: Vec<u32>,
    oov: usize,
}

impl<'v> IdSink<'v> {
    pub fn new(vocab: &'v Vocab, ops: &'v OpIdTable, max_len: usize) -> IdSink<'v> {
        IdSink { vocab, ops, max_len, ids: Vec::with_capacity(max_len), oov: 0 }
    }

    #[inline]
    fn push(&mut self, id: u32) {
        if id == OOV_ID {
            self.oov += 1;
        }
        if self.ids.len() < self.max_len {
            self.ids.push(id.min(EMBED_VOCAB_CAP - 1));
        }
    }

    /// The padded `[max_len]` id row plus the whole-stream OOV count.
    pub fn finish(mut self) -> (Vec<u32>, usize) {
        self.ids.resize(self.max_len, PAD_ID);
        (self.ids, self.oov)
    }
}

impl TokenSink for IdSink<'_> {
    fn token(&mut self, tok: &str) {
        let id = self.vocab.id_of(tok);
        self.push(id);
    }

    fn op(&mut self, kind: &OpKind) {
        let id = self.ops.id(kind);
        self.push(id);
    }
}

/// Counting sink: measures a function's token-stream length without
/// materializing anything — one `usize` increment per token, no vocab,
/// no allocation. The serving router uses this to pick the cheapest
/// model variant whose `max_len` covers a query before committing to
/// that variant's vocabulary.
#[derive(Default)]
pub struct CountSink(pub usize);

impl TokenSink for CountSink {
    #[inline]
    fn token(&mut self, _tok: &str) {
        self.0 += 1;
    }

    #[inline]
    fn op(&mut self, _kind: &OpKind) {
        self.0 += 1;
    }
}

/// The unpadded, untruncated token count of `f` under `scheme` — i.e.
/// how long [`encode`]'s id row would be before any `max_len` clamp.
pub fn token_count(f: &Function, scheme: Scheme) -> usize {
    let mut sink = CountSink::default();
    tokenize_into(f, scheme, &mut sink);
    sink.0
}

/// Fused tokenize+encode for one function — the serving hot path. Returns
/// `(padded ids, whole-stream OOV count)`; the ids are guaranteed
/// identical to the two-phase `encode(&tokenize(f, scheme), ...)` string
/// pipeline (property-tested in `tests/integration.rs`).
pub fn encode_function(
    f: &Function,
    scheme: Scheme,
    vocab: &Vocab,
    ops: &OpIdTable,
    max_len: usize,
) -> (Vec<u32>, usize) {
    let mut sink = IdSink::new(vocab, ops, max_len);
    tokenize_into(f, scheme, &mut sink);
    sink.finish()
}

/// All a-priori-known tokens (op names, keywords): seeded into every
/// vocabulary so op coverage never depends on corpus luck.
pub fn builtin_tokens() -> Vec<String> {
    let mut v: Vec<String> = vec!["func".into(), "->".into(), "return".into()];
    for op in XpuOp::ALL {
        v.push(format!("xpu.{}", op.mnemonic()));
    }
    for name in [
        "affine.for",
        "affine.yield",
        "affine.load",
        "affine.store",
        "affine.vector_load",
        "affine.vector_store",
        "memref.alloc",
    ] {
        v.push(name.to_string());
    }
    for name in [
        "constant", "addf", "subf", "mulf", "divf", "maxf", "minf", "fma", "expf", "tanhf",
        "erff", "sqrtf", "rsqrtf", "negf",
    ] {
        v.push(format!("arith.{name}"));
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphgen::{generate, Family, GraphSpec};
    use crate::mlir::{Attrs, DType, FuncBuilder, Type};

    fn mini() -> Function {
        let mut b = FuncBuilder::new("mini");
        let x = b.arg(Type::tensor(vec![4, 8], DType::F32));
        let w = b.arg(Type::tensor(vec![8, 16], DType::F32));
        let m = b.xpu(XpuOp::MatMul, &[x, w], Attrs::new()).unwrap();
        let r = b.xpu(XpuOp::Relu, &[m], Attrs::new()).unwrap();
        b.ret(&[r]).unwrap()
    }

    #[test]
    fn ops_only_matches_fig4_structure() {
        let f = mini();
        let toks = tokenize(&f, Scheme::OpsOnly);
        assert_eq!(
            toks,
            vec![
                "func", "4x8xf32", "8x16xf32", "->", "4x16xf32", "xpu.matmul", "xpu.relu",
                "return"
            ]
        );
    }

    #[test]
    fn ops_operands_includes_values_and_shapes() {
        let f = mini();
        let toks = tokenize(&f, Scheme::OpsOperands);
        assert!(toks.contains(&"%arg0".to_string()));
        assert!(toks.contains(&"%0".to_string()));
        assert!(toks.iter().filter(|t| *t == "4x16xf32").count() >= 2); // result shapes
        // Tiny 2-op function still gets meaningfully longer; the ~4x ratio
        // is asserted on real corpus graphs below.
        assert!(toks.len() as f64 > tokenize(&f, Scheme::OpsOnly).len() as f64 * 1.5);
    }

    #[test]
    fn operand_sequences_are_about_4x_longer() {
        // Paper Fig 6: "sequences are on average 4x longer".
        let mut total_ratio = 0.0;
        let mut n = 0;
        for i in 0..20u64 {
            let spec = GraphSpec {
                family: Family::ALL[(i % 7) as usize],
                structure_seed: i,
                shape_seed: i + 100,
            };
            let f = generate(&spec).unwrap();
            let a = tokenize(&f, Scheme::OpsOnly).len() as f64;
            let b = tokenize(&f, Scheme::OpsOperands).len() as f64;
            total_ratio += b / a;
            n += 1;
        }
        let mean = total_ratio / n as f64;
        assert!((2.5..=8.0).contains(&mean), "mean ratio {mean}");
    }

    #[test]
    fn encode_pads_and_truncates() {
        let f = mini();
        let toks = tokenize(&f, Scheme::OpsOnly);
        let vocab = Vocab::build([toks.clone()].iter(), 1);
        let ids = encode(&toks, &vocab, 12);
        assert_eq!(ids.len(), 12);
        assert_eq!(&ids[toks.len()..], &[PAD_ID; 4][..]);
        let short = encode(&toks, &vocab, 3);
        assert_eq!(short.len(), 3);
        assert!(short.iter().all(|&i| i != PAD_ID));
    }

    #[test]
    fn oov_detection() {
        let f = mini();
        let toks = tokenize(&f, Scheme::OpsOnly);
        let vocab = Vocab::build([vec!["func".to_string()]].iter(), 1);
        // Everything except "func" and builtins is OOV.
        let oov = count_oov(&toks, &vocab);
        assert!(oov >= 3, "expected shape tokens OOV, got {oov}");
    }

    #[test]
    fn encode_with_oov_fuses_both_passes() {
        let f = mini();
        let toks = tokenize(&f, Scheme::OpsOnly);
        let vocab = Vocab::build([vec!["func".to_string()]].iter(), 1);
        // Truncating max_len must not change the whole-stream OOV count.
        let (ids, oov) = encode_with_oov(&toks, &vocab, 3);
        assert_eq!(ids, encode(&toks, &vocab, 3));
        assert_eq!(oov, count_oov(&toks, &vocab));
        let (ids_full, oov_full) = encode_with_oov(&toks, &vocab, 64);
        assert_eq!(ids_full, encode(&toks, &vocab, 64));
        assert_eq!(oov_full, oov);
    }

    #[test]
    fn id_sink_matches_string_pipeline_on_mini() {
        let f = mini();
        for scheme in [Scheme::OpsOnly, Scheme::OpsOperands] {
            let toks = tokenize(&f, scheme);
            let vocab = Vocab::build([toks.clone()].iter(), 1);
            let table = OpIdTable::build(&vocab);
            for max_len in [4, 16, 64] {
                let (ids, oov) = encode_function(&f, scheme, &vocab, &table, max_len);
                assert_eq!(ids, encode(&toks, &vocab, max_len), "{scheme:?}/{max_len}");
                assert_eq!(oov, count_oov(&toks, &vocab));
            }
        }
    }

    #[test]
    fn token_count_matches_string_pipeline() {
        let f = mini();
        for scheme in [Scheme::OpsOnly, Scheme::OpsOperands] {
            assert_eq!(token_count(&f, scheme), tokenize(&f, scheme).len(), "{scheme:?}");
        }
        // And on a real corpus graph (covers ops, shapes, attrs).
        let spec = GraphSpec { family: Family::Resnet, structure_seed: 3, shape_seed: 4 };
        let g = generate(&spec).unwrap();
        for scheme in [Scheme::OpsOnly, Scheme::OpsOperands] {
            assert_eq!(token_count(&g, scheme), tokenize(&g, scheme).len(), "{scheme:?}");
        }
    }

    #[test]
    fn op_id_table_matches_vocab_lookup() {
        let streams = vec![vec!["xpu.matmul".to_string()]];
        let vocab = Vocab::build(streams.iter(), 1);
        let table = OpIdTable::build(&vocab);
        for kind in OpKind::all() {
            assert_eq!(table.id(&kind), vocab.id_of(&kind.full_name()), "{kind:?}");
        }
    }

    #[test]
    fn affine_functions_tokenize() {
        let spec = GraphSpec { family: Family::Mlp, structure_seed: 1, shape_seed: 2 };
        let f = generate(&spec).unwrap();
        let a = crate::lower::affine::lower_to_affine(&f).unwrap();
        let toks = tokenize(&a, Scheme::OpsOnly);
        assert!(toks.iter().any(|t| t == "affine.for"));
        // Affine form is much longer than the xpu form (paper §5).
        assert!(toks.len() > tokenize(&f, Scheme::OpsOnly).len() * 2);
    }
}

//! Training orchestrator: Rust drives the AOT-compiled `train_step`
//! executable over minibatches — the paper's supervised training (§3),
//! with Python long gone from the process.

pub mod checkpoint;
pub mod metrics;

use crate::dataset::EncodedSet;
use crate::pred::PredVec;
use crate::rng::Rng;
use crate::runtime::{Executable, Manifest, Runtime, Tensor};
use anyhow::{ensure, Context, Result};
use std::sync::Arc;
use std::time::Instant;

/// Configuration for one training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub model: String,
    pub steps: usize,
    pub seed: u64,
    pub eval_every: usize,
    /// Log loss every N steps (0 = silent).
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { model: "conv_ops".into(), steps: 300, seed: 0, eval_every: 100, log_every: 50 }
    }
}

/// Progress + outcome of a run.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    /// (step, train-batch loss) samples.
    pub losses: Vec<(usize, f64)>,
    /// (step, test RMSE in normalized units).
    pub evals: Vec<(usize, f64)>,
    pub steps_per_sec: f64,
    pub total_steps: usize,
}

/// Holds model state (params ⊕ adam moments ⊕ step) across steps.
pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    manifest: &'rt Manifest,
    pub model: String,
    n_params: usize,
    max_len: usize,
    train_batch: usize,
    /// params ++ m ++ v (3n tensors), then step scalar.
    state: Vec<Tensor>,
    step: Tensor,
    train_exe: Arc<Executable>,
}

impl<'rt> Trainer<'rt> {
    /// Initialize from the exported init params.
    pub fn new(rt: &'rt Runtime, manifest: &'rt Manifest, model: &str) -> Result<Trainer<'rt>> {
        let mm = manifest.model(model)?;
        let params = manifest.load_init_params(model)?;
        let n = params.len();
        let zeros: Vec<Tensor> =
            params.iter().map(|p| Tensor::zeros_f32(p.shape().to_vec())).collect();
        let state: Vec<Tensor> =
            params.into_iter().chain(zeros.clone()).chain(zeros).collect();
        let train_exe = rt
            .load(&manifest.path_of(mm.file("train_step")?))
            .context("loading train_step executable")?;
        Ok(Trainer {
            rt,
            manifest,
            model: model.to_string(),
            n_params: n,
            max_len: mm.max_len,
            train_batch: mm.train_batch,
            state,
            step: Tensor::scalar_f32(0.0),
            train_exe,
        })
    }

    /// Current parameter tensors (first n of state).
    pub fn params(&self) -> &[Tensor] {
        &self.state[..self.n_params]
    }

    /// Replace parameters (e.g. from a checkpoint); moments reset.
    pub fn set_params(&mut self, params: Vec<Tensor>) -> Result<()> {
        ensure!(params.len() == self.n_params, "expected {} tensors", self.n_params);
        let zeros: Vec<Tensor> =
            params.iter().map(|p| Tensor::zeros_f32(p.shape().to_vec())).collect();
        self.state = params.into_iter().chain(zeros.clone()).chain(zeros).collect();
        self.step = Tensor::scalar_f32(0.0);
        Ok(())
    }

    /// One optimizer step on a `[B, L]` ids batch with a `[B]` (single
    /// target) or row-major `[B, K]` (multi-output head) label batch —
    /// the width is inferred from the label length, so single-target
    /// callers pass exactly what they always did.
    pub fn step_batch(&mut self, ids: Vec<i32>, targets: Vec<f32>) -> Result<f64> {
        let b = self.train_batch as i64;
        ensure!(ids.len() == (b as usize) * self.max_len, "bad ids length");
        ensure!(
            !targets.is_empty() && targets.len() % b as usize == 0,
            "target length {} is not a multiple of batch {b}",
            targets.len()
        );
        let k = targets.len() / b as usize;
        let tshape = if k == 1 { vec![b] } else { vec![b, k as i64] };
        let mut inputs: Vec<Tensor> = Vec::with_capacity(3 * self.n_params + 3);
        inputs.extend(self.state.iter().cloned());
        inputs.push(self.step.clone());
        inputs.push(Tensor::i32(vec![b, self.max_len as i64], ids)?);
        inputs.push(Tensor::f32(tshape, targets)?);
        let mut out = self.train_exe.run(&inputs)?;
        let loss = out[3 * self.n_params + 1].first_f32()? as f64;
        self.step = out[3 * self.n_params].clone();
        out.truncate(3 * self.n_params);
        self.state = out;
        Ok(loss)
    }

    /// Train for `cfg.steps` minibatches drawn (with reshuffling epochs)
    /// from `train`, evaluating on `test` periodically.
    pub fn run(
        &mut self,
        cfg: &TrainConfig,
        train: &EncodedSet,
        test: &EncodedSet,
    ) -> Result<TrainReport> {
        ensure!(train.max_len == self.max_len, "train set encoded for wrong max_len");
        let mut rng = Rng::new(cfg.seed);
        let mut order: Vec<usize> = (0..train.n).collect();
        rng.shuffle(&mut order);
        let mut cursor = 0usize;
        let bsz = self.train_batch;
        let mut report = TrainReport::default();
        let t0 = Instant::now();
        for step in 1..=cfg.steps {
            if cursor + bsz > order.len() {
                rng.shuffle(&mut order);
                cursor = 0;
            }
            let idx: Vec<usize> = order[cursor..cursor + bsz].to_vec();
            cursor += bsz;
            let (ids, targets) = train.gather(&idx);
            let loss = self.step_batch(ids, targets)?;
            if cfg.log_every > 0 && step % cfg.log_every == 0 {
                report.losses.push((step, loss));
                eprintln!("[train {}] step {step}/{} loss {loss:.5}", self.model, cfg.steps);
            }
            if cfg.eval_every > 0 && (step % cfg.eval_every == 0 || step == cfg.steps) {
                let preds: Vec<f64> = self
                    .predict_set(test)?
                    .iter()
                    .flat_map(|p| p.iter().copied())
                    .collect();
                let truth: Vec<f64> = test.targets.iter().map(|&t| t as f64).collect();
                let rmse = metrics::rmse(&preds, &truth);
                report.evals.push((step, rmse));
                eprintln!("[eval  {}] step {step} test-rmse(norm) {rmse:.4}", self.model);
            }
        }
        report.total_steps = cfg.steps;
        report.steps_per_sec = cfg.steps as f64 / t0.elapsed().as_secs_f64();
        Ok(report)
    }

    /// Predict normalized label vectors for a whole encoded set using
    /// the largest-batch predict executable, padding the tail batch.
    /// One forward pass per batch yields every declared characteristic:
    /// a `[B, K]` head gives each row its K values; a legacy `[B]` head
    /// broadcasts its single output across the set's declared width
    /// (each slot still denormalizes by its own per-target stats).
    pub fn predict_set(&self, set: &EncodedSet) -> Result<Vec<PredVec>> {
        let mm = self.manifest.model(&self.model)?;
        let (key, b) = mm.predict_key_for(usize::MAX, false);
        let exe = self.rt.load(&self.manifest.path_of(mm.file(&key)?))?;
        let params = self.params().to_vec();
        let k = set.n_targets.max(1);
        let mut preds = Vec::with_capacity(set.n);
        let mut i = 0usize;
        while i < set.n {
            let take = (set.n - i).min(b);
            let idx: Vec<usize> = (i..i + take).collect();
            let (mut ids, _) = set.gather(&idx);
            ids.resize(b * set.max_len, 0); // pad rows
            let mut inputs = params.clone();
            inputs.push(Tensor::i32(vec![b as i64, set.max_len as i64], ids)?);
            let out = exe.run(&inputs)?;
            let vals = out[0].as_f32()?;
            let wide = vals.len() >= b * k; // [B, K] row-major head
            for row in 0..take {
                let mut p = PredVec::new();
                for j in 0..k {
                    let v = if wide { vals[row * k + j] } else { vals[row] };
                    p.push(v as f64);
                }
                preds.push(p);
            }
            i += take;
        }
        Ok(preds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{Dataset, EncodedSet, TargetStats};
    use crate::sim::Target;
    use crate::tokenizer::{Scheme, Vocab};
    use std::path::Path;

    fn artifacts_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("artifacts")
    }

    #[test]
    fn short_training_run_improves_rmse() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let ds = Dataset::generate(3, 60, 0).unwrap();
        let (train, test) = ds.split(1, 0.2);
        let streams_tr = train.token_streams(Scheme::OpsOnly).unwrap();
        let streams_te = test.token_streams(Scheme::OpsOnly).unwrap();
        let vocab = Vocab::build(streams_tr.iter(), 1);
        let stats = TargetStats::for_dataset(&train, Target::RegPressure);
        let enc_tr = EncodedSet::build(&train, &streams_tr, &vocab, 128, Target::RegPressure, &stats);
        let enc_te = EncodedSet::build(&test, &streams_te, &vocab, 128, Target::RegPressure, &stats);

        let mut trainer = Trainer::new(&rt, &manifest, "fc_ops").unwrap();
        let norm_rmse = |trainer: &Trainer| {
            let preds: Vec<f64> =
                trainer.predict_set(&enc_te).unwrap().iter().map(|p| p.first()).collect();
            let truth: Vec<f64> = enc_te.targets.iter().map(|&t| t as f64).collect();
            metrics::rmse(&preds, &truth)
        };
        let before = norm_rmse(&trainer);
        let cfg = TrainConfig { steps: 30, eval_every: 0, log_every: 0, ..Default::default() };
        let report = trainer.run(&cfg, &enc_tr, &enc_te).unwrap();
        assert_eq!(report.total_steps, 30);
        let after = norm_rmse(&trainer);
        assert!(
            after < before,
            "30 fc steps should improve test rmse: {before:.4} -> {after:.4}"
        );
    }
}

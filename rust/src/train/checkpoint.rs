//! Checkpoints: trained parameters as raw f32 blobs + JSON metadata, so a
//! `mlir-cost serve` process (or a bench) can pick up where training ended.

use crate::json::{parse, Json};
use crate::runtime::{Manifest, Tensor};
use anyhow::{ensure, Context, Result};
use std::path::Path;

/// Save parameter tensors for `model` under `dir`.
pub fn save(
    dir: &Path,
    manifest: &Manifest,
    model: &str,
    params: &[Tensor],
    meta: Json,
) -> Result<()> {
    let mm = manifest.model(model)?;
    ensure!(params.len() == mm.n_params(), "param count mismatch");
    std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
    for (k, t) in mm.param_order.iter().zip(params) {
        t.to_f32_file(&dir.join(format!("{k}.f32")))?;
    }
    let doc = Json::obj()
        .with("model", Json::str(model))
        .with("n_params", Json::num(params.len() as f64))
        .with("meta", meta);
    std::fs::write(dir.join("checkpoint.json"), doc.to_string())?;
    Ok(())
}

/// Load a checkpoint's parameters (ordered per the manifest).
pub fn load(dir: &Path, manifest: &Manifest, model: &str) -> Result<Vec<Tensor>> {
    let mm = manifest.model(model)?;
    let meta_text = std::fs::read_to_string(dir.join("checkpoint.json"))
        .with_context(|| format!("no checkpoint.json in {dir:?}"))?;
    let meta = parse(&meta_text)?;
    ensure!(
        meta.req_str("model")? == model,
        "checkpoint is for model '{}', wanted '{model}'",
        meta.req_str("model")?
    );
    mm.param_order
        .iter()
        .map(|k| Tensor::from_f32_file(&dir.join(format!("{k}.f32")), mm.param_shapes[k].clone()))
        .collect()
}

/// Read checkpoint metadata (if present).
pub fn load_meta(dir: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(dir.join("checkpoint.json"))?;
    parse(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("artifacts")
    }

    #[test]
    fn save_load_roundtrip() {
        let adir = artifacts_dir();
        if !adir.join("manifest.json").exists() {
            return;
        }
        let manifest = Manifest::load(&adir).unwrap();
        let params = manifest.load_init_params("fc_ops").unwrap();
        let dir = std::env::temp_dir().join("mlir_cost_ckpt_test");
        let meta = Json::obj().with("steps", Json::num(42.0));
        save(&dir, &manifest, "fc_ops", &params, meta).unwrap();
        let loaded = load(&dir, &manifest, "fc_ops").unwrap();
        assert_eq!(params.len(), loaded.len());
        assert_eq!(params[0], loaded[0]);
        let m = load_meta(&dir).unwrap();
        assert_eq!(m.req("meta").unwrap().req_f64("steps").unwrap(), 42.0);
        // Wrong model rejected.
        assert!(load(&dir, &manifest, "conv_ops").is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}

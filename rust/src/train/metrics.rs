//! Evaluation metrics matching the paper's reporting.

/// Root mean squared error.
pub fn rmse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let mse = pred
        .iter()
        .zip(truth)
        .map(|(p, t)| (p - t) * (p - t))
        .sum::<f64>()
        / pred.len() as f64;
    mse.sqrt()
}

/// Mean absolute error.
pub fn mae(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter().zip(truth).map(|(p, t)| (p - t).abs()).sum::<f64>() / pred.len() as f64
}

/// RMSE as a percentage of the target range — the paper's "RMSE in the
/// range of 5-7%" metric.
pub fn rmse_pct(pred: &[f64], truth: &[f64], range: f64) -> f64 {
    100.0 * rmse(pred, truth) / range.max(1e-9)
}

/// Fraction (%) of predictions that are exact after rounding to integers —
/// Fig 6's "in almost 75% of cases we can predict register pressure
/// without any error".
pub fn pct_exact_rounded(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hits = pred
        .iter()
        .zip(truth)
        .filter(|(p, t)| p.round() == t.round())
        .count();
    100.0 * hits as f64 / pred.len() as f64
}

/// Histogram of |rounded error| in unit buckets, capped at `max_bucket`
/// (for regenerating Fig 6's error distribution).
pub fn abs_error_histogram(pred: &[f64], truth: &[f64], max_bucket: usize) -> Vec<usize> {
    let mut hist = vec![0usize; max_bucket + 1];
    for (p, t) in pred.iter().zip(truth) {
        let e = ((p.round() - t.round()).abs() as usize).min(max_bucket);
        hist[e] += 1;
    }
    hist
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmse_known_values() {
        assert_eq!(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        let r = rmse(&[0.0, 0.0], &[3.0, 4.0]);
        assert!((r - (12.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn rmse_pct_scales_by_range() {
        let p = [10.0, 20.0];
        let t = [12.0, 18.0];
        assert!((rmse_pct(&p, &t, 100.0) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn exact_and_histogram() {
        let p = [10.2, 19.7, 30.0, 44.0];
        let t = [10.0, 20.0, 31.0, 40.0];
        assert_eq!(pct_exact_rounded(&p, &t), 50.0);
        let h = abs_error_histogram(&p, &t, 3);
        assert_eq!(h, vec![2, 1, 0, 1]); // errors 0,0,1,4→cap3
    }

    #[test]
    fn mae_basic() {
        assert!((mae(&[1.0, 3.0], &[2.0, 1.0]) - 1.5).abs() < 1e-12);
    }
}

//! Deterministic PRNG (splitmix64 seeding + xoshiro256**).
//!
//! The corpus, augmentation, splits and model-side shuffling must be
//! exactly reproducible from a seed recorded in EXPERIMENTS.md, and no
//! external `rand` crate is vendored in this environment, so we carry our
//! own small, well-known generator.

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the full state via splitmix64 (never all-zero).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-item generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n {
                // Fast accept path covers almost everything.
                return (m >> 64) as u64;
            }
            let t = n.wrapping_neg() % n;
            if lo >= t {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi >= lo, "range({lo}, {hi})");
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// Sample from a standard normal (Box–Muller; one value per call).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Weighted choice: returns an index distributed per `weights`.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights sum to zero");
        let mut x = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit in 1000 draws");
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(3);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let v = r.range(-2, 2);
            assert!((-2..=2).contains(&v));
            lo_seen |= v == -2;
            hi_seen |= v == 2;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(11);
        let mean: f64 = (0..10000).map(|_| r.f64()).sum::<f64>() / 10000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} far from 0.5");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "shuffle should move things");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[r.weighted(&[1.0, 0.0, 9.0])] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }
}

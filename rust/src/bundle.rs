//! Serving bundle: everything needed to answer cost queries for one
//! (model, targets, tokenization-scheme) triple, produced by `mlir-cost
//! train` and consumed by `mlir-cost serve`, the benches and the examples.
//!
//! A bundle declares an *ordered list* of targets — the characteristics
//! one forward pass predicts — plus an optional `hardware` profile
//! string naming the machine those outputs describe. Legacy bundles
//! wrote a single `target` string; those load unchanged as a 1-element
//! target list.
//!
//! Layout of a bundle directory:
//!   bundle.json     — model name, targets, scheme, max_len, stats list
//!   vocab.json      — token vocabulary (train split only)
//!   <param>.f32 ... — trained parameters (checkpoint format)

use crate::dataset::TargetStats;
use crate::json::{parse, Json};
use crate::mlir::Function;
use crate::pred::{PredVec, MAX_TARGETS};
use crate::runtime::{Manifest, Tensor};
use crate::sim::Target;
use crate::tokenizer::{encode_function, OpIdTable, Scheme, Vocab};
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// In-memory serving bundle.
pub struct Bundle {
    pub model: String,
    /// Declared characteristics, in prediction order. Never empty; at
    /// most [`MAX_TARGETS`]. `targets[0]` is the *primary* target — the
    /// one the legacy scalar `"prediction"` field reports.
    pub targets: Vec<Target>,
    pub scheme: Scheme,
    pub max_len: usize,
    pub vocab: Vocab,
    /// Per-target normalization statistics, parallel to `targets`.
    pub stats: Vec<TargetStats>,
    /// Optional hardware profile the outputs describe (e.g. "xpu-v2").
    pub hardware: Option<String>,
    pub params: Vec<Tensor>,
    /// Per-`OpKind` vocabulary ids, precomputed at load so the id-direct
    /// encoder resolves op tokens by array index on every query.
    pub op_ids: OpIdTable,
}

/// Everything `bundle.json` holds except the vocab/params side files —
/// split out so the (version-tolerant) parse is testable without
/// artifacts on disk.
#[derive(Debug, Clone, PartialEq)]
pub struct BundleMeta {
    pub model: String,
    pub targets: Vec<Target>,
    pub scheme: Scheme,
    pub max_len: usize,
    pub stats: Vec<TargetStats>,
    pub hardware: Option<String>,
}

impl BundleMeta {
    /// Parse a `bundle.json` document. Accepts the multi-output format
    /// (`"targets": [...]` + `"stats": [...]`) and the legacy
    /// single-target format (`"target": "..."` + `"stats": {...}`),
    /// which becomes a 1-element vector of each.
    pub fn from_json(doc: &Json) -> Result<BundleMeta> {
        let model = doc.req_str("model")?.to_string();
        let scheme = Scheme::parse(doc.req_str("scheme")?)
            .ok_or_else(|| anyhow!("bad scheme in bundle"))?;
        let max_len = doc.req_f64("max_len")? as usize;
        let hardware = doc.get("hardware").and_then(Json::as_str).map(str::to_string);
        let (targets, stats) = if let Some(list) = doc.get("targets").and_then(Json::as_arr) {
            let targets: Vec<Target> = list
                .iter()
                .map(|j| {
                    j.as_str()
                        .and_then(Target::parse)
                        .ok_or_else(|| anyhow!("bad target in bundle 'targets' list"))
                })
                .collect::<Result<_>>()?;
            let stats: Vec<TargetStats> = doc
                .req_arr("stats")?
                .iter()
                .map(TargetStats::from_json)
                .collect::<Result<_>>()?;
            (targets, stats)
        } else {
            let target = Target::parse(doc.req_str("target")?)
                .ok_or_else(|| anyhow!("bad target in bundle"))?;
            (vec![target], vec![TargetStats::from_json(doc.req("stats")?)?])
        };
        validate_targets(&targets, &stats)?;
        Ok(BundleMeta { model, targets, scheme, max_len, stats, hardware })
    }

    pub fn to_json(&self) -> Json {
        let mut doc = Json::obj()
            .with("model", Json::str(&self.model))
            // Legacy readers still find a scalar "target": the primary.
            .with("target", Json::str(self.targets[0].name()))
            .with(
                "targets",
                Json::Arr(self.targets.iter().map(|t| Json::str(t.name())).collect()),
            )
            .with("scheme", Json::str(self.scheme.name()))
            .with("max_len", Json::num(self.max_len as f64))
            .with("stats", Json::Arr(self.stats.iter().map(TargetStats::to_json).collect()));
        if let Some(hw) = &self.hardware {
            doc = doc.with("hardware", Json::str(hw));
        }
        doc
    }
}

fn validate_targets(targets: &[Target], stats: &[TargetStats]) -> Result<()> {
    if targets.is_empty() {
        bail!("bundle must declare at least one target");
    }
    if targets.len() > MAX_TARGETS {
        bail!("bundle declares {} targets; at most {MAX_TARGETS} supported", targets.len());
    }
    if stats.len() != targets.len() {
        bail!("bundle has {} stats entries for {} targets", stats.len(), targets.len());
    }
    for (i, t) in targets.iter().enumerate() {
        if targets[..i].contains(t) {
            bail!("duplicate target '{}' in bundle", t.name());
        }
    }
    Ok(())
}

impl Bundle {
    /// The primary target — first declared; what scalar consumers see.
    pub fn primary_target(&self) -> Target {
        self.targets[0]
    }

    /// Normalization stats of the primary target.
    pub fn primary_stats(&self) -> &TargetStats {
        &self.stats[0]
    }

    pub fn n_targets(&self) -> usize {
        self.targets.len()
    }

    /// Position of `t` in the declared order, if served.
    pub fn target_index(&self, t: Target) -> Option<usize> {
        self.targets.iter().position(|&x| x == t)
    }

    /// Does this bundle serve every requested characteristic?
    pub fn serves_all(&self, wanted: &[Target]) -> bool {
        wanted.iter().all(|t| self.targets.contains(t))
    }

    /// Denormalize a model-output vector into real units, element `i`
    /// by `stats[i]`. A legacy single-output head (`norm.len() == 1`)
    /// under a multi-target bundle broadcasts its one normalized value
    /// through every target's own stats.
    pub fn denormalize(&self, norm: PredVec) -> PredVec {
        let mut out = PredVec::new();
        if norm.len() == self.stats.len() {
            for (v, st) in norm.iter().zip(&self.stats) {
                out.push(st.denormalize(*v));
            }
        } else {
            let v = norm.first();
            for st in &self.stats {
                out.push(st.denormalize(v));
            }
        }
        out
    }

    /// Write to `dir` (creating it).
    pub fn save(&self, dir: &Path, manifest: &Manifest) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let mm = manifest.model(&self.model)?;
        for (k, t) in mm.param_order.iter().zip(&self.params) {
            t.to_f32_file(&dir.join(format!("{k}.f32")))?;
        }
        self.vocab.save(&dir.join("vocab.json"))?;
        let meta = BundleMeta {
            model: self.model.clone(),
            targets: self.targets.clone(),
            scheme: self.scheme,
            max_len: self.max_len,
            stats: self.stats.clone(),
            hardware: self.hardware.clone(),
        };
        std::fs::write(dir.join("bundle.json"), meta.to_json().to_string())?;
        Ok(())
    }

    /// Load from `dir` (either bundle.json format).
    pub fn load(dir: &Path, manifest: &Manifest) -> Result<Bundle> {
        let doc = parse(
            &std::fs::read_to_string(dir.join("bundle.json"))
                .with_context(|| format!("no bundle.json in {dir:?}"))?,
        )?;
        let meta = BundleMeta::from_json(&doc)?;
        let vocab = Vocab::load(&dir.join("vocab.json"))?;
        let mm = manifest.model(&meta.model)?;
        let params: Vec<Tensor> = mm
            .param_order
            .iter()
            .map(|k| {
                Tensor::from_f32_file(&dir.join(format!("{k}.f32")), mm.param_shapes[k].clone())
            })
            .collect::<Result<_>>()?;
        let op_ids = OpIdTable::build(&vocab);
        Ok(Bundle {
            model: meta.model,
            targets: meta.targets,
            scheme: meta.scheme,
            max_len: meta.max_len,
            vocab,
            stats: meta.stats,
            hardware: meta.hardware,
            params,
            op_ids,
        })
    }

    /// An untrained single-target bundle straight from the AOT init
    /// params (useful for smoke tests and serving-path benches where
    /// accuracy is irrelevant).
    pub fn untrained(
        manifest: &Manifest,
        model: &str,
        target: Target,
        scheme: Scheme,
        vocab: Vocab,
        stats: TargetStats,
    ) -> Result<Bundle> {
        Bundle::untrained_multi(manifest, model, &[target], scheme, vocab, vec![stats], None)
    }

    /// Untrained bundle declaring several characteristics at once.
    pub fn untrained_multi(
        manifest: &Manifest,
        model: &str,
        targets: &[Target],
        scheme: Scheme,
        vocab: Vocab,
        stats: Vec<TargetStats>,
        hardware: Option<String>,
    ) -> Result<Bundle> {
        validate_targets(targets, &stats)?;
        let mm = manifest.model(model)?;
        let op_ids = OpIdTable::build(&vocab);
        Ok(Bundle {
            model: model.to_string(),
            targets: targets.to_vec(),
            scheme,
            max_len: mm.max_len,
            vocab,
            stats,
            hardware,
            params: manifest.load_init_params(model)?,
            op_ids,
        })
    }

    /// Fused tokenize+encode for one parsed function (the serving hot
    /// path): ids byte-identical to the string pipeline, plus the
    /// whole-stream OOV count, in a single pass with no `Vec<String>`.
    pub fn encode_ids(&self, f: &Function) -> (Vec<u32>, usize) {
        encode_function(f, self.scheme, &self.vocab, &self.op_ids, self.max_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("artifacts")
    }

    fn st(mean: f64) -> TargetStats {
        TargetStats { mean, std: 2.0, min: 0.0, max: 100.0 }
    }

    /// Golden back-compat: the exact bundle.json a pre-multi-output
    /// release wrote must keep parsing, as a 1-element target vector.
    #[test]
    fn legacy_single_target_bundle_json_parses() {
        let legacy = r#"{"max_len":128,"model":"fc_ops","scheme":"ops",
            "stats":{"max":40,"mean":10,"min":4,"std":2},"target":"regpressure"}"#;
        let meta = BundleMeta::from_json(&parse(legacy).unwrap()).unwrap();
        assert_eq!(meta.targets, vec![Target::RegPressure]);
        assert_eq!(meta.stats, vec![TargetStats { mean: 10.0, std: 2.0, min: 4.0, max: 40.0 }]);
        assert_eq!(meta.model, "fc_ops");
        assert_eq!(meta.max_len, 128);
        assert_eq!(meta.hardware, None);
    }

    #[test]
    fn meta_roundtrip_multi_target_with_hardware() {
        let meta = BundleMeta {
            model: "conv_ops".into(),
            targets: vec![Target::Cycles, Target::XpuUtil],
            scheme: Scheme::OpsOnly,
            max_len: 256,
            stats: vec![st(100.0), st(50.0)],
            hardware: Some("xpu-v2".into()),
        };
        let j = meta.to_json();
        // New writers still emit the legacy scalar field for old readers.
        assert_eq!(j.req_str("target").unwrap(), "cycles");
        let back = BundleMeta::from_json(&parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, meta);
    }

    #[test]
    fn malformed_target_lists_are_rejected() {
        let no_targets = Json::obj()
            .with("model", Json::str("fc_ops"))
            .with("scheme", Json::str("ops"))
            .with("max_len", Json::num(64.0))
            .with("targets", Json::Arr(vec![]))
            .with("stats", Json::Arr(vec![]));
        assert!(BundleMeta::from_json(&no_targets).is_err());
        let dup = no_targets
            .clone()
            .with("targets", Json::Arr(vec![Json::str("cycles"), Json::str("cycles")]))
            .with("stats", Json::Arr(vec![st(1.0).to_json(), st(1.0).to_json()]));
        let err = BundleMeta::from_json(&dup).unwrap_err().to_string();
        assert!(err.contains("duplicate target"), "{err}");
        let mismatch = no_targets
            .with("targets", Json::Arr(vec![Json::str("cycles")]))
            .with("stats", Json::Arr(vec![]));
        let err = BundleMeta::from_json(&mismatch).unwrap_err().to_string();
        assert!(err.contains("stats entries"), "{err}");
    }

    #[test]
    fn denormalize_elementwise_and_broadcast() {
        let vocab = Vocab::build([vec!["func".to_string()]].iter(), 1);
        let op_ids = OpIdTable::build(&vocab);
        let b = Bundle {
            model: "fc_ops".into(),
            targets: vec![Target::Cycles, Target::XpuUtil],
            scheme: Scheme::OpsOnly,
            max_len: 64,
            vocab,
            stats: vec![
                TargetStats { mean: 100.0, std: 10.0, min: 0.0, max: 500.0 },
                TargetStats { mean: 50.0, std: 5.0, min: 0.0, max: 100.0 },
            ],
            hardware: None,
            params: vec![],
            op_ids,
        };
        // Element-wise: each slot by its own stats.
        let out = b.denormalize(PredVec::from_slice(&[1.0, 2.0]));
        assert_eq!(out.as_slice(), &[110.0, 60.0]);
        // Legacy [B] head: one normalized value through every stats.
        let out = b.denormalize(PredVec::scalar(1.0));
        assert_eq!(out.as_slice(), &[110.0, 55.0]);
        assert_eq!(b.target_index(Target::XpuUtil), Some(1));
        assert_eq!(b.target_index(Target::RegPressure), None);
        assert!(b.serves_all(&[Target::XpuUtil, Target::Cycles]));
        assert!(!b.serves_all(&[Target::RegPressure]));
    }

    #[test]
    fn bundle_roundtrip() {
        let adir = artifacts_dir();
        if !adir.join("manifest.json").exists() {
            return;
        }
        let manifest = Manifest::load(&adir).unwrap();
        let streams = vec![vec!["xpu.matmul".to_string(), "4x8xf32".to_string()]];
        let vocab = Vocab::build(streams.iter(), 1);
        let stats = TargetStats { mean: 10.0, std: 2.0, min: 4.0, max: 40.0 };
        let b = Bundle::untrained(
            &manifest,
            "fc_ops",
            Target::RegPressure,
            Scheme::OpsOnly,
            vocab.clone(),
            stats.clone(),
        )
        .unwrap();
        let dir = std::env::temp_dir().join("mlir_cost_bundle_test");
        b.save(&dir, &manifest).unwrap();
        let b2 = Bundle::load(&dir, &manifest).unwrap();
        assert_eq!(b2.model, "fc_ops");
        assert_eq!(b2.primary_target(), Target::RegPressure);
        assert_eq!(b2.scheme, Scheme::OpsOnly);
        assert_eq!(b2.stats, vec![stats]);
        assert_eq!(b2.hardware, None);
        assert_eq!(b2.params.len(), b.params.len());
        assert_eq!(b2.params[0], b.params[0]);

        // Multi-target round-trip through the same directory format.
        let mb = Bundle::untrained_multi(
            &manifest,
            "fc_ops",
            &[Target::Cycles, Target::RegPressure],
            Scheme::OpsOnly,
            vocab,
            vec![st(100.0), st(10.0)],
            Some("xpu-v2".into()),
        )
        .unwrap();
        mb.save(&dir, &manifest).unwrap();
        let mb2 = Bundle::load(&dir, &manifest).unwrap();
        assert_eq!(mb2.targets, vec![Target::Cycles, Target::RegPressure]);
        assert_eq!(mb2.hardware.as_deref(), Some("xpu-v2"));
        assert_eq!(mb2.n_targets(), 2);
        std::fs::remove_dir_all(dir).ok();
    }
}

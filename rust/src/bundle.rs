//! Serving bundle: everything needed to answer cost queries for one
//! (model, target, tokenization-scheme) triple, produced by `mlir-cost
//! train` and consumed by `mlir-cost serve`, the benches and the examples.
//!
//! Layout of a bundle directory:
//!   bundle.json     — model name, target, scheme, max_len, stats
//!   vocab.json      — token vocabulary (train split only)
//!   <param>.f32 ... — trained parameters (checkpoint format)

use crate::dataset::TargetStats;
use crate::json::{parse, Json};
use crate::mlir::Function;
use crate::runtime::{Manifest, Tensor};
use crate::sim::Target;
use crate::tokenizer::{encode_function, OpIdTable, Scheme, Vocab};
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// In-memory serving bundle.
pub struct Bundle {
    pub model: String,
    pub target: Target,
    pub scheme: Scheme,
    pub max_len: usize,
    pub vocab: Vocab,
    pub stats: TargetStats,
    pub params: Vec<Tensor>,
    /// Per-`OpKind` vocabulary ids, precomputed at load so the id-direct
    /// encoder resolves op tokens by array index on every query.
    pub op_ids: OpIdTable,
}

impl Bundle {
    /// Write to `dir` (creating it).
    pub fn save(&self, dir: &Path, manifest: &Manifest) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let mm = manifest.model(&self.model)?;
        for (k, t) in mm.param_order.iter().zip(&self.params) {
            t.to_f32_file(&dir.join(format!("{k}.f32")))?;
        }
        self.vocab.save(&dir.join("vocab.json"))?;
        let doc = Json::obj()
            .with("model", Json::str(&self.model))
            .with("target", Json::str(self.target.name()))
            .with("scheme", Json::str(self.scheme.name()))
            .with("max_len", Json::num(self.max_len as f64))
            .with("stats", self.stats.to_json());
        std::fs::write(dir.join("bundle.json"), doc.to_string())?;
        Ok(())
    }

    /// Load from `dir`.
    pub fn load(dir: &Path, manifest: &Manifest) -> Result<Bundle> {
        let doc = parse(
            &std::fs::read_to_string(dir.join("bundle.json"))
                .with_context(|| format!("no bundle.json in {dir:?}"))?,
        )?;
        let model = doc.req_str("model")?.to_string();
        let target = Target::parse(doc.req_str("target")?)
            .ok_or_else(|| anyhow!("bad target in bundle"))?;
        let scheme = Scheme::parse(doc.req_str("scheme")?)
            .ok_or_else(|| anyhow!("bad scheme in bundle"))?;
        let max_len = doc.req_f64("max_len")? as usize;
        let stats = TargetStats::from_json(doc.req("stats")?)?;
        let vocab = Vocab::load(&dir.join("vocab.json"))?;
        let mm = manifest.model(&model)?;
        let params: Vec<Tensor> = mm
            .param_order
            .iter()
            .map(|k| {
                Tensor::from_f32_file(&dir.join(format!("{k}.f32")), mm.param_shapes[k].clone())
            })
            .collect::<Result<_>>()?;
        let op_ids = OpIdTable::build(&vocab);
        Ok(Bundle { model, target, scheme, max_len, vocab, stats, params, op_ids })
    }

    /// An untrained bundle straight from the AOT init params (useful for
    /// smoke tests and serving-path benches where accuracy is irrelevant).
    pub fn untrained(
        manifest: &Manifest,
        model: &str,
        target: Target,
        scheme: Scheme,
        vocab: Vocab,
        stats: TargetStats,
    ) -> Result<Bundle> {
        let mm = manifest.model(model)?;
        let op_ids = OpIdTable::build(&vocab);
        Ok(Bundle {
            model: model.to_string(),
            target,
            scheme,
            max_len: mm.max_len,
            vocab,
            stats,
            params: manifest.load_init_params(model)?,
            op_ids,
        })
    }

    /// Fused tokenize+encode for one parsed function (the serving hot
    /// path): ids byte-identical to the string pipeline, plus the
    /// whole-stream OOV count, in a single pass with no `Vec<String>`.
    pub fn encode_ids(&self, f: &Function) -> (Vec<u32>, usize) {
        encode_function(f, self.scheme, &self.vocab, &self.op_ids, self.max_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("artifacts")
    }

    #[test]
    fn bundle_roundtrip() {
        let adir = artifacts_dir();
        if !adir.join("manifest.json").exists() {
            return;
        }
        let manifest = Manifest::load(&adir).unwrap();
        let streams = vec![vec!["xpu.matmul".to_string(), "4x8xf32".to_string()]];
        let vocab = Vocab::build(streams.iter(), 1);
        let stats = TargetStats { mean: 10.0, std: 2.0, min: 4.0, max: 40.0 };
        let b = Bundle::untrained(
            &manifest,
            "fc_ops",
            Target::RegPressure,
            Scheme::OpsOnly,
            vocab,
            stats.clone(),
        )
        .unwrap();
        let dir = std::env::temp_dir().join("mlir_cost_bundle_test");
        b.save(&dir, &manifest).unwrap();
        let b2 = Bundle::load(&dir, &manifest).unwrap();
        assert_eq!(b2.model, "fc_ops");
        assert_eq!(b2.target, Target::RegPressure);
        assert_eq!(b2.scheme, Scheme::OpsOnly);
        assert_eq!(b2.stats, stats);
        assert_eq!(b2.params.len(), b.params.len());
        assert_eq!(b2.params[0], b.params[0]);
        std::fs::remove_dir_all(dir).ok();
    }
}

//! Minimal RFC-4180-style CSV with quoting — the corpus files hold full
//! MLIR text (newlines, commas) in one column, exactly like the paper's
//! "csv file for training consisting of: 1) Full MLIR Text sequence ...".

use anyhow::{bail, ensure, Result};

/// Write one row, quoting fields that need it.
pub fn write_row(out: &mut String, fields: &[&str]) {
    for (i, f) in fields.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if f.contains(',') || f.contains('"') || f.contains('\n') || f.contains('\r') {
            out.push('"');
            for c in f.chars() {
                if c == '"' {
                    out.push('"');
                }
                out.push(c);
            }
            out.push('"');
        } else {
            out.push_str(f);
        }
    }
    out.push('\n');
}

/// Parse an entire CSV document into rows of fields.
pub fn parse(src: &str) -> Result<Vec<Vec<String>>> {
    let mut rows = Vec::new();
    let mut row: Vec<String> = Vec::new();
    let mut field = String::new();
    let mut chars = src.chars().peekable();
    let mut in_quotes = false;
    let mut any = false;
    while let Some(c) = chars.next() {
        any = true;
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        field.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                c => field.push(c),
            }
        } else {
            match c {
                '"' => {
                    ensure!(field.is_empty(), "quote in the middle of an unquoted field");
                    in_quotes = true;
                }
                ',' => {
                    row.push(std::mem::take(&mut field));
                }
                '\r' => {}
                '\n' => {
                    row.push(std::mem::take(&mut field));
                    rows.push(std::mem::take(&mut row));
                }
                c => field.push(c),
            }
        }
    }
    if in_quotes {
        bail!("unterminated quoted field");
    }
    if any && (!field.is_empty() || !row.is_empty()) {
        row.push(field);
        rows.push(row);
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_with_mlir_text() {
        let mlir = "func.func @f(%arg0: tensor<1x2xf32>) {\n  return\n}\n";
        let mut out = String::new();
        write_row(&mut out, &["name1", "resnet", "12.5", mlir]);
        write_row(&mut out, &["name2", "bert", "7", "plain"]);
        let rows = parse(&out).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0][3], mlir);
        assert_eq!(rows[1], vec!["name2", "bert", "7", "plain"]);
    }

    #[test]
    fn quotes_inside_fields() {
        let mut out = String::new();
        write_row(&mut out, &["a", "say \"hi\", ok", "b"]);
        let rows = parse(&out).unwrap();
        assert_eq!(rows[0][1], "say \"hi\", ok");
    }

    #[test]
    fn empty_fields() {
        let rows = parse("a,,c\n,,\n").unwrap();
        assert_eq!(rows[0], vec!["a", "", "c"]);
        assert_eq!(rows[1], vec!["", "", ""]);
    }

    #[test]
    fn crlf_handling() {
        let rows = parse("a,b\r\nc,d\r\n").unwrap();
        assert_eq!(rows, vec![vec!["a", "b"], vec!["c", "d"]]);
    }

    #[test]
    fn errors() {
        assert!(parse("\"unterminated").is_err());
        assert!(parse("ab\"cd\n").is_err());
    }

    #[test]
    fn no_trailing_newline() {
        let rows = parse("a,b").unwrap();
        assert_eq!(rows, vec![vec!["a", "b"]]);
    }
}

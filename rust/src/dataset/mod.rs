//! Dataset pipeline: corpus generation (graphs → MLIR text → ground-truth
//! labels), CSV persistence, train/test split, target normalization, and
//! encoded-batch construction for the PJRT-executed models.
//!
//! Mirrors the paper §3 "Training Dataset": a CSV of (full MLIR text,
//! input/output tensor shapes, target variable), 20k+ training samples
//! plus augmentation, ~2k+ test samples.

pub mod csv;

use crate::graphgen::{corpus_specs, generate, GraphSpec};
use crate::lower::CodegenOpts;
use crate::mlir::{parse_function, print_function};
use crate::rng::Rng;
use crate::sim::{ground_truth, Labels, Target, XpuConfig};
use crate::tokenizer::{encode_with_oov, tokenize, Scheme, Vocab};
use anyhow::{ensure, Context, Result};
use std::path::Path;

/// One corpus row.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub family: String,
    pub mlir_text: String,
    pub labels: Labels,
}

/// A full dataset (one split).
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    pub samples: Vec<Sample>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Generate `count` base graphs (+`augment` shape re-rolls each) and
    /// label them through the compiler+simulator.
    pub fn generate(seed: u64, count: usize, augment: usize) -> Result<Dataset> {
        let opts = CodegenOpts::default();
        let cfg = XpuConfig::default();
        let mut samples = Vec::new();
        for spec in corpus_specs(seed, count, augment) {
            samples.push(make_sample(&spec, &opts, &cfg)?);
        }
        Ok(Dataset { samples })
    }

    /// Persist as CSV (`name,family,regpressure,xpuutil,cycles,spills,dyn_instrs,mlir`).
    pub fn save_csv(&self, path: &Path) -> Result<()> {
        let mut out = String::new();
        csv::write_row(
            &mut out,
            &["name", "family", "regpressure", "xpuutil", "cycles", "spills", "dyn_instrs", "mlir"],
        );
        for s in &self.samples {
            csv::write_row(
                &mut out,
                &[
                    &s.name,
                    &s.family,
                    &format!("{}", s.labels.regpressure),
                    &format!("{:.6}", s.labels.xpu_util),
                    &format!("{}", s.labels.cycles),
                    &format!("{}", s.labels.spills),
                    &format!("{}", s.labels.dyn_instrs),
                    &s.mlir_text,
                ],
            );
        }
        std::fs::write(path, out).with_context(|| format!("writing {path:?}"))?;
        Ok(())
    }

    /// Load a CSV produced by [`Dataset::save_csv`].
    pub fn load_csv(path: &Path) -> Result<Dataset> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        let rows = csv::parse(&text)?;
        ensure!(!rows.is_empty(), "empty dataset file {path:?}");
        let mut samples = Vec::with_capacity(rows.len() - 1);
        for (i, row) in rows.iter().enumerate().skip(1) {
            ensure!(row.len() == 8, "row {i}: expected 8 fields, got {}", row.len());
            samples.push(Sample {
                name: row[0].clone(),
                family: row[1].clone(),
                labels: Labels {
                    regpressure: row[2].parse().with_context(|| format!("row {i} regpressure"))?,
                    xpu_util: row[3].parse().with_context(|| format!("row {i} xpuutil"))?,
                    cycles: row[4].parse().with_context(|| format!("row {i} cycles"))?,
                    spills: row[5].parse().with_context(|| format!("row {i} spills"))?,
                    dyn_instrs: row[6].parse().with_context(|| format!("row {i} dyn_instrs"))?,
                },
                mlir_text: row[7].clone(),
            });
        }
        Ok(Dataset { samples })
    }

    /// Deterministic shuffled split: `test_frac` of samples to the test set.
    pub fn split(mut self, seed: u64, test_frac: f64) -> (Dataset, Dataset) {
        let mut rng = Rng::new(seed);
        rng.shuffle(&mut self.samples);
        let n_test = ((self.samples.len() as f64) * test_frac).round() as usize;
        let test = self.samples.split_off(self.samples.len() - n_test);
        (Dataset { samples: self.samples }, Dataset { samples: test })
    }

    /// Tokenize every sample under `scheme` (re-parsing the stored text —
    /// the text is the source of truth, as in the paper).
    pub fn token_streams(&self, scheme: Scheme) -> Result<Vec<Vec<String>>> {
        self.samples
            .iter()
            .map(|s| {
                let f = parse_function(&s.mlir_text)
                    .with_context(|| format!("re-parsing sample {}", s.name))?;
                Ok(tokenize(&f, scheme))
            })
            .collect()
    }
}

fn make_sample(spec: &GraphSpec, opts: &CodegenOpts, cfg: &XpuConfig) -> Result<Sample> {
    let f = generate(spec).with_context(|| format!("generating {spec:?}"))?;
    let labels = ground_truth(&f, opts, cfg).with_context(|| format!("labeling {spec:?}"))?;
    Ok(Sample {
        name: spec.func_name(),
        family: spec.family.name().to_string(),
        mlir_text: print_function(&f),
        labels,
    })
}

/// Normalization statistics for one target variable, computed on train.
#[derive(Debug, Clone, PartialEq)]
pub struct TargetStats {
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl TargetStats {
    pub fn compute(values: &[f64]) -> TargetStats {
        let n = values.len().max(1) as f64;
        let mean = values.iter().sum::<f64>() / n;
        let var = values.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / n;
        let min = values.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = values.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        TargetStats { mean, std: var.sqrt().max(1e-9), min, max }
    }

    pub fn for_dataset(ds: &Dataset, target: Target) -> TargetStats {
        let vals: Vec<f64> = ds.samples.iter().map(|s| target.of(&s.labels)).collect();
        TargetStats::compute(&vals)
    }

    /// Per-target stats for a declared target list, parallel to it.
    pub fn for_targets(ds: &Dataset, targets: &[Target]) -> Vec<TargetStats> {
        targets.iter().map(|&t| TargetStats::for_dataset(ds, t)).collect()
    }

    pub fn normalize(&self, v: f64) -> f64 {
        (v - self.mean) / self.std
    }

    pub fn denormalize(&self, v: f64) -> f64 {
        v * self.std + self.mean
    }

    /// Target range — the paper reports RMSE as a % of this.
    pub fn range(&self) -> f64 {
        (self.max - self.min).max(1e-9)
    }

    pub fn to_json(&self) -> crate::json::Json {
        crate::json::Json::obj()
            .with("mean", crate::json::Json::num(self.mean))
            .with("std", crate::json::Json::num(self.std))
            .with("min", crate::json::Json::num(self.min))
            .with("max", crate::json::Json::num(self.max))
    }

    pub fn from_json(j: &crate::json::Json) -> Result<TargetStats> {
        Ok(TargetStats {
            mean: j.req_f64("mean")?,
            std: j.req_f64("std")?,
            min: j.req_f64("min")?,
            max: j.req_f64("max")?,
        })
    }
}

/// An encoded batch ready for the PJRT runtime: row-major `[n, max_len]`
/// token ids and row-major `[n, n_targets]` normalized label vectors —
/// one row of characteristics per sample, in the declared target order.
#[derive(Debug, Clone)]
pub struct EncodedSet {
    pub ids: Vec<i32>,
    pub targets: Vec<f32>,
    pub n: usize,
    pub max_len: usize,
    /// Declared characteristics per sample (row width of `targets`).
    pub n_targets: usize,
    /// Whole-stream OOV tokens across all samples, counted during the
    /// same pass that encodes (no second vocabulary-lookup sweep).
    pub oov: usize,
}

impl EncodedSet {
    /// Single-target build — the legacy shape, now a 1-wide label row.
    pub fn build(
        ds: &Dataset,
        streams: &[Vec<String>],
        vocab: &Vocab,
        max_len: usize,
        target: Target,
        stats: &TargetStats,
    ) -> EncodedSet {
        EncodedSet::build_multi(ds, streams, vocab, max_len, &[target], std::slice::from_ref(stats))
    }

    /// Encode one dataset against a declared target list: every sample's
    /// labels (all computed by one simulator run) become one normalized
    /// row of `targets.len()` values — the multi-output head's training
    /// signal, no per-target re-encode.
    pub fn build_multi(
        ds: &Dataset,
        streams: &[Vec<String>],
        vocab: &Vocab,
        max_len: usize,
        targets: &[Target],
        stats: &[TargetStats],
    ) -> EncodedSet {
        assert_eq!(ds.len(), streams.len());
        assert_eq!(targets.len(), stats.len(), "one TargetStats per declared target");
        assert!(!targets.is_empty(), "at least one target required");
        let n = ds.len();
        let k = targets.len();
        let mut ids = Vec::with_capacity(n * max_len);
        let mut tg = Vec::with_capacity(n * k);
        let mut oov = 0usize;
        for (s, toks) in ds.samples.iter().zip(streams) {
            let (row, row_oov) = encode_with_oov(toks, vocab, max_len);
            ids.extend(row.into_iter().map(|x| x as i32));
            oov += row_oov;
            for (t, st) in targets.iter().zip(stats) {
                tg.push(st.normalize(t.of(&s.labels)) as f32);
            }
        }
        EncodedSet { ids, targets: tg, n, max_len, n_targets: k, oov }
    }

    /// Row-slice a minibatch (by precomputed indices): `[b, max_len]`
    /// ids and `[b, n_targets]` labels.
    pub fn gather(&self, idx: &[usize]) -> (Vec<i32>, Vec<f32>) {
        let k = self.n_targets;
        let mut ids = Vec::with_capacity(idx.len() * self.max_len);
        let mut tg = Vec::with_capacity(idx.len() * k);
        for &i in idx {
            ids.extend_from_slice(&self.ids[i * self.max_len..(i + 1) * self.max_len]);
            tg.extend_from_slice(&self.targets[i * k..(i + 1) * k]);
        }
        (ids, tg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_save_load_roundtrip() {
        let ds = Dataset::generate(7, 12, 1).unwrap();
        assert_eq!(ds.len(), 24);
        let dir = std::env::temp_dir().join("mlir_cost_test_ds");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("corpus.csv");
        ds.save_csv(&path).unwrap();
        let ds2 = Dataset::load_csv(&path).unwrap();
        assert_eq!(ds.len(), ds2.len());
        for (a, b) in ds.samples.iter().zip(&ds2.samples) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.mlir_text, b.mlir_text);
            assert_eq!(a.labels.regpressure, b.labels.regpressure);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn split_is_deterministic_and_disjoint() {
        let ds = Dataset::generate(9, 20, 0).unwrap();
        let names: Vec<String> = ds.samples.iter().map(|s| s.name.clone()).collect();
        let (tr1, te1) = ds.clone().split(42, 0.25);
        let (tr2, te2) = ds.split(42, 0.25);
        assert_eq!(te1.len(), 5);
        assert_eq!(tr1.len(), 15);
        assert_eq!(
            te1.samples.iter().map(|s| &s.name).collect::<Vec<_>>(),
            te2.samples.iter().map(|s| &s.name).collect::<Vec<_>>()
        );
        let _ = tr2;
        let mut all: Vec<String> = tr1.samples.iter().chain(&te1.samples).map(|s| s.name.clone()).collect();
        all.sort();
        let mut orig = names;
        orig.sort();
        assert_eq!(all, orig);
    }

    #[test]
    fn stats_and_normalization() {
        let st = TargetStats::compute(&[10.0, 20.0, 30.0]);
        assert!((st.mean - 20.0).abs() < 1e-9);
        assert!((st.range() - 20.0).abs() < 1e-9);
        let z = st.normalize(30.0);
        assert!((st.denormalize(z) - 30.0).abs() < 1e-9);
        let j = st.to_json().to_string();
        let st2 = TargetStats::from_json(&crate::json::parse(&j).unwrap()).unwrap();
        assert_eq!(st, st2);
    }

    #[test]
    fn encoded_set_shapes() {
        let ds = Dataset::generate(11, 8, 0).unwrap();
        let streams = ds.token_streams(Scheme::OpsOnly).unwrap();
        let vocab = Vocab::build(streams.iter(), 1);
        let stats = TargetStats::for_dataset(&ds, Target::RegPressure);
        let enc = EncodedSet::build(&ds, &streams, &vocab, 64, Target::RegPressure, &stats);
        assert_eq!(enc.ids.len(), 8 * 64);
        assert_eq!(enc.targets.len(), 8);
        // Vocab was built from these very streams with min_count 1 → the
        // fused pass must see zero OOV; a foreign vocab must see plenty.
        assert_eq!(enc.oov, 0);
        let tiny = Vocab::build([vec!["func".to_string()]].iter(), 1);
        let enc2 = EncodedSet::build(&ds, &streams, &tiny, 64, Target::RegPressure, &stats);
        let expect: usize =
            streams.iter().map(|s| crate::tokenizer::count_oov(s, &tiny)).sum();
        assert_eq!(enc2.oov, expect);
        let (bi, bt) = enc.gather(&[0, 3, 5]);
        assert_eq!(bi.len(), 3 * 64);
        assert_eq!(bt.len(), 3);
        assert_eq!(&bi[..64], &enc.ids[..64]);
    }

    #[test]
    fn multi_target_rows_are_declared_order() {
        let ds = Dataset::generate(21, 6, 0).unwrap();
        let streams = ds.token_streams(Scheme::OpsOnly).unwrap();
        let vocab = Vocab::build(streams.iter(), 1);
        let targets = [Target::Cycles, Target::XpuUtil, Target::RegPressure];
        let stats = TargetStats::for_targets(&ds, &targets);
        assert_eq!(stats.len(), 3);
        let enc = EncodedSet::build_multi(&ds, &streams, &vocab, 64, &targets, &stats);
        assert_eq!(enc.n_targets, 3);
        assert_eq!(enc.targets.len(), 6 * 3);
        // Row i column j is target j of sample i, normalized by its own stats.
        for (i, s) in ds.samples.iter().enumerate() {
            for (j, (t, st)) in targets.iter().zip(&stats).enumerate() {
                let want = st.normalize(t.of(&s.labels)) as f32;
                assert_eq!(enc.targets[i * 3 + j], want, "sample {i} target {j}");
            }
        }
        // gather slices whole label rows.
        let (_, bt) = enc.gather(&[1, 4]);
        assert_eq!(bt.len(), 2 * 3);
        assert_eq!(&bt[..3], &enc.targets[3..6]);
        assert_eq!(&bt[3..], &enc.targets[12..15]);
        // The 1-target path is the k==1 special case of the same code.
        let single = EncodedSet::build(&ds, &streams, &vocab, 64, Target::Cycles, &stats[0]);
        assert_eq!(single.n_targets, 1);
        let multi_col0: Vec<f32> = (0..6).map(|i| enc.targets[i * 3]).collect();
        assert_eq!(single.targets, multi_col0);
    }

    #[test]
    fn token_streams_reparse_stored_text() {
        let ds = Dataset::generate(13, 6, 0).unwrap();
        let streams = ds.token_streams(Scheme::OpsOperands).unwrap();
        assert_eq!(streams.len(), 6);
        assert!(streams.iter().all(|s| s.len() > 5));
    }
}

//! The `xpu-isa`: the low-level instruction set our DL-compiler emits and
//! the accelerator simulator executes.
//!
//! The machine is modeled after contemporary AI accelerators (and the
//! paper's unnamed Intel part): a 16-lane (f32) vector ALU, a 32×32
//! systolic MXU, an SFU for transcendentals, an LSU moving vectors between
//! scratchpad and vector registers, and DMA engines for HBM↔scratchpad.
//!
//! Code is organized as [`Segment`]s: the instruction window of one
//! steady-state iteration of an innermost tiled loop, plus its trip count.
//! This keeps ground-truth generation O(ops), not O(elements), while
//! preserving the quantities the paper labels with (register pressure is a
//! property of the window; cycles/utilization scale with trips).

use std::fmt;

/// Virtual vector register. `width` is how many physical vector registers
/// it occupies (an MXU accumulator tile spans several).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct VReg {
    pub id: u32,
    pub width: u8,
}

impl fmt::Display for VReg {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.width > 1 {
            write!(f, "v{}:{}", self.id, self.width)
        } else {
            write!(f, "v{}", self.id)
        }
    }
}

/// Memory space an access touches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mem {
    /// On-chip SW-managed scratchpad (fast, DMA-filled).
    Scratch,
    /// Off-chip HBM (slow, high latency).
    Hbm,
}

/// Vector-ALU opcodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VArith {
    Add,
    Sub,
    Mul,
    Max,
    Min,
    /// Broadcast-immediate / move (register shuffle class).
    Mov,
}

/// SFU opcodes (transcendentals + division live here, like real VPUs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SfuOp {
    Div,
    Exp,
    Tanh,
    Erf,
    Sqrt,
    Rsqrt,
    Sigmoid,
    Gelu,
}

/// One machine instruction.
#[derive(Debug, Clone, PartialEq)]
pub enum Instr {
    /// Load a vector register from memory.
    VLoad { dst: VReg, mem: Mem, strided: bool },
    /// Store a vector register to memory.
    VStore { src: VReg, mem: Mem, strided: bool },
    /// Vector-ALU op. `b == None` for unary moves etc.
    VOp { op: VArith, dst: VReg, a: VReg, b: Option<VReg> },
    /// SFU op (always unary except Div which takes two).
    Sfu { op: SfuOp, dst: VReg, a: VReg, b: Option<VReg> },
    /// MXU tile multiply-accumulate: `acc += a @ b`. Reads and writes acc.
    Macc { acc: VReg, a: VReg, b: VReg },
    /// Spill fill/sink inserted by the register allocator.
    SpillLoad { dst: VReg },
    SpillStore { src: VReg },
}

impl Instr {
    /// Registers read by this instruction.
    pub fn uses(&self) -> Vec<VReg> {
        match self {
            Instr::VLoad { .. } | Instr::SpillLoad { .. } => vec![],
            Instr::VStore { src, .. } | Instr::SpillStore { src } => vec![*src],
            Instr::VOp { a, b, .. } | Instr::Sfu { a, b, .. } => {
                let mut v = vec![*a];
                if let Some(b) = b {
                    v.push(*b);
                }
                v
            }
            Instr::Macc { acc, a, b } => vec![*acc, *a, *b],
        }
    }

    /// Register written by this instruction (if any).
    pub fn def(&self) -> Option<VReg> {
        match self {
            Instr::VLoad { dst, .. } | Instr::SpillLoad { dst } => Some(*dst),
            Instr::VOp { dst, .. } | Instr::Sfu { dst, .. } => Some(*dst),
            Instr::Macc { acc, .. } => Some(*acc),
            Instr::VStore { .. } | Instr::SpillStore { .. } => None,
        }
    }

    /// Assembly-ish rendering for debug dumps and the affine-level corpus.
    pub fn render(&self) -> String {
        match self {
            Instr::VLoad { dst, mem, strided } => {
                format!("vload{} {dst}, [{}]", if *strided { ".s" } else { "" }, mem_name(*mem))
            }
            Instr::VStore { src, mem, strided } => {
                format!("vstore{} {src}, [{}]", if *strided { ".s" } else { "" }, mem_name(*mem))
            }
            Instr::VOp { op, dst, a, b } => match b {
                Some(b) => format!("v{op:?} {dst}, {a}, {b}").to_lowercase(),
                None => format!("v{op:?} {dst}, {a}").to_lowercase(),
            },
            Instr::Sfu { op, dst, a, b } => match b {
                Some(b) => format!("sfu.{op:?} {dst}, {a}, {b}").to_lowercase(),
                None => format!("sfu.{op:?} {dst}, {a}").to_lowercase(),
            },
            Instr::Macc { acc, a, b } => format!("mxu.macc {acc}, {a}, {b}"),
            Instr::SpillLoad { dst } => format!("spill.ld {dst}"),
            Instr::SpillStore { src } => format!("spill.st {src}"),
        }
    }
}

fn mem_name(m: Mem) -> &'static str {
    match m {
        Mem::Scratch => "spad",
        Mem::Hbm => "hbm",
    }
}

/// One steady-state loop body and how many times it runs.
#[derive(Debug, Clone)]
pub struct Segment {
    /// Human label for dumps ("matmul %3 inner", "ew-chain %7").
    pub label: String,
    pub instrs: Vec<Instr>,
    pub trips: u64,
    /// Registers that stay live across all trips of this segment
    /// (accumulators, double-buffer residents).
    pub loop_carried: Vec<VReg>,
}

impl Segment {
    pub fn new(label: impl Into<String>, trips: u64) -> Self {
        Segment { label: label.into(), instrs: Vec::new(), trips: trips.max(1), loop_carried: Vec::new() }
    }
}

/// A compiled kernel: the segment list plus static counters the lowering
/// pipeline gathers on the way.
#[derive(Debug, Clone, Default)]
pub struct Program {
    pub segments: Vec<Segment>,
    /// Bytes DMA'd HBM→scratchpad for inputs/weights (per full run).
    pub dma_in_bytes: u64,
    /// Bytes DMA'd scratchpad→HBM for outputs.
    pub dma_out_bytes: u64,
}

impl Program {
    /// Total dynamic instruction count (windows × trips).
    pub fn dyn_instrs(&self) -> u64 {
        self.segments.iter().map(|s| s.instrs.len() as u64 * s.trips).sum()
    }

    /// Total static (window) instruction count.
    pub fn static_instrs(&self) -> usize {
        self.segments.iter().map(|s| s.instrs.len()).sum()
    }

    /// Render the whole program for debugging / the ISA-level corpus.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for seg in &self.segments {
            out.push_str(&format!("; {} (x{})\n", seg.label, seg.trips));
            for i in &seg.instrs {
                out.push_str("  ");
                out.push_str(&i.render());
                out.push('\n');
            }
        }
        out
    }
}

/// Fresh-register source shared across the codegen of one function.
#[derive(Debug, Default)]
pub struct RegAlloc {
    next: u32,
}

impl RegAlloc {
    pub fn fresh(&mut self, width: u8) -> VReg {
        let r = VReg { id: self.next, width };
        self.next += 1;
        r
    }

    pub fn count(&self) -> u32 {
        self.next
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uses_and_defs() {
        let a = VReg { id: 0, width: 1 };
        let b = VReg { id: 1, width: 1 };
        let c = VReg { id: 2, width: 1 };
        let i = Instr::VOp { op: VArith::Add, dst: c, a, b: Some(b) };
        assert_eq!(i.uses(), vec![a, b]);
        assert_eq!(i.def(), Some(c));

        let st = Instr::VStore { src: c, mem: Mem::Scratch, strided: false };
        assert_eq!(st.uses(), vec![c]);
        assert_eq!(st.def(), None);

        let acc = VReg { id: 3, width: 4 };
        let m = Instr::Macc { acc, a, b };
        assert!(m.uses().contains(&acc));
        assert_eq!(m.def(), Some(acc));
    }

    #[test]
    fn dyn_instr_scaling() {
        let mut p = Program::default();
        let mut seg = Segment::new("x", 10);
        let mut ra = RegAlloc::default();
        let r = ra.fresh(1);
        seg.instrs.push(Instr::VLoad { dst: r, mem: Mem::Scratch, strided: false });
        seg.instrs.push(Instr::VStore { src: r, mem: Mem::Scratch, strided: false });
        p.segments.push(seg);
        assert_eq!(p.dyn_instrs(), 20);
        assert_eq!(p.static_instrs(), 2);
    }

    #[test]
    fn render_smoke() {
        let mut ra = RegAlloc::default();
        let r = ra.fresh(1);
        let s = ra.fresh(4);
        let text = Instr::VLoad { dst: r, mem: Mem::Hbm, strided: true }.render();
        assert_eq!(text, "vload.s v0, [hbm]");
        assert_eq!(Instr::SpillStore { src: s }.render(), "spill.st v1:4");
    }
}

//! Lowering from the `xpu` dialect to the `affine` dialect.
//!
//! The paper (§5) claims its model "is scalable to different forms of MLIR
//! — from high-level MLIR dialects to lower-level dialects like affine or
//! scf which can produce much larger sequences of the order of thousands
//! of tokens due to the presence of loops and control flow". This pass
//! produces that lower-level corpus: every tensor becomes a `memref`,
//! every operator a loop nest of `affine.for` / `affine.load` /
//! `arith.*` / `affine.store`.
//!
//! NOTE: this is a *cost/token corpus* lowering — broadcast indexing is
//! structurally approximated (a size-1 dim is addressed with the same
//! induction variable), which preserves op counts, loop structure, and
//! memory-access shape without carrying full affine-map machinery.

use crate::mlir::{
    Attr, Attrs, ArithOp, DType, FuncBuilder, Function, OpKind, Operation, Type, ValueId, XpuOp,
};
use anyhow::{bail, Result};
use std::collections::HashMap;

struct Lowerer<'a> {
    src: &'a Function,
    b: FuncBuilder,
    /// xpu value → memref holding it in the affine function.
    buf: HashMap<ValueId, ValueId>,
}

impl<'a> Lowerer<'a> {
    fn shape_of(&self, v: ValueId) -> (Vec<i64>, DType) {
        let t = self.src.value_type(v).as_tensor().expect("tensor value");
        (t.shape.clone(), t.dtype)
    }

    /// Get (allocating if needed) the memref for an xpu value.
    fn memref(&mut self, v: ValueId) -> ValueId {
        if let Some(&m) = self.buf.get(&v) {
            return m;
        }
        let (shape, dtype) = self.shape_of(v);
        let m = self.b.alloc(shape, dtype);
        self.buf.insert(v, m);
        m
    }

    /// Open a loop nest over `shape`, returning the induction variables.
    fn open_nest(&mut self, shape: &[i64]) -> Result<Vec<ValueId>> {
        Ok(shape.iter().map(|&d| self.b.begin_for(0, d.max(1), 1)).collect())
    }

    fn close_nest(&mut self, n: usize) -> Result<()> {
        for _ in 0..n {
            self.b.end_for()?;
        }
        Ok(())
    }

    /// Index list for memref `m` inside a nest `ivs`: trailing induction
    /// variables, left-padded with the outermost iv when the memref's rank
    /// exceeds the nest depth (reshape views make those differ).
    fn index_for(&self, m: ValueId, ivs: &[ValueId]) -> Vec<ValueId> {
        let rank = self.b.value_type(m).as_memref().expect("memref").rank();
        if rank <= ivs.len() {
            ivs[ivs.len() - rank..].to_vec()
        } else {
            let mut idx = vec![ivs[0]; rank - ivs.len()];
            idx.extend_from_slice(ivs);
            idx
        }
    }

    /// Adapt a hand-built logical index list to the actual rank of `m`
    /// (aliased reshape views change the rank under an op's feet).
    fn fit_index(&self, m: ValueId, logical: Vec<ValueId>) -> Vec<ValueId> {
        let rank = self.b.value_type(m).as_memref().expect("memref").rank();
        match rank.cmp(&logical.len()) {
            std::cmp::Ordering::Equal => logical,
            std::cmp::Ordering::Less => logical[logical.len() - rank..].to_vec(),
            std::cmp::Ordering::Greater => {
                let mut idx = vec![logical[0]; rank - logical.len()];
                idx.extend(logical);
                idx
            }
        }
    }

    /// Load `v` inside a nest indexed by `ivs` (over the *result* shape):
    /// operands of smaller rank use the trailing induction variables.
    fn load_indexed(&mut self, v: ValueId, ivs: &[ValueId]) -> Result<ValueId> {
        let m = self.memref(v);
        let idx = self.index_for(m, ivs);
        self.b.load(m, &idx)
    }

    /// Store `value` into the memref for `v` with rank-corrected indices.
    fn store_indexed(&mut self, value: ValueId, v: ValueId, ivs: &[ValueId]) -> Result<()> {
        let m = self.memref(v);
        let idx = self.index_for(m, ivs);
        self.b.store(value, m, &idx)
    }

    fn constant(&mut self, value: f64, dtype: DType) -> Result<ValueId> {
        self.b.arith(
            ArithOp::Constant,
            &[],
            Attrs::new()
                .with("value", Attr::Float(value))
                .with("dtype", Attr::Str(dtype.mlir_name().into())),
        )
    }

    /// Scalar expansion of a unary xpu activation.
    fn unary_scalar(&mut self, op: XpuOp, x: ValueId, dtype: DType) -> Result<ValueId> {
        let a1 = |s: &mut Self, k: ArithOp, v: ValueId| s.b.arith(k, &[v], Attrs::new());
        let a2 = |s: &mut Self, k: ArithOp, v: ValueId, w: ValueId| {
            s.b.arith(k, &[v, w], Attrs::new())
        };
        Ok(match op {
            XpuOp::Exp => a1(self, ArithOp::ExpF, x)?,
            XpuOp::Tanh => a1(self, ArithOp::TanhF, x)?,
            XpuOp::Erf => a1(self, ArithOp::ErfF, x)?,
            XpuOp::Sqrt => a1(self, ArithOp::SqrtF, x)?,
            XpuOp::Rsqrt => a1(self, ArithOp::RsqrtF, x)?,
            XpuOp::Neg => a1(self, ArithOp::NegF, x)?,
            XpuOp::Relu => {
                let zero = self.constant(0.0, dtype)?;
                a2(self, ArithOp::MaxF, x, zero)?
            }
            XpuOp::Sigmoid => {
                // 1 / (1 + exp(-x))
                let n = a1(self, ArithOp::NegF, x)?;
                let e = a1(self, ArithOp::ExpF, n)?;
                let one = self.constant(1.0, dtype)?;
                let d = a2(self, ArithOp::AddF, e, one)?;
                a2(self, ArithOp::DivF, one, d)?
            }
            XpuOp::Gelu => {
                // 0.5 * x * (1 + erf(x / sqrt(2)))
                let c = self.constant(std::f64::consts::FRAC_1_SQRT_2, dtype)?;
                let sx = a2(self, ArithOp::MulF, x, c)?;
                let e = a1(self, ArithOp::ErfF, sx)?;
                let one = self.constant(1.0, dtype)?;
                let t = a2(self, ArithOp::AddF, e, one)?;
                let half = self.constant(0.5, dtype)?;
                let hx = a2(self, ArithOp::MulF, x, half)?;
                a2(self, ArithOp::MulF, hx, t)?
            }
            other => bail!("not a scalarizable unary op: {other:?}"),
        })
    }

    fn binary_arith(op: XpuOp) -> ArithOp {
        match op {
            XpuOp::Add => ArithOp::AddF,
            XpuOp::Sub => ArithOp::SubF,
            XpuOp::Mult => ArithOp::MulF,
            XpuOp::Div => ArithOp::DivF,
            XpuOp::Maximum => ArithOp::MaxF,
            XpuOp::Minimum => ArithOp::MinF,
            _ => unreachable!(),
        }
    }

    fn lower_op(&mut self, op: &Operation) -> Result<()> {
        let OpKind::Xpu(kind) = op.kind else { return Ok(()) };
        match kind {
            XpuOp::Const => {
                // Weights: just materialize the buffer.
                self.memref(op.results[0]);
            }
            XpuOp::Reshape | XpuOp::Broadcast => {
                // Views: alias the input buffer under the result id.
                let m = self.memref(op.operands[0]);
                self.buf.insert(op.results[0], m);
            }
            k if k.is_elementwise() => {
                let result = op.results[0];
                let (shape, dtype) = self.shape_of(result);
                let ivs = self.open_nest(&shape)?;
                let lhs = self.load_indexed(op.operands[0], &ivs)?;
                let value = if op.operands.len() == 2 {
                    let rhs = self.load_indexed(op.operands[1], &ivs)?;
                    self.b.arith(Self::binary_arith(k), &[lhs, rhs], Attrs::new())?
                } else {
                    self.unary_scalar(k, lhs, dtype)?
                };
                self.store_indexed(value, result, &ivs)?;
                self.close_nest(ivs.len())?;
            }
            XpuOp::MatMul => {
                let result = op.results[0];
                let (out_shape, _) = self.shape_of(result);
                let (a_shape, _) = self.shape_of(op.operands[0]);
                let k_dim = a_shape[a_shape.len() - 1];
                let out = self.memref(result);
                // Nest over output dims then the contraction dim.
                let ivs = self.open_nest(&out_shape)?;
                let kiv = self.b.begin_for(0, k_dim, 1);
                // a[..., m, k] — last two indices are (m_iv, k_iv).
                let (av, bv) = {
                    let (a_sh, _) = self.shape_of(op.operands[0]);
                    let am = self.memref(op.operands[0]);
                    let mut aidx: Vec<ValueId> =
                        ivs[ivs.len() - a_sh.len().min(ivs.len())..ivs.len() - 1].to_vec();
                    aidx.push(kiv);
                    let aidx = self.fit_index(am, aidx);
                    let av = self.b.load(am, &aidx)?;
                    let (b_sh, _) = self.shape_of(op.operands[1]);
                    let bm = self.memref(op.operands[1]);
                    let mut bidx: Vec<ValueId> = Vec::new();
                    if b_sh.len() > 2 {
                        bidx.extend(
                            ivs[ivs.len() - b_sh.len().min(ivs.len())..ivs.len() - 2]
                                .iter()
                                .copied(),
                        );
                    }
                    bidx.push(kiv);
                    bidx.push(ivs[ivs.len() - 1]);
                    let bidx = self.fit_index(bm, bidx);
                    let bv = self.b.load(bm, &bidx)?;
                    (av, bv)
                };
                let prod = self.b.arith(ArithOp::MulF, &[av, bv], Attrs::new())?;
                let acc = self.b.load(out, &ivs)?;
                let sum = self.b.arith(ArithOp::AddF, &[acc, prod], Attrs::new())?;
                self.b.store(sum, out, &ivs)?;
                self.b.end_for()?;
                self.close_nest(ivs.len())?;
            }
            XpuOp::Conv2d => {
                let result = op.results[0];
                let (out_shape, _) = self.shape_of(result);
                let (w_shape, _) = self.shape_of(op.operands[1]);
                let (ic, kh, kw) = (w_shape[1], w_shape[2], w_shape[3]);
                let out = self.memref(result);
                let xm = self.memref(op.operands[0]);
                let wm = self.memref(op.operands[1]);
                let ivs = self.open_nest(&out_shape)?; // n, oc, oh, ow
                let red = self.open_nest(&[ic, kh, kw])?;
                // x[n, ic, oh(+kh), ow(+kw)] — offset arithmetic elided.
                let xidx = self.fit_index(xm, vec![ivs[0], red[0], ivs[2], ivs[3]]);
                let xv = self.b.load(xm, &xidx)?;
                let widx = self.fit_index(wm, vec![ivs[1], red[0], red[1], red[2]]);
                let wv = self.b.load(wm, &widx)?;
                let prod = self.b.arith(ArithOp::MulF, &[xv, wv], Attrs::new())?;
                let acc = self.b.load(out, &ivs)?;
                let sum = self.b.arith(ArithOp::AddF, &[acc, prod], Attrs::new())?;
                self.b.store(sum, out, &ivs)?;
                self.close_nest(red.len())?;
                self.close_nest(ivs.len())?;
            }
            _ => {
                // Default: a nest over the *larger* of input/output with a
                // read-modify-write body — right loop structure and
                // memory-op density for pools/norms/softmax/data-movement
                // at this corpus level.
                let result = op.results[0];
                let (out_shape, _) = self.shape_of(result);
                let (in_shape, _) = self.shape_of(op.operands[0]);
                let nest = if in_shape.len() >= out_shape.len() {
                    in_shape.clone()
                } else {
                    out_shape.clone()
                };
                let out = self.memref(result);
                let ivs = self.open_nest(&nest)?;
                let x = self.load_indexed(op.operands[0], &ivs)?;
                let out_idx = self.index_for(out, &ivs);
                let acc = self.b.load(out, &out_idx)?;
                let v = self.b.arith(ArithOp::AddF, &[x, acc], Attrs::new())?;
                self.b.store(v, out, &out_idx)?;
                self.close_nest(ivs.len())?;
            }
        }
        Ok(())
    }
}

/// Lower an xpu-dialect function to its affine-dialect form.
pub fn lower_to_affine(f: &Function) -> Result<Function> {
    let mut lw = Lowerer {
        src: f,
        b: FuncBuilder::new(&format!("{}_affine", f.name)),
        buf: HashMap::new(),
    };
    // Function args become memref args.
    for id in f.arg_ids() {
        let t = f.value_type(id).as_tensor().expect("xpu args are tensors").clone();
        let m = lw.b.arg(Type::MemRef(t));
        lw.buf.insert(id, m);
    }
    let ops: Vec<Operation> = f.body.ops.clone();
    for op in &ops {
        lw.lower_op(op)?;
    }
    lw.b.ret(&[])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mlir::{parse_function, print_function, verify_function};

    #[test]
    fn matmul_lowers_to_triple_nest() {
        let mut b = FuncBuilder::new("mm");
        let x = b.arg(Type::tensor(vec![4, 8], DType::F32));
        let w = b.arg(Type::tensor(vec![8, 16], DType::F32));
        let m = b.xpu(XpuOp::MatMul, &[x, w], Attrs::new()).unwrap();
        let f = b.ret(&[m]).unwrap();
        let a = lower_to_affine(&f).unwrap();
        verify_function(&a).unwrap();
        assert_eq!(a.max_loop_depth(), 3);
        let text = print_function(&a);
        assert!(text.contains("affine.for"));
        assert!(text.contains("arith.mulf"));
    }

    #[test]
    fn affine_form_is_much_longer() {
        use crate::graphgen::{generate, Family, GraphSpec};
        let spec = GraphSpec { family: Family::Mlp, structure_seed: 3, shape_seed: 4 };
        let f = generate(&spec).unwrap();
        let a = lower_to_affine(&f).unwrap();
        verify_function(&a).unwrap();
        assert!(
            a.num_ops() > f.num_ops() * 3,
            "affine {} vs xpu {}",
            a.num_ops(),
            f.num_ops()
        );
    }

    #[test]
    fn affine_output_roundtrips_through_text() {
        let mut b = FuncBuilder::new("act");
        let x = b.arg(Type::tensor(vec![2, 8], DType::F32));
        let g = b.xpu(XpuOp::Gelu, &[x], Attrs::new()).unwrap();
        let f = b.ret(&[g]).unwrap();
        let a = lower_to_affine(&f).unwrap();
        let text = print_function(&a);
        let a2 = parse_function(&text).unwrap();
        assert_eq!(print_function(&a2), text);
        verify_function(&a2).unwrap();
    }

    #[test]
    fn all_generator_graphs_lower_to_affine() {
        use crate::graphgen::{corpus_specs, generate};
        for spec in corpus_specs(55, 15, 0) {
            let f = generate(&spec).unwrap();
            let a = lower_to_affine(&f).unwrap_or_else(|e| panic!("{spec:?}: {e}"));
            verify_function(&a).unwrap_or_else(|e| panic!("{spec:?}: {e}"));
        }
    }
}

//! Operator fusion — the first graph-level pass of the DL-compiler
//! (paper §1 motivates exactly this optimization as a cost-model client).
//!
//! Greedy producer-consumer fusion: an elementwise op is absorbed into the
//! group that produced its first operand when that value has no other
//! consumer. Contractions, softmax, norms, pools etc. start groups; fused
//! elementwise tails become the epilogue of the group's generated loops.

use crate::mlir::{Function, OpKind, Operation, ValueId, XpuOp};
use std::collections::HashMap;

/// A fusion group: the op that roots the loop nest plus an elementwise
/// tail applied in-register.
#[derive(Debug, Clone)]
pub struct Group {
    /// Index of the root op within the function body.
    pub root: usize,
    /// Indices of fused elementwise ops, in program order.
    pub fused: Vec<usize>,
}

impl Group {
    /// All op indices in this group, root first.
    pub fn ops(&self) -> impl Iterator<Item = usize> + '_ {
        std::iter::once(self.root).chain(self.fused.iter().copied())
    }
}

/// Number of uses of each value across the (flat, xpu-level) body,
/// including the return.
pub fn use_counts(f: &Function) -> HashMap<ValueId, usize> {
    let mut counts: HashMap<ValueId, usize> = HashMap::new();
    for op in &f.body.ops {
        for &o in &op.operands {
            *counts.entry(o).or_default() += 1;
        }
    }
    counts
}

fn is_fusable_tail(op: &Operation) -> bool {
    match op.kind {
        OpKind::Xpu(x) => x.is_elementwise(),
        _ => false,
    }
}

/// True for ops that generate no machine code (views, weight consts).
pub fn is_noop(op: &Operation) -> bool {
    matches!(op.kind, OpKind::Xpu(XpuOp::Const) | OpKind::Xpu(XpuOp::Reshape) | OpKind::Return)
}

/// Partition the function body into fusion groups.
///
/// Assumes a pure dataflow function (no regions) — the generators only
/// produce those at the xpu level.
pub fn fuse(f: &Function) -> Vec<Group> {
    let uses = use_counts(f);
    let mut groups: Vec<Group> = Vec::new();
    // Map: value -> index into `groups` of the group producing it, if that
    // group is still "open" (its result is the group tail).
    let mut open: HashMap<ValueId, usize> = HashMap::new();

    for (i, op) in f.body.ops.iter().enumerate() {
        if is_noop(op) {
            continue;
        }
        let result = op.results.first().copied();
        if is_fusable_tail(op) {
            // Try to fuse into the producer of the first tensor operand
            // that comes from an open group and has a single use.
            let target = op.operands.iter().find_map(|o| {
                let gi = *open.get(o)?;
                (uses.get(o).copied().unwrap_or(0) == 1).then_some((*o, gi))
            });
            if let Some((val, gi)) = target {
                groups[gi].fused.push(i);
                open.remove(&val);
                if let Some(r) = result {
                    open.insert(r, gi);
                }
                continue;
            }
        }
        // Start a new group.
        let gi = groups.len();
        groups.push(Group { root: i, fused: Vec::new() });
        if let Some(r) = result {
            open.insert(r, gi);
        }
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mlir::{Attrs, DType, FuncBuilder, Type};

    fn t(shape: &[i64]) -> Type {
        Type::tensor(shape.to_vec(), DType::F32)
    }

    #[test]
    fn elementwise_tail_fuses_into_matmul() {
        let mut b = FuncBuilder::new("f");
        let x = b.arg(t(&[8, 8]));
        let w = b.arg(t(&[8, 8]));
        let m = b.xpu(XpuOp::MatMul, &[x, w], Attrs::new()).unwrap();
        let r = b.xpu(XpuOp::Relu, &[m], Attrs::new()).unwrap();
        let e = b.xpu(XpuOp::Exp, &[r], Attrs::new()).unwrap();
        let f = b.ret(&[e]).unwrap();
        let groups = fuse(&f);
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].fused.len(), 2);
    }

    #[test]
    fn multi_use_value_blocks_fusion() {
        let mut b = FuncBuilder::new("f");
        let x = b.arg(t(&[8, 8]));
        let w = b.arg(t(&[8, 8]));
        let m = b.xpu(XpuOp::MatMul, &[x, w], Attrs::new()).unwrap();
        // `m` used twice: relu cannot be folded into the matmul epilogue,
        // but add still chains onto relu through `r` (single use).
        let r = b.xpu(XpuOp::Relu, &[m], Attrs::new()).unwrap();
        let s = b.xpu(XpuOp::Add, &[m, r], Attrs::new()).unwrap();
        let f = b.ret(&[s]).unwrap();
        let groups = fuse(&f);
        assert_eq!(groups.len(), 2, "matmul separate, relu+add chained: {groups:?}");
        assert!(groups[0].fused.is_empty(), "matmul must not absorb relu");
        assert_eq!(groups[1].fused.len(), 1);
    }

    #[test]
    fn chain_of_elementwise_forms_one_group() {
        let mut b = FuncBuilder::new("f");
        let x = b.arg(t(&[128]));
        let a = b.xpu(XpuOp::Relu, &[x], Attrs::new()).unwrap();
        let c = b.xpu(XpuOp::Exp, &[a], Attrs::new()).unwrap();
        let d = b.xpu(XpuOp::Neg, &[c], Attrs::new()).unwrap();
        let f = b.ret(&[d]).unwrap();
        let groups = fuse(&f);
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].fused.len(), 2);
    }

    #[test]
    fn consts_and_reshapes_generate_no_groups() {
        let mut b = FuncBuilder::new("f");
        let x = b.arg(t(&[2, 3, 4]));
        let r = b
            .xpu(
                XpuOp::Reshape,
                &[x],
                Attrs::new().with("shape", crate::mlir::Attr::IntArray(vec![6, 4])),
            )
            .unwrap();
        let f = b.ret(&[r]).unwrap();
        assert!(fuse(&f).is_empty());
    }

    #[test]
    fn function_args_do_not_open_groups() {
        let mut b = FuncBuilder::new("f");
        let x = b.arg(t(&[64]));
        let y = b.arg(t(&[64]));
        let s = b.xpu(XpuOp::Add, &[x, y], Attrs::new()).unwrap();
        let f = b.ret(&[s]).unwrap();
        let groups = fuse(&f);
        assert_eq!(groups.len(), 1);
        assert!(groups[0].fused.is_empty());
    }
}

//! Register allocation analysis: live intervals + linear-scan-style
//! pressure measurement over the segment stream, and spill insertion when
//! demand exceeds the physical vector register file.
//!
//! The paper's first target variable — *registerpressure*, "the number of
//! registers that the snippet of code will consume" — is computed here as
//! the peak sum of live virtual-register widths.

use super::isa::{Instr, Program, VReg};
use std::collections::HashMap;

/// Result of the allocation analysis.
#[derive(Debug, Clone, PartialEq)]
pub struct RegReport {
    /// Peak live register demand (physical-register units).
    pub max_live: u32,
    /// Index of the segment where the peak occurs.
    pub peak_segment: usize,
    /// Registers spilled (demand beyond `capacity`), 0 if it fits.
    pub spilled: u32,
    /// Physical register file size used for the spill decision.
    pub capacity: u32,
}

/// Physical vector register file size of the modeled xPU.
pub const VREG_CAPACITY: u32 = 64;

#[derive(Debug, Clone, Copy)]
struct Interval {
    start: usize,
    end: usize,
    width: u32,
}

/// Compute live intervals over the flattened instruction stream.
/// Loop-carried registers are live across their whole segment.
fn intervals(prog: &Program) -> HashMap<u32, Interval> {
    let mut iv: HashMap<u32, Interval> = HashMap::new();
    let mut pos = 0usize;
    for seg in &prog.segments {
        let seg_start = pos;
        let seg_end = pos + seg.instrs.len().saturating_sub(1);
        for instr in &seg.instrs {
            let mut touch = |r: VReg| {
                iv.entry(r.id)
                    .and_modify(|i| {
                        i.start = i.start.min(pos);
                        i.end = i.end.max(pos);
                    })
                    .or_insert(Interval { start: pos, end: pos, width: r.width as u32 });
            };
            for u in instr.uses() {
                touch(u);
            }
            if let Some(d) = instr.def() {
                touch(d);
            }
            pos += 1;
        }
        for &r in &seg.loop_carried {
            iv.entry(r.id)
                .and_modify(|i| {
                    i.start = i.start.min(seg_start);
                    i.end = i.end.max(seg_end);
                })
                .or_insert(Interval { start: seg_start, end: seg_end, width: r.width as u32 });
        }
    }
    iv
}

/// Measure peak register pressure (and where it occurs).
pub fn analyze(prog: &Program) -> RegReport {
    let iv = intervals(prog);
    let total_len: usize = prog.segments.iter().map(|s| s.instrs.len()).sum();
    if total_len == 0 || iv.is_empty() {
        return RegReport { max_live: 0, peak_segment: 0, spilled: 0, capacity: VREG_CAPACITY };
    }
    // Sweep: delta array over positions.
    let mut delta = vec![0i64; total_len + 1];
    for i in iv.values() {
        delta[i.start] += i.width as i64;
        delta[i.end + 1] -= i.width as i64;
    }
    let mut live = 0i64;
    let mut max_live = 0i64;
    let mut peak_pos = 0usize;
    for (p, d) in delta.iter().enumerate().take(total_len) {
        live += d;
        if live > max_live {
            max_live = live;
            peak_pos = p;
        }
    }
    // Locate the peak's segment.
    let mut peak_segment = 0;
    let mut acc = 0usize;
    for (si, seg) in prog.segments.iter().enumerate() {
        if peak_pos < acc + seg.instrs.len() {
            peak_segment = si;
            break;
        }
        acc += seg.instrs.len();
    }
    let max_live = max_live as u32;
    let spilled = max_live.saturating_sub(VREG_CAPACITY);
    RegReport { max_live, peak_segment, spilled, capacity: VREG_CAPACITY }
}

/// Insert spill traffic into the peak segment when demand exceeds the
/// register file: each spilled register unit costs a store + reload per
/// trip of that segment.
pub fn apply_spills(prog: &mut Program, report: &RegReport) {
    if report.spilled == 0 || prog.segments.is_empty() {
        return;
    }
    let idx = report.peak_segment.min(prog.segments.len() - 1);
    let seg = &mut prog.segments[idx];
    for k in 0..report.spilled {
        // Spill slots reuse high vreg ids; width 1 each.
        let r = VReg { id: u32::MAX - k, width: 1 };
        seg.instrs.insert(0, Instr::SpillStore { src: r });
        seg.instrs.push(Instr::SpillLoad { dst: r });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lower::isa::{Mem, RegAlloc, Segment, VArith};

    fn load(ra: &mut RegAlloc) -> (VReg, Instr) {
        let r = ra.fresh(1);
        (r, Instr::VLoad { dst: r, mem: Mem::Scratch, strided: false })
    }

    #[test]
    fn pressure_of_simple_chain() {
        // load a; load b; c = a+b; store c  → peak 2 (a,b live at add; c
        // overlaps a,b at the add position → 3).
        let mut ra = RegAlloc::default();
        let mut seg = Segment::new("t", 1);
        let (a, la) = load(&mut ra);
        let (b, lb) = load(&mut ra);
        let c = ra.fresh(1);
        seg.instrs.push(la);
        seg.instrs.push(lb);
        seg.instrs.push(Instr::VOp { op: VArith::Add, dst: c, a, b: Some(b) });
        seg.instrs.push(Instr::VStore { src: c, mem: Mem::Scratch, strided: false });
        let mut p = Program::default();
        p.segments.push(seg);
        let rep = analyze(&p);
        assert_eq!(rep.max_live, 3);
        assert_eq!(rep.spilled, 0);
    }

    #[test]
    fn wide_registers_count_by_width() {
        let mut ra = RegAlloc::default();
        let mut seg = Segment::new("t", 4);
        let acc = ra.fresh(4);
        let a = ra.fresh(2);
        let b = ra.fresh(2);
        seg.instrs.push(Instr::VLoad { dst: a, mem: Mem::Scratch, strided: false });
        seg.instrs.push(Instr::VLoad { dst: b, mem: Mem::Scratch, strided: false });
        seg.instrs.push(Instr::Macc { acc, a, b });
        seg.loop_carried = vec![acc];
        let mut p = Program::default();
        p.segments.push(seg);
        let rep = analyze(&p);
        assert_eq!(rep.max_live, 8); // 4 + 2 + 2
    }

    #[test]
    fn loop_carried_extends_liveness() {
        let mut ra = RegAlloc::default();
        let acc = ra.fresh(1);
        let mut s1 = Segment::new("s1", 8);
        let (x, lx) = load(&mut ra);
        s1.instrs.push(lx);
        s1.instrs.push(Instr::VOp { op: VArith::Add, dst: acc, a: acc, b: Some(x) });
        s1.loop_carried = vec![acc];
        // A second segment that uses acc keeps it live there too.
        let mut s2 = Segment::new("s2", 1);
        let y = ra.fresh(1);
        s2.instrs.push(Instr::VOp { op: VArith::Mul, dst: y, a: acc, b: Some(acc) });
        s2.instrs.push(Instr::VStore { src: y, mem: Mem::Scratch, strided: false });
        let mut p = Program::default();
        p.segments.push(s1);
        p.segments.push(s2);
        let rep = analyze(&p);
        assert!(rep.max_live >= 2);
    }

    #[test]
    fn spills_inserted_when_over_capacity() {
        let mut ra = RegAlloc::default();
        let mut seg = Segment::new("big", 2);
        // 70 simultaneously-live regs.
        let regs: Vec<VReg> = (0..70).map(|_| ra.fresh(1)).collect();
        for &r in &regs {
            seg.instrs.push(Instr::VLoad { dst: r, mem: Mem::Scratch, strided: false });
        }
        // One op using the first and last keeps everything live in between.
        let d = ra.fresh(1);
        seg.instrs.push(Instr::VOp { op: VArith::Add, dst: d, a: regs[0], b: Some(regs[69]) });
        seg.loop_carried = regs.clone();
        let mut p = Program::default();
        p.segments.push(seg);
        let rep = analyze(&p);
        assert!(rep.max_live >= 70);
        assert_eq!(rep.spilled, rep.max_live - VREG_CAPACITY);
        let before = p.static_instrs();
        apply_spills(&mut p, &rep);
        assert_eq!(p.static_instrs(), before + 2 * rep.spilled as usize);
    }

    #[test]
    fn empty_program() {
        let rep = analyze(&Program::default());
        assert_eq!(rep.max_live, 0);
    }
}

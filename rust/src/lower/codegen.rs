//! Code generation: fusion groups → `xpu-isa` [`Program`]s.
//!
//! Each group lowers to one or more [`Segment`]s — the steady-state window
//! of its innermost tiled loop plus trip counts. Contractions map to the
//! MXU (im2col for convs), elementwise chains to the vector ALU with the
//! fused tail applied in-register, transcendentals to the SFU, reductions
//! to unrolled accumulation loops, and data movement to load/store streams.

use super::fusion::{self, Group};
use super::isa::{Instr, Mem, Program, RegAlloc, Segment, SfuOp, VArith, VReg};
use crate::mlir::{DType, Function, OpKind, Operation, ValueId, XpuOp};
use anyhow::{bail, Result};
use std::collections::HashMap;

/// Codegen knobs. The compiler-integration examples drive these (they are
/// exactly the decisions the paper wants a cost model to guide).
#[derive(Debug, Clone)]
pub struct CodegenOpts {
    /// Enable producer-consumer fusion.
    pub fuse: bool,
    /// Force a fixed elementwise unroll factor (None = heuristic).
    pub unroll: Option<u32>,
    /// MXU systolic tile edge.
    pub mxu_tile: i64,
    /// Vector lanes for f32 (bf16 gets 2x).
    pub lanes_f32: i64,
    /// Scratchpad capacity; larger intermediates stream via HBM.
    pub scratch_bytes: u64,
}

impl Default for CodegenOpts {
    fn default() -> Self {
        CodegenOpts {
            fuse: true,
            unroll: None,
            mxu_tile: 32,
            lanes_f32: 16,
            scratch_bytes: 8 << 20,
        }
    }
}

fn div_ceil(a: i64, b: i64) -> i64 {
    (a + b - 1) / b
}

/// Per-function lowering context.
struct Ctx<'a> {
    f: &'a Function,
    opts: &'a CodegenOpts,
    ra: RegAlloc,
    prog: Program,
}

impl<'a> Ctx<'a> {
    fn lanes(&self, dtype: DType) -> i64 {
        match dtype.size_bytes() {
            4 => self.opts.lanes_f32,
            2 => self.opts.lanes_f32 * 2,
            _ => self.opts.lanes_f32 * 4,
        }
    }

    fn numel(&self, v: ValueId) -> i64 {
        self.f.value_type(v).as_tensor().map(|t| t.num_elements()).unwrap_or(1)
    }

    fn dtype(&self, v: ValueId) -> DType {
        self.f.value_type(v).dtype().unwrap_or(DType::F32)
    }

    fn bytes(&self, v: ValueId) -> u64 {
        self.f.value_type(v).as_tensor().map(|t| t.size_bytes() as u64).unwrap_or(4)
    }

    fn op(&self, idx: usize) -> &'a Operation {
        &self.f.body.ops[idx]
    }

    fn xpu_kind(&self, idx: usize) -> XpuOp {
        match self.op(idx).kind {
            OpKind::Xpu(x) => x,
            _ => unreachable!("group contains non-xpu op"),
        }
    }

    fn unroll_for(&self, iters: i64) -> u32 {
        if let Some(u) = self.opts.unroll {
            return u.max(1);
        }
        if iters >= 256 {
            4
        } else if iters >= 16 {
            2
        } else {
            1
        }
    }
}

/// Which functional unit an elementwise xpu op maps to.
fn ew_lowering(op: XpuOp) -> (Option<VArith>, Option<SfuOp>) {
    match op {
        XpuOp::Add => (Some(VArith::Add), None),
        XpuOp::Sub => (Some(VArith::Sub), None),
        XpuOp::Mult => (Some(VArith::Mul), None),
        XpuOp::Maximum | XpuOp::Relu => (Some(VArith::Max), None),
        XpuOp::Minimum => (Some(VArith::Min), None),
        XpuOp::Neg => (Some(VArith::Sub), None),
        XpuOp::Div => (None, Some(SfuOp::Div)),
        XpuOp::Exp => (None, Some(SfuOp::Exp)),
        XpuOp::Tanh => (None, Some(SfuOp::Tanh)),
        XpuOp::Erf => (None, Some(SfuOp::Erf)),
        XpuOp::Sqrt => (None, Some(SfuOp::Sqrt)),
        XpuOp::Rsqrt => (None, Some(SfuOp::Rsqrt)),
        XpuOp::Sigmoid => (None, Some(SfuOp::Sigmoid)),
        XpuOp::Gelu => (None, Some(SfuOp::Gelu)),
        other => unreachable!("{other:?} is not elementwise"),
    }
}

/// Emit one elementwise instruction (VALU or SFU) into `seg`.
fn emit_ew(seg: &mut Segment, ra: &mut RegAlloc, op: XpuOp, width: u8, a: VReg, b: Option<VReg>) -> VReg {
    let dst = ra.fresh(width);
    match ew_lowering(op) {
        (Some(v), None) => seg.instrs.push(Instr::VOp { op: v, dst, a, b }),
        (None, Some(s)) => seg.instrs.push(Instr::Sfu { op: s, dst, a, b }),
        _ => unreachable!(),
    }
    dst
}

/// Append the group's fused elementwise tail to `seg`, starting from
/// `chain` (the in-register group result). Small (broadcast) operands are
/// hoisted into `hoisted` as loop-carried registers; full-size operands
/// are loaded in the body.
fn emit_fused_tail(
    ctx: &mut Ctx,
    seg: &mut Segment,
    group: &Group,
    mut chain: VReg,
    produced: &mut HashMap<ValueId, VReg>,
    hoisted: &mut Vec<VReg>,
) -> VReg {
    let width = chain.width;
    let root_result = ctx.op(group.root).results[0];
    let out_numel = ctx.numel(root_result);
    for &fi in &group.fused {
        let op = ctx.op(fi);
        let kind = ctx.xpu_kind(fi);
        let mut srcs: Vec<VReg> = Vec::new();
        for &operand in &op.operands {
            if let Some(&r) = produced.get(&operand) {
                srcs.push(r);
            } else if ctx.numel(operand) < out_numel {
                // Broadcast operand: load once, keep live across trips.
                let r = ctx.ra.fresh(1);
                hoisted.push(r);
                srcs.push(r);
            } else {
                let r = ctx.ra.fresh(width);
                seg.instrs.push(Instr::VLoad { dst: r, mem: Mem::Scratch, strided: false });
                srcs.push(r);
            }
        }
        let (a, b) = match srcs.len() {
            1 => (srcs[0], None),
            2 => (srcs[0], Some(srcs[1])),
            n => unreachable!("elementwise op with {n} operands"),
        };
        chain = emit_ew(seg, &mut ctx.ra, kind, width, a, b);
        produced.insert(op.results[0], chain);
    }
    chain
}

/// Prologue segment holding hoisted broadcast loads (runs once).
fn hoist_prologue(label: &str, hoisted: &[VReg]) -> Option<Segment> {
    if hoisted.is_empty() {
        return None;
    }
    let mut seg = Segment::new(format!("{label} hoist"), 1);
    for &r in hoisted {
        seg.instrs.push(Instr::VLoad { dst: r, mem: Mem::Scratch, strided: false });
    }
    Some(seg)
}

// ---------------------------------------------------------------------------
// Group emitters
// ---------------------------------------------------------------------------

fn lower_elementwise(ctx: &mut Ctx, group: &Group) -> Result<()> {
    let root = ctx.op(group.root);
    let result = root.results[0];
    let out_numel = ctx.numel(result);
    let lanes = ctx.lanes(ctx.dtype(result));
    let iters = div_ceil(out_numel, lanes);
    let unroll = ctx.unroll_for(iters);
    let kind = ctx.xpu_kind(group.root);

    let mut seg = Segment::new(format!("ew {}", ctx.f.value_name(result)), div_ceil(iters, unroll as i64) as u64);
    let mut hoisted: Vec<VReg> = Vec::new();
    // Software-pipelined schedule: all loads first (hide LSU latency),
    // then the compute chains, then the stores. This is what makes a
    // bigger unroll factor cost registers — the paper's §1 "do we run out
    // of registers when we unroll aggressively?" trade-off.
    let mut per_iter_srcs: Vec<Vec<VReg>> = Vec::new();
    for _ in 0..unroll {
        let mut srcs = Vec::new();
        for &operand in &root.operands {
            if ctx.numel(operand) < out_numel {
                let r = ctx.ra.fresh(1);
                hoisted.push(r);
                srcs.push(r);
            } else {
                let r = ctx.ra.fresh(1);
                seg.instrs.push(Instr::VLoad { dst: r, mem: Mem::Scratch, strided: false });
                srcs.push(r);
            }
        }
        per_iter_srcs.push(srcs);
    }
    let mut fins: Vec<VReg> = Vec::new();
    for srcs in per_iter_srcs {
        let mut produced: HashMap<ValueId, VReg> = HashMap::new();
        let (a, b) = match srcs.len() {
            1 => (srcs[0], None),
            _ => (srcs[0], Some(srcs[1])),
        };
        let chain = emit_ew(&mut seg, &mut ctx.ra, kind, 1, a, b);
        produced.insert(result, chain);
        fins.push(emit_fused_tail(ctx, &mut seg, group, chain, &mut produced, &mut hoisted));
    }
    for fin in fins {
        seg.instrs.push(Instr::VStore { src: fin, mem: Mem::Scratch, strided: false });
    }
    seg.loop_carried = hoisted.clone();
    if let Some(p) = hoist_prologue(&seg.label.clone(), &hoisted) {
        ctx.prog.segments.push(p);
    }
    ctx.prog.segments.push(seg);
    Ok(())
}

/// Contraction geometry after im2col-style flattening.
struct Gemm {
    m: i64,
    n: i64,
    k: i64,
    strided_a: bool,
}

fn gemm_geometry(ctx: &Ctx, idx: usize) -> Result<Gemm> {
    let op = ctx.op(idx);
    let out = op.results[0];
    match ctx.xpu_kind(idx) {
        XpuOp::MatMul => {
            let a = ctx.f.value_type(op.operands[0]).as_tensor().unwrap();
            let b = ctx.f.value_type(op.operands[1]).as_tensor().unwrap();
            let k = a.shape[a.rank() - 1];
            let n = b.shape[b.rank() - 1];
            let m = ctx.numel(out) / n;
            Ok(Gemm { m, n, k, strided_a: false })
        }
        XpuOp::Conv2d => {
            let x = ctx.f.value_type(op.operands[0]).as_tensor().unwrap();
            let w = ctx.f.value_type(op.operands[1]).as_tensor().unwrap();
            let out_t = ctx.f.value_type(out).as_tensor().unwrap();
            let m = w.shape[0]; // OC
            let n = out_t.shape[0] * out_t.shape[2] * out_t.shape[3]; // B*OH*OW
            let k = x.shape[1] * w.shape[2] * w.shape[3]; // IC*KH*KW
            Ok(Gemm { m, n, k, strided_a: true })
        }
        XpuOp::Conv1d => {
            let x = ctx.f.value_type(op.operands[0]).as_tensor().unwrap();
            let w = ctx.f.value_type(op.operands[1]).as_tensor().unwrap();
            let out_t = ctx.f.value_type(out).as_tensor().unwrap();
            let m = w.shape[0];
            let n = out_t.shape[0] * out_t.shape[2];
            let k = x.shape[1] * w.shape[2];
            Ok(Gemm { m, n, k, strided_a: true })
        }
        other => bail!("not a gemm-able op: {other:?}"),
    }
}

fn lower_contraction(ctx: &mut Ctx, group: &Group) -> Result<()> {
    let g = gemm_geometry(ctx, group.root)?;
    let t = ctx.opts.mxu_tile;
    let (mt, nt, kt) = (div_ceil(g.m, t), div_ceil(g.n, t), div_ceil(g.k, t));
    let name = ctx.f.value_name(ctx.op(group.root).results[0]).to_string();

    // Double-buffer the K loop when it is long enough to hide load latency.
    let db: i64 = if kt >= 4 { 2 } else { 1 };
    let acc = ctx.ra.fresh(4);
    let mut inner = Segment::new(format!("mxu {name} inner"), (mt * nt * div_ceil(kt, db)) as u64);
    for _ in 0..db {
        let a = ctx.ra.fresh(2);
        let b = ctx.ra.fresh(2);
        inner.instrs.push(Instr::VLoad { dst: a, mem: Mem::Scratch, strided: g.strided_a });
        inner.instrs.push(Instr::VLoad { dst: b, mem: Mem::Scratch, strided: false });
        inner.instrs.push(Instr::Macc { acc, a, b });
    }
    inner.loop_carried = vec![acc];
    ctx.prog.segments.push(inner);

    // Epilogue: fused tail on the accumulator tile, then store.
    let mut epi = Segment::new(format!("mxu {name} epilogue"), (mt * nt) as u64);
    let mut produced: HashMap<ValueId, VReg> = HashMap::new();
    produced.insert(ctx.op(group.root).results[0], acc);
    let mut hoisted = Vec::new();
    let fin = emit_fused_tail(ctx, &mut epi, group, acc, &mut produced, &mut hoisted);
    epi.instrs.push(Instr::VStore { src: fin, mem: Mem::Scratch, strided: false });
    epi.loop_carried = hoisted.clone();
    if let Some(p) = hoist_prologue(&epi.label.clone(), &hoisted) {
        ctx.prog.segments.push(p);
    }
    ctx.prog.segments.push(epi);
    Ok(())
}

/// Windowed accumulation (depthwise conv / pools): per output vector, load
/// each tap (strided), combine, apply tail, store.
fn lower_windowed(ctx: &mut Ctx, group: &Group) -> Result<()> {
    let root = ctx.op(group.root);
    let kind = ctx.xpu_kind(group.root);
    let result = root.results[0];
    let lanes = ctx.lanes(ctx.dtype(result));
    let out_iters = div_ceil(ctx.numel(result), lanes);
    let taps = match kind {
        XpuOp::DepthwiseConv2d => {
            let w = ctx.f.value_type(root.operands[1]).as_tensor().unwrap();
            w.shape[2] * w.shape[3]
        }
        XpuOp::MaxPool2d | XpuOp::AvgPool2d => {
            let k = root.attrs.get_int_array("kernel").unwrap_or(&[2, 2]);
            k[0] * k[1]
        }
        other => bail!("not a windowed op: {other:?}"),
    };
    let name = ctx.f.value_name(result).to_string();
    let mut seg = Segment::new(format!("win {name}"), out_iters as u64);
    let mut hoisted = Vec::new();
    let mut acc: Option<VReg> = None;
    for tap in 0..taps {
        let x = ctx.ra.fresh(1);
        seg.instrs.push(Instr::VLoad { dst: x, mem: Mem::Scratch, strided: true });
        let v = if kind == XpuOp::DepthwiseConv2d {
            // Per-tap weight is loop-carried.
            let w = ctx.ra.fresh(1);
            hoisted.push(w);
            let m = ctx.ra.fresh(1);
            seg.instrs.push(Instr::VOp { op: VArith::Mul, dst: m, a: x, b: Some(w) });
            m
        } else {
            x
        };
        acc = Some(match acc {
            None => v,
            Some(prev) => {
                let dst = ctx.ra.fresh(1);
                let op = if kind == XpuOp::MaxPool2d { VArith::Max } else { VArith::Add };
                seg.instrs.push(Instr::VOp { op, dst, a: prev, b: Some(v) });
                dst
            }
        });
        let _ = tap;
    }
    let mut chain = acc.expect("taps >= 1");
    if kind == XpuOp::AvgPool2d {
        let inv = ctx.ra.fresh(1);
        hoisted.push(inv);
        chain = {
            let dst = ctx.ra.fresh(1);
            seg.instrs.push(Instr::VOp { op: VArith::Mul, dst, a: chain, b: Some(inv) });
            dst
        };
    }
    let mut produced = HashMap::new();
    produced.insert(result, chain);
    let fin = emit_fused_tail(ctx, &mut seg, group, chain, &mut produced, &mut hoisted);
    seg.instrs.push(Instr::VStore { src: fin, mem: Mem::Scratch, strided: false });
    seg.loop_carried = hoisted.clone();
    if let Some(p) = hoist_prologue(&seg.label.clone(), &hoisted) {
        ctx.prog.segments.push(p);
    }
    ctx.prog.segments.push(seg);
    Ok(())
}

/// Long reduction (reduce_*, global_avgpool, layernorm stats): 8-way
/// unrolled accumulate, then a short finalize segment with the tail.
fn lower_reduction(ctx: &mut Ctx, group: &Group) -> Result<()> {
    let root = ctx.op(group.root);
    let kind = ctx.xpu_kind(group.root);
    let result = root.results[0];
    let input = root.operands[0];
    let lanes = ctx.lanes(ctx.dtype(input));
    let in_numel = ctx.numel(input);
    let out_numel = ctx.numel(result);
    let reduce_len = (in_numel / out_numel.max(1)).max(1);
    let out_vecs = div_ceil(out_numel, lanes).max(1);
    let name = ctx.f.value_name(result).to_string();

    let is_max = kind == XpuOp::ReduceMax;
    let needs_scale = matches!(kind, XpuOp::ReduceMean | XpuOp::GlobalAvgPool);

    // Accumulation loop: 8 taps per window.
    const UR: i64 = 8;
    let acc = ctx.ra.fresh(1);
    let mut seg = Segment::new(
        format!("red {name}"),
        (out_vecs * div_ceil(reduce_len, UR)) as u64,
    );
    for _ in 0..UR.min(reduce_len) {
        let x = ctx.ra.fresh(1);
        seg.instrs.push(Instr::VLoad { dst: x, mem: Mem::Scratch, strided: true });
        seg.instrs.push(Instr::VOp {
            op: if is_max { VArith::Max } else { VArith::Add },
            dst: acc,
            a: acc,
            b: Some(x),
        });
    }
    seg.loop_carried = vec![acc];
    ctx.prog.segments.push(seg);

    // Finalize: optional 1/len scale, fused tail, store.
    let mut fin_seg = Segment::new(format!("red {name} fin"), out_vecs as u64);
    let mut chain = acc;
    let mut hoisted = Vec::new();
    if needs_scale {
        let inv = ctx.ra.fresh(1);
        hoisted.push(inv);
        let dst = ctx.ra.fresh(1);
        fin_seg.instrs.push(Instr::VOp { op: VArith::Mul, dst, a: chain, b: Some(inv) });
        chain = dst;
    }
    let mut produced = HashMap::new();
    produced.insert(result, chain);
    let fin = emit_fused_tail(ctx, &mut fin_seg, group, chain, &mut produced, &mut hoisted);
    fin_seg.instrs.push(Instr::VStore { src: fin, mem: Mem::Scratch, strided: false });
    fin_seg.loop_carried = hoisted.clone();
    if let Some(p) = hoist_prologue(&fin_seg.label.clone(), &hoisted) {
        ctx.prog.segments.push(p);
    }
    ctx.prog.segments.push(fin_seg);
    Ok(())
}

/// Softmax: three passes over each row (max, exp+sum, normalize).
fn lower_softmax(ctx: &mut Ctx, group: &Group) -> Result<()> {
    let root = ctx.op(group.root);
    let result = root.results[0];
    let x = root.operands[0];
    let t = ctx.f.value_type(x).as_tensor().unwrap();
    let axis = root.attrs.get_int("axis").unwrap_or(t.rank() as i64 - 1) as usize;
    let axis_len = t.shape[axis];
    let rows = (t.num_elements() / axis_len.max(1)).max(1);
    let lanes = ctx.lanes(t.dtype);
    let row_vecs = div_ceil(axis_len, lanes).max(1);
    let name = ctx.f.value_name(result).to_string();

    // Pass 1: running max.
    let mx = ctx.ra.fresh(1);
    let mut p1 = Segment::new(format!("softmax {name} max"), (rows * row_vecs) as u64);
    let v = ctx.ra.fresh(1);
    p1.instrs.push(Instr::VLoad { dst: v, mem: Mem::Scratch, strided: false });
    p1.instrs.push(Instr::VOp { op: VArith::Max, dst: mx, a: mx, b: Some(v) });
    p1.loop_carried = vec![mx];
    ctx.prog.segments.push(p1);

    // Pass 2: exp(x - max), running sum, stash exp values.
    let sum = ctx.ra.fresh(1);
    let mut p2 = Segment::new(format!("softmax {name} expsum"), (rows * row_vecs) as u64);
    let xv = ctx.ra.fresh(1);
    p2.instrs.push(Instr::VLoad { dst: xv, mem: Mem::Scratch, strided: false });
    let sh = ctx.ra.fresh(1);
    p2.instrs.push(Instr::VOp { op: VArith::Sub, dst: sh, a: xv, b: Some(mx) });
    let ex = ctx.ra.fresh(1);
    p2.instrs.push(Instr::Sfu { op: SfuOp::Exp, dst: ex, a: sh, b: None });
    p2.instrs.push(Instr::VOp { op: VArith::Add, dst: sum, a: sum, b: Some(ex) });
    p2.instrs.push(Instr::VStore { src: ex, mem: Mem::Scratch, strided: false });
    p2.loop_carried = vec![mx, sum];
    ctx.prog.segments.push(p2);

    // Pass 3: divide by sum, fused tail, store.
    let mut p3 = Segment::new(format!("softmax {name} norm"), (rows * row_vecs) as u64);
    let ev = ctx.ra.fresh(1);
    p3.instrs.push(Instr::VLoad { dst: ev, mem: Mem::Scratch, strided: false });
    let dv = ctx.ra.fresh(1);
    p3.instrs.push(Instr::Sfu { op: SfuOp::Div, dst: dv, a: ev, b: Some(sum) });
    let mut produced = HashMap::new();
    produced.insert(result, dv);
    let mut hoisted = vec![sum];
    let fin = emit_fused_tail(ctx, &mut p3, group, dv, &mut produced, &mut hoisted);
    p3.instrs.push(Instr::VStore { src: fin, mem: Mem::Scratch, strided: false });
    p3.loop_carried = hoisted;
    ctx.prog.segments.push(p3);
    Ok(())
}

/// Batchnorm (inference): per-channel param prep + streaming normalize.
fn lower_batchnorm(ctx: &mut Ctx, group: &Group) -> Result<()> {
    let root = ctx.op(group.root);
    let result = root.results[0];
    let t = ctx.f.value_type(result).as_tensor().unwrap();
    let c = t.shape[1];
    let lanes = ctx.lanes(t.dtype);
    let name = ctx.f.value_name(result).to_string();

    // Param prep: scale' = scale / sqrt(var + eps); bias' = bias - mean*scale'.
    let mut prep = Segment::new(format!("bn {name} prep"), div_ceil(c, lanes) as u64);
    let regs: Vec<VReg> = (0..4).map(|_| ctx.ra.fresh(1)).collect();
    for &r in &regs {
        prep.instrs.push(Instr::VLoad { dst: r, mem: Mem::Scratch, strided: false });
    }
    let rs = ctx.ra.fresh(1);
    prep.instrs.push(Instr::Sfu { op: SfuOp::Rsqrt, dst: rs, a: regs[3], b: None });
    let sc = ctx.ra.fresh(1);
    prep.instrs.push(Instr::VOp { op: VArith::Mul, dst: sc, a: regs[0], b: Some(rs) });
    let mb = ctx.ra.fresh(1);
    prep.instrs.push(Instr::VOp { op: VArith::Mul, dst: mb, a: regs[2], b: Some(sc) });
    let bi = ctx.ra.fresh(1);
    prep.instrs.push(Instr::VOp { op: VArith::Sub, dst: bi, a: regs[1], b: Some(mb) });
    prep.instrs.push(Instr::VStore { src: sc, mem: Mem::Scratch, strided: false });
    prep.instrs.push(Instr::VStore { src: bi, mem: Mem::Scratch, strided: false });
    ctx.prog.segments.push(prep);

    // Streaming loop: y = x*scale' + bias' (+ fused tail).
    let iters = div_ceil(t.num_elements(), lanes);
    let unroll = ctx.unroll_for(iters);
    let mut main = Segment::new(format!("bn {name} main"), div_ceil(iters, unroll as i64) as u64);
    let mut hoisted = Vec::new();
    for _ in 0..unroll {
        let xv = ctx.ra.fresh(1);
        main.instrs.push(Instr::VLoad { dst: xv, mem: Mem::Scratch, strided: false });
        let scv = ctx.ra.fresh(1);
        main.instrs.push(Instr::VLoad { dst: scv, mem: Mem::Scratch, strided: true });
        let biv = ctx.ra.fresh(1);
        main.instrs.push(Instr::VLoad { dst: biv, mem: Mem::Scratch, strided: true });
        let m = ctx.ra.fresh(1);
        main.instrs.push(Instr::VOp { op: VArith::Mul, dst: m, a: xv, b: Some(scv) });
        let y = ctx.ra.fresh(1);
        main.instrs.push(Instr::VOp { op: VArith::Add, dst: y, a: m, b: Some(biv) });
        let mut produced = HashMap::new();
        produced.insert(result, y);
        let fin = emit_fused_tail(ctx, &mut main, group, y, &mut produced, &mut hoisted);
        main.instrs.push(Instr::VStore { src: fin, mem: Mem::Scratch, strided: false });
    }
    main.loop_carried = hoisted.clone();
    if let Some(p) = hoist_prologue(&main.label.clone(), &hoisted) {
        ctx.prog.segments.push(p);
    }
    ctx.prog.segments.push(main);
    Ok(())
}

/// Layernorm: mean pass, variance pass, rsqrt per row, normalize pass.
fn lower_layernorm(ctx: &mut Ctx, group: &Group) -> Result<()> {
    let root = ctx.op(group.root);
    let result = root.results[0];
    let t = ctx.f.value_type(result).as_tensor().unwrap();
    let d = *t.shape.last().unwrap();
    let rows = (t.num_elements() / d.max(1)).max(1);
    let lanes = ctx.lanes(t.dtype);
    let dv = div_ceil(d, lanes).max(1);
    let name = ctx.f.value_name(result).to_string();

    // Mean accumulate.
    let mean = ctx.ra.fresh(1);
    let mut p1 = Segment::new(format!("ln {name} mean"), (rows * dv) as u64);
    let xv = ctx.ra.fresh(1);
    p1.instrs.push(Instr::VLoad { dst: xv, mem: Mem::Scratch, strided: false });
    p1.instrs.push(Instr::VOp { op: VArith::Add, dst: mean, a: mean, b: Some(xv) });
    p1.loop_carried = vec![mean];
    ctx.prog.segments.push(p1);

    // Variance accumulate.
    let var = ctx.ra.fresh(1);
    let mut p2 = Segment::new(format!("ln {name} var"), (rows * dv) as u64);
    let x2 = ctx.ra.fresh(1);
    p2.instrs.push(Instr::VLoad { dst: x2, mem: Mem::Scratch, strided: false });
    let c = ctx.ra.fresh(1);
    p2.instrs.push(Instr::VOp { op: VArith::Sub, dst: c, a: x2, b: Some(mean) });
    let sq = ctx.ra.fresh(1);
    p2.instrs.push(Instr::VOp { op: VArith::Mul, dst: sq, a: c, b: Some(c) });
    p2.instrs.push(Instr::VOp { op: VArith::Add, dst: var, a: var, b: Some(sq) });
    p2.loop_carried = vec![mean, var];
    ctx.prog.segments.push(p2);

    // Per-row inverse stddev.
    let inv = ctx.ra.fresh(1);
    let mut p3 = Segment::new(format!("ln {name} rsqrt"), rows as u64);
    p3.instrs.push(Instr::Sfu { op: SfuOp::Rsqrt, dst: inv, a: var, b: None });
    p3.loop_carried = vec![var, inv];
    ctx.prog.segments.push(p3);

    // Normalize: (x - mean) * inv * gamma + beta (+ fused tail).
    let mut p4 = Segment::new(format!("ln {name} norm"), (rows * dv) as u64);
    let x3 = ctx.ra.fresh(1);
    p4.instrs.push(Instr::VLoad { dst: x3, mem: Mem::Scratch, strided: false });
    let cc = ctx.ra.fresh(1);
    p4.instrs.push(Instr::VOp { op: VArith::Sub, dst: cc, a: x3, b: Some(mean) });
    let nn = ctx.ra.fresh(1);
    p4.instrs.push(Instr::VOp { op: VArith::Mul, dst: nn, a: cc, b: Some(inv) });
    let ga = ctx.ra.fresh(1);
    p4.instrs.push(Instr::VLoad { dst: ga, mem: Mem::Scratch, strided: false });
    let sg = ctx.ra.fresh(1);
    p4.instrs.push(Instr::VOp { op: VArith::Mul, dst: sg, a: nn, b: Some(ga) });
    let be = ctx.ra.fresh(1);
    p4.instrs.push(Instr::VLoad { dst: be, mem: Mem::Scratch, strided: false });
    let y = ctx.ra.fresh(1);
    p4.instrs.push(Instr::VOp { op: VArith::Add, dst: y, a: sg, b: Some(be) });
    let mut produced = HashMap::new();
    produced.insert(result, y);
    let mut hoisted = vec![mean, inv];
    let fin = emit_fused_tail(ctx, &mut p4, group, y, &mut produced, &mut hoisted);
    p4.instrs.push(Instr::VStore { src: fin, mem: Mem::Scratch, strided: false });
    p4.loop_carried = hoisted;
    ctx.prog.segments.push(p4);
    Ok(())
}

/// Pure data movement: load/store streams (strided where layout changes).
fn lower_datamove(ctx: &mut Ctx, group: &Group, strided: bool) -> Result<()> {
    let root = ctx.op(group.root);
    let result = root.results[0];
    let lanes = ctx.lanes(ctx.dtype(result));
    let iters = div_ceil(ctx.numel(result), lanes);
    let unroll = ctx.unroll_for(iters);
    let name = ctx.f.value_name(result).to_string();
    let mut seg = Segment::new(format!("move {name}"), div_ceil(iters, unroll as i64) as u64);
    let mut hoisted = Vec::new();
    for _ in 0..unroll {
        let r = ctx.ra.fresh(1);
        seg.instrs.push(Instr::VLoad { dst: r, mem: Mem::Scratch, strided });
        let mut produced = HashMap::new();
        produced.insert(result, r);
        let fin = emit_fused_tail(ctx, &mut seg, group, r, &mut produced, &mut hoisted);
        seg.instrs.push(Instr::VStore { src: fin, mem: Mem::Scratch, strided: false });
    }
    seg.loop_carried = hoisted.clone();
    if let Some(p) = hoist_prologue(&seg.label.clone(), &hoisted) {
        ctx.prog.segments.push(p);
    }
    ctx.prog.segments.push(seg);
    Ok(())
}

fn lower_group(ctx: &mut Ctx, group: &Group) -> Result<()> {
    match ctx.xpu_kind(group.root) {
        XpuOp::MatMul | XpuOp::Conv2d | XpuOp::Conv1d => lower_contraction(ctx, group),
        XpuOp::DepthwiseConv2d | XpuOp::MaxPool2d | XpuOp::AvgPool2d => lower_windowed(ctx, group),
        XpuOp::ReduceSum | XpuOp::ReduceMax | XpuOp::ReduceMean | XpuOp::GlobalAvgPool => {
            lower_reduction(ctx, group)
        }
        XpuOp::Softmax => lower_softmax(ctx, group),
        XpuOp::BatchNorm => lower_batchnorm(ctx, group),
        XpuOp::LayerNorm => lower_layernorm(ctx, group),
        XpuOp::Transpose | XpuOp::Embedding => lower_datamove(ctx, group, true),
        XpuOp::Concat | XpuOp::Slice | XpuOp::Pad | XpuOp::Broadcast | XpuOp::Upsample => {
            lower_datamove(ctx, group, false)
        }
        op if op.is_elementwise() => lower_elementwise(ctx, group),
        other => bail!("no lowering for {other:?}"),
    }
}

/// Lower a (pure-dataflow) function to an `xpu-isa` program.
pub fn lower(f: &Function, opts: &CodegenOpts) -> Result<Program> {
    let groups = if opts.fuse {
        fusion::fuse(f)
    } else {
        f.body
            .ops
            .iter()
            .enumerate()
            .filter(|(_, op)| !fusion::is_noop(op))
            .map(|(i, _)| Group { root: i, fused: Vec::new() })
            .collect()
    };
    lower_with_groups(f, opts, &groups)
}

/// Lower with an explicitly chosen fusion-group partition instead of
/// the global `opts.fuse` switch. The autotuner uses this to score
/// per-group fusion decisions: a group it declines to fuse is passed
/// as singleton groups, everything else exactly as [`fuse`] produced
/// it. `opts.fuse` is ignored; every other knob applies unchanged.
pub fn lower_with_groups(f: &Function, opts: &CodegenOpts, groups: &[Group]) -> Result<Program> {
    let mut ctx = Ctx { f, opts, ra: RegAlloc::default(), prog: Program::default() };

    // DMA accounting: args + weight consts stream in, results stream out;
    // intermediates larger than scratch spill through HBM too.
    for id in f.arg_ids() {
        ctx.prog.dma_in_bytes += ctx.bytes(id);
    }
    for op in &f.body.ops {
        if matches!(op.kind, OpKind::Xpu(XpuOp::Const)) {
            ctx.prog.dma_in_bytes += ctx.bytes(op.results[0]);
        }
    }
    for &r in &f.ret {
        ctx.prog.dma_out_bytes += ctx.bytes(r);
    }
    for group in groups {
        let result = ctx.op(group.ops().last().unwrap_or(group.root)).results.first().copied();
        if let Some(r) = result {
            let b = ctx.bytes(r);
            if b > ctx.opts.scratch_bytes {
                ctx.prog.dma_in_bytes += b;
                ctx.prog.dma_out_bytes += b;
            }
        }
        lower_group(&mut ctx, group)?;
    }
    Ok(ctx.prog)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mlir::{Attrs, FuncBuilder, Type};

    fn t(shape: &[i64]) -> Type {
        Type::tensor(shape.to_vec(), DType::F32)
    }

    fn simple_matmul_relu() -> Function {
        let mut b = FuncBuilder::new("f");
        let x = b.arg(t(&[64, 64]));
        let w = b.arg(t(&[64, 64]));
        let m = b.xpu(XpuOp::MatMul, &[x, w], Attrs::new()).unwrap();
        let r = b.xpu(XpuOp::Relu, &[m], Attrs::new()).unwrap();
        b.ret(&[r]).unwrap()
    }

    #[test]
    fn matmul_lowering_has_macc_and_epilogue() {
        let f = simple_matmul_relu();
        let p = lower(&f, &CodegenOpts::default()).unwrap();
        assert!(p.segments.iter().any(|s| s.label.contains("inner")));
        assert!(p.segments.iter().any(|s| s.label.contains("epilogue")));
        let has_macc = p
            .segments
            .iter()
            .flat_map(|s| &s.instrs)
            .any(|i| matches!(i, Instr::Macc { .. }));
        assert!(has_macc);
        // Fused relu: a VMax in the epilogue, not a separate pass.
        let epi = p.segments.iter().find(|s| s.label.contains("epilogue")).unwrap();
        assert!(epi.instrs.iter().any(|i| matches!(i, Instr::VOp { op: VArith::Max, .. })));
    }

    #[test]
    fn trip_counts_scale_with_size() {
        let small = {
            let mut b = FuncBuilder::new("s");
            let x = b.arg(t(&[64, 64]));
            let w = b.arg(t(&[64, 64]));
            let m = b.xpu(XpuOp::MatMul, &[x, w], Attrs::new()).unwrap();
            b.ret(&[m]).unwrap()
        };
        let big = {
            let mut b = FuncBuilder::new("b");
            let x = b.arg(t(&[256, 256]));
            let w = b.arg(t(&[256, 256]));
            let m = b.xpu(XpuOp::MatMul, &[x, w], Attrs::new()).unwrap();
            b.ret(&[m]).unwrap()
        };
        let ps = lower(&small, &CodegenOpts::default()).unwrap();
        let pb = lower(&big, &CodegenOpts::default()).unwrap();
        assert!(pb.dyn_instrs() > ps.dyn_instrs() * 8, "{} vs {}", pb.dyn_instrs(), ps.dyn_instrs());
    }

    #[test]
    fn unfused_produces_more_segments() {
        let f = simple_matmul_relu();
        let fused = lower(&f, &CodegenOpts::default()).unwrap();
        let unfused = lower(&f, &CodegenOpts { fuse: false, ..Default::default() }).unwrap();
        assert!(unfused.segments.len() > fused.segments.len());
        // Unfused streams the intermediate through memory: more dynamic instrs.
        assert!(unfused.dyn_instrs() > fused.dyn_instrs());
    }

    #[test]
    fn unroll_override_grows_window() {
        let mut b = FuncBuilder::new("e");
        let x = b.arg(t(&[1024, 1024]));
        let y = b.xpu(XpuOp::Relu, &[x], Attrs::new()).unwrap();
        let f = b.ret(&[y]).unwrap();
        let u1 = lower(&f, &CodegenOpts { unroll: Some(1), ..Default::default() }).unwrap();
        let u8 = lower(&f, &CodegenOpts { unroll: Some(8), ..Default::default() }).unwrap();
        assert!(u8.static_instrs() > u1.static_instrs() * 4);
        assert!(u8.segments.last().unwrap().trips < u1.segments.last().unwrap().trips);
    }

    #[test]
    fn every_generator_graph_lowers() {
        use crate::graphgen::{corpus_specs, generate};
        for spec in corpus_specs(99, 40, 0) {
            let f = generate(&spec).unwrap();
            let p = lower(&f, &CodegenOpts::default())
                .unwrap_or_else(|e| panic!("{:?} failed: {e}", spec));
            assert!(p.dyn_instrs() > 0, "{spec:?} produced empty program");
        }
    }

    #[test]
    fn softmax_three_passes() {
        let mut b = FuncBuilder::new("sm");
        let x = b.arg(t(&[8, 128]));
        let s = b
            .xpu(XpuOp::Softmax, &[x], Attrs::new().with("axis", crate::mlir::Attr::Int(1)))
            .unwrap();
        let f = b.ret(&[s]).unwrap();
        let p = lower(&f, &CodegenOpts::default()).unwrap();
        assert_eq!(p.segments.len(), 3);
        assert!(p.segments.iter().any(|s| s.instrs.iter().any(|i| matches!(
            i,
            Instr::Sfu { op: SfuOp::Exp, .. }
        ))));
    }
}

//! The DL-compiler lowering pipeline: `xpu` dialect → fusion → tiled
//! loops → `xpu-isa`, plus register allocation analysis and a lowering
//! to the `affine` dialect for the paper's lower-level-IR experiments.
//!
//! This is the substrate that plays the role of Intel's in-house
//! DL-compiler: it turns every corpus graph into machine-level code whose
//! measured characteristics become the training labels.

pub mod affine;
pub mod codegen;
pub mod fusion;
pub mod isa;
pub mod regalloc;

pub use codegen::{lower, lower_with_groups, CodegenOpts};
pub use fusion::{fuse, Group};
pub use isa::{Instr, Mem, Program, Segment, SfuOp, VArith, VReg};
pub use regalloc::{analyze, apply_spills, RegReport, VREG_CAPACITY};

//! PJRT runtime layer: the only place the `xla` crate is touched.
//!
//! - [`tensor`] — host tensors ↔ literals
//! - [`artifacts`] — manifest of the AOT-compiled HLO files
//! - [`client`] — PJRT client, compile-once executable cache, execution
//!
//! Python authors the computations (L2/L1); after `make artifacts` this
//! module makes the Rust binary self-contained.

pub mod artifacts;
pub mod client;
pub mod tensor;

pub use artifacts::{Manifest, ModelManifest};
pub use client::{Executable, Runtime};
pub use tensor::Tensor;

//! Host tensors and conversion to/from PJRT literals.

use anyhow::{anyhow, bail, ensure, Result};

/// A host-side tensor (only the dtypes the models use).
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<i64>, data: Vec<f32> },
    I32 { shape: Vec<i64>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(shape: Vec<i64>, data: Vec<f32>) -> Result<Tensor> {
        ensure!(
            shape.iter().product::<i64>() as usize == data.len(),
            "shape {shape:?} does not match {} elements",
            data.len()
        );
        Ok(Tensor::F32 { shape, data })
    }

    pub fn i32(shape: Vec<i64>, data: Vec<i32>) -> Result<Tensor> {
        ensure!(
            shape.iter().product::<i64>() as usize == data.len(),
            "shape {shape:?} does not match {} elements",
            data.len()
        );
        Ok(Tensor::I32 { shape, data })
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn zeros_f32(shape: Vec<i64>) -> Tensor {
        let n = shape.iter().product::<i64>() as usize;
        Tensor::F32 { shape, data: vec![0.0; n] }
    }

    pub fn shape(&self) -> &[i64] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn num_elements(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// First element of an f32 tensor (loss scalars etc).
    pub fn first_f32(&self) -> Result<f32> {
        Ok(*self.as_f32()?.first().ok_or_else(|| anyhow!("empty tensor"))?)
    }

    /// Convert to a PJRT literal.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Tensor::F32 { shape, data } => {
                let lit = xla::Literal::vec1(data);
                if shape.is_empty() {
                    // vec1 of len-1 → reshape to scalar shape []
                    lit.reshape(&[])?
                } else {
                    lit.reshape(shape)?
                }
            }
            Tensor::I32 { shape, data } => {
                let lit = xla::Literal::vec1(data);
                if shape.is_empty() {
                    lit.reshape(&[])?
                } else {
                    lit.reshape(shape)?
                }
            }
        })
    }

    /// Convert back from a PJRT literal.
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<i64> = shape.dims().to_vec();
        match shape.element_type() {
            xla::ElementType::F32 => Ok(Tensor::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
            xla::ElementType::S32 => Ok(Tensor::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }

    /// Load a raw little-endian f32 blob (the `init_*/{name}.f32` files).
    pub fn from_f32_file(path: &std::path::Path, shape: Vec<i64>) -> Result<Tensor> {
        let bytes = std::fs::read(path)?;
        ensure!(bytes.len() % 4 == 0, "file {path:?} is not a multiple of 4 bytes");
        let n = bytes.len() / 4;
        ensure!(
            shape.iter().product::<i64>() as usize == n,
            "file {path:?} has {n} f32s, expected shape {shape:?}"
        );
        let mut data = Vec::with_capacity(n);
        for c in bytes.chunks_exact(4) {
            data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(Tensor::F32 { shape, data })
    }

    /// Write as a raw little-endian f32 blob (checkpoints).
    pub fn to_f32_file(&self, path: &std::path::Path) -> Result<()> {
        let data = self.as_f32()?;
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for v in data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(path, bytes)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_checks_shape() {
        assert!(Tensor::f32(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::i32(vec![4], vec![1, 2, 3, 4]).is_ok());
    }

    #[test]
    fn accessors() {
        let t = Tensor::f32(vec![2], vec![1.5, 2.5]).unwrap();
        assert_eq!(t.as_f32().unwrap(), &[1.5, 2.5]);
        assert!(t.as_i32().is_err());
        assert_eq!(t.first_f32().unwrap(), 1.5);
        assert_eq!(t.num_elements(), 2);
    }

    #[test]
    fn f32_file_roundtrip() {
        let dir = std::env::temp_dir().join("mlir_cost_tensor_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.f32");
        let t = Tensor::f32(vec![2, 2], vec![1.0, -2.0, 3.5, 0.25]).unwrap();
        t.to_f32_file(&path).unwrap();
        let t2 = Tensor::from_f32_file(&path, vec![2, 2]).unwrap();
        assert_eq!(t, t2);
        assert!(Tensor::from_f32_file(&path, vec![5]).is_err());
        std::fs::remove_file(path).ok();
    }
}

//! PJRT runtime: load HLO text, compile once, execute from the hot path.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1, CPU PJRT). One
//! [`Runtime`] per process; executables are compiled once and cached by
//! artifact path.

use super::tensor::Tensor;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

/// Process-wide PJRT client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

/// A compiled executable plus a little bookkeeping.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub path: String,
    pub compile_ms: f64,
    calls: Mutex<u64>,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file (cached by path).
    pub fn load(&self, path: &Path) -> Result<std::sync::Arc<Executable>> {
        let key = path.to_string_lossy().to_string();
        if let Some(e) = self.cache.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&key)
            .with_context(|| format!("parsing HLO text {key}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {key}"))?;
        let compiled = std::sync::Arc::new(Executable {
            exe,
            path: key.clone(),
            compile_ms: t0.elapsed().as_secs_f64() * 1e3,
            calls: Mutex::new(0),
        });
        self.cache.lock().unwrap().insert(key, compiled.clone());
        Ok(compiled)
    }

    /// Number of cached executables.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

impl Executable {
    /// Execute with host tensors; unpacks the 1-tuple-of-N convention
    /// produced by `return_tuple=True` lowering.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(Tensor::to_literal).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        *self.calls.lock().unwrap() += 1;
        let parts = result.to_tuple()?;
        parts.iter().map(Tensor::from_literal).collect()
    }

    /// How many times this executable has run.
    pub fn call_count(&self) -> u64 {
        *self.calls.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::Manifest;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("artifacts")
    }

    #[test]
    fn load_and_run_predict() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let m = manifest.model("fc_ops").unwrap();
        let exe = rt.load(&manifest.path_of(m.file("predict_b1").unwrap())).unwrap();
        let mut inputs = manifest.load_init_params("fc_ops").unwrap();
        let ids = Tensor::i32(vec![1, m.max_len as i64], vec![2i32; m.max_len]).unwrap();
        inputs.push(ids);
        let out = exe.run(&inputs).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape(), &[1]);
        assert!(out[0].first_f32().unwrap().is_finite());
        assert_eq!(exe.call_count(), 1);
        // Cache hit.
        let exe2 = rt.load(&manifest.path_of(m.file("predict_b1").unwrap())).unwrap();
        assert_eq!(rt.cached(), 1);
        assert_eq!(exe2.call_count(), 1);
    }

    #[test]
    fn pallas_and_ref_predicts_agree() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let m = manifest.model("conv_ops").unwrap();
        let mut inputs = manifest.load_init_params("conv_ops").unwrap();
        let ids: Vec<i32> = (0..m.max_len as i32).map(|i| 2 + (i * 7) % 50).collect();
        inputs.push(Tensor::i32(vec![1, m.max_len as i64], ids).unwrap());
        let a = rt
            .load(&manifest.path_of(m.file("predict_b1").unwrap()))
            .unwrap()
            .run(&inputs)
            .unwrap();
        let b = rt
            .load(&manifest.path_of(m.file("predict_b1_pallas").unwrap()))
            .unwrap()
            .run(&inputs)
            .unwrap();
        let (x, y) = (a[0].first_f32().unwrap(), b[0].first_f32().unwrap());
        assert!(
            (x - y).abs() < 1e-4 * (1.0 + x.abs()),
            "ref {x} vs pallas {y}"
        );
    }

    #[test]
    fn train_step_decreases_loss() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let m = manifest.model("fc_ops").unwrap();
        let exe = rt.load(&manifest.path_of(m.file("train_step").unwrap())).unwrap();
        let params = manifest.load_init_params("fc_ops").unwrap();
        let n = params.len();
        let zeros: Vec<Tensor> = params
            .iter()
            .map(|p| Tensor::zeros_f32(p.shape().to_vec()))
            .collect();
        let b = m.train_batch as i64;
        let ids = Tensor::i32(
            vec![b, m.max_len as i64],
            (0..b * m.max_len as i64).map(|i| 2 + (i % 40) as i32).collect(),
        )
        .unwrap();
        let targets = Tensor::f32(vec![b], (0..b).map(|i| (i as f32) / b as f32).collect()).unwrap();

        let mut state: Vec<Tensor> = params.into_iter().chain(zeros.clone()).chain(zeros).collect();
        state.push(Tensor::scalar_f32(0.0));
        state.push(ids.clone());
        state.push(targets.clone());
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for step in 0..10 {
            let out = exe.run(&state).unwrap();
            assert_eq!(out.len(), 3 * n + 2);
            let loss = out[3 * n + 1].first_f32().unwrap();
            if step == 0 {
                first = loss;
            }
            last = loss;
            // Thread updated state back in.
            for (i, t) in out.into_iter().take(3 * n + 1).enumerate() {
                state[i] = t;
            }
        }
        assert!(last < first, "loss did not decrease: {first} -> {last}");
    }
}

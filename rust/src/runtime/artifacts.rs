//! AOT artifact manifest: the contract between `python/compile/aot.py`
//! (build time) and the Rust runtime (request time).

use crate::json::parse;
use anyhow::{anyhow, ensure, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use super::tensor::Tensor;

/// One exported model variant.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    /// Canonical (sorted) parameter names — flattening order at the AOT
    /// boundary.
    pub param_order: Vec<String>,
    pub param_shapes: BTreeMap<String, Vec<i64>>,
    pub max_len: usize,
    pub vocab_size: usize,
    pub predict_batches: Vec<usize>,
    pub train_batch: usize,
    /// Logical file key → relative path (e.g. "predict_b32" → "....hlo.txt").
    pub files: BTreeMap<String, String>,
}

impl ModelManifest {
    /// Number of parameter tensors.
    pub fn n_params(&self) -> usize {
        self.param_order.len()
    }

    /// Total scalar parameter count.
    pub fn total_params(&self) -> usize {
        self.param_order
            .iter()
            .map(|k| self.param_shapes[k].iter().product::<i64>() as usize)
            .sum()
    }

    pub fn file(&self, key: &str) -> Result<&str> {
        self.files
            .get(key)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("model {}: no artifact file '{key}'", self.name))
    }

    /// Pick the predict artifact for a batch size (smallest batch >= n, or
    /// the largest available). Returns (file key, batch).
    pub fn predict_key_for(&self, n: usize, pallas: bool) -> (String, usize) {
        let mut batches = self.predict_batches.clone();
        batches.sort_unstable();
        let b = batches
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| batches.last().copied().unwrap_or(1));
        let suffix = if pallas { "_pallas" } else { "" };
        (format!("predict_b{b}{suffix}"), b)
    }

    /// Every compiled predict variant useful for flushes up to
    /// `max_batch`: the sorted ladder of batch sizes up to and including
    /// the one `predict_key_for(max_batch)` would pick. A
    /// batch-size-aware worker compiles all of them and runs each
    /// drained chunk on the smallest rung that covers it, so a 3-query
    /// flush pays for a b=8 executable instead of padding a b=32 one.
    /// Returns ascending `(file key, batch)` pairs; never empty.
    pub fn predict_ladder(&self, max_batch: usize, pallas: bool) -> Vec<(String, usize)> {
        let mut batches = self.predict_batches.clone();
        batches.sort_unstable();
        batches.dedup();
        let cover = batches
            .iter()
            .copied()
            .find(|&b| b >= max_batch)
            .unwrap_or_else(|| batches.last().copied().unwrap_or(1));
        let suffix = if pallas { "_pallas" } else { "" };
        let ladder: Vec<(String, usize)> = batches
            .into_iter()
            .filter(|&b| b <= cover)
            .map(|b| (format!("predict_b{b}{suffix}"), b))
            .collect();
        if ladder.is_empty() {
            // Manifest listed no predict batches: mirror predict_key_for's
            // b=1 fallback so callers always have one rung.
            return vec![(format!("predict_b1{suffix}"), 1)];
        }
        ladder
    }
}

/// The whole artifact directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub vocab_size: usize,
    pub models: BTreeMap<String, ModelManifest>,
}

impl Manifest {
    /// Load `artifacts/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path:?}"))?;
        let j = parse(&text)?;
        let vocab_size = j.req_f64("vocab_size")? as usize;
        let mut models = BTreeMap::new();
        for (name, m) in j.req("models")?.as_obj().ok_or_else(|| anyhow!("models not an object"))? {
            let param_order: Vec<String> = m
                .req_arr("param_order")?
                .iter()
                .map(|t| t.as_str().map(str::to_string).ok_or_else(|| anyhow!("bad param name")))
                .collect::<Result<_>>()?;
            let mut param_shapes = BTreeMap::new();
            let shapes =
                m.req("param_shapes")?.as_obj().ok_or_else(|| anyhow!("param_shapes"))?;
            for (k, v) in shapes {
                let dims: Vec<i64> = v
                    .as_arr()
                    .ok_or_else(|| anyhow!("shape of {k}"))?
                    .iter()
                    .map(|d| d.as_f64().map(|f| f as i64).ok_or_else(|| anyhow!("dim")))
                    .collect::<Result<_>>()?;
                param_shapes.insert(k.clone(), dims);
            }
            let mut files = BTreeMap::new();
            for (k, v) in m.req("files")?.as_obj().ok_or_else(|| anyhow!("files"))? {
                files.insert(k.clone(), v.as_str().unwrap_or_default().to_string());
            }
            let predict_batches = m
                .req_arr("predict_batches")?
                .iter()
                .filter_map(|b| b.as_u64().map(|x| x as usize))
                .collect();
            models.insert(
                name.clone(),
                ModelManifest {
                    name: name.clone(),
                    param_order,
                    param_shapes,
                    max_len: m.req_f64("max_len")? as usize,
                    vocab_size,
                    predict_batches,
                    train_batch: m.req_f64("train_batch")? as usize,
                    files,
                },
            );
        }
        ensure!(!models.is_empty(), "manifest has no models");
        Ok(Manifest { dir: dir.to_path_buf(), vocab_size, models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("unknown model '{name}' (have: {:?})", self.models.keys()))
    }

    /// Absolute path of an artifact file.
    pub fn path_of(&self, rel: &str) -> PathBuf {
        self.dir.join(rel)
    }

    /// Load the initial parameters of a model (ordered per param_order).
    pub fn load_init_params(&self, model: &str) -> Result<Vec<Tensor>> {
        let m = self.model(model)?;
        let init_dir = self.dir.join(m.file("init_dir")?);
        m.param_order
            .iter()
            .map(|k| {
                Tensor::from_f32_file(&init_dir.join(format!("{k}.f32")), m.param_shapes[k].clone())
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // CARGO_MANIFEST_DIR = rust/; artifacts sit next to it.
        Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.models.contains_key("conv_ops"), "{:?}", m.models.keys());
        let conv = m.model("conv_ops").unwrap();
        assert_eq!(conv.max_len, 128);
        assert!(conv.n_params() > 10);
        assert!(conv.total_params() > 100_000);
        assert!(conv.file("train_step").unwrap().ends_with(".hlo.txt"));
        // Param loading.
        let params = m.load_init_params("conv_ops").unwrap();
        assert_eq!(params.len(), conv.n_params());
        assert_eq!(params[0].shape(), &conv.param_shapes[&conv.param_order[0]][..]);
    }

    #[test]
    fn predict_key_selection() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let conv = m.model("conv_ops").unwrap();
        let (k1, b1) = conv.predict_key_for(1, false);
        assert_eq!((k1.as_str(), b1), ("predict_b1", 1));
        let (k2, b2) = conv.predict_key_for(7, true);
        assert_eq!((k2.as_str(), b2), ("predict_b32_pallas", 32));
        let (k3, b3) = conv.predict_key_for(999, false);
        assert_eq!((k3.as_str(), b3), ("predict_b32", 32));
    }

    #[test]
    fn predict_ladder_enumerates_all_covering_rungs() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let conv = m.model("conv_ops").unwrap();
        // Full ladder: every compiled size up to the covering one,
        // ascending, topped by what predict_key_for would have chosen.
        let ladder = conv.predict_ladder(32, false);
        let sizes: Vec<usize> = ladder.iter().map(|(_, b)| *b).collect();
        assert!(sizes.windows(2).all(|w| w[0] < w[1]), "ladder not ascending: {sizes:?}");
        assert_eq!(*sizes.last().unwrap(), conv.predict_key_for(32, false).1);
        assert!(sizes.contains(&1), "b=1 rung missing from {sizes:?}");
        for (key, b) in &ladder {
            assert_eq!(key, &format!("predict_b{b}"));
        }
        // A small max_batch trims the ladder to the covering rung.
        let small = conv.predict_ladder(1, false);
        assert_eq!(small.iter().map(|(_, b)| *b).collect::<Vec<_>>(), vec![1]);
        // Pallas variants keep the suffix on every rung.
        let pallas = conv.predict_ladder(32, true);
        assert!(pallas.iter().all(|(k, _)| k.ends_with("_pallas")));
        assert_eq!(pallas.len(), ladder.len());
    }

    #[test]
    fn missing_model_is_error() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.model("nope").is_err());
    }
}

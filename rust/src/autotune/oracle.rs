//! The measured-regret oracle: score what the model chose against the
//! simulator ground truth, and — on exhaustively-enumerable spaces —
//! against the true optimum.
//!
//! Regret = sim-measured objective of the model-chosen schedule divided
//! by the exhaustive oracle best (1.0 = the model found the optimum).
//! "Speedup found per second" compares the chosen schedule against the
//! compiler's default heuristic schedule and amortizes the win over the
//! wall-clock the search spent probing — the end-to-end number that
//! justifies the serving stack.

use super::search::{Objective, SearchOutcome};
use super::space::{self, Knobs, SearchSpace};
use crate::mlir::Function;
use crate::sim::{ground_truth_default, ground_truth_with_groups, Labels, XpuConfig};
use anyhow::{ensure, Result};

/// Sim-measured labels for one candidate text.
pub fn measure_labels(text: &str, cfg: &XpuConfig) -> Result<Labels> {
    let sched = space::decode(text)?;
    ground_truth_with_groups(&sched.func, &sched.opts, &sched.groups, cfg)
}

/// Sim-measured objective score for one candidate text.
pub fn measure(text: &str, objective: &Objective, cfg: &XpuConfig) -> Result<f64> {
    let labels = measure_labels(text, cfg)?;
    Ok(objective.score(|t| Some(t.of(&labels))))
}

/// Exhaustively sim-score the whole space: `(best knobs, best score,
/// space size)`. Ties keep the first candidate in enumeration order,
/// so the result is deterministic.
pub fn exhaustive(
    base: &Function,
    sp: &SearchSpace,
    objective: &Objective,
    cfg: &XpuConfig,
) -> Result<(Knobs, f64, usize)> {
    let cands = space::enumerate(base, sp)?;
    ensure!(!cands.is_empty(), "empty search space");
    let mut best: Option<(Knobs, f64)> = None;
    for c in &cands {
        let m = measure(&c.text, objective, cfg)?;
        if best.as_ref().map(|(_, b)| m < *b).unwrap_or(true) {
            best = Some((c.knobs.clone(), m));
        }
    }
    let (knobs, score) = best.unwrap();
    Ok((knobs, score, cands.len()))
}

/// Everything the oracle measured about one finished search.
#[derive(Debug, Clone)]
pub struct OracleReport {
    pub chosen_knobs: Knobs,
    /// Sim-measured objective of the model-chosen schedule.
    pub chosen_measured: f64,
    pub oracle_knobs: Knobs,
    /// Exhaustive oracle best over the whole space.
    pub oracle_measured: f64,
    /// `chosen_measured / oracle_measured`; 1.0 = the model found the
    /// true optimum, +inf = the model chose an infeasible schedule.
    pub regret: f64,
    pub space_size: usize,
    /// Primary-target cost of the default heuristic schedule (the base
    /// function, unannotated, default codegen).
    pub baseline_primary: f64,
    /// Primary-target cost of the chosen schedule.
    pub chosen_primary: f64,
    /// `baseline_primary / chosen_primary`.
    pub speedup: f64,
    pub search_seconds: f64,
    /// `(speedup - 1) / search_seconds` — speedup found per second.
    pub speedup_per_sec: f64,
}

/// Score a finished search against the exhaustive sim oracle.
pub fn regret(
    base: &Function,
    sp: &SearchSpace,
    objective: &Objective,
    outcome: &SearchOutcome,
    cfg: &XpuConfig,
) -> Result<OracleReport> {
    let chosen_labels = measure_labels(&outcome.best.candidate.text, cfg)?;
    let chosen_measured = objective.score(|t| Some(t.of(&chosen_labels)));
    let (oracle_knobs, oracle_measured, space_size) = exhaustive(base, sp, objective, cfg)?;
    let regret = if chosen_measured.is_finite() && oracle_measured > 0.0 {
        chosen_measured / oracle_measured
    } else {
        f64::INFINITY
    };
    let baseline = ground_truth_default(base)?;
    let baseline_primary = objective.minimize.of(&baseline);
    let chosen_primary = objective.minimize.of(&chosen_labels);
    let speedup =
        if chosen_primary > 0.0 { baseline_primary / chosen_primary } else { f64::INFINITY };
    let search_seconds = (outcome.elapsed_ns as f64 / 1e9).max(1e-9);
    Ok(OracleReport {
        chosen_knobs: outcome.best.candidate.knobs.clone(),
        chosen_measured,
        oracle_knobs,
        oracle_measured,
        regret,
        space_size,
        baseline_primary,
        chosen_primary,
        speedup,
        search_seconds,
        speedup_per_sec: (speedup - 1.0) / search_seconds,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::search::{search, SearchConfig, SimProbe};
    use crate::mlir::{Attrs, DType, FuncBuilder, Type, XpuOp};
    use crate::sim::Target;

    fn base_fn() -> Function {
        let mut b = FuncBuilder::new("tune");
        let x = b.arg(Type::tensor(vec![64, 64], DType::F32));
        let w = b.arg(Type::tensor(vec![64, 64], DType::F32));
        let m = b.xpu(XpuOp::MatMul, &[x, w], Attrs::new()).unwrap();
        let r = b.xpu(XpuOp::Relu, &[m], Attrs::new()).unwrap();
        b.ret(&[r]).unwrap()
    }

    /// With the perfect (sim) probe and a space whose tile dimension is
    /// a single point, beam 2 visits every full configuration — regret
    /// is exactly 1.0 by construction.
    #[test]
    fn sim_probe_beam_search_finds_the_optimum() {
        let base = base_fn();
        let sp = SearchSpace { unrolls: vec![1, 2, 4], tiles: vec![32], fusion: true };
        let cfg = SearchConfig { beam: 2, objective: Objective::minimize(Target::Cycles) };
        let xcfg = XpuConfig::default();
        let outcome = search(&base, &sp, &cfg, &mut SimProbe::new()).unwrap();
        let report = regret(&base, &sp, &cfg.objective, &outcome, &xcfg).unwrap();
        assert_eq!(report.space_size, 6);
        assert!(
            (report.regret - 1.0).abs() < 1e-12,
            "perfect probe + exhaustive beam must have regret 1.0, got {}",
            report.regret
        );
        assert_eq!(report.chosen_measured, report.oracle_measured);
        assert!(report.speedup > 0.0 && report.speedup.is_finite());
    }
}

//! Cost-model-guided schedule search — the loop the paper exists to
//! close.
//!
//! The paper's model is built to *guide* fusion / tiling / unroll
//! decisions; this subsystem is the client that does the guiding:
//!
//! - [`space`] enumerates schedule candidates over a declared
//!   [`SearchSpace`] (elementwise unroll factors, MXU tile edges,
//!   per-group fusion on/off), each rendered back to MLIR text so
//!   candidates are ordinary servable queries;
//! - [`search`](mod@search) ranks them greedily or with beam search by
//!   probing a cost model — the sim itself ([`SimProbe`]), an
//!   in-process [`ServiceProbe`], or a remote [`ClientProbe`] — using
//!   batched cold probes (`mlir_batch`) or near-duplicate delta probes
//!   (`session_open` + `mlir_delta`);
//! - [`oracle`] sim-scores the winner and, on small spaces, the whole
//!   space, reporting **measured regret** (chosen cost ÷ true optimum)
//!   and speedup found per second of search.
//!
//! Driven by the `mlir-cost autotune` CLI subcommand and
//! `benches/e10_autotune.rs`.

pub mod oracle;
pub mod search;
pub mod space;

pub use oracle::{exhaustive, measure, measure_labels, regret, OracleReport};
pub use search::{
    search, ClientProbe, CostProbe, Objective, ProbeMode, Scored, SearchConfig, SearchOutcome,
    ServiceProbe, SimProbe,
};
pub use space::{
    annotate, decode, enumerate, fusable_count, render, Candidate, Knobs, Schedule, SearchSpace,
};

//! Schedule candidate enumeration: knob vectors rendered to MLIR text.
//!
//! A candidate is an ordinary servable query — a clone of the base
//! function annotated with `sched.*` attributes (`sched.unroll` /
//! `sched.tile` on the first code-generating op, `sched.fuse = false`
//! on each fusion-group root the schedule declines to fuse) and printed
//! back through [`crate::mlir::print_function`]. The attributes
//! round-trip through the parser, are ignored by shape inference, and
//! leave [`crate::lower::fuse`]'s partition unchanged, so the SAME text
//! drives both the served cost model and the sim oracle: [`decode`]
//! recovers the knob vector from the text and nothing else.

use crate::lower::fusion::is_noop;
use crate::lower::{fuse, CodegenOpts, Group};
use crate::mlir::{parse_function, print_function, Attr, Function};
use anyhow::{bail, Result};

/// Attribute carrying the elementwise-unroll factor (first non-noop op).
pub const UNROLL_ATTR: &str = "sched.unroll";
/// Attribute carrying the MXU tile edge (first non-noop op).
pub const TILE_ATTR: &str = "sched.tile";
/// `sched.fuse = false` on a group root splits that group; absent = fused.
pub const FUSE_ATTR: &str = "sched.fuse";

/// Declared search space: the knob values candidates are drawn from.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    /// Elementwise unroll factors ([`CodegenOpts::unroll`]).
    pub unrolls: Vec<u32>,
    /// MXU tile edges ([`CodegenOpts::mxu_tile`]).
    pub tiles: Vec<i64>,
    /// Explore per-group fusion on/off (one binary knob per group that
    /// actually fused a tail).
    pub fusion: bool,
}

impl Default for SearchSpace {
    fn default() -> Self {
        SearchSpace { unrolls: vec![1, 2, 4], tiles: vec![16, 32, 64], fusion: true }
    }
}

impl SearchSpace {
    /// Unroll options, never empty (empty list = the single default 1).
    pub fn unroll_options(&self) -> Vec<u32> {
        if self.unrolls.is_empty() { vec![1] } else { self.unrolls.clone() }
    }

    /// Tile options, never empty (empty list = the single default 32).
    pub fn tile_options(&self) -> Vec<i64> {
        if self.tiles.is_empty() { vec![32] } else { self.tiles.clone() }
    }

    /// Fusion decisions this space explores for `base`.
    pub fn fusion_bits(&self, base: &Function) -> usize {
        if self.fusion { fusable_count(base) } else { 0 }
    }

    /// Full cross-product size for `base` (saturating).
    pub fn size(&self, base: &Function) -> usize {
        let k = self.fusion_bits(base) as u32;
        let masks = if k >= usize::BITS { usize::MAX } else { 1usize << k };
        self.unroll_options().len().saturating_mul(self.tile_options().len()).saturating_mul(masks)
    }
}

/// One point in the space: the knob vector a candidate carries.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Knobs {
    pub unroll: u32,
    pub tile: i64,
    /// Per fusable group (groups of [`fuse`] that absorbed at least one
    /// tail op, in program order): `true` keeps the fusion, `false`
    /// splits the group into singleton ops.
    pub fuse_mask: Vec<bool>,
}

impl Knobs {
    /// The all-default point: first value of each dimension, everything
    /// fused.
    pub fn initial(space: &SearchSpace, base: &Function) -> Knobs {
        Knobs {
            unroll: space.unroll_options()[0],
            tile: space.tile_options()[0],
            fuse_mask: vec![true; space.fusion_bits(base)],
        }
    }

    /// Deterministic identity string — dedup key and tie-break ordering.
    pub fn key(&self) -> String {
        let mask: String = self.fuse_mask.iter().map(|&b| if b { '1' } else { '0' }).collect();
        format!("u{}.t{}.f{}", self.unroll, self.tile, mask)
    }
}

/// A servable schedule candidate: knob vector + rendered MLIR text.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub knobs: Knobs,
    pub text: String,
}

/// Number of per-group fusion decisions `base` exposes.
pub fn fusable_count(base: &Function) -> usize {
    fuse(base).iter().filter(|g| !g.fused.is_empty()).count()
}

/// Clone `base` with `knobs` written into `sched.*` attributes.
///
/// Only non-default decisions touch lines beyond the first op
/// (`sched.fuse = false`), so sibling candidates differ by a handful of
/// lines — exactly the shape the `mlir_delta` probe path is built for.
pub fn annotate(base: &Function, knobs: &Knobs) -> Function {
    let mut f = base.clone();
    if let Some(first) = f.body.ops.iter().position(|op| !is_noop(op)) {
        f.body.ops[first].attrs.set(UNROLL_ATTR, Attr::Int(knobs.unroll as i64));
        f.body.ops[first].attrs.set(TILE_ATTR, Attr::Int(knobs.tile));
    }
    let fusable: Vec<usize> =
        fuse(base).into_iter().filter(|g| !g.fused.is_empty()).map(|g| g.root).collect();
    for (j, &root) in fusable.iter().enumerate() {
        if !knobs.fuse_mask.get(j).copied().unwrap_or(true) {
            f.body.ops[root].attrs.set(FUSE_ATTR, Attr::Bool(false));
        }
    }
    f
}

/// Render one knob vector to a servable candidate.
pub fn render(base: &Function, knobs: &Knobs) -> Candidate {
    Candidate { text: print_function(&annotate(base, knobs)), knobs: knobs.clone() }
}

/// A decoded candidate: everything the oracle needs to score it exactly.
#[derive(Debug)]
pub struct Schedule {
    pub func: Function,
    /// Loop-level knobs as codegen options (`fuse` is ignored — the
    /// partition below is authoritative).
    pub opts: CodegenOpts,
    /// Fusion-group partition after applying the candidate's mask.
    pub groups: Vec<Group>,
    pub knobs: Knobs,
}

/// Recover the schedule from candidate text. Unannotated text decodes
/// to unroll 1 / tile 32 / everything fused.
pub fn decode(text: &str) -> Result<Schedule> {
    let func = parse_function(text)?;
    let mut unroll = 1u32;
    let mut tile = 32i64;
    for op in &func.body.ops {
        if let Some(u) = op.attrs.get_int(UNROLL_ATTR) {
            unroll = u.max(1) as u32;
        }
        if let Some(t) = op.attrs.get_int(TILE_ATTR) {
            tile = t.max(1);
        }
    }
    let mut groups = Vec::new();
    let mut fuse_mask = Vec::new();
    for g in fuse(&func) {
        if g.fused.is_empty() {
            groups.push(g);
            continue;
        }
        let keep =
            func.body.ops[g.root].attrs.get(FUSE_ATTR).and_then(Attr::as_bool).unwrap_or(true);
        fuse_mask.push(keep);
        if keep {
            groups.push(g);
        } else {
            let split: Vec<usize> = g.ops().collect();
            groups.extend(split.into_iter().map(|i| Group { root: i, fused: Vec::new() }));
        }
    }
    let knobs = Knobs { unroll, tile, fuse_mask };
    let opts =
        CodegenOpts { unroll: Some(knobs.unroll), mxu_tile: knobs.tile, ..Default::default() };
    Ok(Schedule { func, opts, groups, knobs })
}

/// Enumerate the FULL cross product, deterministically ordered
/// (fusion mask counting up from all-fused, then unrolls, then tiles,
/// each in declared order). Only for exhaustively-scoreable spaces —
/// bails past 20 fusion bits rather than materializing 2^k texts.
pub fn enumerate(base: &Function, space: &SearchSpace) -> Result<Vec<Candidate>> {
    let k = space.fusion_bits(base);
    if k > 20 {
        bail!("search space too large to enumerate: {k} fusion bits");
    }
    let unrolls = space.unroll_options();
    let tiles = space.tile_options();
    let mut out = Vec::with_capacity(space.size(base));
    for m in 0..(1usize << k) {
        // Bit j set = UNfuse fusable group j, so m = 0 is the all-fused
        // default and comes first.
        let fuse_mask: Vec<bool> = (0..k).map(|j| m >> j & 1 == 0).collect();
        for &unroll in &unrolls {
            for &tile in &tiles {
                out.push(render(base, &Knobs { unroll, tile, fuse_mask: fuse_mask.clone() }));
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mlir::{verify_function, Attrs, DType, FuncBuilder, Type, XpuOp};
    use crate::sim::{ground_truth, ground_truth_with_groups, XpuConfig};

    fn t(shape: &[i64]) -> Type {
        Type::tensor(shape.to_vec(), DType::F32)
    }

    /// matmul+relu (one fusable group) feeding an elementwise chain.
    fn base_fn() -> Function {
        let mut b = FuncBuilder::new("tune");
        let x = b.arg(t(&[64, 64]));
        let w = b.arg(t(&[64, 64]));
        let m = b.xpu(XpuOp::MatMul, &[x, w], Attrs::new()).unwrap();
        let r = b.xpu(XpuOp::Relu, &[m], Attrs::new()).unwrap();
        b.ret(&[r]).unwrap()
    }

    #[test]
    fn candidates_round_trip_and_verify() {
        let base = base_fn();
        assert_eq!(fusable_count(&base), 1);
        let knobs = Knobs { unroll: 4, tile: 64, fuse_mask: vec![false] };
        let cand = render(&base, &knobs);
        let sched = decode(&cand.text).unwrap();
        assert_eq!(sched.knobs, knobs, "knobs must survive print→parse");
        verify_function(&sched.func).unwrap();
        // Split group: matmul and relu each lower as their own group.
        assert_eq!(sched.groups.len(), 2);
        // Unannotated text decodes to the defaults.
        let plain = decode(&crate::mlir::print_function(&base)).unwrap();
        assert_eq!(plain.knobs, Knobs { unroll: 1, tile: 32, fuse_mask: vec![true] });
        assert_eq!(plain.groups.len(), 1);
    }

    #[test]
    fn enumerate_is_deterministic_and_complete() {
        let base = base_fn();
        let space = SearchSpace::default();
        let cands = enumerate(&base, &space).unwrap();
        assert_eq!(cands.len(), space.size(&base));
        assert_eq!(cands.len(), 3 * 3 * 2);
        let again = enumerate(&base, &space).unwrap();
        for (a, b) in cands.iter().zip(&again) {
            assert_eq!(a.text, b.text);
            assert_eq!(a.knobs, b.knobs);
        }
        // All keys distinct.
        let mut keys: Vec<String> = cands.iter().map(|c| c.knobs.key()).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), cands.len());
        // First candidate is the all-default point.
        assert_eq!(cands[0].knobs, Knobs::initial(&space, &base));
    }

    #[test]
    fn decoded_schedule_scores_like_direct_opts() {
        // The text is the only channel: sim-scoring a decoded candidate
        // must equal lowering the clean base with the same options.
        let base = base_fn();
        let cfg = XpuConfig::default();
        for (unroll, tile) in [(1u32, 16i64), (4, 64)] {
            let cand = render(&base, &Knobs { unroll, tile, fuse_mask: vec![true] });
            let sched = decode(&cand.text).unwrap();
            let via_text =
                ground_truth_with_groups(&sched.func, &sched.opts, &sched.groups, &cfg).unwrap();
            let direct = ground_truth(
                &base,
                &CodegenOpts { unroll: Some(unroll), mxu_tile: tile, ..Default::default() },
                &cfg,
            )
            .unwrap();
            assert_eq!(via_text, direct, "u{unroll} t{tile}");
        }
        // Full-split mask ≡ the global fuse:false switch for this graph
        // (every non-noop op its own group).
        let cand = render(&base, &Knobs { unroll: 2, tile: 32, fuse_mask: vec![false] });
        let sched = decode(&cand.text).unwrap();
        let via_text =
            ground_truth_with_groups(&sched.func, &sched.opts, &sched.groups, &cfg).unwrap();
        let direct = ground_truth(
            &base,
            &CodegenOpts { fuse: false, unroll: Some(2), ..Default::default() },
            &cfg,
        )
        .unwrap();
        assert_eq!(via_text, direct);
    }
}

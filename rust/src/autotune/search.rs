//! Greedy / beam-search schedule drivers ranking candidates with a
//! served cost model.
//!
//! The search is staged over knob dimensions — each per-group fusion
//! bit, then the unroll factor, then the MXU tile edge. Every stage
//! expands the surviving configurations along one dimension, probes the
//! rendered texts through a [`CostProbe`], and keeps the `beam` best by
//! the [`Objective`] with a deterministic tie-break on the knob key, so
//! a fixed seed + space chooses a byte-identical schedule on every run.
//! Greedy search is simply `beam = 1`.
//!
//! Probes are ordinary serving traffic: batched cold probes ride
//! `predict_many` / `mlir_batch`, near-duplicate probes ride
//! `session_open` + `mlir_delta` — nothing autotune-specific exists on
//! the wire.

use super::space::{self, Candidate, Knobs, SearchSpace};
use crate::coordinator::server::Client;
use crate::coordinator::session::Delta;
use crate::coordinator::Service;
use crate::mlir::Function;
use crate::sim::{ground_truth_with_groups, Target, XpuConfig};
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::BTreeSet;
use std::fmt;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

/// What the search minimizes: a primary characteristic, subject to
/// upper-bound caps on others (all served from one `PredVec` bundle).
///
/// Text form: `cycles;regpressure<=64` — first token names the target
/// to minimize, each further `;`-separated token is a `target<=cap`
/// constraint. A candidate violating any cap scores `+inf` (infeasible).
#[derive(Debug, Clone)]
pub struct Objective {
    pub minimize: Target,
    pub constraints: Vec<(Target, f64)>,
}

impl Objective {
    /// Minimize one characteristic, unconstrained.
    pub fn minimize(target: Target) -> Objective {
        Objective { minimize: target, constraints: Vec::new() }
    }

    /// Parse the `primary[;target<=cap]...` text form.
    pub fn parse(s: &str) -> Result<Objective> {
        let mut parts = s.split(';').map(str::trim).filter(|p| !p.is_empty());
        let first = parts.next().ok_or_else(|| anyhow!("empty objective"))?;
        let minimize = Target::parse(first)
            .ok_or_else(|| anyhow!("unknown objective target {first:?}"))?;
        let mut constraints = Vec::new();
        for p in parts {
            let (name, cap) = p
                .split_once("<=")
                .ok_or_else(|| anyhow!("constraint must be `target<=cap`, got {p:?}"))?;
            let t = Target::parse(name.trim())
                .ok_or_else(|| anyhow!("unknown constraint target {name:?}"))?;
            let cap: f64 =
                cap.trim().parse().map_err(|e| anyhow!("bad constraint cap {cap:?}: {e}"))?;
            constraints.push((t, cap));
        }
        Ok(Objective { minimize, constraints })
    }

    /// Every characteristic a probe must return, primary first.
    pub fn required(&self) -> Vec<Target> {
        let mut out = vec![self.minimize];
        for &(t, _) in &self.constraints {
            if !out.contains(&t) {
                out.push(t);
            }
        }
        out
    }

    /// Scalar score of one candidate from its predicted (or measured)
    /// characteristic values — lower is better, `+inf` = infeasible.
    pub fn score(&self, value_of: impl Fn(Target) -> Option<f64>) -> f64 {
        for &(t, cap) in &self.constraints {
            match value_of(t) {
                Some(v) if v <= cap => {}
                _ => return f64::INFINITY,
            }
        }
        value_of(self.minimize).unwrap_or(f64::INFINITY)
    }
}

impl fmt::Display for Objective {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.minimize.name())?;
        for (t, cap) in &self.constraints {
            write!(f, ";{}<={cap}", t.name())?;
        }
        Ok(())
    }
}

/// How a serving-backed probe issues its queries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProbeMode {
    /// Batched full-text probes (`predict_many` / `mlir_batch`).
    Cold,
    /// `session_open` on the first candidate, `mlir_delta` (full-text
    /// form, server-side line diff, no rebase) for every sibling.
    Delta,
}

impl ProbeMode {
    pub fn name(self) -> &'static str {
        match self {
            ProbeMode::Cold => "cold",
            ProbeMode::Delta => "delta",
        }
    }

    pub fn parse(s: &str) -> Option<ProbeMode> {
        match s {
            "cold" => Some(ProbeMode::Cold),
            "delta" => Some(ProbeMode::Delta),
            _ => None,
        }
    }
}

/// A cost model the search can rank candidates with.
pub trait CostProbe {
    /// One predicted value per requested target, per text, input order.
    fn probe(&mut self, texts: &[String], targets: &[Target]) -> Result<Vec<Vec<f64>>>;

    /// Probes that rode the session/delta path so far.
    fn delta_probes(&self) -> u64 {
        0
    }

    /// Per-search telemetry hook — serving-backed probes mirror these
    /// into the service's stats counters.
    fn record_search(&self, _candidates: u64, _elapsed_ns: u64) {}
}

/// The artifact-free perfect model: scores candidates with the sim
/// ground truth itself. Zero-regret reference for tests and the
/// offline CLI default.
#[derive(Debug, Default)]
pub struct SimProbe {
    pub cfg: XpuConfig,
}

impl SimProbe {
    pub fn new() -> SimProbe {
        SimProbe::default()
    }
}

impl CostProbe for SimProbe {
    fn probe(&mut self, texts: &[String], targets: &[Target]) -> Result<Vec<Vec<f64>>> {
        texts
            .iter()
            .map(|t| {
                let sched = space::decode(t)?;
                let labels =
                    ground_truth_with_groups(&sched.func, &sched.opts, &sched.groups, &self.cfg)?;
                Ok(targets.iter().map(|tg| tg.of(&labels)).collect())
            })
            .collect()
    }
}

/// In-process probe against a running [`Service`] — the same code path
/// wire queries take, minus the socket. Increments the service's
/// `search_*` counters.
pub struct ServiceProbe {
    svc: Arc<Service>,
    mode: ProbeMode,
    /// Delta mode: the session opened on the first text probed.
    session: Option<u64>,
    delta_probes: u64,
}

impl ServiceProbe {
    pub fn new(svc: Arc<Service>, mode: ProbeMode) -> ServiceProbe {
        ServiceProbe { svc, mode, session: None, delta_probes: 0 }
    }

    /// Close the delta session, if one was opened.
    pub fn finish(&mut self) {
        if let Some(id) = self.session.take() {
            self.svc.session_close(id);
        }
    }
}

impl CostProbe for ServiceProbe {
    fn probe(&mut self, texts: &[String], targets: &[Target]) -> Result<Vec<Vec<f64>>> {
        ensure!(!targets.is_empty(), "probe needs at least one target");
        let primary = targets[0];
        self.svc.stats.search_probes.fetch_add(texts.len() as u64, Ordering::Relaxed);
        let row = |p: &crate::coordinator::RoutedPrediction| -> Result<Vec<f64>> {
            targets
                .iter()
                .map(|&t| {
                    p.value_for(t)
                        .ok_or_else(|| anyhow!("variant does not serve target {}", t.name()))
                })
                .collect()
        };
        match self.mode {
            ProbeMode::Cold => {
                let refs: Vec<&str> = texts.iter().map(String::as_str).collect();
                self.svc
                    .predict_many_full(primary, &refs, None, targets)
                    .into_iter()
                    .map(|r| row(&r?))
                    .collect()
            }
            ProbeMode::Delta => texts
                .iter()
                .map(|text| {
                    if let Some(id) = self.session {
                        let out = self.svc.predict_delta(
                            id,
                            Delta::Full(text.clone()),
                            false,
                            None,
                            targets,
                        )?;
                        self.delta_probes += 1;
                        self.svc.stats.search_delta_probes.fetch_add(1, Ordering::Relaxed);
                        row(&out.prediction)
                    } else {
                        let opened = self.svc.session_open(primary, text, None, targets)?;
                        self.session = Some(opened.session_id);
                        row(&opened.prediction)
                    }
                })
                .collect(),
        }
    }

    fn delta_probes(&self) -> u64 {
        self.delta_probes
    }

    fn record_search(&self, candidates: u64, elapsed_ns: u64) {
        self.svc.stats.search_candidates.fetch_add(candidates, Ordering::Relaxed);
        self.svc.stats.search_ns.fetch_add(elapsed_ns, Ordering::Relaxed);
    }
}

impl Drop for ServiceProbe {
    fn drop(&mut self) {
        self.finish();
    }
}

/// Remote probe over the wire [`Client`] — what `mlir-cost autotune
/// --probe ADDR` uses. Cold probes ride `mlir_batch` (single-target
/// objectives) or per-text `predict_multi` (constrained objectives);
/// delta probes ride `session_open` + `mlir_delta`, whose wire response
/// carries only the primary prediction, so delta mode requires an
/// unconstrained objective.
pub struct ClientProbe {
    client: Client,
    mode: ProbeMode,
    session: Option<u64>,
    delta_probes: u64,
}

impl ClientProbe {
    pub fn connect(addr: &str, mode: ProbeMode) -> Result<ClientProbe> {
        Ok(ClientProbe { client: Client::connect(addr)?, mode, session: None, delta_probes: 0 })
    }

    pub fn finish(&mut self) {
        if let Some(id) = self.session.take() {
            let _ = self.client.session_close(id);
        }
    }
}

impl CostProbe for ClientProbe {
    fn probe(&mut self, texts: &[String], targets: &[Target]) -> Result<Vec<Vec<f64>>> {
        ensure!(!targets.is_empty(), "probe needs at least one target");
        let primary = targets[0];
        match self.mode {
            ProbeMode::Cold if targets.len() == 1 => {
                let refs: Vec<&str> = texts.iter().map(String::as_str).collect();
                self.client
                    .predict_many(primary, &refs)?
                    .into_iter()
                    .map(|r| r.map(|v| vec![v]))
                    .collect()
            }
            ProbeMode::Cold => texts
                .iter()
                .map(|text| {
                    let preds = self.client.predict_multi(primary, text, targets)?;
                    targets
                        .iter()
                        .map(|&t| {
                            preds.iter().find(|(pt, _)| *pt == t).map(|&(_, v)| v).ok_or_else(
                                || anyhow!("server did not answer target {}", t.name()),
                            )
                        })
                        .collect()
                })
                .collect(),
            ProbeMode::Delta => {
                ensure!(
                    targets.len() == 1,
                    "delta probes answer only the primary target on the wire — \
                     use cold probes for constrained objectives"
                );
                texts
                    .iter()
                    .map(|text| {
                        if let Some(id) = self.session {
                            let (v, _, _) = self.client.predict_delta_full(id, text, false)?;
                            self.delta_probes += 1;
                            Ok(vec![v])
                        } else {
                            let (id, v) = self.client.session_open(primary, text)?;
                            self.session = Some(id);
                            Ok(vec![v])
                        }
                    })
                    .collect()
            }
        }
    }

    fn delta_probes(&self) -> u64 {
        self.delta_probes
    }
}

impl Drop for ClientProbe {
    fn drop(&mut self) {
        self.finish();
    }
}

/// Search knobs: beam width (1 = greedy) and the objective.
#[derive(Debug, Clone)]
pub struct SearchConfig {
    pub beam: usize,
    pub objective: Objective,
}

/// One probed candidate with its objective score and the raw predicted
/// characteristic values behind it.
#[derive(Debug, Clone)]
pub struct Scored {
    pub candidate: Candidate,
    pub score: f64,
    pub values: Vec<(Target, f64)>,
}

/// What a search run found and what it cost.
#[derive(Debug)]
pub struct SearchOutcome {
    /// The model-chosen schedule.
    pub best: Scored,
    /// Every candidate probed, in search order.
    pub evaluated: Vec<Scored>,
    /// Distinct candidates rendered and probed.
    pub candidates: u64,
    /// Model probes issued (== candidates; cold and delta both count).
    pub probes: u64,
    /// Probes that rode the session/delta path.
    pub delta_probes: u64,
    pub elapsed_ns: u64,
}

fn score_batch(
    base: &Function,
    knobs: &[Knobs],
    targets: &[Target],
    objective: &Objective,
    probe: &mut dyn CostProbe,
    evaluated: &mut Vec<Scored>,
) -> Result<Vec<Scored>> {
    if knobs.is_empty() {
        return Ok(Vec::new());
    }
    let cands: Vec<Candidate> = knobs.iter().map(|k| space::render(base, k)).collect();
    let texts: Vec<String> = cands.iter().map(|c| c.text.clone()).collect();
    let rows = probe.probe(&texts, targets)?;
    ensure!(
        rows.len() == texts.len(),
        "probe returned {} rows for {} texts",
        rows.len(),
        texts.len()
    );
    let mut out = Vec::with_capacity(cands.len());
    for (cand, r) in cands.into_iter().zip(rows) {
        ensure!(
            r.len() == targets.len(),
            "probe row has {} values for {} targets",
            r.len(),
            targets.len()
        );
        let values: Vec<(Target, f64)> = targets.iter().copied().zip(r).collect();
        let score = objective.score(|t| values.iter().find(|(vt, _)| *vt == t).map(|&(_, v)| v));
        let s = Scored { candidate: cand, score, values };
        evaluated.push(s.clone());
        out.push(s);
    }
    Ok(out)
}

/// Keep the `beam` best of parents + freshly scored, ordered by
/// (score, knob key) — the key tie-break makes the survivor set, and
/// therefore the chosen schedule, deterministic.
fn select(mut pool: Vec<Scored>, beam: usize) -> Vec<Scored> {
    pool.sort_by(|a, b| {
        a.score
            .total_cmp(&b.score)
            .then_with(|| a.candidate.knobs.key().cmp(&b.candidate.knobs.key()))
    });
    pool.truncate(beam);
    pool
}

/// Run the staged beam search. `cfg.beam == 1` is greedy descent.
pub fn search(
    base: &Function,
    sp: &SearchSpace,
    cfg: &SearchConfig,
    probe: &mut dyn CostProbe,
) -> Result<SearchOutcome> {
    ensure!(cfg.beam >= 1, "beam width must be >= 1");
    let start = Instant::now();
    let targets = cfg.objective.required();
    let k = sp.fusion_bits(base);
    let unrolls = sp.unroll_options();
    let tiles = sp.tile_options();

    let mut evaluated: Vec<Scored> = Vec::new();
    let mut seen: BTreeSet<String> = BTreeSet::new();

    let init = Knobs::initial(sp, base);
    seen.insert(init.key());
    let mut beam = score_batch(base, &[init], &targets, &cfg.objective, probe, &mut evaluated)?;

    // Stage 1..k: each fusion decision in turn.
    for bit in 0..k {
        let mut fresh: Vec<Knobs> = Vec::new();
        for s in &beam {
            for keep in [true, false] {
                let mut kn = s.candidate.knobs.clone();
                kn.fuse_mask[bit] = keep;
                if seen.insert(kn.key()) {
                    fresh.push(kn);
                }
            }
        }
        let scored = score_batch(base, &fresh, &targets, &cfg.objective, probe, &mut evaluated)?;
        beam = select([beam, scored].concat(), cfg.beam);
    }

    // Unroll stage.
    let mut fresh: Vec<Knobs> = Vec::new();
    for s in &beam {
        for &u in &unrolls {
            let kn = Knobs { unroll: u, ..s.candidate.knobs.clone() };
            if seen.insert(kn.key()) {
                fresh.push(kn);
            }
        }
    }
    let scored = score_batch(base, &fresh, &targets, &cfg.objective, probe, &mut evaluated)?;
    beam = select([beam, scored].concat(), cfg.beam);

    // Tile stage.
    let mut fresh: Vec<Knobs> = Vec::new();
    for s in &beam {
        for &t in &tiles {
            let kn = Knobs { tile: t, ..s.candidate.knobs.clone() };
            if seen.insert(kn.key()) {
                fresh.push(kn);
            }
        }
    }
    let scored = score_batch(base, &fresh, &targets, &cfg.objective, probe, &mut evaluated)?;
    beam = select([beam, scored].concat(), cfg.beam);

    let best = beam.first().cloned().ok_or_else(|| anyhow!("empty beam — no candidates"))?;
    if best.score.is_infinite() {
        bail!("no feasible schedule in the space for objective `{}`", cfg.objective);
    }
    let candidates = evaluated.len() as u64;
    let elapsed_ns = start.elapsed().as_nanos() as u64;
    probe.record_search(candidates, elapsed_ns);
    Ok(SearchOutcome {
        best,
        candidates,
        probes: candidates,
        delta_probes: probe.delta_probes(),
        elapsed_ns,
        evaluated,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn objective_parse_and_score() {
        let o = Objective::parse("cycles;regpressure<=64").unwrap();
        assert_eq!(o.minimize, Target::Cycles);
        assert_eq!(o.constraints, vec![(Target::RegPressure, 64.0)]);
        assert_eq!(o.required(), vec![Target::Cycles, Target::RegPressure]);
        assert_eq!(o.to_string(), "cycles;regpressure<=64");
        let get = |cyc: f64, rp: f64| {
            move |t: Target| match t {
                Target::Cycles => Some(cyc),
                Target::RegPressure => Some(rp),
                _ => None,
            }
        };
        assert_eq!(o.score(get(1000.0, 32.0)), 1000.0);
        assert!(o.score(get(1000.0, 65.0)).is_infinite(), "violated cap = infeasible");
        assert!(Objective::parse("cycles").unwrap().constraints.is_empty());
        assert!(Objective::parse("bogus").is_err());
        assert!(Objective::parse("cycles;regpressure<64").is_err());
    }

    #[test]
    fn probe_mode_names_round_trip() {
        for m in [ProbeMode::Cold, ProbeMode::Delta] {
            assert_eq!(ProbeMode::parse(m.name()), Some(m));
        }
        assert_eq!(ProbeMode::parse("warm"), None);
    }
}

//! `mlir-cost` — leader binary: dataset generation, training, evaluation,
//! serving, and one-off prediction for the ML-driven MLIR hardware cost
//! model.
//!
//! Subcommands (run with no args for usage):
//!   gen-dataset  — build the labeled corpus (graphs → MLIR → ground truth)
//!   train        — train a model variant via the AOT train_step (PJRT)
//!   eval         — evaluate a trained bundle; writes metrics JSON
//!   serve        — start the cost-model TCP service from bundles
//!   predict      — one-shot prediction for an MLIR file
//!   ground-truth — compile+simulate an MLIR file (the label path)
//!   autotune     — cost-model-guided schedule search with measured regret
//!   metrics      — scrape a running server's counters as `name value` text
//!   info         — artifact manifest summary

use anyhow::{anyhow, bail, Context, Result};
use mlir_cost::bundle::Bundle;
use mlir_cost::coordinator::batcher::BatchPolicy;
use mlir_cost::coordinator::router::VariantSpec;
use mlir_cost::coordinator::{server, ServeOptions, Service};
use mlir_cost::dataset::{Dataset, EncodedSet, TargetStats};
use mlir_cost::json::Json;
use mlir_cost::pred::PredVec;
use mlir_cost::runtime::{Manifest, Runtime};
use mlir_cost::sim::{ground_truth_default, Target, XpuConfig};
use mlir_cost::tokenizer::{OpIdTable, Scheme, Vocab};
use mlir_cost::train::{metrics, TrainConfig, Trainer};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

/// Parse `--key value` style flags into a map; returns (cmd, flags).
fn parse_flags(args: &[String]) -> Result<(String, HashMap<String, String>)> {
    let cmd = args.first().cloned().unwrap_or_default();
    let mut flags = HashMap::new();
    let mut i = 1;
    while i < args.len() {
        let key = args[i]
            .strip_prefix("--")
            .ok_or_else(|| anyhow!("expected --flag, got '{}'", args[i]))?;
        let value = args.get(i + 1).cloned().ok_or_else(|| anyhow!("--{key} needs a value"))?;
        flags.insert(key.to_string(), value);
        i += 2;
    }
    Ok((cmd, flags))
}

fn flag<'a>(flags: &'a HashMap<String, String>, key: &str, default: &'a str) -> &'a str {
    flags.get(key).map(String::as_str).unwrap_or(default)
}

fn artifacts_dir(flags: &HashMap<String, String>) -> PathBuf {
    PathBuf::from(flag(flags, "artifacts", "artifacts"))
}

fn run(args: &[String]) -> Result<()> {
    let (cmd, flags) = parse_flags(args)?;
    match cmd.as_str() {
        "gen-dataset" => gen_dataset(&flags),
        "train" => train(&flags),
        "eval" => eval(&flags),
        "serve" => serve(&flags),
        "predict" => predict(&flags),
        "ground-truth" => ground_truth_cmd(&flags),
        "autotune" => autotune(&flags),
        "metrics" => metrics_cmd(&flags),
        "info" => info(&flags),
        _ => {
            eprintln!(
                "usage: mlir-cost <cmd> [--flag value]...\n\
                 cmds:\n  \
                 gen-dataset --count N --augment K --seed S --out-train f --out-test f [--test-frac 0.1]\n  \
                 train --model conv_ops --target regpressure --scheme ops_only --train f --test f \
                 --steps N --out bundle_dir [--targets cycles,xpuutil] [--hardware xpu-v1]\n    \
                 [--artifacts dir] [--out-metrics m.json]\n  \
                 eval --bundle dir --test f [--out metrics.json]\n  \
                 serve --bundles d1,d2,... --addr 127.0.0.1:7071 [--pallas true] [--io-threads 1]\n    \
                 [--variants variants.json] [--workers-per-head 1] [--max-batch 32] [--max-wait-us 2000]\n    \
                 [--request-workers 0] [--batch-policy static|adaptive] [--reuseport false]\n    \
                 [--quota 0] [--quota-burst 0] [--tenant-inflight 0] [--shed-deadlines false]\n    \
                 [--peers host:port,... --node-id host:port [--vnodes 64]]\n  \
                 metrics [--addr 127.0.0.1:7071]\n  \
                 predict --bundle dir --file graph.mlir\n  \
                 ground-truth --file graph.mlir\n  \
                 autotune --family mlp --seed 7 [--file graph.mlir] [--objective cycles]\n    \
                 [--beam 4] [--probe sim|serve|host:port] [--probe-mode cold|delta]\n    \
                 [--unrolls 1,2,4] [--tiles 16,32,64] [--fusion true] [--oracle auto|on|off]\n    \
                 (objective syntax: primary[;target<=cap]..., e.g. cycles;regpressure<=64)\n  \
                 info [--artifacts dir]"
            );
            bail!("unknown command '{cmd}'");
        }
    }
}

fn gen_dataset(flags: &HashMap<String, String>) -> Result<()> {
    let count: usize = flag(flags, "count", "2000").parse()?;
    let augment: usize = flag(flags, "augment", "1").parse()?;
    let seed: u64 = flag(flags, "seed", "42").parse()?;
    let test_frac: f64 = flag(flags, "test-frac", "0.1").parse()?;
    let out_train = PathBuf::from(flag(flags, "out-train", "runs/train.csv"));
    let out_test = PathBuf::from(flag(flags, "out-test", "runs/test.csv"));
    if let Some(p) = out_train.parent() {
        std::fs::create_dir_all(p)?;
    }
    let t0 = std::time::Instant::now();
    let ds = Dataset::generate(seed, count, augment)?;
    let n = ds.len();
    let (train, test) = ds.split(seed ^ 0xD5, test_frac);
    train.save_csv(&out_train)?;
    test.save_csv(&out_test)?;
    eprintln!(
        "generated {n} samples in {:.1}s -> {} train / {} test",
        t0.elapsed().as_secs_f64(),
        train.len(),
        test.len()
    );
    Ok(())
}

struct Encoded {
    train: EncodedSet,
    test: EncodedSet,
    vocab: Vocab,
    stats: Vec<TargetStats>,
    /// Ground truth per declared target, `test_truth[k][i]` = target k,
    /// sample i — all characteristics come from the one simulator run
    /// that labeled the dataset.
    test_truth: Vec<Vec<f64>>,
}

fn encode_sets(
    train_csv: &Path,
    test_csv: &Path,
    scheme: Scheme,
    targets: &[Target],
    max_len: usize,
) -> Result<Encoded> {
    let train = Dataset::load_csv(train_csv)?;
    let test = Dataset::load_csv(test_csv)?;
    let streams_tr = train.token_streams(scheme)?;
    let streams_te = test.token_streams(scheme)?;
    let vocab = Vocab::build(streams_tr.iter(), 2);
    let stats = TargetStats::for_targets(&train, targets);
    let enc_tr = EncodedSet::build_multi(&train, &streams_tr, &vocab, max_len, targets, &stats);
    let enc_te = EncodedSet::build_multi(&test, &streams_te, &vocab, max_len, targets, &stats);
    let test_truth: Vec<Vec<f64>> = targets
        .iter()
        .map(|&t| test.samples.iter().map(|s| t.of(&s.labels)).collect())
        .collect();
    Ok(Encoded { train: enc_tr, test: enc_te, vocab, stats, test_truth })
}

/// The declared characteristic list: `--targets a,b,...` when present,
/// else the single `--target` (default regpressure). The first entry is
/// the primary target — the one the scalar protocol surface answers.
fn parse_targets(flags: &HashMap<String, String>) -> Result<Vec<Target>> {
    if let Some(list) = flags.get("targets") {
        let targets: Vec<Target> = list
            .split(',')
            .map(|name| {
                Target::parse(name.trim())
                    .ok_or_else(|| anyhow!("bad --targets entry '{}'", name.trim()))
            })
            .collect::<Result<_>>()?;
        if targets.is_empty() {
            bail!("--targets needs at least one characteristic");
        }
        return Ok(targets);
    }
    Ok(vec![Target::parse(flag(flags, "target", "regpressure"))
        .ok_or_else(|| anyhow!("bad --target"))?])
}

fn train(flags: &HashMap<String, String>) -> Result<()> {
    let model = flag(flags, "model", "conv_ops").to_string();
    let targets = parse_targets(flags)?;
    let scheme =
        Scheme::parse(flag(flags, "scheme", "ops_only")).ok_or_else(|| anyhow!("bad --scheme"))?;
    let steps: usize = flag(flags, "steps", "300").parse()?;
    let out = PathBuf::from(flag(flags, "out", "runs/bundle"));
    let hardware = flags.get("hardware").cloned();
    let adir = artifacts_dir(flags);

    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(&adir)?;
    let mm = manifest.model(&model)?;
    let max_len = mm.max_len;
    let enc = encode_sets(
        Path::new(flag(flags, "train", "runs/train.csv")),
        Path::new(flag(flags, "test", "runs/test.csv")),
        scheme,
        &targets,
        max_len,
    )?;
    let target_names: Vec<&str> = targets.iter().map(|t| t.name()).collect();
    eprintln!(
        "training {model} on [{}] ({}; vocab {} tokens, {} train / {} test, {} / {} OOV)",
        target_names.join(", "),
        scheme.name(),
        enc.vocab.len(),
        enc.train.n,
        enc.test.n,
        enc.train.oov,
        enc.test.oov
    );
    let mut trainer = Trainer::new(&rt, &manifest, &model)?;
    let cfg = TrainConfig {
        model: model.clone(),
        steps,
        seed: flag(flags, "seed", "0").parse()?,
        eval_every: flag(flags, "eval-every", "200").parse()?,
        log_every: flag(flags, "log-every", "50").parse()?,
    };
    let report = trainer.run(&cfg, &enc.train, &enc.test)?;
    eprintln!("trained at {:.2} steps/s", report.steps_per_sec);

    let op_ids = OpIdTable::build(&enc.vocab);
    let bundle = Bundle {
        model: model.clone(),
        targets: targets.clone(),
        scheme,
        max_len,
        vocab: enc.vocab,
        stats: enc.stats,
        hardware,
        params: trainer.params().to_vec(),
        op_ids,
    };
    bundle.save(&out, &manifest)?;
    eprintln!("bundle saved to {out:?}");

    // Final metrics: every declared characteristic, from the ONE
    // prediction pass over the test set.
    let preds_norm = trainer.predict_set(&enc.test)?;
    let out_metrics = flags.get("out-metrics").map(PathBuf::from);
    print_metrics(
        &model,
        &targets,
        &bundle.stats,
        &preds_norm,
        &enc.test_truth,
        report.steps_per_sec,
        out_metrics.as_deref(),
    )?;
    Ok(())
}

/// Per-characteristic metrics from one prediction pass. The metrics
/// JSON keeps the legacy top-level keys (describing the PRIMARY target,
/// for dashboards that predate multi-output bundles) and adds a
/// `by_target` object with one block per declared characteristic.
#[allow(clippy::too_many_arguments)]
fn print_metrics(
    model: &str,
    targets: &[Target],
    stats: &[TargetStats],
    preds_norm: &[PredVec],
    truth: &[Vec<f64>],
    steps_per_sec: f64,
    out: Option<&Path>,
) -> Result<()> {
    let mut by_target = Json::obj();
    let mut primary: Option<Json> = None;
    for (k, (&target, st)) in targets.iter().zip(stats).enumerate() {
        let preds: Vec<f64> = preds_norm
            .iter()
            .map(|p| st.denormalize(p.get(k).unwrap_or_else(|| p.first())))
            .collect();
        let truth = &truth[k];
        let rmse = metrics::rmse(&preds, truth);
        let rmse_pct = metrics::rmse_pct(&preds, truth, st.range());
        let mae = metrics::mae(&preds, truth);
        let exact = metrics::pct_exact_rounded(&preds, truth);
        let hist = metrics::abs_error_histogram(&preds, truth, 8);
        println!(
            "model={model} target={} rmse={rmse:.3} rmse_pct={rmse_pct:.2}% mae={mae:.3} exact={exact:.1}%",
            target.name()
        );
        let block = Json::obj()
            .with("rmse", Json::num(rmse))
            .with("rmse_pct_of_range", Json::num(rmse_pct))
            .with("mae", Json::num(mae))
            .with("pct_exact", Json::num(exact))
            .with(
                "abs_error_histogram",
                Json::Arr(hist.iter().map(|&h| Json::num(h as f64)).collect()),
            )
            .with("target_range", Json::num(st.range()));
        if k == 0 {
            primary = Some(block.clone());
        }
        by_target = by_target.with(target.name(), block);
    }
    let mut doc = Json::obj()
        .with("model", Json::str(model))
        .with("target", Json::str(targets[0].name()))
        .with(
            "targets",
            Json::Arr(targets.iter().map(|t| Json::str(t.name())).collect()),
        )
        .with("steps_per_sec", Json::num(steps_per_sec))
        .with("n_test", Json::num(truth[0].len() as f64))
        .with("by_target", by_target);
    if let Some(Json::Obj(fields)) = primary {
        // Legacy flat keys mirror the primary target's block.
        for (key, value) in fields {
            doc = doc.with(&key, value);
        }
    }
    if let Some(path) = out {
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        std::fs::write(path, doc.to_string())?;
        eprintln!("metrics written to {path:?}");
    }
    Ok(())
}

fn eval(flags: &HashMap<String, String>) -> Result<()> {
    let adir = artifacts_dir(flags);
    let bundle_dir = PathBuf::from(flag(flags, "bundle", "runs/bundle"));
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(&adir)?;
    let bundle = Bundle::load(&bundle_dir, &manifest)?;
    let test = Dataset::load_csv(Path::new(flag(flags, "test", "runs/test.csv")))?;
    let streams = test.token_streams(bundle.scheme)?;
    let enc = EncodedSet::build_multi(
        &test,
        &streams,
        &bundle.vocab,
        bundle.max_len,
        &bundle.targets,
        &bundle.stats,
    );
    let truth: Vec<Vec<f64>> = bundle
        .targets
        .iter()
        .map(|&t| test.samples.iter().map(|s| t.of(&s.labels)).collect())
        .collect();

    let mut trainer = Trainer::new(&rt, &manifest, &bundle.model)?;
    trainer.set_params(bundle.params.clone())?;
    let preds_norm = trainer.predict_set(&enc)?;
    let out = flags.get("out").map(PathBuf::from);
    print_metrics(&bundle.model, &bundle.targets, &bundle.stats, &preds_norm, &truth, 0.0, out.as_deref())
}

fn serve(flags: &HashMap<String, String>) -> Result<()> {
    let adir = artifacts_dir(flags);
    let manifest = Arc::new(Manifest::load(&adir)?);
    let use_pallas = flag(flags, "pallas", "true") == "true";
    // Two ways to register serving variants, combinable:
    //   --bundles d1,d2      each bundle is the sole variant of its
    //                        target, named after its model (the
    //                        pre-router behavior; default runs/bundle
    //                        when --variants is absent)
    //   --variants file.json a variants manifest registering several
    //                        model variants per target; the router
    //                        picks one per query by token length and
    //                        optional per-request budget_us
    let mut specs: Vec<VariantSpec> = Vec::new();
    let variants_file = flags.get("variants");
    let bundle_dirs = flags
        .get("bundles")
        .cloned()
        .or_else(|| variants_file.is_none().then(|| "runs/bundle".to_string()));
    if let Some(dirs) = &bundle_dirs {
        for dir in dirs.split(',') {
            let bundle =
                Bundle::load(Path::new(dir), &manifest).with_context(|| dir.to_string())?;
            specs.push(VariantSpec { name: bundle.model.clone(), bundle });
        }
    }
    // Warm-start latencies and batch policies from the manifest,
    // applied after startup.
    let mut warm_ewma: Vec<(Target, String, f64)> = Vec::new();
    let mut warm_policy: Vec<(Target, String, Option<usize>, Option<u64>)> = Vec::new();
    if let Some(path) = variants_file {
        let doc = mlir_cost::json::parse(
            &std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?,
        )
        .with_context(|| format!("parsing {path}"))?;
        for entry in doc.req_arr("variants").with_context(|| format!("{path}: variants"))? {
            let dir = entry.req_str("bundle").with_context(|| format!("{path}: bundle"))?;
            let bundle =
                Bundle::load(Path::new(dir), &manifest).with_context(|| dir.to_string())?;
            let name = entry
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or(&bundle.model)
                .to_string();
            if let Some(us) = entry.get("ewma_us").and_then(Json::as_f64) {
                warm_ewma.push((bundle.primary_target(), name.clone(), us));
            }
            // Optional `policy` object: known-good batching knobs for
            // this variant, clamped to the startup bounds on apply.
            if let Some(p) = entry.get("policy") {
                let max_batch = p.get("max_batch").and_then(Json::as_f64).map(|v| v as usize);
                let max_wait_us = p.get("max_wait_us").and_then(Json::as_f64).map(|v| v as u64);
                warm_policy.push((bundle.primary_target(), name.clone(), max_batch, max_wait_us));
            }
            specs.push(VariantSpec { name, bundle });
        }
    }
    if specs.is_empty() {
        bail!("serve needs --bundles and/or --variants");
    }
    let policy = BatchPolicy {
        max_batch: flag(flags, "max-batch", "32").parse()?,
        max_wait: std::time::Duration::from_micros(flag(flags, "max-wait-us", "2000").parse()?),
    };
    let adaptive_batch = match flag(flags, "batch-policy", "static") {
        "static" => false,
        "adaptive" => true,
        other => bail!("--batch-policy must be 'static' or 'adaptive', got '{other}'"),
    };
    let opts = ServeOptions {
        use_pallas,
        workers_per_head: flag(flags, "workers-per-head", "1").parse()?,
        adaptive_batch,
    };
    let config = server::ServerConfig {
        io_threads: flag(flags, "io-threads", "1").parse()?,
        request_workers: flag(flags, "request-workers", "0").parse()?,
        reuseport: flag(flags, "reuseport", "false") == "true",
        // Admission control: all off by default (a 0 quota means the
        // line path is byte-identical to the pre-quota server).
        quota: flag(flags, "quota", "0").parse()?,
        quota_burst: flag(flags, "quota-burst", "0").parse()?,
        tenant_inflight: flag(flags, "tenant-inflight", "0").parse()?,
        shed_deadlines: flag(flags, "shed-deadlines", "false") == "true",
    };
    let addr = flag(flags, "addr", "127.0.0.1:7071");
    let mut service = Service::start_variants(manifest, specs, policy, opts)?;
    for (target, name, us) in warm_ewma {
        service.set_variant_ewma_us(target, &name, us)?;
    }
    for (target, name, max_batch, max_wait_us) in warm_policy {
        service.set_variant_policy(target, &name, max_batch, max_wait_us)?;
    }
    for target in service.targets() {
        eprintln!(
            "[serve] target {}: variants {:?}",
            target.name(),
            service.variant_names(target)?
        );
    }
    // Cluster tier: `--peers` lists every node's serving address (or
    // just the other nodes'), `--node-id` this node's own. All nodes
    // must agree on the membership set — the consistent-hash ring is
    // derived from it deterministically on each node.
    if let Some(peers) = flags.get("peers") {
        let node_id = flags.get("node-id").ok_or_else(|| {
            anyhow!("--peers requires --node-id (this node's address as peers see it)")
        })?;
        let mut cfg = mlir_cost::cluster::ClusterConfig::new(peers, node_id)?;
        if let Some(v) = flags.get("vnodes") {
            cfg.vnodes = v.parse()?;
        }
        let cluster = mlir_cost::cluster::Cluster::new(&cfg)?;
        eprintln!(
            "[serve] cluster tier: {} node(s), this node is {} ({} vnodes/node)",
            cluster.ring().len(),
            cfg.self_id,
            cfg.vnodes
        );
        service.set_cluster(Arc::new(cluster));
    } else if flags.contains_key("node-id") {
        bail!("--node-id without --peers (single-node serving needs neither)");
    }
    let service = Arc::new(service);
    // `Stop::trigger()` is the shutdown path; the CLI serves until killed.
    let stop = server::Stop::new();
    server::serve(service, addr, stop, config)
}

/// Scrape a running server's stats as flat `name value` text (the
/// `metrics` wire command) — pipeable straight into a fleet collector:
/// `mlir-cost metrics --addr host:7071`.
fn metrics_cmd(flags: &HashMap<String, String>) -> Result<()> {
    let addr = flag(flags, "addr", "127.0.0.1:7071");
    let mut client = server::Client::connect(addr)?;
    print!("{}", client.metrics()?);
    Ok(())
}

fn predict(flags: &HashMap<String, String>) -> Result<()> {
    let adir = artifacts_dir(flags);
    let manifest = Arc::new(Manifest::load(&adir)?);
    let bundle = Bundle::load(Path::new(flag(flags, "bundle", "runs/bundle")), &manifest)?;
    let target = bundle.primary_target();
    let service = Arc::new(Service::start(
        manifest,
        vec![bundle],
        BatchPolicy {
            max_batch: 1,
            max_wait: std::time::Duration::from_micros(flag(flags, "max-wait-us", "100").parse()?),
        },
        true,
    )?);
    let text = std::fs::read_to_string(flag(flags, "file", "graph.mlir"))?;
    // One forward pass answers every characteristic the bundle declares.
    let routed = service.predict_full(target, &text, None, &[])?;
    for (t, v) in routed.targets.iter().zip(routed.value.iter()) {
        println!("{} = {v:.3}", t.name());
    }
    Ok(())
}

fn ground_truth_cmd(flags: &HashMap<String, String>) -> Result<()> {
    let text = std::fs::read_to_string(flag(flags, "file", "graph.mlir"))?;
    let func = mlir_cost::mlir::parse_function(&text)?;
    mlir_cost::mlir::verify_function(&func)?;
    let labels = ground_truth_default(&func)?;
    println!(
        "regpressure={} xpuutil={:.2}% cycles={} spills={} dyn_instrs={}",
        labels.regpressure, labels.xpu_util, labels.cycles, labels.spills, labels.dyn_instrs
    );
    Ok(())
}

fn parse_u32_list(s: &str) -> Result<Vec<u32>> {
    s.split(',')
        .map(|v| v.trim().parse::<u32>().map_err(|e| anyhow!("bad list entry '{v}': {e}")))
        .collect()
}

fn parse_i64_list(s: &str) -> Result<Vec<i64>> {
    s.split(',')
        .map(|v| v.trim().parse::<i64>().map_err(|e| anyhow!("bad list entry '{v}': {e}")))
        .collect()
}

/// Cost-model-guided schedule search: enumerate `sched.*` candidates of
/// one graph, rank them with a cost model (the sim itself, an
/// in-process service, or a remote server), then sim-score the winner —
/// and, on small spaces, the whole space — to report measured regret.
fn autotune(flags: &HashMap<String, String>) -> Result<()> {
    use mlir_cost::autotune as at;
    // Graph under search: an MLIR file, or a generated corpus graph.
    let func = if let Some(path) = flags.get("file") {
        let text = std::fs::read_to_string(path).with_context(|| path.to_string())?;
        let f = mlir_cost::mlir::parse_function(&text)?;
        mlir_cost::mlir::verify_function(&f)?;
        f
    } else {
        let family = mlir_cost::graphgen::Family::parse(flag(flags, "family", "mlp"))
            .ok_or_else(|| anyhow!("bad --family"))?;
        let spec = mlir_cost::graphgen::GraphSpec {
            family,
            structure_seed: flag(flags, "seed", "7").parse()?,
            shape_seed: flag(flags, "shape-seed", "17").parse()?,
        };
        mlir_cost::graphgen::generate(&spec)?
    };
    let space = at::SearchSpace {
        unrolls: parse_u32_list(flag(flags, "unrolls", "1,2,4"))?,
        tiles: parse_i64_list(flag(flags, "tiles", "16,32,64"))?,
        fusion: flag(flags, "fusion", "true") == "true",
    };
    let objective = at::Objective::parse(flag(flags, "objective", "cycles"))?;
    let cfg =
        at::SearchConfig { beam: flag(flags, "beam", "4").parse()?, objective: objective.clone() };
    let mode = at::ProbeMode::parse(flag(flags, "probe-mode", "cold"))
        .ok_or_else(|| anyhow!("--probe-mode must be 'cold' or 'delta'"))?;

    let t0 = std::time::Instant::now();
    let outcome = match flag(flags, "probe", "sim") {
        "sim" => at::search(&func, &space, &cfg, &mut at::SimProbe::new())?,
        "serve" => {
            // In-process service from --bundle: the full serving path
            // (router, caches, batcher, session tier) minus the socket.
            let adir = artifacts_dir(flags);
            let manifest = Arc::new(Manifest::load(&adir)?);
            let bundle =
                Bundle::load(Path::new(flag(flags, "bundle", "runs/bundle")), &manifest)?;
            let svc =
                Arc::new(Service::start(manifest, vec![bundle], BatchPolicy::default(), true)?);
            let mut probe = at::ServiceProbe::new(svc, mode);
            let outcome = at::search(&func, &space, &cfg, &mut probe)?;
            probe.finish();
            outcome
        }
        addr => {
            let mut probe = at::ClientProbe::connect(addr, mode)?;
            let outcome = at::search(&func, &space, &cfg, &mut probe)?;
            probe.finish();
            outcome
        }
    };
    println!(
        "chosen schedule {} (model score {:.3}, objective {objective})",
        outcome.best.candidate.knobs.key(),
        outcome.best.score
    );
    for (t, v) in &outcome.best.values {
        println!("  predicted {} = {v:.3}", t.name());
    }
    println!(
        "search: {} candidates, {} probes ({} delta) in {:.3}s",
        outcome.candidates,
        outcome.probes,
        outcome.delta_probes,
        t0.elapsed().as_secs_f64()
    );

    let size = space.size(&func);
    let oracle_max: usize = flag(flags, "oracle-max", "512").parse()?;
    let run_oracle = match flag(flags, "oracle", "auto") {
        "on" => true,
        "off" => false,
        "auto" => size <= oracle_max,
        other => bail!("--oracle must be auto|on|off, got '{other}'"),
    };
    let xcfg = XpuConfig::default();
    if run_oracle {
        let report = at::regret(&func, &space, &objective, &outcome, &xcfg)?;
        println!(
            "oracle: best {} measures {:.3} over {} schedules",
            report.oracle_knobs.key(),
            report.oracle_measured,
            report.space_size
        );
        println!(
            "measured regret: {:.4} (chosen {:.3} / oracle best {:.3})",
            report.regret, report.chosen_measured, report.oracle_measured
        );
        println!(
            "speedup vs default schedule: {:.3}x ({:.4} speedup found per second of search)",
            report.speedup, report.speedup_per_sec
        );
    } else {
        let measured = at::measure(&outcome.best.candidate.text, &objective, &xcfg)?;
        println!(
            "sim-measured chosen objective: {measured:.3} \
             (space size {size} > --oracle-max {oracle_max}; pass --oracle on to force)"
        );
    }
    Ok(())
}

fn info(flags: &HashMap<String, String>) -> Result<()> {
    let adir = artifacts_dir(flags);
    let manifest = Manifest::load(&adir)?;
    println!("artifacts: {:?} (vocab capacity {})", manifest.dir, manifest.vocab_size);
    for (name, m) in &manifest.models {
        println!(
            "  {name:<12} max_len {:>4}  {:>9} params in {} tensors  files: {}",
            m.max_len,
            m.total_params(),
            m.n_params(),
            m.files.len()
        );
    }
    Ok(())
}

//! The prediction value type threaded through every serving layer:
//! a small fixed-capacity vector of hardware characteristics, in the
//! order the serving bundle declares its targets.
//!
//! The paper's model predicts *several* characteristics (utilization,
//! cycles, register pressure) from one forward pass over the same
//! encoder. [`PredVec`] is what that pass returns: element `i` is the
//! value for the bundle's `targets[i]`. Single-target bundles produce
//! 1-element vectors, so the scalar serving path degenerates to exactly
//! the old behavior.
//!
//! Deliberately `Copy` with an inline array — no per-query heap
//! allocation anywhere on the hot path, cache entries stay
//! uniform-size, and `cluster::PeerReply` keeps its `Copy` derive.
//! [`MAX_TARGETS`] bounds the capacity at the number of characteristics
//! the simulator can label ([`crate::sim::Target::ALL`] plus headroom).

use crate::json::Json;
use anyhow::{bail, Result};

/// Maximum characteristics one bundle may declare. Raising this grows
/// every cache entry and batch-queue row by 8 bytes per slot — keep it
/// at "what the simulator labels", not "what might exist someday".
pub const MAX_TARGETS: usize = 4;

/// A fixed-order vector of predicted hardware characteristics.
///
/// Equality is element-wise over the occupied prefix (two `PredVec`s
/// with different lengths are never equal, regardless of what the
/// unoccupied slots hold).
#[derive(Debug, Clone, Copy)]
pub struct PredVec {
    vals: [f64; MAX_TARGETS],
    len: u8,
}

impl PredVec {
    /// The empty vector (pushed into via [`PredVec::push`]).
    pub fn new() -> PredVec {
        PredVec { vals: [0.0; MAX_TARGETS], len: 0 }
    }

    /// A 1-element vector — the single-target serving path's value.
    pub fn scalar(v: f64) -> PredVec {
        let mut p = PredVec::new();
        p.push(v);
        p
    }

    /// Build from a slice. Panics past [`MAX_TARGETS`] — bundle target
    /// lists are validated at load time, so an oversized slice here is
    /// a programmer error, not an input error.
    pub fn from_slice(vals: &[f64]) -> PredVec {
        assert!(vals.len() <= MAX_TARGETS, "PredVec overflow: {} values", vals.len());
        let mut p = PredVec::new();
        for &v in vals {
            p.push(v);
        }
        p
    }

    pub fn push(&mut self, v: f64) {
        assert!((self.len as usize) < MAX_TARGETS, "PredVec overflow");
        self.vals[self.len as usize] = v;
        self.len += 1;
    }

    pub fn len(&self) -> usize {
        self.len as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn get(&self, i: usize) -> Option<f64> {
        self.as_slice().get(i).copied()
    }

    /// The first (primary) characteristic — what the legacy scalar
    /// `"prediction"` response field and `Service::predict` return.
    pub fn first(&self) -> f64 {
        self.vals[0]
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.vals[..self.len as usize]
    }

    pub fn iter(&self) -> std::slice::Iter<'_, f64> {
        self.as_slice().iter()
    }

    /// Every occupied element is finite (the wire layer rejects
    /// non-finite values, mirroring the old scalar check).
    pub fn is_finite(&self) -> bool {
        self.as_slice().iter().all(|v| v.is_finite())
    }

    /// Wire form: always a JSON array, even for one element — readers
    /// accept the legacy scalar via [`PredVec::from_json`].
    pub fn to_json(&self) -> Json {
        Json::arr_num(self.as_slice())
    }

    /// Version-tolerant wire parse: a JSON array of 1..=[`MAX_TARGETS`]
    /// numbers, or a bare number (the pre-multi-output scalar form,
    /// still emitted by older nodes) which becomes a 1-element vector.
    pub fn from_json(j: &Json) -> Result<PredVec> {
        if let Some(v) = j.as_f64() {
            return Ok(PredVec::scalar(v));
        }
        let Some(arr) = j.as_arr() else {
            bail!("prediction value must be a number or an array of numbers");
        };
        if arr.is_empty() || arr.len() > MAX_TARGETS {
            bail!("prediction vector must have 1..={MAX_TARGETS} elements, got {}", arr.len());
        }
        let mut p = PredVec::new();
        for x in arr {
            match x.as_f64() {
                Some(v) => p.push(v),
                None => bail!("prediction vector element is not a number"),
            }
        }
        Ok(p)
    }
}

impl Default for PredVec {
    fn default() -> PredVec {
        PredVec::new()
    }
}

impl PartialEq for PredVec {
    fn eq(&self, other: &PredVec) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<'a> IntoIterator for &'a PredVec {
    type Item = &'a f64;
    type IntoIter = std::slice::Iter<'a, f64>;
    fn into_iter(self) -> Self::IntoIter {
        self.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_and_slice_roundtrip() {
        let s = PredVec::scalar(7.25);
        assert_eq!(s.len(), 1);
        assert_eq!(s.first(), 7.25);
        assert_eq!(s.as_slice(), &[7.25]);
        let v = PredVec::from_slice(&[1.0, 2.0, 3.0]);
        assert_eq!(v.len(), 3);
        assert_eq!(v.get(2), Some(3.0));
        assert_eq!(v.get(3), None);
        assert_eq!(v.first(), 1.0);
    }

    #[test]
    fn equality_is_over_the_occupied_prefix() {
        // A 1-element vector never equals a 2-element one, even when the
        // unoccupied slot happens to hold the same bits.
        let a = PredVec::scalar(5.0);
        let mut b = PredVec::scalar(5.0);
        b.push(0.0);
        assert_ne!(a, b);
        assert_eq!(a, PredVec::scalar(5.0));
        assert_eq!(PredVec::from_slice(&[1.0, 2.0]), PredVec::from_slice(&[1.0, 2.0]));
    }

    #[test]
    fn json_roundtrip_and_legacy_scalar() {
        let v = PredVec::from_slice(&[27.5, 0.93, 1e300, 1e-300]);
        let j = v.to_json();
        assert_eq!(PredVec::from_json(&j).unwrap(), v);
        // Legacy scalar form parses to a 1-element vector.
        let legacy = PredVec::from_json(&Json::num(12.5)).unwrap();
        assert_eq!(legacy, PredVec::scalar(12.5));
        // Malformed shapes are clean errors.
        assert!(PredVec::from_json(&Json::Arr(vec![])).is_err());
        assert!(PredVec::from_json(&Json::str("x")).is_err());
        assert!(PredVec::from_json(&Json::Arr(vec![Json::str("x")])).is_err());
        let too_many = Json::arr_num(&[1.0; MAX_TARGETS + 1]);
        assert!(PredVec::from_json(&too_many).is_err());
    }

    #[test]
    fn finiteness_covers_every_element() {
        assert!(PredVec::from_slice(&[1.0, 2.0]).is_finite());
        assert!(!PredVec::from_slice(&[1.0, f64::NAN]).is_finite());
        assert!(!PredVec::from_slice(&[f64::INFINITY]).is_finite());
    }

    #[test]
    #[should_panic(expected = "PredVec overflow")]
    fn push_past_capacity_panics() {
        let mut p = PredVec::new();
        for i in 0..=MAX_TARGETS {
            p.push(i as f64);
        }
    }
}

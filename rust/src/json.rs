//! Minimal JSON reader/writer.
//!
//! Used for the vocab files, the AOT artifact manifest written by
//! `python/compile/aot.py`, checkpoints metadata, and the serving wire
//! protocol. (No `serde`/`serde_json` is vendored in this offline image —
//! see DESIGN.md §3.4 — so we carry a small, well-tested implementation.)

use anyhow::{anyhow, bail, ensure, Result};
use std::collections::BTreeMap;
use std::io::Write;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Builder-style insert (panics on non-object: programmer error).
    pub fn with(mut self, key: &str, value: Json) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value);
            }
            _ => panic!("Json::with on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field accessors with contextual errors.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing field '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| anyhow!("field '{key}' is not a string"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?.as_f64().ok_or_else(|| anyhow!("field '{key}' is not a number"))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json]> {
        self.req(key)?.as_arr().ok_or_else(|| anyhow!("field '{key}' is not an array"))
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    pub fn arr_num(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_i64(v: &[i64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Stream the compact serialization straight into `w` — the wire
    /// protocol writes responses into a connection's `BufWriter` without
    /// materializing an intermediate `String` per reply.
    pub fn write_to<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        match self {
            Json::Null => w.write_all(b"null"),
            Json::Bool(b) => w.write_all(if *b { b"true" } else { b"false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(w, "{}", *n as i64)
                } else {
                    write!(w, "{n}")
                }
            }
            Json::Str(s) => write_escaped(s, w),
            Json::Arr(v) => {
                w.write_all(b"[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        w.write_all(b",")?;
                    }
                    x.write_to(w)?;
                }
                w.write_all(b"]")
            }
            Json::Obj(m) => {
                w.write_all(b"{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        w.write_all(b",")?;
                    }
                    write_escaped(k, w)?;
                    w.write_all(b":")?;
                    v.write_to(w)?;
                }
                w.write_all(b"}")
            }
        }
    }
}

/// Adapts a `fmt::Formatter` to `io::Write` so `Display` can reuse
/// [`Json::write_to`] without an intermediate buffer. Sound because
/// `write_to` only ever emits whole UTF-8 chunks: `&str` slices cut at
/// char boundaries, ASCII punctuation, and `write!` output.
struct FmtWriter<'a, 'b>(&'a mut std::fmt::Formatter<'b>);

impl Write for FmtWriter<'_, '_> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let s = std::str::from_utf8(buf)
            .map_err(|_| std::io::Error::from(std::io::ErrorKind::InvalidData))?;
        self.0
            .write_str(s)
            .map_err(|_| std::io::Error::from(std::io::ErrorKind::Other))?;
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// Compact serialization; `to_string()` comes via the `ToString` blanket.
/// [`Json::write_to`] is the streaming core — `Display` streams through
/// it directly (no temporary buffer), and the wire path calls it with a
/// connection's `BufWriter`.
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.write_to(&mut FmtWriter(f)).map_err(|_| std::fmt::Error)
    }
}

/// Write `s` quoted + escaped. Maximal runs of chars needing no escape go
/// out as one `write_all` (the common case is the whole string).
fn write_escaped<W: Write>(s: &str, w: &mut W) -> std::io::Result<()> {
    w.write_all(b"\"")?;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        let escape_needed = matches!(c, '"' | '\\') || (c as u32) < 0x20;
        if !escape_needed {
            continue;
        }
        if start < i {
            w.write_all(s[start..i].as_bytes())?;
        }
        match c {
            '"' => w.write_all(b"\\\"")?,
            '\\' => w.write_all(b"\\\\")?,
            '\n' => w.write_all(b"\\n")?,
            '\r' => w.write_all(b"\\r")?,
            '\t' => w.write_all(b"\\t")?,
            c => write!(w, "\\u{:04x}", c as u32)?,
        }
        start = i + c.len_utf8();
    }
    if start < s.len() {
        w.write_all(s[start..].as_bytes())?;
    }
    w.write_all(b"\"")
}

/// Parse a JSON document.
pub fn parse(src: &str) -> Result<Json> {
    let mut p = P { b: src.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    ensure!(p.i == p.b.len(), "trailing characters at byte {}", p.i);
    Ok(v)
}

struct P<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> P<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        ensure!(self.peek()? == c, "expected '{}' at byte {}", c as char, self.i);
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        ensure!(
            self.b[self.i..].starts_with(s.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += s.len();
        Ok(v)
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => {
                self.i += 1;
                let mut v = Vec::new();
                self.ws();
                if self.peek()? == b']' {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                loop {
                    self.ws();
                    v.push(self.value()?);
                    self.ws();
                    match self.peek()? {
                        b',' => self.i += 1,
                        b']' => {
                            self.i += 1;
                            return Ok(Json::Arr(v));
                        }
                        c => bail!("expected ',' or ']' at byte {}, got '{}'", self.i, c as char),
                    }
                }
            }
            b'{' => {
                self.i += 1;
                let mut m = BTreeMap::new();
                self.ws();
                if self.peek()? == b'}' {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                loop {
                    self.ws();
                    let k = self.string()?;
                    self.ws();
                    self.eat(b':')?;
                    self.ws();
                    m.insert(k, self.value()?);
                    self.ws();
                    match self.peek()? {
                        b',' => self.i += 1,
                        b'}' => {
                            self.i += 1;
                            return Ok(Json::Obj(m));
                        }
                        c => bail!("expected ',' or '}}' at byte {}, got '{}'", self.i, c as char),
                    }
                }
            }
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            ensure!(self.i + 4 <= self.b.len(), "truncated \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        other => bail!("bad escape '\\{}'", other as char),
                    }
                }
                c => {
                    // Collect the full UTF-8 sequence.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    ensure!(start + len <= self.b.len(), "truncated UTF-8");
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|_| anyhow!("bad number '{text}'"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    if first < 0x80 {
        1
    } else if first < 0xE0 {
        2
    } else if first < 0xF0 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let j = Json::obj()
            .with("name", Json::str("conv1d"))
            .with("dims", Json::arr_i64(&[1, 128, 64]))
            .with("lr", Json::num(0.001))
            .with("ok", Json::Bool(true))
            .with("none", Json::Null);
        let text = j.to_string();
        assert_eq!(parse(&text).unwrap(), j);
    }

    #[test]
    fn parses_whitespace_and_nesting() {
        let src = r#" { "a" : [ 1 , 2.5 , { "b" : "x" } ] , "c" : null } "#;
        let v = parse(src).unwrap();
        assert_eq!(v.req_arr("a").unwrap().len(), 3);
        assert_eq!(v.req_arr("a").unwrap()[2].req_str("b").unwrap(), "x");
    }

    #[test]
    fn string_escapes() {
        let j = Json::str("line1\nline2\t\"quoted\" \\ slash");
        let text = j.to_string();
        assert_eq!(parse(&text).unwrap(), j);
        // Unicode escape in.
        assert_eq!(parse(r#""A""#).unwrap(), Json::str("A"));
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::str("tensor→mlir λ");
        assert_eq!(parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(parse("42").unwrap().as_u64(), Some(42));
        let big = Json::Num(20000.0);
        assert_eq!(big.to_string(), "20000");
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn write_to_matches_to_string() {
        let j = Json::obj()
            .with("s", Json::str("a\"b\\c\nd\u{1}é→"))
            .with("arr", Json::Arr(vec![Json::num(1.0), Json::Bool(false), Json::Null]))
            .with("n", Json::num(-2.5));
        let mut buf = Vec::new();
        j.write_to(&mut buf).unwrap();
        assert_eq!(String::from_utf8(buf).unwrap(), j.to_string());
        assert_eq!(parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn req_accessors() {
        let v = parse(r#"{"a": 1, "s": "x"}"#).unwrap();
        assert_eq!(v.req_f64("a").unwrap(), 1.0);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert!(v.req_str("missing").is_err());
        assert!(v.req_f64("s").is_err());
    }
}

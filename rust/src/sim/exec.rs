//! Cycle-level execution model: in-order scoreboard over each segment's
//! instruction window, steady-state pipelining across trips, DMA overlap.

use super::machine::{Unit, XpuConfig, UNITS};
use crate::lower::isa::Program;
use std::collections::HashMap;

/// Simulation output for one program.
#[derive(Debug, Clone, PartialEq)]
pub struct SimReport {
    /// End-to-end cycles (compute/DMA overlapped, plus startup).
    pub cycles: u64,
    /// Compute-only cycles.
    pub compute_cycles: u64,
    /// DMA-only cycles.
    pub dma_cycles: u64,
    /// Busy cycles per unit (occupancy, not latency).
    pub busy: HashMap<Unit, u64>,
    /// Vector-ALU utilization in percent — the paper's *xpuutilization*.
    pub valu_util_pct: f64,
    /// MXU utilization in percent.
    pub mxu_util_pct: f64,
    /// Total dynamic instructions executed.
    pub dyn_instrs: u64,
    /// Peak vector-register demand — filled by [`super::report`] from
    /// the regalloc pass over the same lowered program, so one run
    /// carries every label. `simulate` alone (no allocation context)
    /// leaves it 0.
    pub regpressure: u32,
    /// Registers spilled at the peak (same provenance as `regpressure`).
    pub spills: u32,
}

/// Simulate one segment window with an in-order scoreboard.
/// Returns (window span in cycles, steady-state initiation interval,
/// per-unit busy cycles for one trip).
fn simulate_window(prog_seg: &crate::lower::isa::Segment, cfg: &XpuConfig) -> (u64, u64, HashMap<Unit, u64>) {
    let mut reg_ready: HashMap<u32, u64> = HashMap::new();
    let mut unit_free: HashMap<Unit, u64> = HashMap::new();
    let mut busy: HashMap<Unit, u64> = HashMap::new();
    let mut issue_cycle = 0u64;
    let mut issued_this_cycle = 0u64;
    let mut span = 0u64;

    for instr in &prog_seg.instrs {
        let (unit, lat, ii) = cfg.cost(instr);
        // Operand readiness (undefined regs — loop-carried seeds — are
        // ready at 0).
        let ready = instr
            .uses()
            .iter()
            .map(|r| reg_ready.get(&r.id).copied().unwrap_or(0))
            .max()
            .unwrap_or(0);
        // In-order issue: bounded by issue width and unit availability.
        if issued_this_cycle >= cfg.issue_width {
            issue_cycle += 1;
            issued_this_cycle = 0;
        }
        let start = issue_cycle.max(ready).max(unit_free.get(&unit).copied().unwrap_or(0));
        if start > issue_cycle {
            issue_cycle = start;
            issued_this_cycle = 0;
        }
        issued_this_cycle += 1;
        unit_free.insert(unit, start + ii);
        *busy.entry(unit).or_default() += ii;
        let finish = start + lat;
        if let Some(d) = instr.def() {
            reg_ready.insert(d.id, finish);
        }
        span = span.max(finish);
    }

    // Steady state: successive trips are limited by the busiest resource
    // (a unit's occupancy or the issue front-end), not the full latency
    // chain — standard software-pipelining assumption.
    let n = prog_seg.instrs.len() as u64;
    let issue_limit = n.div_ceil(cfg.issue_width);
    let unit_limit = busy.values().copied().max().unwrap_or(0);
    let ii = issue_limit.max(unit_limit).max(1);
    (span, ii, busy)
}

/// Run the whole program.
pub fn simulate(prog: &Program, cfg: &XpuConfig) -> SimReport {
    let mut compute_cycles = 0u64;
    let mut busy_total: HashMap<Unit, u64> = HashMap::new();
    for seg in &prog.segments {
        if seg.instrs.is_empty() {
            continue;
        }
        let (span, ii, busy) = simulate_window(seg, cfg);
        compute_cycles += span + (seg.trips.saturating_sub(1)) * ii;
        for (u, b) in busy {
            *busy_total.entry(u).or_default() += b * seg.trips;
        }
    }
    let dma_cycles =
        (prog.dma_in_bytes + prog.dma_out_bytes).div_ceil(cfg.dma_bytes_per_cycle.max(1));
    // DMA overlaps compute; whichever dominates sets the envelope.
    let cycles = compute_cycles.max(dma_cycles) + cfg.startup_cycles;
    let pct = |u: Unit| -> f64 {
        100.0 * busy_total.get(&u).copied().unwrap_or(0) as f64 / cycles.max(1) as f64
    };
    let valu_util_pct = pct(Unit::Valu);
    let mxu_util_pct = pct(Unit::Mxu);
    for u in UNITS {
        busy_total.entry(u).or_default();
    }
    SimReport {
        cycles,
        compute_cycles,
        dma_cycles,
        busy: busy_total,
        valu_util_pct,
        mxu_util_pct,
        dyn_instrs: prog.dyn_instrs(),
        regpressure: 0,
        spills: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lower::isa::{Instr, Mem, RegAlloc, Segment, VArith};

    fn one_seg(instrs: Vec<Instr>, trips: u64) -> Program {
        let mut p = Program::default();
        let mut s = Segment::new("t", trips);
        s.instrs = instrs;
        p.segments.push(s);
        p
    }

    #[test]
    fn dependent_chain_is_latency_bound_in_window() {
        let cfg = XpuConfig::default();
        let mut ra = RegAlloc::default();
        let a = ra.fresh(1);
        let b = ra.fresh(1);
        let c = ra.fresh(1);
        let p = one_seg(
            vec![
                Instr::VLoad { dst: a, mem: Mem::Scratch, strided: false },
                Instr::VOp { op: VArith::Add, dst: b, a, b: None },
                Instr::VOp { op: VArith::Add, dst: c, a: b, b: None },
            ],
            1,
        );
        let r = simulate(&p, &cfg);
        // load lat 4 + add 2 + add 2 = 8 compute cycles.
        assert_eq!(r.compute_cycles, 8);
    }

    #[test]
    fn trips_scale_cycles_via_steady_state_ii() {
        let cfg = XpuConfig::default();
        let mut ra = RegAlloc::default();
        let a = ra.fresh(1);
        let instrs = vec![
            Instr::VLoad { dst: a, mem: Mem::Scratch, strided: false },
            Instr::VStore { src: a, mem: Mem::Scratch, strided: false },
        ];
        let r1 = simulate(&one_seg(instrs.clone(), 1), &cfg);
        let r100 = simulate(&one_seg(instrs, 100), &cfg);
        // Steady state: LSU busy = 2/trip → +2 cycles per extra trip.
        assert_eq!(
            r100.compute_cycles - r1.compute_cycles,
            99 * 2,
            "{} vs {}",
            r100.compute_cycles,
            r1.compute_cycles
        );
    }

    #[test]
    fn valu_utilization_reflects_op_mix() {
        let cfg = XpuConfig::default();
        let mut ra = RegAlloc::default();
        let a = ra.fresh(1);
        let b = ra.fresh(1);
        // Pure VALU loop vs pure LSU loop.
        let valu_heavy = one_seg(
            vec![
                Instr::VOp { op: VArith::Add, dst: a, a, b: None },
                Instr::VOp { op: VArith::Mul, dst: b, a, b: None },
            ],
            1000,
        );
        let lsu_heavy = one_seg(
            vec![
                Instr::VLoad { dst: a, mem: Mem::Scratch, strided: false },
                Instr::VStore { src: a, mem: Mem::Scratch, strided: false },
            ],
            1000,
        );
        let rv = simulate(&valu_heavy, &cfg);
        let rl = simulate(&lsu_heavy, &cfg);
        assert!(rv.valu_util_pct > 50.0, "valu-heavy: {}", rv.valu_util_pct);
        assert!(rl.valu_util_pct < 5.0, "lsu-heavy: {}", rl.valu_util_pct);
    }

    #[test]
    fn dma_bound_program() {
        let cfg = XpuConfig::default();
        let mut ra = RegAlloc::default();
        let a = ra.fresh(1);
        let mut p = one_seg(vec![Instr::VOp { op: VArith::Add, dst: a, a, b: None }], 1);
        p.dma_in_bytes = 10 << 20; // 10 MiB at 64 B/cy ≈ 164k cycles
        let r = simulate(&p, &cfg);
        assert!(r.dma_cycles > r.compute_cycles);
        assert_eq!(r.cycles, r.dma_cycles + cfg.startup_cycles);
    }

    #[test]
    fn empty_program_is_startup_only() {
        let cfg = XpuConfig::default();
        let r = simulate(&Program::default(), &cfg);
        assert_eq!(r.cycles, cfg.startup_cycles);
        assert_eq!(r.dyn_instrs, 0);
    }
}

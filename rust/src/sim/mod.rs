//! xPU accelerator simulator — the ground-truth generator.
//!
//! The paper compiles each MLIR function with Intel's in-house DL-compiler,
//! runs it on their AI accelerator, and records register pressure and
//! vector-ALU ("xpu") utilization as labels. Here the role of compiler +
//! silicon is played by [`crate::lower`] + this module: same causal chain
//! (high-level IR → fused tiled loops → ISA → machine behavior), fully
//! deterministic and inspectable.

pub mod exec;
pub mod machine;

pub use exec::{simulate, SimReport};
pub use machine::{Unit, XpuConfig};

use crate::lower::{analyze, apply_spills, lower, lower_with_groups, CodegenOpts, Group};
use crate::mlir::Function;
use anyhow::Result;

/// Ground-truth labels for one MLIR function — the dataset targets.
#[derive(Debug, Clone, PartialEq)]
pub struct Labels {
    /// Peak vector-register demand (paper target #1, *registerpressure*).
    pub regpressure: f64,
    /// Vector-ALU utilization % (paper target #2, *xpuutilization*).
    pub xpu_util: f64,
    /// Total cycles (paper's future-work latency target).
    pub cycles: f64,
    /// Registers spilled at the peak.
    pub spills: u32,
    /// Dynamic instruction count.
    pub dyn_instrs: u64,
}

/// Target variable selector used across dataset/training/serving.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Target {
    RegPressure,
    XpuUtil,
    Cycles,
}

impl Target {
    pub const ALL: [Target; 3] = [Target::RegPressure, Target::XpuUtil, Target::Cycles];

    pub fn name(self) -> &'static str {
        match self {
            Target::RegPressure => "regpressure",
            Target::XpuUtil => "xpuutil",
            Target::Cycles => "cycles",
        }
    }

    pub fn parse(s: &str) -> Option<Target> {
        Target::ALL.iter().copied().find(|t| t.name() == s)
    }

    pub fn of(self, labels: &Labels) -> f64 {
        match self {
            Target::RegPressure => labels.regpressure,
            Target::XpuUtil => labels.xpu_util,
            Target::Cycles => labels.cycles,
        }
    }
}

impl Labels {
    /// Every label from one combined report — the single-pass label
    /// vector the dataset/trainer consume.
    pub fn from_report(r: &SimReport) -> Labels {
        Labels {
            regpressure: r.regpressure as f64,
            xpu_util: r.valu_util_pct,
            cycles: r.cycles as f64,
            spills: r.spills,
            dyn_instrs: r.dyn_instrs,
        }
    }
}

/// Compile + allocate + simulate one function in a SINGLE pass,
/// returning the full machine report with the register-allocation
/// results (`regpressure`/`spills`) folded in. Every characteristic —
/// cycles, both utilizations, dynamic instructions, register pressure,
/// spills — comes from this one run; per-target label extraction never
/// re-lowers or re-simulates.
pub fn report(f: &Function, opts: &CodegenOpts, cfg: &XpuConfig) -> Result<SimReport> {
    let mut prog = lower(f, opts)?;
    let reg = analyze(&prog);
    apply_spills(&mut prog, &reg);
    let mut sim = simulate(&prog, cfg);
    sim.regpressure = reg.max_live;
    sim.spills = reg.spilled;
    Ok(sim)
}

/// Single-pass report with default compiler/machine settings.
pub fn report_default(f: &Function) -> Result<SimReport> {
    report(f, &CodegenOpts::default(), &XpuConfig::default())
}

/// [`report`] with an explicit fusion-group partition instead of the
/// global `opts.fuse` switch — the autotune oracle's scoring path for
/// per-group fusion decisions.
pub fn report_with_groups(
    f: &Function,
    opts: &CodegenOpts,
    groups: &[Group],
    cfg: &XpuConfig,
) -> Result<SimReport> {
    let mut prog = lower_with_groups(f, opts, groups)?;
    let reg = analyze(&prog);
    apply_spills(&mut prog, &reg);
    let mut sim = simulate(&prog, cfg);
    sim.regpressure = reg.max_live;
    sim.spills = reg.spilled;
    Ok(sim)
}

/// [`ground_truth`] with an explicit fusion-group partition.
pub fn ground_truth_with_groups(
    f: &Function,
    opts: &CodegenOpts,
    groups: &[Group],
    cfg: &XpuConfig,
) -> Result<Labels> {
    Ok(Labels::from_report(&report_with_groups(f, opts, groups, cfg)?))
}

/// Compile + allocate + simulate one function: the full ground-truth
/// path. Thin wrapper over [`report`] — one lower + one simulation
/// produce every label.
pub fn ground_truth(f: &Function, opts: &CodegenOpts, cfg: &XpuConfig) -> Result<Labels> {
    Ok(Labels::from_report(&report(f, opts, cfg)?))
}

/// Ground truth with default compiler/machine settings.
pub fn ground_truth_default(f: &Function) -> Result<Labels> {
    ground_truth(f, &CodegenOpts::default(), &XpuConfig::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphgen::{corpus_specs, generate, Family, GraphSpec};

    #[test]
    fn labels_for_all_families() {
        for (i, family) in Family::ALL.into_iter().enumerate() {
            let spec = GraphSpec { family, structure_seed: 31 + i as u64, shape_seed: 17 };
            let f = generate(&spec).unwrap();
            let l = ground_truth_default(&f).unwrap();
            assert!(l.regpressure > 0.0, "{family:?}: zero pressure");
            assert!(l.cycles > 0.0);
            assert!((0.0..=100.0).contains(&l.xpu_util), "{family:?}: util {}", l.xpu_util);
        }
    }

    #[test]
    fn labels_vary_across_corpus() {
        let specs = corpus_specs(1234, 30, 0);
        let labels: Vec<Labels> = specs
            .iter()
            .map(|s| ground_truth_default(&generate(s).unwrap()).unwrap())
            .collect();
        let rp: Vec<f64> = labels.iter().map(|l| l.regpressure).collect();
        let util: Vec<f64> = labels.iter().map(|l| l.xpu_util).collect();
        let spread = |v: &[f64]| {
            let mn = v.iter().cloned().fold(f64::INFINITY, f64::min);
            let mx = v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            mx - mn
        };
        assert!(spread(&rp) > 4.0, "regpressure too flat: {rp:?}");
        assert!(spread(&util) > 5.0, "util too flat: {util:?}");
    }

    #[test]
    fn fusion_reduces_cycles() {
        use crate::lower::CodegenOpts;
        let spec = GraphSpec { family: Family::Mlp, structure_seed: 2, shape_seed: 3 };
        let f = generate(&spec).unwrap();
        let cfg = XpuConfig::default();
        let fused = ground_truth(&f, &CodegenOpts::default(), &cfg).unwrap();
        let unfused =
            ground_truth(&f, &CodegenOpts { fuse: false, ..Default::default() }, &cfg).unwrap();
        assert!(
            fused.cycles <= unfused.cycles,
            "fusion should not slow down: {} vs {}",
            fused.cycles,
            unfused.cycles
        );
    }

    #[test]
    fn unroll_increases_pressure() {
        use crate::lower::CodegenOpts;
        use crate::mlir::{Attrs, DType, FuncBuilder, Type, XpuOp};
        // Standalone elementwise chain (in an MLP the elementwise tail is
        // fused into the matmul epilogue, which unroll does not touch).
        let mut b = FuncBuilder::new("ew");
        let x = b.arg(Type::tensor(vec![4096], DType::F32));
        let y = b.arg(Type::tensor(vec![4096], DType::F32));
        let s = b.xpu(XpuOp::Add, &[x, y], Attrs::new()).unwrap();
        let t = b.xpu(XpuOp::Tanh, &[s], Attrs::new()).unwrap();
        let f = b.ret(&[t]).unwrap();
        let cfg = XpuConfig::default();
        let u1 = ground_truth(&f, &CodegenOpts { unroll: Some(1), ..Default::default() }, &cfg)
            .unwrap();
        let u8 = ground_truth(&f, &CodegenOpts { unroll: Some(8), ..Default::default() }, &cfg)
            .unwrap();
        assert!(
            u8.regpressure > u1.regpressure,
            "unroll 8 vs 1: {} vs {}",
            u8.regpressure,
            u1.regpressure
        );
    }

    /// The single-pass report carries every characteristic at once, and
    /// the Labels derived from it match the legacy per-function path.
    #[test]
    fn single_pass_report_carries_all_characteristics() {
        let spec = GraphSpec { family: Family::Mlp, structure_seed: 8, shape_seed: 9 };
        let f = generate(&spec).unwrap();
        let r = report_default(&f).unwrap();
        assert!(r.regpressure > 0, "regalloc results must be folded in");
        assert!(r.cycles > 0 && r.dyn_instrs > 0);
        let l = ground_truth_default(&f).unwrap();
        assert_eq!(l, Labels::from_report(&r));
        assert_eq!(l.regpressure, r.regpressure as f64);
        assert_eq!(l.xpu_util, r.valu_util_pct);
        assert_eq!(l.cycles, r.cycles as f64);
        // `simulate` alone (no allocation context) leaves pressure 0.
        let prog = crate::lower::lower(&f, &crate::lower::CodegenOpts::default()).unwrap();
        assert_eq!(simulate(&prog, &XpuConfig::default()).regpressure, 0);
    }

    #[test]
    fn target_selector() {
        let l = Labels { regpressure: 10.0, xpu_util: 55.0, cycles: 999.0, spills: 0, dyn_instrs: 1 };
        assert_eq!(Target::RegPressure.of(&l), 10.0);
        assert_eq!(Target::XpuUtil.of(&l), 55.0);
        assert_eq!(Target::Cycles.of(&l), 999.0);
        for t in Target::ALL {
            assert_eq!(Target::parse(t.name()), Some(t));
        }
    }
}

//! The modeled xPU: functional units, latencies, issue model.
//!
//! Numbers are representative of contemporary AI-accelerator vector cores
//! (VPU-class SIMD + systolic MXU + SFU + scratchpad LSU). The absolute
//! values matter less than their *relationships* — the cost model learns
//! whatever machine this defines, exactly as the paper's model learns
//! Intel's unnamed accelerator.

use crate::lower::isa::{Instr, Mem};

/// Functional units of the xPU core.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Unit {
    /// Vector ALU — the unit whose utilization the paper predicts.
    Valu,
    /// Special-function unit (transcendentals, division).
    Sfu,
    /// Systolic matrix unit.
    Mxu,
    /// Load/store between scratchpad and the vector register file.
    Lsu,
}

pub const UNITS: [Unit; 4] = [Unit::Valu, Unit::Sfu, Unit::Mxu, Unit::Lsu];

/// Machine description.
#[derive(Debug, Clone)]
pub struct XpuConfig {
    /// In-order issue slots per cycle.
    pub issue_width: u64,
    /// (latency, initiation interval) per unit.
    pub valu: (u64, u64),
    pub sfu: (u64, u64),
    pub mxu: (u64, u64),
    pub lsu_scratch: (u64, u64),
    pub lsu_hbm: (u64, u64),
    /// Extra (latency, ii) added to strided accesses.
    pub strided_penalty: (u64, u64),
    /// HBM↔scratchpad DMA bandwidth.
    pub dma_bytes_per_cycle: u64,
    /// Fixed kernel-launch overhead.
    pub startup_cycles: u64,
}

impl Default for XpuConfig {
    fn default() -> Self {
        XpuConfig {
            issue_width: 2,
            valu: (2, 1),
            sfu: (6, 2),
            mxu: (8, 2),
            lsu_scratch: (4, 1),
            lsu_hbm: (24, 4),
            strided_penalty: (8, 2),
            dma_bytes_per_cycle: 64,
            startup_cycles: 500,
        }
    }
}

impl XpuConfig {
    /// Which unit executes `instr`, with (latency, initiation interval).
    pub fn cost(&self, instr: &Instr) -> (Unit, u64, u64) {
        match instr {
            Instr::VLoad { mem, strided, .. } | Instr::VStore { mem, strided, .. } => {
                let (mut lat, mut ii) = match mem {
                    Mem::Scratch => self.lsu_scratch,
                    Mem::Hbm => self.lsu_hbm,
                };
                if *strided {
                    lat += self.strided_penalty.0;
                    ii += self.strided_penalty.1;
                }
                (Unit::Lsu, lat, ii)
            }
            Instr::SpillLoad { .. } | Instr::SpillStore { .. } => {
                (Unit::Lsu, self.lsu_scratch.0, self.lsu_scratch.1)
            }
            Instr::VOp { .. } => (Unit::Valu, self.valu.0, self.valu.1),
            Instr::Sfu { .. } => (Unit::Sfu, self.sfu.0, self.sfu.1),
            Instr::Macc { .. } => (Unit::Mxu, self.mxu.0, self.mxu.1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lower::isa::{VArith, VReg};

    #[test]
    fn cost_mapping() {
        let cfg = XpuConfig::default();
        let r = VReg { id: 0, width: 1 };
        let (u, lat, _) = cfg.cost(&Instr::VLoad { dst: r, mem: Mem::Hbm, strided: true });
        assert_eq!(u, Unit::Lsu);
        assert_eq!(lat, 24 + 8);
        let (u, ..) = cfg.cost(&Instr::VOp { op: VArith::Add, dst: r, a: r, b: None });
        assert_eq!(u, Unit::Valu);
        let (u, ..) = cfg.cost(&Instr::Macc { acc: r, a: r, b: r });
        assert_eq!(u, Unit::Mxu);
    }
}

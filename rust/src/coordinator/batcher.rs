//! Dynamic batcher: individual cost queries arrive asynchronously from
//! compiler threads; the batcher coalesces them into fixed-size predict
//! batches (size OR deadline triggered, vLLM-router style) so the model
//! executable amortizes per-call overhead.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One queued query: encoded ids + a one-shot response channel.
pub struct Pending {
    pub ids: Vec<u32>,
    pub respond: Sender<f64>,
}

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Flush as soon as this many requests are queued.
    pub max_batch: usize,
    /// Flush a non-empty queue after this long regardless of size.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(2) }
    }
}

/// Thread-safe queue with deadline-aware draining.
pub struct BatchQueue {
    inner: Mutex<Vec<Pending>>,
    cv: Condvar,
    policy: BatchPolicy,
    closed: Mutex<bool>,
}

impl BatchQueue {
    pub fn new(policy: BatchPolicy) -> Arc<Self> {
        Arc::new(BatchQueue {
            inner: Mutex::new(Vec::new()),
            cv: Condvar::new(),
            policy,
            closed: Mutex::new(false),
        })
    }

    /// Enqueue a query; returns the receiver for its prediction.
    pub fn submit(&self, ids: Vec<u32>) -> Receiver<f64> {
        let (tx, rx) = channel();
        {
            let mut q = self.inner.lock().unwrap();
            q.push(Pending { ids, respond: tx });
        }
        self.cv.notify_one();
        rx
    }

    /// Mark closed (drains return None once empty).
    pub fn close(&self) {
        *self.closed.lock().unwrap() = true;
        self.cv.notify_all();
    }

    /// Block until a batch is ready per policy; None when closed + empty.
    pub fn next_batch(&self) -> Option<Vec<Pending>> {
        let mut q = self.inner.lock().unwrap();
        loop {
            if q.is_empty() {
                if *self.closed.lock().unwrap() {
                    return None;
                }
                // Wait for the first element.
                let (guard, _) = self
                    .cv
                    .wait_timeout(q, Duration::from_millis(50))
                    .expect("queue lock poisoned");
                q = guard;
                continue;
            }
            // Non-empty: wait for fill-up or deadline.
            let deadline = Instant::now() + self.policy.max_wait;
            while q.len() < self.policy.max_batch {
                let now = Instant::now();
                if now >= deadline || *self.closed.lock().unwrap() {
                    break;
                }
                let (guard, timeout) = self
                    .cv
                    .wait_timeout(q, deadline - now)
                    .expect("queue lock poisoned");
                q = guard;
                if timeout.timed_out() {
                    break;
                }
            }
            let take = q.len().min(self.policy.max_batch);
            let batch: Vec<Pending> = q.drain(..take).collect();
            return Some(batch);
        }
    }

    pub fn queued(&self) -> usize {
        self.inner.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn size_triggered_flush() {
        let q = BatchQueue::new(BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10) });
        let mut rxs = Vec::new();
        for i in 0..4u32 {
            rxs.push(q.submit(vec![i]));
        }
        let batch = q.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        for (i, p) in batch.into_iter().enumerate() {
            p.respond.send(i as f64).unwrap();
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap(), i as f64);
        }
    }

    #[test]
    fn deadline_triggered_flush() {
        let q = BatchQueue::new(BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(5) });
        let _rx = q.submit(vec![1]);
        let t0 = Instant::now();
        let batch = q.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn close_unblocks_worker() {
        let q = BatchQueue::new(BatchPolicy::default());
        let q2 = q.clone();
        let h = thread::spawn(move || q2.next_batch().is_none());
        thread::sleep(Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap());
    }

    #[test]
    fn concurrent_submitters() {
        let q = BatchQueue::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(3) });
        let mut handles = Vec::new();
        for i in 0..16u32 {
            let q = q.clone();
            handles.push(thread::spawn(move || {
                let rx = q.submit(vec![i]);
                rx.recv().unwrap()
            }));
        }
        // Drain in a worker: echo first id as the "prediction".
        let worker = {
            let q = q.clone();
            thread::spawn(move || {
                let mut served = 0;
                while served < 16 {
                    if let Some(batch) = q.next_batch() {
                        for p in batch {
                            let v = p.ids[0] as f64;
                            p.respond.send(v).unwrap();
                            served += 1;
                        }
                    }
                }
            })
        };
        let mut got: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        worker.join().unwrap();
        got.sort_by(f64::total_cmp);
        assert_eq!(got, (0..16).map(|i| i as f64).collect::<Vec<_>>());
    }
}

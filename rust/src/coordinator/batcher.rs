//! Dynamic batcher: individual cost queries arrive asynchronously from
//! compiler threads; the batcher coalesces them into fixed-size predict
//! batches (size OR deadline triggered, vLLM-router style) so the model
//! executable amortizes per-call overhead.
//!
//! The queue and the closed flag live under ONE mutex: `close()` takes a
//! single lock and wakes waiters through the condvar immediately — there
//! is no second lock to check out-of-order and no fallback polling
//! interval. An idle worker sleeps on the condvar until a submit or a
//! close arrives.
//!
//! The queue is multi-consumer: a worker *pool* (`--workers-per-head`)
//! parks several threads on the same condvar, each `next_batch` call
//! drains up to `max_batch` requests under the lock, and whichever
//! worker wakes first takes the flush — so one slow model invocation
//! never head-of-line-blocks the next flush when a sibling is idle.

use crate::pred::PredVec;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One queued query: encoded ids + a one-shot response channel. The
/// response is the full normalized characteristic vector from one
/// forward pass — a batch slot is occupied once per query, never once
/// per target.
pub struct Pending {
    pub ids: Vec<u32>,
    pub respond: Sender<PredVec>,
    /// When the query entered the queue — workers observe
    /// `submitted.elapsed()` (queue wait + execute) into the serving
    /// variant's latency EWMA at completion, so the estimate is
    /// per-request accurate no matter how callers collect results.
    pub submitted: Instant,
}

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Flush as soon as this many requests are queued.
    pub max_batch: usize,
    /// Flush a non-empty queue after this long regardless of size.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(2) }
    }
}

/// Everything the queue guards, under a single lock.
struct State {
    queue: Vec<Pending>,
    closed: bool,
}

/// Thread-safe queue with deadline-aware draining.
pub struct BatchQueue {
    state: Mutex<State>,
    cv: Condvar,
    policy: BatchPolicy,
}

impl BatchQueue {
    pub fn new(policy: BatchPolicy) -> Arc<Self> {
        Arc::new(BatchQueue {
            state: Mutex::new(State { queue: Vec::new(), closed: false }),
            cv: Condvar::new(),
            policy,
        })
    }

    /// Enqueue a query; returns the receiver for its prediction. After
    /// `close()`, the sender is dropped immediately so the receiver sees a
    /// disconnect instead of blocking forever.
    pub fn submit(&self, ids: Vec<u32>) -> Receiver<PredVec> {
        let (tx, rx) = channel();
        {
            let mut st = self.state.lock().unwrap();
            if !st.closed {
                st.queue.push(Pending { ids, respond: tx, submitted: Instant::now() });
            }
        }
        self.cv.notify_one();
        rx
    }

    /// Enqueue many queries under one lock acquisition and one wakeup —
    /// the batch API's fast path. Receivers are returned in input order.
    pub fn submit_many(&self, batches: Vec<Vec<u32>>) -> Vec<Receiver<PredVec>> {
        let mut rxs = Vec::with_capacity(batches.len());
        {
            let mut st = self.state.lock().unwrap();
            let submitted = Instant::now();
            for ids in batches {
                let (tx, rx) = channel();
                if !st.closed {
                    st.queue.push(Pending { ids, respond: tx, submitted });
                }
                rxs.push(rx);
            }
        }
        self.cv.notify_all();
        rxs
    }

    /// Mark closed: one lock, and waiters wake immediately. A draining
    /// worker still sees already-queued requests (`next_batch` returns
    /// them) and then gets `None`.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }

    /// Block until a batch is ready per policy; `None` when closed + empty.
    pub fn next_batch(&self) -> Option<Vec<Pending>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.queue.is_empty() {
                if st.closed {
                    return None;
                }
                // Sleep until a submit or close notifies — no polling.
                st = self.cv.wait(st).expect("queue lock poisoned");
                continue;
            }
            // Non-empty: wait for fill-up, deadline, or close.
            let deadline = Instant::now() + self.policy.max_wait;
            while st.queue.len() < self.policy.max_batch && !st.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, timeout) = self
                    .cv
                    .wait_timeout(st, deadline - now)
                    .expect("queue lock poisoned");
                st = guard;
                if timeout.timed_out() {
                    break;
                }
            }
            let take = st.queue.len().min(self.policy.max_batch);
            let batch: Vec<Pending> = st.queue.drain(..take).collect();
            return Some(batch);
        }
    }

    pub fn queued(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn size_triggered_flush() {
        let q = BatchQueue::new(BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10) });
        let mut rxs = Vec::new();
        for i in 0..4u32 {
            rxs.push(q.submit(vec![i]));
        }
        let batch = q.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        for (i, p) in batch.into_iter().enumerate() {
            p.respond.send(PredVec::scalar(i as f64)).unwrap();
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap(), PredVec::scalar(i as f64));
        }
    }

    #[test]
    fn deadline_triggered_flush() {
        let q = BatchQueue::new(BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(5) });
        let _rx = q.submit(vec![1]);
        let t0 = Instant::now();
        let batch = q.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn close_unblocks_worker() {
        let q = BatchQueue::new(BatchPolicy::default());
        let q2 = q.clone();
        let h = thread::spawn(move || q2.next_batch().is_none());
        thread::sleep(Duration::from_millis(20));
        let t0 = Instant::now();
        q.close();
        assert!(h.join().unwrap());
        // The old two-lock design fell back to a 50 ms poll; the condvar
        // wakeup must be immediate.
        assert!(t0.elapsed() < Duration::from_millis(45), "close() did not wake the worker");
    }

    #[test]
    fn close_drains_queued_then_none() {
        let q = BatchQueue::new(BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10) });
        let _rxs: Vec<_> = (0..6u32).map(|i| q.submit(vec![i])).collect();
        q.close();
        // Already-queued work is still handed out (shutdown drains)...
        assert_eq!(q.next_batch().unwrap().len(), 4);
        assert_eq!(q.next_batch().unwrap().len(), 2);
        // ...then the queue reports exhaustion.
        assert!(q.next_batch().is_none());
    }

    #[test]
    fn submit_after_close_disconnects() {
        let q = BatchQueue::new(BatchPolicy::default());
        q.close();
        let rx = q.submit(vec![1]);
        assert!(rx.recv().is_err(), "post-close submit must disconnect, not hang");
        assert_eq!(q.queued(), 0);
    }

    #[test]
    fn submit_many_enqueues_in_order() {
        let q = BatchQueue::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_secs(10) });
        let rxs = q.submit_many((0..8u32).map(|i| vec![i]).collect());
        assert_eq!(rxs.len(), 8);
        let batch = q.next_batch().unwrap();
        assert_eq!(batch.len(), 8);
        for (i, p) in batch.iter().enumerate() {
            assert_eq!(p.ids, vec![i as u32]);
        }
        for (i, p) in batch.into_iter().enumerate() {
            p.respond.send(PredVec::scalar(i as f64 * 2.0)).unwrap();
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap(), PredVec::scalar(i as f64 * 2.0));
        }
    }

    /// Worker-pool shape: two consumers drain ONE queue concurrently;
    /// every submitted query is answered exactly once, and no batch is
    /// handed to both consumers.
    #[test]
    fn multi_consumer_drain_partitions_the_queue() {
        let q = BatchQueue::new(BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) });
        let total = 64u32;
        let mut workers = Vec::new();
        for _ in 0..2 {
            let q = q.clone();
            workers.push(thread::spawn(move || {
                let mut served: Vec<u32> = Vec::new();
                while let Some(batch) = q.next_batch() {
                    for p in batch {
                        let id = p.ids[0];
                        p.respond.send(PredVec::scalar(id as f64)).unwrap();
                        served.push(id);
                    }
                }
                served
            }));
        }
        let rxs: Vec<_> = (0..total).map(|i| q.submit(vec![i])).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap(), PredVec::scalar(i as f64), "query {i} misrouted");
        }
        q.close();
        let mut all: Vec<u32> = Vec::new();
        for w in workers {
            all.extend(w.join().unwrap());
        }
        all.sort_unstable();
        // Exactly-once: the union of both consumers' drains is the input.
        assert_eq!(all, (0..total).collect::<Vec<_>>());
    }

    /// A consumer blocked mid-wait must not starve a sibling: while one
    /// worker sits on a drained batch (slow model call), the other picks
    /// up the next flush.
    #[test]
    fn idle_sibling_takes_next_flush() {
        let q = BatchQueue::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) });
        let _first = q.submit(vec![1]);
        let _second = q.submit(vec![2]);
        // Consumer A drains the first flush and "stalls" holding it.
        let a = q.next_batch().unwrap();
        assert!(!a.is_empty());
        // New work arrives while A is stalled.
        let _third = q.submit(vec![3]);
        // Consumer B (this thread) gets it without waiting on A.
        let b = q.next_batch().unwrap();
        assert_eq!(b[0].ids, vec![3]);
    }

    #[test]
    fn concurrent_submitters() {
        let q = BatchQueue::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(3) });
        let mut handles = Vec::new();
        for i in 0..16u32 {
            let q = q.clone();
            handles.push(thread::spawn(move || {
                let rx = q.submit(vec![i]);
                rx.recv().unwrap().first()
            }));
        }
        // Drain in a worker: echo first id as the "prediction".
        let worker = {
            let q = q.clone();
            thread::spawn(move || {
                let mut served = 0;
                while served < 16 {
                    if let Some(batch) = q.next_batch() {
                        for p in batch {
                            let v = PredVec::scalar(p.ids[0] as f64);
                            p.respond.send(v).unwrap();
                            served += 1;
                        }
                    }
                }
            })
        };
        let mut got: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        worker.join().unwrap();
        got.sort_by(f64::total_cmp);
        assert_eq!(got, (0..16).map(|i| i as f64).collect::<Vec<_>>());
    }
}

//! Dynamic batcher: individual cost queries arrive asynchronously from
//! compiler threads; the batcher coalesces them into fixed-size predict
//! batches (size OR deadline triggered, vLLM-router style) so the model
//! executable amortizes per-call overhead.
//!
//! The queue and the closed flag live under ONE mutex: `close()` takes a
//! single lock and wakes waiters through the condvar immediately — there
//! is no second lock to check out-of-order and no fallback polling
//! interval. An idle worker sleeps on the condvar until a submit or a
//! close arrives.
//!
//! The queue is multi-consumer: a worker *pool* (`--workers-per-head`)
//! parks several threads on the same condvar, each `next_batch` call
//! drains up to `max_batch` requests under the lock, and whichever
//! worker wakes first takes the flush — so one slow model invocation
//! never head-of-line-blocks the next flush when a sibling is idle.
//!
//! The policy is **live**: `max_batch`/`max_wait` are atomics read at
//! every `next_batch` call, so a [`PolicyController`] (one per serving
//! variant, `--batch-policy adaptive`) can retune them from observed
//! flush sizes and execute latencies without taking the queue lock.
//! Tuning only ever changes *when* queries flush, never what a flush
//! computes — predictions are byte-identical under any policy.

use crate::pred::PredVec;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One queued query: encoded ids + a one-shot response channel. The
/// response is the full normalized characteristic vector from one
/// forward pass — a batch slot is occupied once per query, never once
/// per target.
pub struct Pending {
    pub ids: Vec<u32>,
    pub respond: Sender<PredVec>,
    /// When the query entered the queue — workers observe
    /// `submitted.elapsed()` (queue wait + execute) into the serving
    /// variant's latency EWMA at completion, so the estimate is
    /// per-request accurate no matter how callers collect results.
    pub submitted: Instant,
}

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Flush as soon as this many requests are queued.
    pub max_batch: usize,
    /// Flush a non-empty queue after this long regardless of size.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(2) }
    }
}

/// Everything the queue guards, under a single lock.
struct State {
    queue: Vec<Pending>,
    closed: bool,
}

/// Thread-safe queue with deadline-aware draining. The policy lives in
/// atomics (not under the state lock) so retuning never contends with
/// submitters or draining workers.
pub struct BatchQueue {
    state: Mutex<State>,
    cv: Condvar,
    max_batch: AtomicUsize,
    max_wait_us: AtomicU64,
}

impl BatchQueue {
    pub fn new(policy: BatchPolicy) -> Arc<Self> {
        Arc::new(BatchQueue {
            state: Mutex::new(State { queue: Vec::new(), closed: false }),
            cv: Condvar::new(),
            max_batch: AtomicUsize::new(policy.max_batch.max(1)),
            max_wait_us: AtomicU64::new(policy.max_wait.as_micros() as u64),
        })
    }

    /// Snapshot of the live policy (atomics, no lock).
    pub fn policy(&self) -> BatchPolicy {
        BatchPolicy {
            max_batch: self.max_batch.load(Ordering::Relaxed),
            max_wait: Duration::from_micros(self.max_wait_us.load(Ordering::Relaxed)),
        }
    }

    /// Replace the live policy. Takes effect on the next `next_batch`
    /// deadline computation; a worker already waiting on the old
    /// deadline finishes that wait under the old values.
    pub fn set_policy(&self, max_batch: usize, max_wait_us: u64) {
        self.max_batch.store(max_batch.max(1), Ordering::Relaxed);
        self.max_wait_us.store(max_wait_us, Ordering::Relaxed);
    }

    /// Enqueue a query; returns the receiver for its prediction. After
    /// `close()`, the sender is dropped immediately so the receiver sees a
    /// disconnect instead of blocking forever.
    pub fn submit(&self, ids: Vec<u32>) -> Receiver<PredVec> {
        let (tx, rx) = channel();
        {
            let mut st = self.state.lock().unwrap();
            if !st.closed {
                st.queue.push(Pending { ids, respond: tx, submitted: Instant::now() });
            }
        }
        self.cv.notify_one();
        rx
    }

    /// Enqueue many queries under one lock acquisition and one wakeup —
    /// the batch API's fast path. Receivers are returned in input order.
    pub fn submit_many(&self, batches: Vec<Vec<u32>>) -> Vec<Receiver<PredVec>> {
        let mut rxs = Vec::with_capacity(batches.len());
        {
            let mut st = self.state.lock().unwrap();
            let submitted = Instant::now();
            for ids in batches {
                let (tx, rx) = channel();
                if !st.closed {
                    st.queue.push(Pending { ids, respond: tx, submitted });
                }
                rxs.push(rx);
            }
        }
        self.cv.notify_all();
        rxs
    }

    /// Mark closed: one lock, and waiters wake immediately. A draining
    /// worker still sees already-queued requests (`next_batch` returns
    /// them) and then gets `None`.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }

    /// Block until a batch is ready per policy; `None` when closed + empty.
    pub fn next_batch(&self) -> Option<Vec<Pending>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.queue.is_empty() {
                if st.closed {
                    return None;
                }
                // Sleep until a submit or close notifies — no polling.
                st = self.cv.wait(st).expect("queue lock poisoned");
                continue;
            }
            // Non-empty: wait for fill-up, deadline, or close. The
            // policy is re-read per flush so a controller retune
            // applies from the very next drain.
            let max_batch = self.max_batch.load(Ordering::Relaxed).max(1);
            let max_wait = Duration::from_micros(self.max_wait_us.load(Ordering::Relaxed));
            let deadline = Instant::now() + max_wait;
            while st.queue.len() < max_batch && !st.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, timeout) = self
                    .cv
                    .wait_timeout(st, deadline - now)
                    .expect("queue lock poisoned");
                st = guard;
                if timeout.timed_out() {
                    break;
                }
            }
            let take = st.queue.len().min(max_batch);
            let batch: Vec<Pending> = st.queue.drain(..take).collect();
            return Some(batch);
        }
    }

    pub fn queued(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }
}

/// Flushes observed between policy adjustments. Small enough to react
/// within seconds under load, large enough that one odd flush cannot
/// whipsaw the policy.
const RETUNE_WINDOW: u64 = 32;
/// A window whose mean flush fills at least this fraction of
/// `max_batch` is saturated: arrivals are being truncated by the cap,
/// so raising it can amortize more queries per invocation.
const GROW_FILL: f64 = 0.9;
/// A window whose mean flush fills at most this fraction is oversized:
/// demand never approaches the cap, so shrink toward it.
const SHRINK_FILL: f64 = 0.25;

/// Hard limits the adaptive controller may never leave, derived from
/// the operator's startup policy: `max_batch` is an upper bound (it is
/// also the top rung of the compiled predict ladder — a larger flush
/// could not execute), and `max_wait` is a latency ceiling the
/// controller may only tighten (down to 1/8th).
#[derive(Debug, Clone)]
pub struct PolicyBounds {
    pub min_batch: usize,
    pub max_batch: usize,
    pub min_wait_us: u64,
    pub max_wait_us: u64,
}

impl PolicyBounds {
    pub fn from_startup(policy: &BatchPolicy) -> PolicyBounds {
        let wait_hi = (policy.max_wait.as_micros() as u64).max(1);
        PolicyBounds {
            min_batch: 1,
            max_batch: policy.max_batch.max(1),
            min_wait_us: (wait_hi / 8).max(1),
            max_wait_us: wait_hi,
        }
    }

    fn clamp(&self, max_batch: usize, wait_us: u64) -> (usize, u64) {
        (
            max_batch.clamp(self.min_batch, self.max_batch),
            wait_us.clamp(self.min_wait_us, self.max_wait_us),
        )
    }
}

/// Per-window accumulation of flush observations.
#[derive(Default)]
struct Window {
    flushes: u64,
    queries: u64,
    exec_us: u64,
}

/// Per-variant adaptive batch-policy controller (`--batch-policy
/// adaptive`). Workers feed it one observation per executed flush
/// (size + execute latency); every [`RETUNE_WINDOW`] flushes it
/// hill-climbs the owning queue's live policy within [`PolicyBounds`]:
///
/// - saturated windows (mean fill ≥ 90% of the cap) double `max_batch`,
///   starved windows (≤ 25%) halve it — so the cap converges onto the
///   observed demand instead of the operator's static guess;
/// - `max_wait` tracks the window's mean execute latency (clamped to
///   bounds): waiting much longer than one invocation costs latency
///   without buying amortization, waiting much less under-batches.
///
/// With `adaptive == false` (the default `--batch-policy static`) the
/// controller is inert: observations are dropped and `retunes` stays 0.
pub struct PolicyController {
    queue: Arc<BatchQueue>,
    bounds: PolicyBounds,
    adaptive: bool,
    window: Mutex<Window>,
    retunes: AtomicU64,
}

impl PolicyController {
    pub fn new(queue: Arc<BatchQueue>, adaptive: bool) -> Arc<PolicyController> {
        let bounds = PolicyBounds::from_startup(&queue.policy());
        Arc::new(PolicyController {
            queue,
            bounds,
            adaptive,
            window: Mutex::new(Window::default()),
            retunes: AtomicU64::new(0),
        })
    }

    /// Applied policy changes so far (the `policy_retunes` stat).
    pub fn retunes(&self) -> u64 {
        self.retunes.load(Ordering::Relaxed)
    }

    pub fn bounds(&self) -> &PolicyBounds {
        &self.bounds
    }

    /// Warm-start the live policy from a variants-manifest `policy`
    /// entry, clamped to bounds (a manifest may not widen the
    /// operator's startup ceiling). Counts as a retune only if it
    /// changes anything.
    pub fn warm_start(&self, max_batch: Option<usize>, max_wait_us: Option<u64>) {
        let current = self.queue.policy();
        let (b, w) = self.bounds.clamp(
            max_batch.unwrap_or(current.max_batch),
            max_wait_us.unwrap_or(current.max_wait.as_micros() as u64),
        );
        self.apply(current, b, w);
    }

    /// One executed flush: `flush_len` queries ran in one model
    /// invocation taking `exec_us`. Called worker-side per chunk, off
    /// the IO threads.
    pub fn observe_flush(&self, flush_len: usize, exec_us: u64) {
        if !self.adaptive {
            return;
        }
        let (mean_fill, mean_exec_us) = {
            let mut w = self.window.lock().unwrap();
            w.flushes += 1;
            w.queries += flush_len as u64;
            w.exec_us += exec_us;
            if w.flushes < RETUNE_WINDOW {
                return;
            }
            let fill = w.queries as f64 / w.flushes as f64;
            let exec = w.exec_us / w.flushes;
            *w = Window::default();
            (fill, exec)
        };
        let current = self.queue.policy();
        let mut next_batch = current.max_batch;
        if mean_fill >= GROW_FILL * current.max_batch as f64 {
            next_batch = current.max_batch.saturating_mul(2);
        } else if mean_fill <= SHRINK_FILL * current.max_batch as f64 {
            next_batch = (current.max_batch / 2).max(1);
        }
        let (b, w) = self.bounds.clamp(next_batch, mean_exec_us);
        self.apply(current, b, w);
    }

    fn apply(&self, current: BatchPolicy, max_batch: usize, max_wait_us: u64) {
        if max_batch == current.max_batch
            && max_wait_us == current.max_wait.as_micros() as u64
        {
            return;
        }
        self.queue.set_policy(max_batch, max_wait_us);
        self.retunes.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn size_triggered_flush() {
        let q = BatchQueue::new(BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10) });
        let mut rxs = Vec::new();
        for i in 0..4u32 {
            rxs.push(q.submit(vec![i]));
        }
        let batch = q.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        for (i, p) in batch.into_iter().enumerate() {
            p.respond.send(PredVec::scalar(i as f64)).unwrap();
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap(), PredVec::scalar(i as f64));
        }
    }

    #[test]
    fn deadline_triggered_flush() {
        let q = BatchQueue::new(BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(5) });
        let _rx = q.submit(vec![1]);
        let t0 = Instant::now();
        let batch = q.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn close_unblocks_worker() {
        let q = BatchQueue::new(BatchPolicy::default());
        let q2 = q.clone();
        let h = thread::spawn(move || q2.next_batch().is_none());
        thread::sleep(Duration::from_millis(20));
        let t0 = Instant::now();
        q.close();
        assert!(h.join().unwrap());
        // The old two-lock design fell back to a 50 ms poll; the condvar
        // wakeup must be immediate.
        assert!(t0.elapsed() < Duration::from_millis(45), "close() did not wake the worker");
    }

    #[test]
    fn close_drains_queued_then_none() {
        let q = BatchQueue::new(BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10) });
        let _rxs: Vec<_> = (0..6u32).map(|i| q.submit(vec![i])).collect();
        q.close();
        // Already-queued work is still handed out (shutdown drains)...
        assert_eq!(q.next_batch().unwrap().len(), 4);
        assert_eq!(q.next_batch().unwrap().len(), 2);
        // ...then the queue reports exhaustion.
        assert!(q.next_batch().is_none());
    }

    #[test]
    fn submit_after_close_disconnects() {
        let q = BatchQueue::new(BatchPolicy::default());
        q.close();
        let rx = q.submit(vec![1]);
        assert!(rx.recv().is_err(), "post-close submit must disconnect, not hang");
        assert_eq!(q.queued(), 0);
    }

    #[test]
    fn submit_many_enqueues_in_order() {
        let q = BatchQueue::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_secs(10) });
        let rxs = q.submit_many((0..8u32).map(|i| vec![i]).collect());
        assert_eq!(rxs.len(), 8);
        let batch = q.next_batch().unwrap();
        assert_eq!(batch.len(), 8);
        for (i, p) in batch.iter().enumerate() {
            assert_eq!(p.ids, vec![i as u32]);
        }
        for (i, p) in batch.into_iter().enumerate() {
            p.respond.send(PredVec::scalar(i as f64 * 2.0)).unwrap();
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap(), PredVec::scalar(i as f64 * 2.0));
        }
    }

    /// Worker-pool shape: two consumers drain ONE queue concurrently;
    /// every submitted query is answered exactly once, and no batch is
    /// handed to both consumers.
    #[test]
    fn multi_consumer_drain_partitions_the_queue() {
        let q = BatchQueue::new(BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) });
        let total = 64u32;
        let mut workers = Vec::new();
        for _ in 0..2 {
            let q = q.clone();
            workers.push(thread::spawn(move || {
                let mut served: Vec<u32> = Vec::new();
                while let Some(batch) = q.next_batch() {
                    for p in batch {
                        let id = p.ids[0];
                        p.respond.send(PredVec::scalar(id as f64)).unwrap();
                        served.push(id);
                    }
                }
                served
            }));
        }
        let rxs: Vec<_> = (0..total).map(|i| q.submit(vec![i])).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap(), PredVec::scalar(i as f64), "query {i} misrouted");
        }
        q.close();
        let mut all: Vec<u32> = Vec::new();
        for w in workers {
            all.extend(w.join().unwrap());
        }
        all.sort_unstable();
        // Exactly-once: the union of both consumers' drains is the input.
        assert_eq!(all, (0..total).collect::<Vec<_>>());
    }

    /// A consumer blocked mid-wait must not starve a sibling: while one
    /// worker sits on a drained batch (slow model call), the other picks
    /// up the next flush.
    #[test]
    fn idle_sibling_takes_next_flush() {
        let q = BatchQueue::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) });
        let _first = q.submit(vec![1]);
        let _second = q.submit(vec![2]);
        // Consumer A drains the first flush and "stalls" holding it.
        let a = q.next_batch().unwrap();
        assert!(!a.is_empty());
        // New work arrives while A is stalled.
        let _third = q.submit(vec![3]);
        // Consumer B (this thread) gets it without waiting on A.
        let b = q.next_batch().unwrap();
        assert_eq!(b[0].ids, vec![3]);
    }

    #[test]
    fn concurrent_submitters() {
        let q = BatchQueue::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(3) });
        let mut handles = Vec::new();
        for i in 0..16u32 {
            let q = q.clone();
            handles.push(thread::spawn(move || {
                let rx = q.submit(vec![i]);
                rx.recv().unwrap().first()
            }));
        }
        // Drain in a worker: echo first id as the "prediction".
        let worker = {
            let q = q.clone();
            thread::spawn(move || {
                let mut served = 0;
                while served < 16 {
                    if let Some(batch) = q.next_batch() {
                        for p in batch {
                            let v = PredVec::scalar(p.ids[0] as f64);
                            p.respond.send(v).unwrap();
                            served += 1;
                        }
                    }
                }
            })
        };
        let mut got: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        worker.join().unwrap();
        got.sort_by(f64::total_cmp);
        assert_eq!(got, (0..16).map(|i| i as f64).collect::<Vec<_>>());
    }

    #[test]
    fn set_policy_applies_to_next_flush() {
        let q = BatchQueue::new(BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10) });
        let _rxs: Vec<_> = (0..6u32).map(|i| q.submit(vec![i])).collect();
        assert_eq!(q.next_batch().unwrap().len(), 4);
        q.set_policy(1, 10_000_000);
        // The retuned cap applies to the very next drain.
        assert_eq!(q.next_batch().unwrap().len(), 1);
        assert_eq!(q.policy().max_batch, 1);
        assert_eq!(q.policy().max_wait, Duration::from_secs(10));
    }

    /// Feed a controller one synthetic window: `demand` queries are
    /// available per flush (flush size = min(demand, live max_batch)),
    /// and executing a flush of size `b` takes `exec_us(b)`. Returns
    /// the live `max_batch` after the window retunes.
    fn drive_window(
        ctl: &PolicyController,
        q: &BatchQueue,
        demand: usize,
        exec_us: impl Fn(usize) -> u64,
    ) -> usize {
        for _ in 0..RETUNE_WINDOW {
            let b = demand.min(q.policy().max_batch);
            ctl.observe_flush(b, exec_us(b));
        }
        q.policy().max_batch
    }

    /// Satellite regression: on a synthetic latency table the adaptive
    /// controller converges MONOTONICALLY (no oscillation) to a fixed
    /// point, and never leaves the configured bounds.
    #[test]
    fn adaptive_policy_converges_monotonically_within_bounds() {
        // Synthetic table: executing batch b costs 100 + 10*b us.
        let exec = |b: usize| 100 + 10 * b as u64;

        // Saturated demand (100 queries always waiting), cap starts at
        // 8 with a 2000us ceiling: max_batch must climb monotonically
        // 8 → 16 → 32 → 64 → 128 and stop (0.9*128 > 100 > 0.25*128).
        let q = BatchQueue::new(BatchPolicy { max_batch: 128, max_wait: Duration::from_millis(2) });
        let ctl = PolicyController::new(q.clone(), true); // bounds from startup policy
        q.set_policy(8, 2000);
        let mut trajectory = vec![q.policy().max_batch];
        for _ in 0..8 {
            trajectory.push(drive_window(&ctl, &q, 100, exec));
        }
        assert!(
            trajectory.windows(2).all(|w| w[0] <= w[1]),
            "growth must be monotone: {trajectory:?}"
        );
        assert_eq!(*trajectory.last().unwrap(), 128, "fixed point: {trajectory:?}");
        assert_eq!(trajectory[4], 128, "converged within 4 windows: {trajectory:?}");
        assert!(ctl.retunes() >= 4);
        // max_wait tracks mean execute latency for the converged batch
        // (100 + 10*100 = 1100us), inside [250, 2000].
        let wait_us = q.policy().max_wait.as_micros() as u64;
        assert!((250..=2000).contains(&wait_us), "wait {wait_us} left bounds");

        // Starved demand (2 queries per flush), cap starts at the 128
        // ceiling: max_batch halves monotonically until the fill ratio
        // leaves the shrink band (2 <= 0.25*8 still shrinks; at cap 4
        // the window's 2-query flushes sit between the bands), so the
        // fixed point is 4 — never dipping below the floor of 1.
        let q2 =
            BatchQueue::new(BatchPolicy { max_batch: 128, max_wait: Duration::from_millis(2) });
        let ctl2 = PolicyController::new(q2.clone(), true);
        let mut shrink = vec![q2.policy().max_batch];
        for _ in 0..10 {
            shrink.push(drive_window(&ctl2, &q2, 2, exec));
        }
        assert!(
            shrink.windows(2).all(|w| w[0] >= w[1]),
            "shrink must be monotone: {shrink:?}"
        );
        assert_eq!(*shrink.last().unwrap(), 4, "fixed point: {shrink:?}");
        assert!(shrink.iter().all(|&b| (1..=128).contains(&b)), "left bounds: {shrink:?}");
    }

    #[test]
    fn static_controller_is_inert() {
        let q = BatchQueue::new(BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(2) });
        let ctl = PolicyController::new(q.clone(), false);
        for _ in 0..10 * RETUNE_WINDOW {
            ctl.observe_flush(32, 50_000);
        }
        assert_eq!(ctl.retunes(), 0);
        assert_eq!(q.policy().max_batch, 32);
        assert_eq!(q.policy().max_wait, Duration::from_millis(2));
    }

    #[test]
    fn warm_start_clamps_to_startup_bounds() {
        let q =
            BatchQueue::new(BatchPolicy { max_batch: 32, max_wait: Duration::from_micros(2000) });
        let ctl = PolicyController::new(q.clone(), true);
        // A manifest may tighten the policy...
        ctl.warm_start(Some(8), Some(500));
        assert_eq!(q.policy().max_batch, 8);
        assert_eq!(q.policy().max_wait, Duration::from_micros(500));
        assert_eq!(ctl.retunes(), 1);
        // ...but never widen past the operator's startup ceiling (the
        // compiled ladder tops out at the startup max_batch).
        ctl.warm_start(Some(4096), Some(90_000));
        assert_eq!(q.policy().max_batch, 32);
        assert_eq!(q.policy().max_wait, Duration::from_micros(2000));
        // Partial warm-start leaves the other knob alone.
        ctl.warm_start(Some(16), None);
        assert_eq!(q.policy().max_batch, 16);
        assert_eq!(q.policy().max_wait, Duration::from_micros(2000));
        // A no-op warm start is not a retune.
        let before = ctl.retunes();
        ctl.warm_start(Some(16), None);
        assert_eq!(ctl.retunes(), before);
    }
}

//! TCP front end: newline-delimited JSON, one request per line.
//!
//! Request:  {"id": 7, "target": "regpressure", "mlir": "func.func @f..."}
//!           {"id": 10, "target": "regpressure", "mlir_batch": ["func.func @a...", "func.func @b..."]}
//!           {"id": 8, "cmd": "stats"}
//!           {"id": 9, "cmd": "ping"}
//! Response: {"id": 7, "ok": true, "prediction": 27.4, "us": 812}
//!           {"id": 10, "ok": true, "predictions": [{"ok": true, "prediction": 27.4},
//!                                                  {"ok": false, "error": "..."}], "us": 930}
//!           {"id": 8, "ok": true, "stats": {...}}
//!           {"id": 7, "ok": false, "error": "..."}
//!
//! `mlir_batch` is the batch API: the whole array travels the
//! parse→cache→batcher pipeline in one `Service::predict_many` call (all
//! cache misses enter the batch queue together), and per-entry failures
//! come back in-position without failing the rest. The `stats` command
//! returns the merged service + cache view, including `coalesced_queries`
//! (single-flight), `cache_shard_contention`, `batch_fill_ratio`,
//! `padded_slots`, and the front-end counters `frontend_memo_hits` /
//! `encode_ns` / `frontend_memo_entries`.
//!
//! A DL-compiler links a 30-line client (see `examples/`) and calls this
//! from its pass pipeline. Threads, not tokio: no async runtime is
//! vendored in this image, and one thread per compiler connection is the
//! right shape for this workload anyway (few long-lived clients).

use super::Service;
use crate::json::{parse, Json};
use crate::sim::Target;
use anyhow::{anyhow, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Serve until `stop` flips (or forever).
pub fn serve(service: Arc<Service>, addr: &str, stop: Arc<AtomicBool>) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    serve_on(service, listener, stop)
}

/// Serve on an already-bound listener (lets tests bind port 0).
pub fn serve_on(service: Arc<Service>, listener: TcpListener, stop: Arc<AtomicBool>) -> Result<()> {
    listener.set_nonblocking(true)?;
    eprintln!("[server] cost-model service listening on {}", listener.local_addr()?);
    let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        // Reap finished connection threads every iteration — a long-lived
        // server must not accumulate one JoinHandle per connection ever
        // accepted until shutdown.
        let mut i = 0;
        while i < handles.len() {
            if handles[i].is_finished() {
                let _ = handles.swap_remove(i).join();
            } else {
                i += 1;
            }
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                eprintln!("[server] compiler connected from {peer}");
                let svc = service.clone();
                let stop = stop.clone();
                handles.push(std::thread::spawn(move || {
                    if let Err(e) = handle_conn(svc, stream, stop) {
                        eprintln!("[server] connection ended: {e:#}");
                    }
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

fn handle_conn(service: Arc<Service>, stream: TcpStream, stop: Arc<AtomicBool>) -> Result<()> {
    // Read with a timeout so shutdown can interrupt an idle connection.
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    // Responses stream into a per-connection BufWriter (one syscall per
    // reply on flush, no per-reply String); the request line buffer is
    // reused across the connection's lifetime.
    let mut writer = BufWriter::new(stream.try_clone()?);
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {
                if line.trim().is_empty() {
                    continue;
                }
                let response = handle_line(&service, &line);
                response.write_to(&mut writer)?;
                writer.write_all(b"\n")?;
                writer.flush()?;
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if stop.load(Ordering::Relaxed) {
                    return Ok(());
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// Process one request line (exposed for tests + in-process clients).
pub fn handle_line(service: &Service, line: &str) -> Json {
    let t0 = Instant::now();
    let req = match parse(line) {
        Ok(r) => r,
        Err(e) => {
            return Json::obj()
                .with("ok", Json::Bool(false))
                .with("error", Json::str(format!("bad json: {e}")))
        }
    };
    let id = req.get("id").cloned().unwrap_or(Json::Null);
    let fail = |msg: String| {
        Json::obj()
            .with("id", id.clone())
            .with("ok", Json::Bool(false))
            .with("error", Json::str(msg))
    };
    if let Some(cmd) = req.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "ping" => Json::obj()
                .with("id", id.clone())
                .with("ok", Json::Bool(true))
                .with("pong", Json::Bool(true)),
            "stats" => Json::obj()
                .with("id", id.clone())
                .with("ok", Json::Bool(true))
                .with("stats", service.stats_json()),
            "targets" => Json::obj().with("id", id.clone()).with("ok", Json::Bool(true)).with(
                "targets",
                Json::Arr(
                    service.targets().iter().map(|t| Json::str(t.name())).collect(),
                ),
            ),
            other => fail(format!("unknown cmd '{other}'")),
        };
    }
    let target = match req.req_str("target").ok().and_then(Target::parse) {
        Some(t) => t,
        None => return fail("missing/invalid 'target'".into()),
    };
    // Batch request: an array of MLIR texts through predict_many.
    if let Some(batch) = req.get("mlir_batch") {
        let Some(items) = batch.as_arr() else {
            return fail("'mlir_batch' must be an array of strings".into());
        };
        let mut texts: Vec<&str> = Vec::with_capacity(items.len());
        for item in items {
            match item.as_str() {
                Some(s) => texts.push(s),
                None => return fail("'mlir_batch' entries must be strings".into()),
            }
        }
        let results = service.predict_many(target, &texts);
        let predictions: Vec<Json> = results
            .into_iter()
            .map(|r| match r {
                Ok(v) => Json::obj()
                    .with("ok", Json::Bool(true))
                    .with("prediction", Json::num(v)),
                Err(e) => Json::obj()
                    .with("ok", Json::Bool(false))
                    .with("error", Json::str(format!("{e:#}"))),
            })
            .collect();
        return Json::obj()
            .with("id", id)
            .with("ok", Json::Bool(true))
            .with("predictions", Json::Arr(predictions))
            .with("us", Json::num(t0.elapsed().as_micros() as f64));
    }
    let mlir = match req.req_str("mlir") {
        Ok(m) => m,
        Err(e) => return fail(e.to_string()),
    };
    match service.predict(target, mlir) {
        Ok(v) => Json::obj()
            .with("id", id)
            .with("ok", Json::Bool(true))
            .with("prediction", Json::num(v))
            .with("us", Json::num(t0.elapsed().as_micros() as f64)),
        Err(e) => fail(format!("{e:#}")),
    }
}

/// Minimal blocking client for the line protocol (used by examples and
/// the serving bench).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
            next_id: 1,
        })
    }

    fn next_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    fn roundtrip(&mut self, req: Json) -> Result<Json> {
        req.write_to(&mut self.writer)?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let resp = parse(&line)?;
        if resp.get("ok").and_then(Json::as_bool) != Some(true) {
            anyhow::bail!(
                "server error: {}",
                resp.get("error").and_then(Json::as_str).unwrap_or("unknown")
            );
        }
        Ok(resp)
    }

    /// Query a prediction.
    pub fn predict(&mut self, target: Target, mlir: &str) -> Result<f64> {
        let id = self.next_id();
        let req = Json::obj()
            .with("id", Json::num(id as f64))
            .with("target", Json::str(target.name()))
            .with("mlir", Json::str(mlir));
        let resp = self.roundtrip(req)?;
        resp.req_f64("prediction")
    }

    /// Query many predictions in one protocol round trip (`mlir_batch`).
    /// Per-entry results mirror `Service::predict_many`.
    pub fn predict_many(&mut self, target: Target, mlirs: &[&str]) -> Result<Vec<Result<f64>>> {
        let id = self.next_id();
        let req = Json::obj()
            .with("id", Json::num(id as f64))
            .with("target", Json::str(target.name()))
            .with(
                "mlir_batch",
                Json::Arr(mlirs.iter().map(|m| Json::str(*m)).collect()),
            );
        let resp = self.roundtrip(req)?;
        let arr = resp.req_arr("predictions")?;
        Ok(arr
            .iter()
            .map(|p| {
                if p.get("ok").and_then(Json::as_bool) == Some(true) {
                    p.req_f64("prediction")
                } else {
                    Err(anyhow!(
                        "{}",
                        p.get("error").and_then(Json::as_str).unwrap_or("unknown error")
                    ))
                }
            })
            .collect())
    }

    /// Fetch server stats.
    pub fn stats(&mut self) -> Result<Json> {
        let id = self.next_id();
        let req = Json::obj()
            .with("id", Json::num(id as f64))
            .with("cmd", Json::str("stats"));
        Ok(self.roundtrip(req)?.req("stats")?.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bundle::Bundle;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::dataset::TargetStats;
    use crate::graphgen::{generate, Family, GraphSpec};
    use crate::mlir::print_function;
    use crate::runtime::Manifest;
    use crate::tokenizer::{Scheme, Vocab};
    use std::path::Path;

    fn service() -> Option<Arc<Service>> {
        let adir = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("artifacts");
        if !adir.join("manifest.json").exists() {
            return None;
        }
        let manifest = Arc::new(Manifest::load(&adir).unwrap());
        let vocab = Vocab::build(vec![vec!["x".to_string()]].iter(), 1);
        let stats = TargetStats { mean: 0.0, std: 1.0, min: 0.0, max: 10.0 };
        let bundle =
            Bundle::untrained(&manifest, "fc_ops", Target::RegPressure, Scheme::OpsOnly, vocab, stats)
                .unwrap();
        Some(Arc::new(
            Service::start(manifest, vec![bundle], BatchPolicy::default(), false).unwrap(),
        ))
    }

    fn graph(structure_seed: u64, shape_seed: u64) -> String {
        let spec = GraphSpec { family: Family::Mlp, structure_seed, shape_seed };
        print_function(&generate(&spec).unwrap())
    }

    #[test]
    fn line_protocol_handles_commands() {
        let Some(svc) = service() else { return };
        let pong = handle_line(&svc, r#"{"id": 1, "cmd": "ping"}"#);
        assert_eq!(pong.get("pong").and_then(Json::as_bool), Some(true));
        let stats = handle_line(&svc, r#"{"id": 2, "cmd": "stats"}"#);
        assert!(stats.get("stats").is_some());
        // The merged stats view carries the new pipeline counters.
        let inner = stats.get("stats").unwrap();
        assert!(inner.get("coalesced_queries").is_some());
        assert!(inner.get("cache_shard_contention").is_some());
        assert!(inner.get("batch_fill_ratio").is_some());
        assert!(inner.get("padded_slots").is_some());
        assert!(inner.get("frontend_memo_hits").is_some());
        assert!(inner.get("encode_ns").is_some());
        assert!(inner.get("frontend_memo_entries").is_some());
        let targets = handle_line(&svc, r#"{"id": 3, "cmd": "targets"}"#);
        assert_eq!(targets.req_arr("targets").unwrap().len(), 1);
        let bad = handle_line(&svc, "{nope");
        assert_eq!(bad.get("ok").and_then(Json::as_bool), Some(false));
        let missing = handle_line(&svc, r#"{"id": 4}"#);
        assert_eq!(missing.get("ok").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn batch_request_over_handle_line() {
        let Some(svc) = service() else { return };
        let text = graph(21, 22);
        let req = Json::obj()
            .with("id", Json::num(5.0))
            .with("target", Json::str("regpressure"))
            .with(
                "mlir_batch",
                Json::Arr(vec![
                    Json::str(text.as_str()),
                    Json::str("not mlir"),
                    Json::str(text.as_str()),
                ]),
            );
        let resp = handle_line(&svc, &req.to_string());
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        let preds = resp.req_arr("predictions").unwrap();
        assert_eq!(preds.len(), 3);
        assert_eq!(preds[0].get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(preds[1].get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(preds[2].get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(
            preds[0].req_f64("prediction").unwrap(),
            preds[2].req_f64("prediction").unwrap()
        );
        // Malformed shapes of the batch field fail whole-request.
        let bad =
            handle_line(&svc, r#"{"id": 6, "target": "regpressure", "mlir_batch": "nope"}"#);
        assert_eq!(bad.get("ok").and_then(Json::as_bool), Some(false));
        let bad2 =
            handle_line(&svc, r#"{"id": 7, "target": "regpressure", "mlir_batch": [1, 2]}"#);
        assert_eq!(bad2.get("ok").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn tcp_roundtrip_with_client() {
        let Some(svc) = service() else { return };
        let stop = Arc::new(AtomicBool::new(false));
        // Bind port 0: no collisions with other test runs.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = {
            let svc = svc.clone();
            let stop = stop.clone();
            std::thread::spawn(move || serve_on(svc, listener, stop))
        };
        std::thread::sleep(std::time::Duration::from_millis(100));
        let mut client = Client::connect(&addr).unwrap();
        let text = graph(3, 4);
        let v = client.predict(Target::RegPressure, &text).unwrap();
        assert!(v.is_finite());
        // Batch request over the wire: mixed valid/invalid entries.
        let text2 = graph(5, 6);
        let many = client
            .predict_many(Target::RegPressure, &[text.as_str(), "not mlir", text2.as_str()])
            .unwrap();
        assert_eq!(many.len(), 3);
        assert_eq!(many[0].as_ref().unwrap(), &v, "cached value must match");
        assert!(many[1].is_err());
        assert!(many[2].as_ref().unwrap().is_finite());
        let stats = client.stats().unwrap();
        assert!(stats.req_f64("requests").unwrap() >= 4.0);
        assert!(stats.req_f64("batch_requests").unwrap() >= 1.0);
        stop.store(true, Ordering::Relaxed);
        let _ = server.join();
    }
}

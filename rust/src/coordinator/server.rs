//! TCP front end: newline-delimited JSON, one request per line. The
//! authoritative field-by-field reference (every command, every
//! response shape, every error form) is `docs/protocol.md`, pinned by
//! the doc-example test in `tests/protocol_docs.rs`.
//!
//! Request:  {"id": 7, "target": "regpressure", "mlir": "func.func @f..."}
//!           {"id": 7, "target": "regpressure", "mlir": "...", "budget_us": 500}
//!           {"id": 7, "target": "cycles", "mlir": "...", "targets": ["cycles", "xpuutil"]}
//!           {"id": 10, "target": "regpressure", "mlir_batch": ["func.func @a...", "func.func @b..."]}
//!           {"id": 8, "cmd": "stats"}
//!           {"id": 9, "cmd": "ping"}
//!           {"id": 11, "cmd": "cache_get", "key": "00f3a9..."}
//!           {"id": 12, "cmd": "cache_put", "key": "00f3a9...", "value": [27.4, 61.0]}
//!           {"id": 13, "cmd": "session_open", "target": "regpressure", "mlir": "func.func @f..."}
//!           {"id": 14, "cmd": "mlir_delta", "session": 1, "splices": [{"start": 120, "end": 138, "text": "..."}]}
//!           {"id": 15, "cmd": "mlir_delta", "session": 1, "mlir": "func.func @f...", "rebase": true}
//!           {"id": 16, "cmd": "session_close", "session": 1}
//!           {"id": 17, "cmd": "metrics"}
//!           {"id": 18, "target": "regpressure", "mlir": "...", "tenant": "tuner-a"}
//! Response: {"id": 7, "ok": true, "prediction": 27.4, "predictions": {"regpressure": 27.4},
//!            "variant": "fc_ops", "us": 812}
//!           {"id": 10, "ok": true, "predictions": [{"ok": true, "prediction": 27.4,
//!                                                   "predictions": {"regpressure": 27.4},
//!                                                   "variant": "fc_ops"},
//!                                                  {"ok": false, "error": "..."}], "us": 930}
//!           {"id": 8, "ok": true, "stats": {...}}
//!           {"id": 11, "ok": true, "found": true, "value": [27.4, 61.0]}   (or "found": false)
//!           {"id": 12, "ok": true, "stored": true}
//!           {"id": 13, "ok": true, "session": 1, "token_len": 42, "prediction": 27.4, ...}
//!           {"id": 14, "ok": true, "prediction": 28.1, "spans_spliced": 11, "spans_reencoded": 1, ...}
//!           {"id": 16, "ok": true, "closed": true}
//!           {"id": 7, "ok": false, "error": "..."}
//!
//! `session_open` / `mlir_delta` / `session_close` are the incremental
//! tier (`super::session`): an autotuner registers a base text once,
//! then sends only what changed — explicit byte-range `splices` into
//! the base, or the full text for the server to line-diff — and the
//! tokenizer re-lexes only the changed lines, splicing every unchanged
//! line's cached id-span (byte-identical to a full re-encode; watch
//! `spans_spliced` / `spans_reencoded` / `delta_bytes_rescanned` in the
//! stats). `"rebase": true` promotes a delta's result to the session's
//! new base; otherwise deltas keep addressing the registered text.
//!
//! `mlir` / `mlir_batch` requests route through the serving tier's
//! variant router: each query's token length picks the cheapest
//! registered model variant that covers it, the optional `budget_us`
//! field downgrades to a smaller/faster variant when the preferred
//! one's latency estimate would blow the budget (see
//! `super::router`), and the response's `variant` field names the
//! variant that served each prediction. A query longer than every
//! registered variant fails with a per-entry error (and increments
//! `no_covering_variant` in the stats).
//!
//! Predictions are multi-output: one forward pass yields every
//! characteristic the serving variant's bundle declares, returned as
//! the `predictions` object (characteristic name → value). The scalar
//! `prediction` field stays — it carries the bundle's PRIMARY (first
//! declared) characteristic, so pre-multi-output clients keep working
//! unchanged. The optional request field `targets` lists the
//! characteristics the caller requires; a variant that does not serve
//! all of them is skipped by routing, and when none qualifies the
//! request fails with a clean `targets_not_served` error (counted in
//! the stats) — never a silent partial answer.
//!
//! `cache_get` / `cache_put` are the cluster tier's peer-to-peer
//! commands (`crate::cluster`): a node that does not own a cache key
//! probes the owner with `cache_get` before computing, and writes a
//! value it had to compute back to the owner with `cache_put`. Keys are
//! 16-digit hex strings ([`super::cache::key_to_wire`]) because JSON numbers
//! lose u64 precision. Values are JSON arrays (the full characteristic
//! vector); a bare number is still accepted on read as the pre-vector
//! wire form, so mixed-version clusters interoperate. Both commands are pure local-cache operations —
//! they never forward again and never invoke the model, so a `cache_get`
//! storm from peers costs hash probes, not PJRT calls (and peer chains
//! cannot recurse or deadlock).
//!
//! `mlir_batch` is the batch API: the whole array travels the
//! parse→cache→batcher pipeline in one `Service::predict_many` call (all
//! cache misses enter the batch queue together), and per-entry failures
//! come back in-position without failing the rest. The `stats` command
//! returns the merged service + cache view, including the serving-plane
//! counters `active_connections` / `connections_accepted` /
//! `epoll_wakeups` / `exec_by_batch` next to the pipeline counters from
//! earlier PRs (`coalesced_queries`, `batch_fill_ratio`, `padded_slots`,
//! `frontend_memo_hits`, ...).
//!
//! A DL-compiler links a 30-line client (see `examples/`) and calls this
//! from its pass pipeline. The front end is a readiness-driven event
//! loop over the vendored [`minipoll`] epoll bindings — still no tokio,
//! but no longer a thread per connection either: one (or `--io-threads
//! N`) event-loop thread(s) own every connection as a nonblocking socket
//! with per-connection read/write buffers. Partial request lines are
//! reassembled across TCP segments by construction (bytes accumulate in
//! the connection's read buffer until a `\n` arrives), short writes park
//! the remainder in the write buffer and re-arm `EPOLLOUT`, and shutdown
//! is an eventfd doorbell — no accept polling, no read timeouts, idle
//! connections cost zero CPU. An autotuning fleet can hold hundreds of
//! mostly-idle probe connections open for the price of their buffers.
//!
//! Within one wakeup, buffered request lines are answered by a
//! round-robin scheduler with a per-connection line budget
//! (`FAIR_LINE_BUDGET`): a client pipelining thousands of requests in
//! one burst takes a budgeted turn like everyone else instead of
//! monopolizing the IO thread until its backlog drains — interactive
//! connections interleave at worst one budget's worth of lines behind
//! the flood (`fairness_deferrals` in the stats counts requeued turns).
//!
//! Request *processing* splits by cost. Cache hits, memo probes, and
//! bookkeeping commands are answered inline on the IO thread that owns
//! the connection — they are microseconds. Any line that would block
//! the thread (a cache-miss model invocation, a cluster peer wait) is
//! handed to the bounded request-worker pool (`--request-workers N`,
//! [`super::offload`]): the worker executes the same `handle_line`
//! path, renders the identical response bytes, and bounces them back
//! to the owning loop through that loop's eventfd doorbell. While a
//! connection has an offloaded line in flight it parks — parsing stops
//! at that line and `EPOLLIN` is dropped — so per-connection response
//! order is preserved by construction, and the loop spends the wait
//! serving its OTHER connections instead of stalling them
//! (`offloaded_misses` / `offload_queue_depth` / `io_stall_ns` in the
//! stats; the last counts would-block lines the loop had to run inline
//! because the pool's bounded queue was full). `--request-workers 0`
//! (the default) skips classification entirely and runs every line
//! inline — the pre-offload behavior, byte for byte.
//!
//! Backpressure by buffer is survival; admission control is policy.
//! With `--quota N` every request line passes a token bucket before it
//! is processed: the bucket is keyed by the request's optional
//! `tenant` field (one bucket per tenant NAME, shared across all of
//! that tenant's connections and all IO threads), falling back to one
//! bucket per connection for untagged traffic. A line over quota is
//! answered with a typed `over_quota` error — cheap microseconds on
//! the IO thread — instead of being queued. `--shed-deadlines` adds
//! deadline-aware shedding: a prediction whose `budget_us` is already
//! unmeetable given the fastest variant's latency estimate and the
//! current offload queue depth is rejected up front with
//! `shed_deadline` rather than queueing work the client will discard
//! (requests without `budget_us` are never shed). `--tenant-inflight
//! K` caps one tenant's simultaneously queued+executing offloaded
//! lines; the K+1'th is rejected with a typed `overloaded` error while
//! other tenants' lines keep flowing through the pool's per-tenant
//! round-robin queues ([`super::offload`]). All three knobs default to
//! off, and when off the line path is byte-identical to the pre-quota
//! server. The admission ledger is pinned by
//! `ServiceStats::conservation_debt`: every admitted line settles as
//! exactly one of `lines_answered` / `over_quota` / `shed_deadline` /
//! `rejected_overloaded` / `lines_dropped`. The `metrics` command (and
//! the `mlir-cost metrics` CLI) exports every stats counter as flat
//! scrape-friendly `name value` text for fleet dashboards.
//!
//! With `--reuseport`, accept sharding replaces the shared acceptor:
//! every IO thread owns its own `SO_REUSEPORT` listener socket bound to
//! the same address and the kernel spreads incoming connections across
//! them — no cross-thread handoff on accept. Where the option is
//! unsupported the server logs a warning and falls back to the shared
//! single-listener accept path.
//!
//! The old thread-per-connection loop survives as
//! [`serve_on_threaded`], kept as the baseline the serving bench
//! (`benches/e3_serving.rs`) compares the event loop against.

use super::offload::{CompletionInbox, Job, LineService, OffloadPool, SubmitError};
use super::session::{Delta, Splice};
use super::Service;
use crate::json::{parse, Json};
use crate::pred::PredVec;
use crate::sim::Target;
use anyhow::{anyhow, Context, Result};
use minipoll::{Epoll, EventFd, Events, EPOLLIN, EPOLLOUT, EPOLLRDHUP};
use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Shutdown signal shared by the front end's threads: an atomic flag
/// plus the eventfd doorbells of every event loop that must be woken to
/// observe it. `trigger()` is the only way the server stops.
pub struct Stop {
    flag: AtomicBool,
    wakers: Mutex<Vec<Arc<EventFd>>>,
}

impl Stop {
    #[allow(clippy::new_ret_no_self)]
    pub fn new() -> Arc<Stop> {
        Arc::new(Stop { flag: AtomicBool::new(false), wakers: Mutex::new(Vec::new()) })
    }

    /// Flip the flag and ring every registered event loop's doorbell.
    pub fn trigger(&self) {
        self.flag.store(true, Ordering::SeqCst);
        for w in self.wakers.lock().unwrap().iter() {
            w.signal();
        }
    }

    pub fn is_triggered(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }

    /// Attach a loop's doorbell. Signals immediately if the stop already
    /// fired, so registration can never miss a trigger.
    fn register(&self, efd: &Arc<EventFd>) {
        self.wakers.lock().unwrap().push(efd.clone());
        if self.is_triggered() {
            efd.signal();
        }
    }
}

/// Front-end shape knobs (the compute side's knobs live on
/// [`super::ServeOptions`]).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Event-loop threads. Thread 0 accepts and distributes connections
    /// round-robin across all loops (including itself) — unless
    /// `reuseport` shards accepting across every loop.
    pub io_threads: usize,
    /// Request-worker pool size ([`super::offload`]): would-block lines
    /// (cache-miss model executions, cluster peer waits) run on these
    /// workers instead of the IO threads. 0 = no pool, every line runs
    /// inline on its IO thread (the pre-offload behavior).
    pub request_workers: usize,
    /// Give every IO thread its own `SO_REUSEPORT` listener socket so
    /// the kernel shards accepts across loops, instead of thread 0
    /// dealing connections out. Falls back to the shared acceptor (with
    /// a logged warning) where the option is unsupported.
    pub reuseport: bool,
    /// Admission quota in requests/second per tenant (token bucket;
    /// tenant = the request's optional `tenant` field, falling back to
    /// one bucket per connection for untagged traffic). A line over
    /// quota is answered with a typed `over_quota` error instead of
    /// being processed. 0 = quotas off (the default): admission is not
    /// consulted and the line path is byte-identical to the pre-quota
    /// server.
    pub quota: f64,
    /// Token-bucket burst depth — the most unspent quota a tenant can
    /// bank for a spike. 0 = default to `max(quota, 1)`.
    pub quota_burst: f64,
    /// Per-tenant in-flight cap on the request-worker pool: at most
    /// this many of one tenant's would-block lines queued + executing
    /// at once; the next is rejected with a typed `overloaded` error
    /// while other tenants keep flowing. 0 = no cap.
    pub tenant_inflight: usize,
    /// Shed doomed work at admission: reject a prediction whose
    /// `budget_us` is already unmeetable (fastest-variant latency
    /// estimate × offload queue depth — see
    /// [`super::deadline_unmeetable`]) with a typed `shed_deadline`
    /// error instead of queueing it. Requests without `budget_us` are
    /// never shed.
    pub shed_deadlines: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            io_threads: 1,
            request_workers: 0,
            reuseport: false,
            quota: 0.0,
            quota_burst: 0.0,
            tenant_inflight: 0,
            shed_deadlines: false,
        }
    }
}

/// Classic token bucket: `rate` tokens/second refill up to a `burst`
/// ceiling; one token buys one admitted line. Refill is computed
/// lazily from elapsed time at each take — no timer thread, no
/// background refill work for idle tenants. The clock is an explicit
/// parameter so unit tests are deterministic.
struct TokenBucket {
    tokens: f64,
    last: Instant,
    rate: f64,
    burst: f64,
}

impl TokenBucket {
    /// A fresh bucket starts full: a new tenant gets its burst.
    fn new(rate: f64, burst: f64) -> TokenBucket {
        TokenBucket { tokens: burst, last: Instant::now(), rate, burst }
    }

    fn try_take_at(&mut self, n: f64, now: Instant) -> bool {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        if self.tokens >= n {
            self.tokens -= n;
            true
        } else {
            false
        }
    }
}

/// Shared admission state, one instance for the whole front end: the
/// quota knobs plus one token bucket per tenant NAME. Shared across
/// every IO loop so a tenant spreading connections over threads still
/// draws from a single bucket; untagged traffic uses the per-`Conn`
/// fallback bucket instead and never touches this map. `None` on
/// [`LoopCtx`] (every knob off, the default) short-circuits admission
/// entirely — the line path is byte-identical to the pre-quota server.
struct Admission {
    quota: f64,
    burst: f64,
    shed_deadlines: bool,
    tenants: Mutex<HashMap<String, TokenBucket>>,
}

/// Serve until `stop.trigger()` (or forever).
pub fn serve(
    service: Arc<Service>,
    addr: &str,
    stop: Arc<Stop>,
    config: ServerConfig,
) -> Result<()> {
    if config.reuseport {
        match bind_reuseport_set(addr, config.io_threads.max(1)) {
            Ok(listeners) => return serve_loops(service, listeners, stop, config),
            Err(e) => eprintln!(
                "[server] --reuseport unavailable ({e:#}); falling back to shared accept"
            ),
        }
    }
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    serve_on_with(service, listener, stop, config)
}

/// Bind `n` `SO_REUSEPORT` listener sockets to the same address — one
/// per IO thread, the kernel sharding accepts across them. Port 0 works:
/// the first bind picks the port, its siblings join it.
fn bind_reuseport_set(addr: &str, n: usize) -> Result<Vec<TcpListener>> {
    use std::net::ToSocketAddrs;
    let sa = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving {addr}"))?
        .next()
        .ok_or_else(|| anyhow!("no addresses resolved for {addr}"))?;
    let first = minipoll::listener_reuseport(&sa, ACCEPT_BACKLOG)
        .with_context(|| format!("reuseport-binding {sa}"))?;
    let bound = first.local_addr()?;
    let mut listeners = vec![first];
    for _ in 1..n {
        listeners.push(
            minipoll::listener_reuseport(&bound, ACCEPT_BACKLOG)
                .with_context(|| format!("reuseport-binding sibling on {bound}"))?,
        );
    }
    Ok(listeners)
}

/// Listen backlog for reuseport-bound sockets (std's own default).
const ACCEPT_BACKLOG: i32 = 128;

/// Serve on an already-bound listener (lets tests bind port 0) with one
/// IO thread.
pub fn serve_on(service: Arc<Service>, listener: TcpListener, stop: Arc<Stop>) -> Result<()> {
    serve_on_with(service, listener, stop, ServerConfig::default())
}

/// Serve on an already-bound listener with an explicit config. Blocks
/// the calling thread (it becomes IO thread 0, the acceptor) until
/// `stop.trigger()`.
pub fn serve_on_with(
    service: Arc<Service>,
    listener: TcpListener,
    stop: Arc<Stop>,
    config: ServerConfig,
) -> Result<()> {
    serve_loops(service, vec![listener], stop, config)
}

/// The front end proper, generic over the service so tests and benches
/// can drive it with an artifact-free [`LineService`] fake (the
/// admission and chaos suites live on this seam). One listener =
/// thread 0 accepts and deals connections round-robin; `io_threads`
/// listeners (the reuseport path) = every thread accepts from its own.
pub fn serve_loops(
    service: Arc<dyn LineService>,
    mut listeners: Vec<TcpListener>,
    stop: Arc<Stop>,
    config: ServerConfig,
) -> Result<()> {
    let n = config.io_threads.max(1);
    debug_assert!(listeners.len() == 1 || listeners.len() == n);
    for l in &listeners {
        l.set_nonblocking(true)?;
    }
    eprintln!(
        "[server] cost-model service listening on {} ({n} io thread{}{}{})",
        listeners[0].local_addr()?,
        if n == 1 { "" } else { "s" },
        if listeners.len() > 1 { ", reuseport accept sharding" } else { "" },
        if config.request_workers > 0 {
            format!(", {} request worker(s)", config.request_workers)
        } else {
            String::new()
        },
    );
    if config.quota > 0.0 || config.tenant_inflight > 0 || config.shed_deadlines {
        eprintln!(
            "[server] admission control on: quota {}/s{}{}{}",
            config.quota,
            if config.quota_burst > 0.0 {
                format!(" (burst {})", config.quota_burst)
            } else {
                String::new()
            },
            if config.tenant_inflight > 0 {
                format!(", tenant in-flight cap {}", config.tenant_inflight)
            } else {
                String::new()
            },
            if config.shed_deadlines { ", deadline shedding" } else { "" },
        );
    }
    // Every loop gets an inbox (handoff queue + completion inbox +
    // doorbell); doorbells are registered with `stop` up front so a
    // trigger can never race a loop's startup.
    let mut inboxes: Vec<Arc<Inbox>> = Vec::with_capacity(n);
    for _ in 0..n {
        inboxes.push(Arc::new(Inbox::new()?));
    }
    for inbox in &inboxes {
        stop.register(&inbox.doorbell);
    }
    // The request-worker pool is shared by every loop; each loop's jobs
    // carry that loop's completion inbox home.
    let pool = (config.request_workers > 0).then(|| {
        OffloadPool::start_with_cap(service.clone(), config.request_workers, config.tenant_inflight)
    });
    // Admission state exists only when some knob is on: a `None` here
    // keeps the default line path byte-identical to the pre-quota
    // server (no per-line parse for the tenant field, no bucket math).
    // `tenant_inflight` alone still needs it — the pool's fair queues
    // key on the tenant labels admission extracts.
    let admission = (config.quota > 0.0 || config.tenant_inflight > 0 || config.shed_deadlines)
        .then(|| {
            Arc::new(Admission {
                quota: config.quota,
                burst: if config.quota_burst > 0.0 {
                    config.quota_burst
                } else {
                    config.quota.max(1.0)
                },
                shed_deadlines: config.shed_deadlines,
                tenants: Mutex::new(HashMap::new()),
            })
        });
    // One acceptor per listener: index 0 runs on thread 0; with accept
    // sharding each remaining listener rides its own thread and pushes
    // into that thread's inbox only.
    let sharded = listeners.len() > 1;
    let mut acceptors: Vec<Option<Acceptor>> = listeners
        .drain(..)
        .enumerate()
        .map(|(i, listener)| {
            let inboxes = if sharded { vec![inboxes[i].clone()] } else { inboxes.clone() };
            Some(Acceptor { listener, inboxes, next: 0 })
        })
        .collect();
    let mut joins = Vec::new();
    for (i, inbox) in inboxes.iter().enumerate().skip(1) {
        let ctx = LoopCtx {
            svc: service.clone(),
            pool: pool.clone(),
            admission: admission.clone(),
        };
        let inbox = inbox.clone();
        let stop = stop.clone();
        let acceptor = if sharded { acceptors[i].take() } else { None };
        joins.push(std::thread::spawn(move || {
            if let Err(e) = io_loop(ctx, stop.clone(), inbox, acceptor) {
                // A dead loop would silently strand every connection the
                // acceptor keeps dealing to its inbox — wind the whole
                // front end down instead.
                eprintln!("[server] io thread {i} failed, stopping server: {e:#}");
                stop.trigger();
            }
        }));
    }
    let ctx = LoopCtx { svc: service.clone(), pool: pool.clone(), admission };
    let res = io_loop(ctx, stop.clone(), inboxes[0].clone(), acceptors[0].take());
    // If thread 0 failed, the sibling loops are still parked in
    // epoll_wait — trigger so the joins below cannot hang, and the
    // startup/run error reaches the caller.
    stop.trigger();
    for j in joins {
        let _ = j.join();
    }
    if let Some(pool) = pool {
        pool.shutdown();
    }
    // Workers that finished after a loop's teardown drain pushed their
    // completions into an inbox nobody will read again. Those lines
    // were admitted but never answered — settle them as dropped so the
    // conservation ledger balances at quiescence.
    for inbox in &inboxes {
        let stranded = inbox.completions.drain().len();
        if stranded > 0 {
            service.stats().lines_dropped.fetch_add(stranded as u64, Ordering::Relaxed);
        }
    }
    res
}

/// Cross-thread connection handoff: the acceptor pushes fresh streams
/// here and rings the doorbell; the owning loop drains it on wakeup.
struct Inbox {
    conns: Mutex<VecDeque<TcpStream>>,
    doorbell: Arc<EventFd>,
    /// Finished offload jobs land here; shares `doorbell`, so the loop
    /// has exactly one wakeup source for everything handed to it.
    completions: Arc<CompletionInbox>,
}

impl Inbox {
    fn new() -> Result<Inbox> {
        let doorbell = Arc::new(EventFd::new()?);
        Ok(Inbox {
            conns: Mutex::new(VecDeque::new()),
            completions: Arc::new(CompletionInbox::new(doorbell.clone())),
            doorbell,
        })
    }

    fn push(&self, stream: TcpStream) {
        self.conns.lock().unwrap().push_back(stream);
        self.doorbell.signal();
    }

    fn drain(&self) -> VecDeque<TcpStream> {
        std::mem::take(&mut *self.conns.lock().unwrap())
    }
}

/// Everything an IO loop needs beyond its own epoll state: the service
/// (behind the [`LineService`] seam so tests can drive the loop with an
/// artifact-free fake) and the shared request-worker pool, if any.
struct LoopCtx {
    svc: Arc<dyn LineService>,
    pool: Option<Arc<OffloadPool>>,
    /// Admission state shared by every loop; `None` = every admission
    /// knob off, the pre-quota fast path.
    admission: Option<Arc<Admission>>,
}

/// Thread 0's extra role: own the listener and deal connections out.
struct Acceptor {
    listener: TcpListener,
    inboxes: Vec<Arc<Inbox>>,
    next: usize,
}

// Event-loop tokens: two fixed doorbell/listener slots, then one per
// connection slab slot.
const TOK_DOORBELL: u64 = 0;
const TOK_LISTENER: u64 = 1;
const TOK_CONN_BASE: u64 = 2;

/// Reject a single request line longer than this (a line that long is a
/// protocol violation, not a query) instead of buffering it forever.
const MAX_LINE_BYTES: usize = 32 << 20;

/// Once this much flushed prefix accumulates in a backpressured write
/// buffer, compact it.
const WBUF_COMPACT_BYTES: usize = 64 << 10;

/// Backpressure propagation: once this many response bytes are stuck
/// behind a slow reader, the connection stops reading new requests
/// (EPOLLIN is dropped) and stops answering already-buffered lines until
/// the kernel drains the backlog — a client that never reads cannot grow
/// `wbuf` without bound.
const WBUF_PAUSE_BYTES: usize = 1 << 20;

/// Per-wakeup read budget: a client that streams faster than we answer
/// could otherwise keep the socket readable forever and grow `rbuf`
/// without bound inside ONE event. Level-triggered epoll re-delivers
/// the readable event, so the remainder is picked up next wakeup (and
/// TCP backpressures the sender meanwhile).
const RBUF_READ_BUDGET: usize = 256 << 10;

/// Per-turn line budget for the round-robin answer phase: a connection
/// with more buffered complete lines than this answers a budget's worth,
/// goes to the back of the ready queue (counted in `fairness_deferrals`),
/// and every other ready connection takes a turn before it continues. A
/// flooding pipeliner still gets full throughput — its lines are all
/// answered within the wakeup — but an interactive connection's request
/// waits behind at most one budget per competitor, not a whole backlog.
const FAIR_LINE_BUDGET: usize = 32;

/// One nonblocking connection owned by an event loop.
struct Conn {
    stream: TcpStream,
    /// Partial-line reassembly: bytes accumulate here across TCP
    /// segments until a `\n` completes a request.
    rbuf: Vec<u8>,
    /// Pending response bytes not yet accepted by the kernel.
    wbuf: Vec<u8>,
    /// How much of `wbuf` is already written.
    wpos: usize,
    /// Interest bits currently armed in epoll.
    interest: u32,
    /// The peer sent EOF: answer what the kernel will still take, then
    /// close at the end of the wakeup.
    peer_closed: bool,
    /// Set by [`respond_turn`]: complete lines remain in `rbuf` (the
    /// turn stopped on its budget or on write backpressure, not because
    /// the buffer ran dry). Lets `finish_conn` know whether a flush that
    /// made room must resume answering — without rescanning `rbuf`.
    deferred_lines: bool,
    /// Registration stamp, unique per loop: a completion carrying a
    /// stale `gen` belongs to a previous occupant of this slab slot and
    /// is dropped.
    gen: u64,
    /// Next offload sequence number for this connection.
    seq: u64,
    /// `Some(seq)` while an offloaded line is in flight: the connection
    /// is parked — no parsing past that line, `EPOLLIN` dropped — until
    /// the matching completion lands, preserving response order.
    waiting: Option<u64>,
    /// Quota bucket for untagged traffic (no `tenant` field), created
    /// lazily on this connection's first admitted line. Tagged traffic
    /// draws from [`Admission::tenants`] instead.
    bucket: Option<TokenBucket>,
}

impl Conn {
    fn wants_write(&self) -> bool {
        self.wpos < self.wbuf.len()
    }

    /// Push buffered response bytes to the kernel until done or
    /// `WouldBlock`. Returns false when the connection is dead.
    fn flush(&mut self) -> bool {
        while self.wants_write() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => return false,
                Ok(n) => self.wpos += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
        if self.wants_write() {
            if self.wpos >= WBUF_COMPACT_BYTES {
                self.wbuf.drain(..self.wpos);
                self.wpos = 0;
            }
        } else {
            self.wbuf.clear();
            self.wpos = 0;
        }
        true
    }
}

/// The event loop proper: one epoll instance owning a doorbell, the
/// listener (thread 0 only), and a slab of nonblocking connections.
fn io_loop(
    ctx: LoopCtx,
    stop: Arc<Stop>,
    inbox: Arc<Inbox>,
    mut acceptor: Option<Acceptor>,
) -> Result<()> {
    let epoll = Epoll::new().context("creating epoll instance")?;
    epoll
        .add(inbox.doorbell.as_raw_fd(), EPOLLIN, TOK_DOORBELL)
        .context("registering doorbell")?;
    if let Some(a) = &acceptor {
        epoll.add(a.listener.as_raw_fd(), EPOLLIN, TOK_LISTENER).context("registering listener")?;
    }
    let mut slab: Vec<Option<Conn>> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    // Registration stamp source: slab slots are recycled, so a slot
    // index alone cannot identify a connection across time — each
    // registration takes the next stamp and completions carry it.
    let mut next_gen: u64 = 0;
    let mut events = Events::with_capacity(512);
    let mut touched: Vec<usize> = Vec::new();
    let mut ready: VecDeque<usize> = VecDeque::new();

    'outer: while !stop.is_triggered() {
        // Block until something is ready — no timeout, no sleep. Idle
        // connections park in the kernel for free.
        epoll.wait(&mut events, -1)?;
        ctx.svc.stats().epoll_wakeups.fetch_add(1, Ordering::Relaxed);
        // Phase 1 — IO: flush backpressured writes, drain readable
        // sockets into per-connection buffers, land finished offload
        // jobs. No request is answered yet; connections that survived
        // their IO are queued for the fairness scheduler.
        for ev in events.iter() {
            match ev.token {
                TOK_DOORBELL => {
                    inbox.doorbell.drain();
                    if stop.is_triggered() {
                        break 'outer;
                    }
                    for stream in inbox.drain() {
                        next_gen += 1;
                        register_conn(&ctx, &epoll, &mut slab, &mut free, stream, next_gen);
                    }
                    for c in inbox.completions.drain() {
                        let Some(conn) = slab.get_mut(c.conn).and_then(Option::as_mut) else {
                            // Connection closed while its job ran: the
                            // line was admitted, its answer has nowhere
                            // to go — settle it as dropped.
                            ctx.svc.stats().lines_dropped.fetch_add(1, Ordering::Relaxed);
                            continue;
                        };
                        if conn.gen != c.gen {
                            // Slot recycled by a newer connection.
                            ctx.svc.stats().lines_dropped.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                        // At most one job is ever in flight per
                        // connection, so a live (conn, gen) can only be
                        // waiting on exactly this completion.
                        debug_assert_eq!(conn.waiting, Some(c.seq));
                        if conn.waiting != Some(c.seq) {
                            ctx.svc.stats().lines_dropped.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                        conn.waiting = None;
                        ctx.svc.stats().lines_answered.fetch_add(1, Ordering::Relaxed);
                        conn.wbuf.extend_from_slice(&c.bytes);
                        // Drives phase 2 (resume parsing the backlog
                        // behind the offloaded line) and phase 3 (flush
                        // + re-arm EPOLLIN). Duplicate indices in
                        // `touched` are harmless.
                        touched.push(c.conn);
                    }
                }
                TOK_LISTENER => {
                    if let Some(a) = &mut acceptor {
                        accept_ready(&ctx, a);
                    }
                }
                t => {
                    let idx = (t - TOK_CONN_BASE) as usize;
                    if conn_io(&ctx, &epoll, &mut slab, &mut free, idx, ev.events) {
                        touched.push(idx);
                    }
                }
            }
        }
        // Phase 2 — fairness: answer buffered lines round-robin, at most
        // FAIR_LINE_BUDGET per connection per turn. A connection with
        // more goes to the back of the queue so a flooding pipeliner
        // cannot monopolize the thread.
        ready.extend(touched.iter().copied());
        while let Some(idx) = ready.pop_front() {
            let Some(conn) = slab.get_mut(idx).and_then(Option::as_mut) else {
                continue; // closed earlier this wakeup
            };
            match respond_turn(&ctx, &inbox, idx, conn, FAIR_LINE_BUDGET) {
                Turn::Closed => close_conn(&ctx, &epoll, &mut slab, &mut free, idx),
                Turn::MoreReady => {
                    ctx.svc.stats().fairness_deferrals.fetch_add(1, Ordering::Relaxed);
                    ready.push_back(idx);
                }
                Turn::Drained => {}
            }
        }
        // Phase 3 — flush what the kernel will take, close EOF'd
        // connections, re-arm interest.
        for idx in touched.drain(..) {
            finish_conn(&ctx, &inbox, &epoll, &mut slab, &mut free, idx);
        }
    }

    // Teardown: close every connection this loop owns (and any streams
    // handed off but never registered). `close_conn` no-ops on empty
    // slots. In-flight offload completions die with the inbox — their
    // lines were admitted but never answered, so they settle as
    // dropped (completions still in flight at this instant are caught
    // by `serve_loops`' post-shutdown drain).
    for idx in 0..slab.len() {
        close_conn(&ctx, &epoll, &mut slab, &mut free, idx);
    }
    drop(inbox.drain());
    let stranded = inbox.completions.drain().len();
    if stranded > 0 {
        ctx.svc.stats().lines_dropped.fetch_add(stranded as u64, Ordering::Relaxed);
    }
    Ok(())
}

/// Accept until the listener runs dry, dealing streams round-robin.
fn accept_ready(ctx: &LoopCtx, a: &mut Acceptor) {
    loop {
        match a.listener.accept() {
            Ok((stream, _peer)) => {
                ctx.svc.stats().connections_accepted.fetch_add(1, Ordering::Relaxed);
                let i = a.next % a.inboxes.len();
                a.next = a.next.wrapping_add(1);
                a.inboxes[i].push(stream);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => {
                // Persistent errors (EMFILE under fd exhaustion, ...)
                // leave the listener readable, so level-triggered epoll
                // would hand the event right back — back off briefly
                // instead of spinning a core on accept→fail cycles.
                eprintln!("[server] accept failed: {e}");
                std::thread::sleep(std::time::Duration::from_millis(10));
                break;
            }
        }
    }
}

fn register_conn(
    ctx: &LoopCtx,
    epoll: &Epoll,
    slab: &mut Vec<Option<Conn>>,
    free: &mut Vec<usize>,
    stream: TcpStream,
    gen: u64,
) {
    if let Err(e) = stream.set_nonblocking(true) {
        eprintln!("[server] could not make connection nonblocking: {e}");
        return;
    }
    // Responses are single small writes; don't let Nagle delay them.
    let _ = stream.set_nodelay(true);
    let idx = free.pop().unwrap_or_else(|| {
        slab.push(None);
        slab.len() - 1
    });
    let interest = EPOLLIN | EPOLLRDHUP;
    if let Err(e) = epoll.add(stream.as_raw_fd(), interest, TOK_CONN_BASE + idx as u64) {
        eprintln!("[server] could not register connection: {e}");
        free.push(idx);
        return;
    }
    slab[idx] = Some(Conn {
        stream,
        rbuf: Vec::new(),
        wbuf: Vec::new(),
        wpos: 0,
        interest,
        peer_closed: false,
        deferred_lines: false,
        gen,
        seq: 0,
        waiting: None,
        bucket: None,
    });
    ctx.svc.stats().active_connections.fetch_add(1, Ordering::Relaxed);
}

fn close_conn(
    ctx: &LoopCtx,
    epoll: &Epoll,
    slab: &mut [Option<Conn>],
    free: &mut Vec<usize>,
    idx: usize,
) {
    if let Some(conn) = slab[idx].take() {
        let _ = epoll.delete(conn.stream.as_raw_fd());
        free.push(idx);
        ctx.svc.stats().active_connections.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Phase-1 IO for one connection's readiness event: flush backpressured
/// writes, drain the socket into `rbuf`. Returns whether the connection
/// is still registered (and should take fairness turns this wakeup).
fn conn_io(
    ctx: &LoopCtx,
    epoll: &Epoll,
    slab: &mut [Option<Conn>],
    free: &mut Vec<usize>,
    idx: usize,
    bits: u32,
) -> bool {
    let Some(conn) = slab.get_mut(idx).and_then(Option::as_mut) else {
        return false; // stale event for a slot already closed this wakeup
    };
    let mut alive = true;
    if bits & EPOLLOUT != 0 {
        alive = conn.flush();
    }
    if alive && bits & (EPOLLIN | EPOLLRDHUP | minipoll::EPOLLHUP | minipoll::EPOLLERR) != 0 {
        // Drain the socket up to the per-wakeup budget (level-triggered
        // epoll re-delivers whatever is left).
        let mut chunk = [0u8; 16 * 1024];
        let mut budget = RBUF_READ_BUDGET;
        while budget > 0 {
            let want = budget.min(chunk.len());
            match conn.stream.read(&mut chunk[..want]) {
                Ok(0) => {
                    // A closing peer still gets its final responses if
                    // the kernel will take them (phase 3 closes it).
                    conn.peer_closed = true;
                    break;
                }
                Ok(n) => {
                    conn.rbuf.extend_from_slice(&chunk[..n]);
                    budget -= n;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    alive = false;
                    break;
                }
            }
        }
    }
    if !alive {
        close_conn(ctx, epoll, slab, free, idx);
        return false;
    }
    true
}

/// Result of one fairness turn over a connection's buffered lines.
enum Turn {
    /// No more answerable complete lines (none left, or write-paused —
    /// EPOLLOUT will resume the latter).
    Drained,
    /// Budget exhausted with complete lines still buffered: requeue.
    MoreReady,
    /// Protocol violation (oversized line): close the connection.
    Closed,
}

/// One line's admission verdict.
enum Admit {
    /// Admitted; carries the request's `tenant` label when present
    /// (the offload pool's fair-queueing key).
    Pass(Option<String>),
    /// Rejected at admission; the typed error response to write. The
    /// rejecting gate has already counted the outcome in the stats.
    Reject(Json),
}

/// A typed admission rejection: same shape as every other protocol
/// error, echoing the request's id.
fn reject_json(id: Json, error: String) -> Json {
    Json::obj().with("id", id).with("ok", Json::Bool(false)).with("error", Json::str(error))
}

/// The admission gate, run once per complete line BEFORE any
/// processing: quota bucket first (cheapest, and a flooding tenant
/// must not reach the shed estimator), deadline shedding second. With
/// no admission state configured every line passes untouched — no
/// parse, no allocation, the pre-quota path byte for byte. `bucket` is
/// the connection's untagged-traffic fallback bucket (a disjoint field
/// borrow of `Conn` so `text` may keep borrowing `rbuf`).
fn admit_line(ctx: &LoopCtx, bucket: &mut Option<TokenBucket>, text: &str) -> Admit {
    let Some(adm) = &ctx.admission else {
        return Admit::Pass(None);
    };
    // One parse for the id (echoed on rejections) and the tenant
    // label. A malformed line passes through with neither —
    // `handle_line` owns its error reply, and quota still applies via
    // the connection bucket so garbage cannot bypass the limiter.
    let (id, tenant) = match parse(text) {
        Ok(req) => (
            req.get("id").cloned().unwrap_or(Json::Null),
            req.get("tenant").and_then(Json::as_str).map(str::to_string),
        ),
        Err(_) => (Json::Null, None),
    };
    if adm.quota > 0.0 {
        let now = Instant::now();
        let ok = match &tenant {
            Some(t) => adm
                .tenants
                .lock()
                .unwrap()
                .entry(t.clone())
                .or_insert_with(|| TokenBucket::new(adm.quota, adm.burst))
                .try_take_at(1.0, now),
            None => bucket
                .get_or_insert_with(|| TokenBucket::new(adm.quota, adm.burst))
                .try_take_at(1.0, now),
        };
        if !ok {
            ctx.svc.stats().over_quota.fetch_add(1, Ordering::Relaxed);
            return Admit::Reject(reject_json(
                id,
                format!(
                    "over_quota: rate limit exceeded ({} req/s, burst {})",
                    adm.quota, adm.burst
                ),
            ));
        }
    }
    if adm.shed_deadlines {
        if let Some(resp) = ctx.svc.shed(text) {
            ctx.svc.stats().shed_deadline.fetch_add(1, Ordering::Relaxed);
            return Admit::Reject(resp);
        }
    }
    Admit::Pass(tenant)
}

/// Answer up to `budget` `\n`-terminated requests sitting in `rbuf`;
/// leftover partial-line bytes stay buffered for the next segment. Stops
/// early when the write buffer passes the backpressure threshold (the
/// unanswered lines stay in `rbuf` and resume after a flush makes room).
///
/// With a request-worker pool, a line classified as would-block is
/// submitted to the pool instead of being answered here: the connection
/// parks (`waiting`) and the turn ends — nothing behind the offloaded
/// line may be answered before its response lands, or per-connection
/// order would break. The completion re-queues the connection.
fn respond_turn(ctx: &LoopCtx, inbox: &Inbox, idx: usize, conn: &mut Conn, budget: usize) -> Turn {
    if conn.waiting.is_some() {
        // Parked on an in-flight offloaded line. The backlog stays in
        // `rbuf`; clearing `deferred_lines` keeps `finish_conn`'s
        // resume loop from spinning on it — the completion (→ touched)
        // is what resumes this connection.
        conn.deferred_lines = false;
        return Turn::Drained;
    }
    let mut start = 0;
    let mut answered = 0;
    // True when the loop stopped on budget/backpressure with bytes it
    // never scanned; false when the newline search itself ran dry (so we
    // KNOW no complete line remains without rescanning).
    let mut stopped_early = false;
    loop {
        if answered >= budget || conn.wbuf.len() - conn.wpos > WBUF_PAUSE_BYTES {
            stopped_early = true;
            break;
        }
        let Some(nl) = conn.rbuf[start..].iter().position(|&b| b == b'\n') else {
            break;
        };
        let line = &conn.rbuf[start..start + nl];
        start += nl + 1;
        let response = match std::str::from_utf8(line) {
            Ok(text) if text.trim().is_empty() => continue,
            Ok(text) => {
                // Every complete non-empty line enters the admission
                // ledger here and must settle as exactly one of
                // answered / over_quota / shed_deadline /
                // rejected_overloaded / dropped (pinned by
                // `ServiceStats::conservation_debt`).
                ctx.svc.stats().lines_admitted.fetch_add(1, Ordering::Relaxed);
                // `&mut conn.bucket` + `text` (borrowing `conn.rbuf`)
                // are disjoint field borrows.
                match admit_line(ctx, &mut conn.bucket, text) {
                    Admit::Reject(resp) => resp,
                    Admit::Pass(tenant) => match &ctx.pool {
                        Some(pool) if ctx.svc.would_block(text) => {
                            // Fair-queueing key: the wire tenant when
                            // tagged, else a per-connection key —
                            // doorbell fd + gen, unique across loops
                            // (gen alone collides between threads).
                            let tenant = tenant.unwrap_or_else(|| {
                                format!("conn:{}/{}", inbox.doorbell.as_raw_fd(), conn.gen)
                            });
                            let job = Job {
                                line: text.to_string(),
                                inbox: inbox.completions.clone(),
                                conn: idx,
                                gen: conn.gen,
                                seq: conn.seq,
                                tenant,
                            };
                            match pool.submit(job) {
                                Ok(()) => {
                                    conn.waiting = Some(conn.seq);
                                    conn.seq += 1;
                                    // `start` is already past the offloaded
                                    // line; everything behind it waits in rbuf.
                                    conn.rbuf.drain(..start);
                                    conn.deferred_lines = false;
                                    return Turn::Drained;
                                }
                                Err(SubmitError::Full(job)) => {
                                    // Bounded queue full: degrade to the
                                    // in-loop path and record the stall the
                                    // pool could not absorb.
                                    let t = Instant::now();
                                    let resp = ctx.svc.handle(&job.line);
                                    let stalled = t.elapsed().as_nanos() as u64;
                                    let stats = ctx.svc.stats();
                                    stats.io_stall_ns.fetch_add(stalled, Ordering::Relaxed);
                                    stats.lines_answered.fetch_add(1, Ordering::Relaxed);
                                    resp
                                }
                                Err(SubmitError::TenantSaturated(job)) => {
                                    // This tenant already has its
                                    // in-flight cap's worth of work in
                                    // the pool: typed rejection; other
                                    // tenants' lines keep flowing.
                                    ctx.svc
                                        .stats()
                                        .rejected_overloaded
                                        .fetch_add(1, Ordering::Relaxed);
                                    let id = parse(&job.line)
                                        .ok()
                                        .and_then(|r| r.get("id").cloned())
                                        .unwrap_or(Json::Null);
                                    reject_json(
                                        id,
                                        "overloaded: tenant in-flight cap reached, retry later"
                                            .to_string(),
                                    )
                                }
                            }
                        }
                        _ => {
                            let resp = ctx.svc.handle(text);
                            ctx.svc.stats().lines_answered.fetch_add(1, Ordering::Relaxed);
                            resp
                        }
                    },
                }
            }
            Err(_) => {
                // An unparseable line still settles in the ledger:
                // admitted and immediately answered with an error.
                let stats = ctx.svc.stats();
                stats.lines_admitted.fetch_add(1, Ordering::Relaxed);
                stats.lines_answered.fetch_add(1, Ordering::Relaxed);
                Json::obj()
                    .with("ok", Json::Bool(false))
                    .with("error", Json::str("request line is not valid UTF-8"))
            }
        };
        // Vec<u8> writes are infallible.
        response.write_to(&mut conn.wbuf).expect("buffer write");
        conn.wbuf.push(b'\n');
        answered += 1;
    }
    if start > 0 {
        conn.rbuf.drain(..start);
    }
    // Complete lines still buffered? Known false when the scan ran dry
    // (a partial-line tail — e.g. a large request arriving over many
    // wakeups — costs exactly one scan per wakeup, here); otherwise one
    // scan of the unconsumed remainder, whose size the read budget +
    // pause cycle bounds.
    let more = stopped_early && conn.rbuf.contains(&b'\n');
    conn.deferred_lines = more;
    // Only an oversized SINGLE line (no complete line in sight) is a
    // protocol violation; complete lines deferred by the budget or by
    // write backpressure are fine.
    if !more && conn.rbuf.len() > MAX_LINE_BYTES {
        return Turn::Closed;
    }
    if more && conn.wbuf.len() - conn.wpos <= WBUF_PAUSE_BYTES {
        Turn::MoreReady
    } else {
        Turn::Drained
    }
}

/// Phase 3 for one touched connection: flush, answer anything a flush
/// just un-paused, close EOF'd peers, re-arm epoll interest.
fn finish_conn(
    ctx: &LoopCtx,
    inbox: &Inbox,
    epoll: &Epoll,
    slab: &mut [Option<Conn>],
    free: &mut Vec<usize>,
    idx: usize,
) {
    let mut close = false;
    {
        let Some(conn) = slab.get_mut(idx).and_then(Option::as_mut) else {
            return; // closed during this wakeup
        };
        // Invariant on parking: a connection never sleeps holding
        // answerable complete lines unless a wakeup is armed for it. If
        // a flush drains the backlog below the pause threshold while
        // complete lines remain (possible when the kernel's send buffer
        // swallows everything), answer them now — otherwise EPOLLIN
        // would stay silent until the client sent more bytes, stranding
        // the buffered requests. `deferred_lines` (maintained by
        // `respond_turn`, which phase 2 ran for every touched conn)
        // makes the check free — no rbuf rescans here.
        loop {
            if conn.wants_write() && !conn.flush() {
                close = true;
                break;
            }
            let paused = conn.wbuf.len() - conn.wpos > WBUF_PAUSE_BYTES;
            if paused || !conn.deferred_lines {
                break; // paused ⇒ wants_write ⇒ EPOLLOUT re-arms below
            }
            if matches!(respond_turn(ctx, inbox, idx, conn, FAIR_LINE_BUDGET), Turn::Closed) {
                close = true;
                break;
            }
        }
        if !close {
            if conn.peer_closed && conn.waiting.is_none() {
                // A peer that sent EOF right after its request still
                // gets an in-flight offloaded response: the close waits
                // for the completion (which re-touches this slot), and
                // the flush above runs before this check.
                close = true;
            } else {
                // Backpressure: past the pause threshold, stop reading
                // (and thus stop generating responses) until the
                // backlog drains. Same while an offloaded line is in
                // flight — the connection is parked, so reading more
                // would only grow `rbuf` without bound; and once the
                // peer EOF'd, the read side must go quiet or the
                // level-triggered EOF would spin the loop until the
                // completion lands.
                let mut want = if conn.wants_write() { EPOLLOUT } else { 0 };
                if !conn.peer_closed && conn.waiting.is_none() {
                    want |= EPOLLRDHUP;
                    if conn.wbuf.len() - conn.wpos <= WBUF_PAUSE_BYTES {
                        want |= EPOLLIN;
                    }
                }
                if want != conn.interest {
                    if epoll
                        .modify(conn.stream.as_raw_fd(), want, TOK_CONN_BASE + idx as u64)
                        .is_ok()
                    {
                        conn.interest = want;
                    } else {
                        close = true;
                    }
                }
            }
        }
    }
    if close {
        close_conn(ctx, epoll, slab, free, idx);
    }
}

/// The legacy thread-per-connection front end, kept as the measured
/// baseline for `benches/e3_serving.rs`: accept polls on a 10 ms sleep
/// and every idle connection wakes on a 200 ms read timeout — the costs
/// the event loop exists to delete. The partial-read handling is shared
/// with the event loop in spirit: a timeout mid-request preserves the
/// bytes already read (see `handle_conn_threaded`).
pub fn serve_on_threaded(
    service: Arc<Service>,
    listener: TcpListener,
    stop: Arc<Stop>,
) -> Result<()> {
    listener.set_nonblocking(true)?;
    let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !stop.is_triggered() {
        // Reap finished connection threads every iteration.
        let mut i = 0;
        while i < handles.len() {
            if handles[i].is_finished() {
                let _ = handles.swap_remove(i).join();
            } else {
                i += 1;
            }
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                service.stats.connections_accepted.fetch_add(1, Ordering::Relaxed);
                let svc = service.clone();
                let stop = stop.clone();
                handles.push(std::thread::spawn(move || {
                    svc.stats.active_connections.fetch_add(1, Ordering::Relaxed);
                    if let Err(e) = handle_conn_threaded(&svc, stream, stop) {
                        eprintln!("[server] connection ended: {e:#}");
                    }
                    svc.stats.active_connections.fetch_sub(1, Ordering::Relaxed);
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

fn handle_conn_threaded(service: &Service, stream: TcpStream, stop: Arc<Stop>) -> Result<()> {
    // Read with a timeout so shutdown can interrupt an idle connection.
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    let mut writer = BufWriter::new(stream.try_clone()?);
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {
                if !line.trim().is_empty() {
                    let response = handle_line(service, &line);
                    response.write_to(&mut writer)?;
                    writer.write_all(b"\n")?;
                    writer.flush()?;
                }
                // Clear only after a COMPLETE line was handled. The old
                // loop cleared at the top of every iteration, so a read
                // timeout that fired mid-request silently discarded the
                // partial bytes `read_line` had already appended.
                line.clear();
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // Timeout tick: `line` keeps any partial request bytes;
                // the next successful read appends the rest.
                if stop.is_triggered() {
                    return Ok(());
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// Process one request line (exposed for tests + in-process clients).
pub fn handle_line(service: &Service, line: &str) -> Json {
    let t0 = Instant::now();
    let req = match parse(line) {
        Ok(r) => r,
        Err(e) => {
            return Json::obj()
                .with("ok", Json::Bool(false))
                .with("error", Json::str(format!("bad json: {e}")))
        }
    };
    let id = req.get("id").cloned().unwrap_or(Json::Null);
    let fail = |msg: String| {
        Json::obj()
            .with("id", id.clone())
            .with("ok", Json::Bool(false))
            .with("error", Json::str(msg))
    };
    // Optional per-request latency budget in microseconds: the router
    // downgrades to a smaller/faster variant when the length-preferred
    // one's latency estimate exceeds this. Parsed up front because both
    // the plain predict path and the session commands honor it.
    let budget_us = match req.get("budget_us") {
        None => None,
        Some(j) => match j.as_f64() {
            Some(b) if b.is_finite() && b >= 0.0 => Some(b as u64),
            _ => return fail("'budget_us' must be a non-negative number".into()),
        },
    };
    // Optional required-characteristic list: only variants serving ALL
    // of these may answer (see the module docs' targets_not_served
    // contract).
    let required: Vec<Target> = match req.get("targets") {
        None => Vec::new(),
        Some(j) => {
            let Some(items) = j.as_arr() else {
                return fail("'targets' must be an array of characteristic names".into());
            };
            let mut out = Vec::with_capacity(items.len());
            for item in items {
                match item.as_str().and_then(Target::parse) {
                    Some(t) => out.push(t),
                    None => {
                        return fail(format!("unknown characteristic in 'targets': {item}"))
                    }
                }
            }
            out
        }
    };
    // One routed row's response fields: the scalar `prediction`
    // (primary characteristic, back-compat) plus the full `predictions`
    // object naming every slot of the vector.
    let row_json = |p: &super::RoutedPrediction| {
        let mut named = Json::obj();
        for (t, v) in p.targets.iter().zip(p.value.iter()) {
            named = named.with(t.name(), Json::num(*v));
        }
        Json::obj()
            .with("prediction", Json::num(p.value.first()))
            .with("predictions", named)
            .with("variant", Json::str(&*p.variant))
    };
    if let Some(cmd) = req.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "ping" => Json::obj()
                .with("id", id.clone())
                .with("ok", Json::Bool(true))
                .with("pong", Json::Bool(true)),
            "stats" => Json::obj()
                .with("id", id.clone())
                .with("ok", Json::Bool(true))
                .with("stats", service.stats_json()),
            // Cluster-tier peer commands: pure local-cache operations.
            // They never forward to another node and never invoke the
            // model, so peer chains cannot recurse and an IO thread
            // answering them does only hash probes.
            "cache_get" => {
                let key = req
                    .get("key")
                    .and_then(Json::as_str)
                    .and_then(super::cache::key_from_wire);
                let Some(key) = key else {
                    return fail("missing/invalid 'key' (16-digit hex u64)".into());
                };
                match service.cache.get(key) {
                    // Always the array form on write-out; readers old
                    // enough to expect a scalar must upgrade first.
                    Some(v) => Json::obj()
                        .with("id", id.clone())
                        .with("ok", Json::Bool(true))
                        .with("found", Json::Bool(true))
                        .with("value", v.to_json()),
                    None => Json::obj()
                        .with("id", id.clone())
                        .with("ok", Json::Bool(true))
                        .with("found", Json::Bool(false)),
                }
            }
            "cache_put" => {
                let key = req
                    .get("key")
                    .and_then(Json::as_str)
                    .and_then(super::cache::key_from_wire);
                let Some(key) = key else {
                    return fail("missing/invalid 'key' (16-digit hex u64)".into());
                };
                // Version-tolerant: an array is the vector wire form, a
                // bare number the pre-vector scalar one.
                let Some(vj) = req.get("value") else {
                    return fail("missing/invalid 'value'".into());
                };
                let value = match PredVec::from_json(vj) {
                    Ok(v) => v,
                    Err(e) => return fail(format!("invalid 'value': {e:#}")),
                };
                if !value.is_finite() {
                    return fail("'value' must be finite".into());
                }
                service.cache.put(key, value);
                Json::obj()
                    .with("id", id.clone())
                    .with("ok", Json::Bool(true))
                    .with("stored", Json::Bool(true))
            }
            "targets" => Json::obj().with("id", id.clone()).with("ok", Json::Bool(true)).with(
                "targets",
                Json::Arr(
                    service.targets().iter().map(|t| Json::str(t.name())).collect(),
                ),
            ),
            // Incremental tier: register a base text for delta probes.
            "session_open" => {
                let Some(target) = req.req_str("target").ok().and_then(Target::parse) else {
                    return fail("missing/invalid 'target'".into());
                };
                let mlir = match req.req_str("mlir") {
                    Ok(m) => m,
                    Err(e) => return fail(e.to_string()),
                };
                match service.session_open(target, mlir, budget_us, &required) {
                    Ok(opened) => row_json(&opened.prediction)
                        .with("id", id.clone())
                        .with("ok", Json::Bool(true))
                        .with("session", Json::num(opened.session_id as f64))
                        .with("token_len", Json::num(opened.token_len as f64))
                        .with("us", Json::num(t0.elapsed().as_micros() as f64)),
                    Err(e) => fail(format!("{e:#}")),
                }
            }
            // Incremental tier: predict an edit against a session's base,
            // re-lexing only the changed lines.
            "mlir_delta" => {
                let Some(sid) =
                    req.get("session").and_then(Json::as_f64).filter(|s| *s >= 0.0)
                else {
                    return fail("missing/invalid 'session' (id from session_open)".into());
                };
                let delta = if let Some(splices) = req.get("splices") {
                    let Some(items) = splices.as_arr() else {
                        return fail(
                            "'splices' must be an array of {start, end, text} objects".into(),
                        );
                    };
                    let mut out = Vec::with_capacity(items.len());
                    for item in items {
                        let (Some(start), Some(end), Some(text)) = (
                            item.get("start").and_then(Json::as_f64),
                            item.get("end").and_then(Json::as_f64),
                            item.get("text").and_then(Json::as_str),
                        ) else {
                            return fail(
                                "each splice needs numeric 'start'/'end' and string 'text'"
                                    .into(),
                            );
                        };
                        if start < 0.0 || end < 0.0 {
                            return fail("splice 'start'/'end' must be non-negative".into());
                        }
                        out.push(Splice {
                            start: start as usize,
                            end: end as usize,
                            text: text.to_string(),
                        });
                    }
                    Delta::Splices(out)
                } else if let Ok(m) = req.req_str("mlir") {
                    Delta::Full(m.to_string())
                } else {
                    return fail("'mlir_delta' needs either 'splices' or full 'mlir' text".into());
                };
                let rebase = req.get("rebase").and_then(Json::as_bool).unwrap_or(false);
                match service.predict_delta(sid as u64, delta, rebase, budget_us, &required) {
                    Ok(out) => row_json(&out.prediction)
                        .with("id", id.clone())
                        .with("ok", Json::Bool(true))
                        .with("token_len", Json::num(out.token_len as f64))
                        .with("spans_spliced", Json::num(out.spans_spliced as f64))
                        .with("spans_reencoded", Json::num(out.spans_reencoded as f64))
                        .with("us", Json::num(t0.elapsed().as_micros() as f64)),
                    Err(e) => fail(format!("{e:#}")),
                }
            }
            // Incremental tier: drop a session (idempotent — a second
            // close answers `"closed": false`).
            "session_close" => {
                let Some(sid) =
                    req.get("session").and_then(Json::as_f64).filter(|s| *s >= 0.0)
                else {
                    return fail("missing/invalid 'session' (id from session_open)".into());
                };
                Json::obj()
                    .with("id", id.clone())
                    .with("ok", Json::Bool(true))
                    .with("closed", Json::Bool(service.session_close(sid as u64)))
            }
            // The full stats view flattened into scrape-friendly
            // `name value` text (see `Service::metrics_text`) — what
            // the `mlir-cost metrics` CLI prints for a fleet scraper.
            "metrics" => Json::obj()
                .with("id", id.clone())
                .with("ok", Json::Bool(true))
                .with("metrics", Json::str(service.metrics_text())),
            other => fail(format!("unknown cmd '{other}'")),
        };
    }
    let target = match req.req_str("target").ok().and_then(Target::parse) {
        Some(t) => t,
        None => return fail("missing/invalid 'target'".into()),
    };
    // Batch request: an array of MLIR texts through predict_many.
    if let Some(batch) = req.get("mlir_batch") {
        let Some(items) = batch.as_arr() else {
            return fail("'mlir_batch' must be an array of strings".into());
        };
        let mut texts: Vec<&str> = Vec::with_capacity(items.len());
        for item in items {
            match item.as_str() {
                Some(s) => texts.push(s),
                None => return fail("'mlir_batch' entries must be strings".into()),
            }
        }
        let results = service.predict_many_full(target, &texts, budget_us, &required);
        let predictions: Vec<Json> = results
            .into_iter()
            .map(|r| match r {
                Ok(p) => row_json(&p).with("ok", Json::Bool(true)),
                Err(e) => Json::obj()
                    .with("ok", Json::Bool(false))
                    .with("error", Json::str(format!("{e:#}"))),
            })
            .collect();
        return Json::obj()
            .with("id", id)
            .with("ok", Json::Bool(true))
            .with("predictions", Json::Arr(predictions))
            .with("us", Json::num(t0.elapsed().as_micros() as f64));
    }
    let mlir = match req.req_str("mlir") {
        Ok(m) => m,
        Err(e) => return fail(e.to_string()),
    };
    match service.predict_full(target, mlir, budget_us, &required) {
        Ok(p) => row_json(&p)
            .with("id", id)
            .with("ok", Json::Bool(true))
            .with("us", Json::num(t0.elapsed().as_micros() as f64)),
        Err(e) => fail(format!("{e:#}")),
    }
}

/// The offload classifier: would answering this line inline risk
/// blocking the IO thread? Mirrors [`handle_line`]'s parsing exactly so
/// every malformed-request error stays inline (errors are microseconds)
/// — and stays ADVISORY: a wrong answer costs one line's latency, never
/// correctness, because both paths run the same [`handle_line`].
fn line_would_block(service: &Service, line: &str) -> bool {
    let Ok(req) = parse(line) else {
        return false; // bad json: error answered inline
    };
    if let Some(cmd) = req.get("cmd").and_then(Json::as_str) {
        // `session_open` tokenizes an unseen base and usually executes;
        // `mlir_delta` re-lexes and may miss the cache. Everything else
        // (ping/stats/metrics/cache_get/cache_put/targets/session_close/
        // unknown) is pure local bookkeeping.
        return matches!(cmd, "session_open" | "mlir_delta");
    }
    let Some(target) = req.req_str("target").ok().and_then(Target::parse) else {
        return false; // missing/invalid target: error answered inline
    };
    if req.get("mlir_batch").is_some() {
        // A batch's cost scales with its length and one cold entry
        // executes the model — not worth probing element-wise.
        return true;
    }
    let Ok(mlir) = req.req_str("mlir") else {
        return false;
    };
    let budget_us = match req.get("budget_us") {
        None => None,
        Some(j) => match j.as_f64() {
            Some(b) if b.is_finite() && b >= 0.0 => Some(b as u64),
            _ => return false, // malformed budget: error answered inline
        },
    };
    let required: Vec<Target> = match req.get("targets") {
        None => Vec::new(),
        Some(j) => {
            let Some(items) = j.as_arr() else {
                return false;
            };
            let mut out = Vec::with_capacity(items.len());
            for item in items {
                match item.as_str().and_then(Target::parse) {
                    Some(t) => out.push(t),
                    None => return false, // unknown characteristic: inline error
                }
            }
            out
        }
    };
    // Warm single query (memo'd length + routing + memo'd encoding +
    // cached prediction) answers in microseconds inline; anything
    // colder goes to the pool.
    !service.probe_warm(target, mlir, budget_us, &required)
}

/// The deadline shedder: `Some(rejection)` when this line is a
/// prediction whose `budget_us` is already unmeetable — the fastest
/// credible variant estimate times (1 + offload queue depth) exceeds
/// the budget (see [`super::deadline_unmeetable`]). Advisory like the
/// offload classifier and deliberately conservative: commands, lines
/// without `budget_us`, malformed requests, and cold routers (no
/// latency samples yet) all return `None` and proceed to
/// [`handle_line`], which owns their real answer or error.
fn line_shed(service: &Service, line: &str) -> Option<Json> {
    let req = parse(line).ok()?;
    if req.get("cmd").is_some() {
        return None; // commands carry no prediction deadline
    }
    let budget = req
        .get("budget_us")
        .and_then(Json::as_f64)
        .filter(|b| b.is_finite() && *b >= 0.0)?;
    let target = req.req_str("target").ok().and_then(Target::parse)?;
    let est = service.min_latency_estimate_us(target)?;
    let depth = service.stats.offload_queue_depth.load(Ordering::Relaxed);
    if !super::deadline_unmeetable(est, depth, budget) {
        return None;
    }
    let id = req.get("id").cloned().unwrap_or(Json::Null);
    Some(reject_json(
        id,
        format!(
            "shed_deadline: budget_us {budget} unmeetable \
             (fastest variant ~{est:.0} us, {depth} queued)"
        ),
    ))
}

impl LineService for Service {
    fn stats(&self) -> &super::stats::ServiceStats {
        &self.stats
    }

    fn would_block(&self, line: &str) -> bool {
        line_would_block(self, line)
    }

    fn handle(&self, line: &str) -> Json {
        handle_line(self, line)
    }

    fn shed(&self, line: &str) -> Option<Json> {
        line_shed(self, line)
    }
}

/// Default connect timeout for [`Client::connect`]. Before this existed,
/// a dead peer address could hang the caller on the OS connect default
/// (minutes of SYN retries).
const CLIENT_CONNECT_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(5);

/// io::ErrorKinds that mean "the connection died under us" — the cases
/// [`Client::roundtrip`] absorbs with one reconnect-and-retry. Timeouts
/// are deliberately NOT here: retrying a slow server could double-send.
fn is_disconnect(kind: std::io::ErrorKind) -> bool {
    use std::io::ErrorKind::*;
    matches!(
        kind,
        BrokenPipe | ConnectionReset | ConnectionAborted | NotConnected | UnexpectedEof | WriteZero
    )
}

/// Resolve `addr` and connect with a per-address timeout.
fn connect_stream(addr: &str, timeout: std::time::Duration) -> Result<TcpStream> {
    use std::net::ToSocketAddrs;
    let mut last: Option<std::io::Error> = None;
    for sa in addr.to_socket_addrs().with_context(|| format!("resolving {addr}"))? {
        match TcpStream::connect_timeout(&sa, timeout) {
            Ok(s) => return Ok(s),
            Err(e) => last = Some(e),
        }
    }
    match last {
        Some(e) => Err(anyhow::Error::from(e).context(format!("connecting {addr}"))),
        None => Err(anyhow!("no addresses resolved for {addr}")),
    }
}

/// Minimal blocking client for the line protocol (used by examples, the
/// serving bench, and the cluster tier's peer pool).
///
/// Hardened for pool use: connecting always carries a timeout, requests
/// whose connection died underneath them (server restart, broken pipe)
/// are retried ONCE over a fresh connection — every protocol request is
/// an idempotent query, so a single retry is safe — and an optional IO
/// timeout ([`Client::set_io_timeout`]) bounds how long any roundtrip
/// may block on a hung server.
pub struct Client {
    addr: String,
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    /// Bound used for the initial connect AND any retry reconnect.
    connect_timeout: std::time::Duration,
    io_timeout: Option<std::time::Duration>,
    next_id: u64,
    /// Tenant label stamped onto every request (the server's
    /// quota/fairness identity); `None` = untagged, per-connection
    /// admission.
    tenant: Option<String>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        Client::connect_timeout(addr, CLIENT_CONNECT_TIMEOUT)
    }

    /// Connect with an explicit bound (the peer pool uses a short one —
    /// a cluster node that cannot accept promptly is better served by
    /// the degraded local path).
    pub fn connect_timeout(addr: &str, timeout: std::time::Duration) -> Result<Client> {
        let stream = connect_stream(addr, timeout)?;
        Ok(Client {
            addr: addr.to_string(),
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
            connect_timeout: timeout,
            io_timeout: None,
            next_id: 1,
            tenant: None,
        })
    }

    /// Tag every subsequent request with a tenant label — the server's
    /// quota and fair-queueing identity. Survives reconnects: the
    /// label rides in each request line, not in connection state.
    pub fn set_tenant(&mut self, tenant: &str) {
        self.tenant = Some(tenant.to_string());
    }

    /// Bound every subsequent socket read/write (`None` = block forever,
    /// the default). Survives reconnects.
    pub fn set_io_timeout(&mut self, timeout: Option<std::time::Duration>) -> Result<()> {
        let s = self.writer.get_ref();
        s.set_read_timeout(timeout)?;
        s.set_write_timeout(timeout)?;
        self.io_timeout = timeout;
        Ok(())
    }

    fn next_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    fn reconnect(&mut self) -> Result<()> {
        let stream = connect_stream(&self.addr, self.connect_timeout)?;
        stream.set_read_timeout(self.io_timeout)?;
        stream.set_write_timeout(self.io_timeout)?;
        self.reader = BufReader::new(stream.try_clone()?);
        self.writer = BufWriter::new(stream);
        Ok(())
    }

    /// One request/response over the current connection, at the io
    /// layer: the error kind is what decides retryability.
    fn wire_roundtrip(&mut self, line: &str) -> std::io::Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut resp = String::new();
        if self.reader.read_line(&mut resp)? == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed the connection",
            ));
        }
        Ok(resp)
    }

    fn roundtrip(&mut self, mut req: Json) -> Result<Json> {
        if let Some(t) = &self.tenant {
            req = req.with("tenant", Json::str(t));
        }
        let line = req.to_string();
        let resp_line = match self.wire_roundtrip(&line) {
            Ok(l) => l,
            Err(e) if is_disconnect(e.kind()) => {
                // The connection died mid-request (e.g. the server
                // restarted between requests): reconnect and retry once.
                self.reconnect()
                    .with_context(|| format!("reconnecting {} after: {e}", self.addr))?;
                self.wire_roundtrip(&line)
                    .with_context(|| format!("retry after reconnecting {}", self.addr))?
            }
            Err(e) => return Err(e.into()),
        };
        let resp = parse(&resp_line)?;
        // The response must answer THIS request. After an io timeout
        // (which is not retried) the stream can desynchronize — the
        // previous request's late response arrives first — and without
        // this check the wrong answer would be returned silently.
        if let Some(want) = req.get("id") {
            if resp.get("id") != Some(want) {
                anyhow::bail!(
                    "response id mismatch from {} (sent {want:?}, got {:?}): \
                     connection desynchronized — discard this client",
                    self.addr,
                    resp.get("id"),
                );
            }
        }
        if resp.get("ok").and_then(Json::as_bool) != Some(true) {
            anyhow::bail!(
                "server error: {}",
                resp.get("error").and_then(Json::as_str).unwrap_or("unknown")
            );
        }
        Ok(resp)
    }

    /// Query a prediction.
    pub fn predict(&mut self, target: Target, mlir: &str) -> Result<f64> {
        let id = self.next_id();
        let req = Json::obj()
            .with("id", Json::num(id as f64))
            .with("target", Json::str(target.name()))
            .with("mlir", Json::str(mlir));
        let resp = self.roundtrip(req)?;
        resp.req_f64("prediction")
    }

    /// Query a prediction with an optional latency budget
    /// (`budget_us`); returns `(prediction, serving variant name)` so
    /// callers can observe routing decisions.
    pub fn predict_routed(
        &mut self,
        target: Target,
        mlir: &str,
        budget_us: Option<u64>,
    ) -> Result<(f64, String)> {
        let id = self.next_id();
        let mut req = Json::obj()
            .with("id", Json::num(id as f64))
            .with("target", Json::str(target.name()))
            .with("mlir", Json::str(mlir));
        if let Some(b) = budget_us {
            req = req.with("budget_us", Json::num(b as f64));
        }
        let resp = self.roundtrip(req)?;
        Ok((resp.req_f64("prediction")?, resp.req_str("variant")?.to_string()))
    }

    /// Typed multi-output query: require `targets` (the server routes
    /// only to a variant serving ALL of them, or fails with
    /// `targets_not_served`) and return each requested characteristic's
    /// value in the requested order. With an empty `targets` list the
    /// serving variant's full declared vector comes back in its
    /// declared order.
    pub fn predict_multi(
        &mut self,
        target: Target,
        mlir: &str,
        targets: &[Target],
    ) -> Result<Vec<(Target, f64)>> {
        let id = self.next_id();
        let mut req = Json::obj()
            .with("id", Json::num(id as f64))
            .with("target", Json::str(target.name()))
            .with("mlir", Json::str(mlir));
        if !targets.is_empty() {
            req = req.with(
                "targets",
                Json::Arr(targets.iter().map(|t| Json::str(t.name())).collect()),
            );
        }
        let resp = self.roundtrip(req)?;
        let named = resp.req("predictions")?;
        if targets.is_empty() {
            let obj = named
                .as_obj()
                .ok_or_else(|| anyhow!("'predictions' is not an object"))?;
            return obj
                .iter()
                .map(|(name, v)| {
                    let t = Target::parse(name)
                        .ok_or_else(|| anyhow!("unknown characteristic '{name}' in response"))?;
                    let v = v.as_f64().ok_or_else(|| anyhow!("'{name}' is not a number"))?;
                    Ok((t, v))
                })
                .collect();
        }
        targets
            .iter()
            .map(|&t| Ok((t, named.req_f64(t.name())?)))
            .collect()
    }

    /// Query many predictions in one protocol round trip (`mlir_batch`).
    /// Per-entry results mirror `Service::predict_many`.
    pub fn predict_many(&mut self, target: Target, mlirs: &[&str]) -> Result<Vec<Result<f64>>> {
        let id = self.next_id();
        let req = Json::obj()
            .with("id", Json::num(id as f64))
            .with("target", Json::str(target.name()))
            .with(
                "mlir_batch",
                Json::Arr(mlirs.iter().map(|m| Json::str(*m)).collect()),
            );
        let resp = self.roundtrip(req)?;
        let arr = resp.req_arr("predictions")?;
        Ok(arr
            .iter()
            .map(|p| {
                if p.get("ok").and_then(Json::as_bool) == Some(true) {
                    p.req_f64("prediction")
                } else {
                    Err(anyhow!(
                        "{}",
                        p.get("error").and_then(Json::as_str).unwrap_or("unknown error")
                    ))
                }
            })
            .collect())
    }

    /// Fetch server stats.
    pub fn stats(&mut self) -> Result<Json> {
        let id = self.next_id();
        let req = Json::obj()
            .with("id", Json::num(id as f64))
            .with("cmd", Json::str("stats"));
        Ok(self.roundtrip(req)?.req("stats")?.clone())
    }

    /// Fetch the flat `name value` metrics export (`metrics` command)
    /// — every stats counter, one per line, ready for a scraper.
    pub fn metrics(&mut self) -> Result<String> {
        let id = self.next_id();
        let req = Json::obj()
            .with("id", Json::num(id as f64))
            .with("cmd", Json::str("metrics"));
        Ok(self.roundtrip(req)?.req_str("metrics")?.to_string())
    }

    /// Probe the remote node's prediction cache (`cache_get`):
    /// `Ok(Some(v))` when the remote cache holds the key. The value is
    /// the full characteristic vector; a scalar answer from a
    /// pre-vector node parses as a 1-wide vector.
    pub fn cache_get(&mut self, key: u64) -> Result<Option<PredVec>> {
        let id = self.next_id();
        let req = Json::obj()
            .with("id", Json::num(id as f64))
            .with("cmd", Json::str("cache_get"))
            .with("key", Json::str(super::cache::key_to_wire(key)));
        let resp = self.roundtrip(req)?;
        if resp.get("found").and_then(Json::as_bool) == Some(true) {
            Ok(Some(PredVec::from_json(resp.req("value")?)?))
        } else {
            Ok(None)
        }
    }

    /// Write a computed characteristic vector into the remote node's
    /// prediction cache (`cache_put`). Always sends the array wire
    /// form.
    pub fn cache_put(&mut self, key: u64, value: PredVec) -> Result<()> {
        let id = self.next_id();
        let req = Json::obj()
            .with("id", Json::num(id as f64))
            .with("cmd", Json::str("cache_put"))
            .with("key", Json::str(super::cache::key_to_wire(key)))
            .with("value", value.to_json());
        self.roundtrip(req)?;
        Ok(())
    }

    /// Open an incremental session (`session_open`): register `mlir` as
    /// the base text subsequent [`Client::predict_delta_splices`] /
    /// [`Client::predict_delta_full`] calls edit against. Returns the
    /// session id and the base prediction.
    pub fn session_open(&mut self, target: Target, mlir: &str) -> Result<(u64, f64)> {
        let id = self.next_id();
        let req = Json::obj()
            .with("id", Json::num(id as f64))
            .with("cmd", Json::str("session_open"))
            .with("target", Json::str(target.name()))
            .with("mlir", Json::str(mlir));
        let resp = self.roundtrip(req)?;
        Ok((resp.req_f64("session")? as u64, resp.req_f64("prediction")?))
    }

    /// Predict an edit (`mlir_delta`) expressed as byte-range splices
    /// into the session's base text (each `(start, end, replacement)`
    /// sorted ascending, non-overlapping). Returns the prediction plus
    /// this request's `(spans_spliced, spans_reencoded)` split.
    pub fn predict_delta_splices(
        &mut self,
        session: u64,
        splices: &[(usize, usize, &str)],
        rebase: bool,
    ) -> Result<(f64, u64, u64)> {
        let arr: Vec<Json> = splices
            .iter()
            .map(|&(start, end, text)| {
                Json::obj()
                    .with("start", Json::num(start as f64))
                    .with("end", Json::num(end as f64))
                    .with("text", Json::str(text))
            })
            .collect();
        self.mlir_delta(session, ("splices", Json::Arr(arr)), rebase)
    }

    /// Predict an edit (`mlir_delta`) sent as the full new text; the
    /// server line-diffs it against the session's base so only changed
    /// lines are re-lexed. Returns the prediction plus this request's
    /// `(spans_spliced, spans_reencoded)` split.
    pub fn predict_delta_full(
        &mut self,
        session: u64,
        mlir: &str,
        rebase: bool,
    ) -> Result<(f64, u64, u64)> {
        self.mlir_delta(session, ("mlir", Json::str(mlir)), rebase)
    }

    fn mlir_delta(
        &mut self,
        session: u64,
        (field, body): (&str, Json),
        rebase: bool,
    ) -> Result<(f64, u64, u64)> {
        let id = self.next_id();
        let mut req = Json::obj()
            .with("id", Json::num(id as f64))
            .with("cmd", Json::str("mlir_delta"))
            .with("session", Json::num(session as f64))
            .with(field, body);
        if rebase {
            req = req.with("rebase", Json::Bool(true));
        }
        let resp = self.roundtrip(req)?;
        Ok((
            resp.req_f64("prediction")?,
            resp.req_f64("spans_spliced")? as u64,
            resp.req_f64("spans_reencoded")? as u64,
        ))
    }

    /// Drop an incremental session (`session_close`). `Ok(true)` when
    /// the id was live; closing twice answers `Ok(false)`.
    pub fn session_close(&mut self, session: u64) -> Result<bool> {
        let id = self.next_id();
        let req = Json::obj()
            .with("id", Json::num(id as f64))
            .with("cmd", Json::str("session_close"))
            .with("session", Json::num(session as f64));
        let resp = self.roundtrip(req)?;
        Ok(resp.get("closed").and_then(Json::as_bool) == Some(true))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bundle::Bundle;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::dataset::TargetStats;
    use crate::graphgen::{generate, Family, GraphSpec};
    use crate::mlir::print_function;
    use crate::runtime::Manifest;
    use crate::tokenizer::{Scheme, Vocab};
    use std::path::Path;

    fn service() -> Option<Arc<Service>> {
        let adir = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("artifacts");
        if !adir.join("manifest.json").exists() {
            return None;
        }
        let manifest = Arc::new(Manifest::load(&adir).unwrap());
        let vocab = Vocab::build(vec![vec!["x".to_string()]].iter(), 1);
        let stats = TargetStats { mean: 0.0, std: 1.0, min: 0.0, max: 10.0 };
        let bundle =
            Bundle::untrained(&manifest, "fc_ops", Target::RegPressure, Scheme::OpsOnly, vocab, stats)
                .unwrap();
        Some(Arc::new(
            Service::start(manifest, vec![bundle], BatchPolicy::default(), false).unwrap(),
        ))
    }

    /// A service whose one variant declares TWO characteristics, for the
    /// wire-level multi-output tests.
    fn multi_service() -> Option<Arc<Service>> {
        let adir = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("artifacts");
        if !adir.join("manifest.json").exists() {
            return None;
        }
        let manifest = Arc::new(Manifest::load(&adir).unwrap());
        let vocab = Vocab::build(vec![vec!["x".to_string()]].iter(), 1);
        let bundle = Bundle::untrained_multi(
            &manifest,
            "fc_ops",
            &[Target::Cycles, Target::XpuUtil],
            Scheme::OpsOnly,
            vocab,
            vec![
                TargetStats { mean: 900.0, std: 200.0, min: 100.0, max: 4000.0 },
                TargetStats { mean: 40.0, std: 10.0, min: 0.0, max: 100.0 },
            ],
            Some("xpu-v1".to_string()),
        )
        .unwrap();
        Some(Arc::new(
            Service::start(manifest, vec![bundle], BatchPolicy::default(), false).unwrap(),
        ))
    }

    fn graph(structure_seed: u64, shape_seed: u64) -> String {
        let spec = GraphSpec { family: Family::Mlp, structure_seed, shape_seed };
        print_function(&generate(&spec).unwrap())
    }

    /// Spawn the event-loop server on port 0; returns (addr, stop, join).
    fn spawn_server(
        svc: Arc<Service>,
        io_threads: usize,
    ) -> (String, Arc<Stop>, std::thread::JoinHandle<Result<()>>) {
        let stop = Stop::new();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = {
            let stop = stop.clone();
            let config = ServerConfig { io_threads, ..Default::default() };
            std::thread::spawn(move || serve_on_with(svc, listener, stop, config))
        };
        (addr, stop, server)
    }

    /// Read one `\n`-terminated line from a raw stream.
    fn read_response(stream: &TcpStream) -> String {
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        line
    }

    #[test]
    fn line_protocol_handles_commands() {
        let Some(svc) = service() else { return };
        let pong = handle_line(&svc, r#"{"id": 1, "cmd": "ping"}"#);
        assert_eq!(pong.get("pong").and_then(Json::as_bool), Some(true));
        let stats = handle_line(&svc, r#"{"id": 2, "cmd": "stats"}"#);
        assert!(stats.get("stats").is_some());
        // The merged stats view carries the new pipeline counters.
        let inner = stats.get("stats").unwrap();
        assert!(inner.get("coalesced_queries").is_some());
        assert!(inner.get("cache_shard_contention").is_some());
        assert!(inner.get("batch_fill_ratio").is_some());
        assert!(inner.get("padded_slots").is_some());
        assert!(inner.get("frontend_memo_hits").is_some());
        assert!(inner.get("encode_ns").is_some());
        assert!(inner.get("frontend_memo_entries").is_some());
        // ...and the serving-plane counters from the event-loop front end.
        assert!(inner.get("active_connections").is_some());
        assert!(inner.get("connections_accepted").is_some());
        assert!(inner.get("epoll_wakeups").is_some());
        assert!(inner.get("exec_by_batch").is_some());
        // ...and the cluster-tier + fairness counters, pinned so the
        // JSON shape peers and dashboards rely on cannot silently drop
        // them (they are present, zero, even when no cluster is
        // configured).
        assert!(inner.get("forwarded_gets").is_some());
        assert!(inner.get("remote_hits").is_some());
        assert!(inner.get("forwarded_puts").is_some());
        assert!(inner.get("peer_failures").is_some());
        assert!(inner.get("degraded_fallbacks").is_some());
        assert!(inner.get("fairness_deferrals").is_some());
        // ...and the offload-pool counters, present (zero) from startup
        // even when no request-worker pool is configured.
        assert_eq!(inner.req_f64("offloaded_misses").unwrap(), 0.0);
        assert_eq!(inner.req_f64("io_stall_ns").unwrap(), 0.0);
        assert_eq!(inner.req_f64("offload_queue_depth").unwrap(), 0.0);
        // ...and the routing-tier counters: the per-variant objects plus
        // the budget/coverage counters, present (zero) from startup so
        // dashboards and peers can rely on the shape.
        assert!(inner.get("budget_downgrades").is_some());
        assert!(inner.get("no_covering_variant").is_some());
        assert!(inner.get("len_memo_entries").is_some());
        // ...and the incremental-tier counters, present (zero) from
        // startup so dashboards can rely on the shape.
        assert!(inner.get("frontend_memo_evictions").is_some());
        assert_eq!(inner.req_f64("sessions_open").unwrap(), 0.0);
        assert_eq!(inner.req_f64("delta_requests").unwrap(), 0.0);
        assert_eq!(inner.req_f64("spans_spliced").unwrap(), 0.0);
        assert_eq!(inner.req_f64("spans_reencoded").unwrap(), 0.0);
        assert_eq!(inner.req_f64("delta_bytes_rescanned").unwrap(), 0.0);
        // The multi-output counter is present (zero) from startup.
        assert_eq!(inner.req_f64("targets_not_served").unwrap(), 0.0);
        // ...and the autotune-search counters, present (zero) before
        // any search probes this service.
        assert_eq!(inner.req_f64("search_candidates").unwrap(), 0.0);
        assert_eq!(inner.req_f64("search_probes").unwrap(), 0.0);
        assert_eq!(inner.req_f64("search_delta_probes").unwrap(), 0.0);
        assert_eq!(inner.req_f64("search_ns").unwrap(), 0.0);
        // ...and the admission-tier ledger, present (zero) from startup
        // — these direct handle_line calls never cross line admission,
        // so every side of the conservation invariant is untouched.
        assert_eq!(inner.req_f64("lines_admitted").unwrap(), 0.0);
        assert_eq!(inner.req_f64("lines_answered").unwrap(), 0.0);
        assert_eq!(inner.req_f64("lines_dropped").unwrap(), 0.0);
        assert_eq!(inner.req_f64("over_quota").unwrap(), 0.0);
        assert_eq!(inner.req_f64("shed_deadline").unwrap(), 0.0);
        assert_eq!(inner.req_f64("rejected_overloaded").unwrap(), 0.0);
        assert_eq!(svc.stats.conservation_debt(), 0);
        let routed = inner.get("routed_by_variant").expect("routed_by_variant missing");
        assert_eq!(routed.req_f64("regpressure/fc_ops").unwrap(), 0.0);
        let variants = inner.get("variants").expect("variants missing");
        let v = variants.get("regpressure/fc_ops").expect("variant entry missing");
        assert_eq!(v.req_str("model").unwrap(), "fc_ops");
        // Each variant names its declared characteristics in order.
        let tnames: Vec<&str> =
            v.req_arr("targets").unwrap().iter().filter_map(Json::as_str).collect();
        assert_eq!(tnames, vec!["regpressure"]);
        assert!(v.req_f64("max_len").unwrap() > 0.0);
        assert_eq!(v.req_f64("routed").unwrap(), 0.0);
        assert_eq!(v.req_f64("budget_downgrades").unwrap(), 0.0);
        assert_eq!(v.req_f64("ewma_us").unwrap(), 0.0);
        // The P² sketch reads 0 until it has seen 5 samples.
        assert_eq!(v.req_f64("p95_us").unwrap(), 0.0);
        // The per-variant batch policy is observable from startup:
        // static bounds until (and unless) the adaptive controller
        // retunes them.
        assert!(v.req_f64("policy_max_batch").unwrap() >= 1.0);
        assert!(v.req_f64("policy_max_wait_us").unwrap() > 0.0);
        assert_eq!(v.req_f64("policy_retunes").unwrap(), 0.0);
        assert_eq!(v.req_f64("span_entries").unwrap(), 0.0);
        assert!(inner.get("cluster").is_none(), "unclustered service must omit the peer view");
        // The metrics command exports the same view as flat
        // `name value` text: every admission counter is scrapable and
        // nested variant metrics are dot-joined.
        let metrics = handle_line(&svc, r#"{"id": 9, "cmd": "metrics"}"#);
        assert_eq!(metrics.get("ok").and_then(Json::as_bool), Some(true));
        let text = metrics.req_str("metrics").unwrap();
        for want in [
            "requests ",
            "lines_admitted 0",
            "lines_answered 0",
            "lines_dropped 0",
            "over_quota 0",
            "shed_deadline 0",
            "rejected_overloaded 0",
            "offload_queue_depth 0",
            "variants.regpressure/fc_ops.ewma_us 0",
        ] {
            assert!(
                text.lines().any(|l| l.starts_with(want)),
                "metrics export missing '{want}':\n{text}"
            );
        }
        let targets = handle_line(&svc, r#"{"id": 3, "cmd": "targets"}"#);
        assert_eq!(targets.req_arr("targets").unwrap().len(), 1);
        let bad = handle_line(&svc, "{nope");
        assert_eq!(bad.get("ok").and_then(Json::as_bool), Some(false));
        let missing = handle_line(&svc, r#"{"id": 4}"#);
        assert_eq!(missing.get("ok").and_then(Json::as_bool), Some(false));
    }

    /// The incremental tier's acceptance bar, wire level: editing ONE
    /// line of an N-line function re-lexes only that line —
    /// `spans_spliced` / `spans_reencoded` in the response prove it —
    /// and the spliced encoding lands on the same prediction-cache
    /// entry a full re-encode would (the comment-only edit leaves the
    /// token stream untouched).
    #[test]
    fn session_delta_relexes_only_changed_lines() {
        let Some(svc) = service() else { return };
        let text = graph(31, 32);
        let n_lines = text.lines().count();
        assert!(n_lines >= 3, "graph too small to edit meaningfully");
        let open = handle_line(
            &svc,
            &Json::obj()
                .with("id", Json::num(1.0))
                .with("cmd", Json::str("session_open"))
                .with("target", Json::str("regpressure"))
                .with("mlir", Json::str(text.as_str()))
                .to_string(),
        );
        assert_eq!(open.get("ok").and_then(Json::as_bool), Some(true), "{}", open.to_string());
        let sid = open.req_f64("session").unwrap();
        let base_pred = open.req_f64("prediction").unwrap();
        assert!(open.req_f64("token_len").unwrap() > 0.0);

        // Full-text delta: one middle line gains a trailing comment.
        // The lexer skips comments, so the token stream (and therefore
        // the prediction) is unchanged — but the line's bytes differ,
        // so exactly that one line must be re-lexed.
        let edit_at = n_lines / 2;
        let edited: Vec<String> = text
            .lines()
            .enumerate()
            .map(|(i, l)| if i == edit_at { format!("{l} // tweaked") } else { l.to_string() })
            .collect();
        let resp = handle_line(
            &svc,
            &Json::obj()
                .with("id", Json::num(2.0))
                .with("cmd", Json::str("mlir_delta"))
                .with("session", Json::num(sid))
                .with("mlir", Json::str(edited.join("\n")))
                .to_string(),
        );
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{}", resp.to_string());
        assert_eq!(resp.req_f64("spans_spliced").unwrap(), (n_lines - 1) as f64);
        assert_eq!(resp.req_f64("spans_reencoded").unwrap(), 1.0);
        assert_eq!(resp.req_f64("prediction").unwrap(), base_pred);

        // Splice-form delta against the same (un-rebased) base: insert
        // a different comment at the end of the same line. New bytes →
        // new hash → again exactly one re-lex.
        let line_end: usize =
            text.lines().take(edit_at + 1).map(|l| l.len() + 1).sum::<usize>() - 1;
        let splice = Json::obj()
            .with("start", Json::num(line_end as f64))
            .with("end", Json::num(line_end as f64))
            .with("text", Json::str(" // again"));
        let resp2 = handle_line(
            &svc,
            &Json::obj()
                .with("id", Json::num(3.0))
                .with("cmd", Json::str("mlir_delta"))
                .with("session", Json::num(sid))
                .with("splices", Json::Arr(vec![splice]))
                .to_string(),
        );
        assert_eq!(resp2.get("ok").and_then(Json::as_bool), Some(true), "{}", resp2.to_string());
        assert_eq!(resp2.req_f64("spans_spliced").unwrap(), (n_lines - 1) as f64);
        assert_eq!(resp2.req_f64("spans_reencoded").unwrap(), 1.0);
        assert_eq!(resp2.req_f64("prediction").unwrap(), base_pred);

        // The stats view agrees with the per-response accounting.
        let stats = handle_line(&svc, r#"{"id": 4, "cmd": "stats"}"#);
        let inner = stats.get("stats").unwrap();
        assert_eq!(inner.req_f64("sessions_open").unwrap(), 1.0);
        assert_eq!(inner.req_f64("delta_requests").unwrap(), 2.0);
        assert_eq!(inner.req_f64("spans_spliced").unwrap(), 2.0 * (n_lines - 1) as f64);
        assert_eq!(inner.req_f64("spans_reencoded").unwrap(), 2.0);
        assert!(inner.req_f64("delta_bytes_rescanned").unwrap() > 0.0);
        let v = inner.get("variants").unwrap().get("regpressure/fc_ops").unwrap();
        assert!(v.req_f64("span_entries").unwrap() >= n_lines as f64);

        // Close is observable and idempotent; a delta on a closed
        // session is a clean error.
        let close = handle_line(
            &svc,
            &format!(r#"{{"id": 5, "cmd": "session_close", "session": {sid}}}"#),
        );
        assert_eq!(close.get("closed").and_then(Json::as_bool), Some(true));
        let again = handle_line(
            &svc,
            &format!(r#"{{"id": 6, "cmd": "session_close", "session": {sid}}}"#),
        );
        assert_eq!(again.get("closed").and_then(Json::as_bool), Some(false));
        let stale = handle_line(
            &svc,
            &format!(r#"{{"id": 7, "cmd": "mlir_delta", "session": {sid}, "mlir": "x"}}"#),
        );
        assert_eq!(stale.get("ok").and_then(Json::as_bool), Some(false));
        assert!(stale.req_str("error").unwrap().contains("unknown session"));
        let stats = handle_line(&svc, r#"{"id": 8, "cmd": "stats"}"#);
        assert_eq!(stats.get("stats").unwrap().req_f64("sessions_open").unwrap(), 0.0);

        // Malformed session commands fail at the protocol edge.
        for bad in [
            r#"{"id": 9, "cmd": "session_open", "target": "regpressure"}"#,
            r#"{"id": 10, "cmd": "mlir_delta", "session": 1}"#,
            r#"{"id": 11, "cmd": "mlir_delta", "mlir": "x"}"#,
            r#"{"id": 12, "cmd": "session_close"}"#,
            r#"{"id": 13, "cmd": "mlir_delta", "session": 1, "splices": [{"start": 0}]}"#,
        ] {
            let resp = handle_line(&svc, bad);
            assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false), "accepted: {bad}");
        }
    }

    #[test]
    fn batch_request_over_handle_line() {
        let Some(svc) = service() else { return };
        let text = graph(21, 22);
        let req = Json::obj()
            .with("id", Json::num(5.0))
            .with("target", Json::str("regpressure"))
            .with(
                "mlir_batch",
                Json::Arr(vec![
                    Json::str(text.as_str()),
                    Json::str("not mlir"),
                    Json::str(text.as_str()),
                ]),
            );
        let resp = handle_line(&svc, &req.to_string());
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        let preds = resp.req_arr("predictions").unwrap();
        assert_eq!(preds.len(), 3);
        assert_eq!(preds[0].get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(preds[1].get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(preds[2].get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(
            preds[0].req_f64("prediction").unwrap(),
            preds[2].req_f64("prediction").unwrap()
        );
        // Malformed shapes of the batch field fail whole-request.
        let bad =
            handle_line(&svc, r#"{"id": 6, "target": "regpressure", "mlir_batch": "nope"}"#);
        assert_eq!(bad.get("ok").and_then(Json::as_bool), Some(false));
        let bad2 =
            handle_line(&svc, r#"{"id": 7, "target": "regpressure", "mlir_batch": [1, 2]}"#);
        assert_eq!(bad2.get("ok").and_then(Json::as_bool), Some(false));
    }

    /// Responses name the serving variant, and `budget_us` is
    /// validated at the protocol edge.
    #[test]
    fn predict_response_names_variant_and_validates_budget() {
        let Some(svc) = service() else { return };
        let text = graph(91, 92);
        let req = Json::obj()
            .with("id", Json::num(1.0))
            .with("target", Json::str("regpressure"))
            .with("mlir", Json::str(text.as_str()))
            .with("budget_us", Json::num(1_000_000_000.0));
        let resp = handle_line(&svc, &req.to_string());
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(resp.req_str("variant").unwrap(), "fc_ops");
        // Batch rows carry the variant too.
        let breq = Json::obj()
            .with("id", Json::num(2.0))
            .with("target", Json::str("regpressure"))
            .with("mlir_batch", Json::Arr(vec![Json::str(text.as_str())]));
        let bresp = handle_line(&svc, &breq.to_string());
        let rows = bresp.req_arr("predictions").unwrap();
        assert_eq!(rows[0].req_str("variant").unwrap(), "fc_ops");
        // Malformed budgets fail whole-request, before any routing.
        for bad in [
            r#"{"id": 3, "target": "regpressure", "mlir": "x", "budget_us": -5}"#,
            r#"{"id": 4, "target": "regpressure", "mlir": "x", "budget_us": "fast"}"#,
        ] {
            let resp = handle_line(&svc, bad);
            assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false), "accepted: {bad}");
            assert!(resp.req_str("error").unwrap().contains("budget_us"));
        }
    }

    /// The acceptance bar from the issue, wire level: ONE `mlir` query
    /// against a multi-target bundle returns every declared
    /// characteristic from a single forward pass — no per-target
    /// re-encode or re-execute.
    #[test]
    fn mlir_request_returns_all_characteristics_from_one_pass() {
        let Some(svc) = multi_service() else { return };
        let text = graph(61, 62);
        let req = Json::obj()
            .with("id", Json::num(1.0))
            .with("target", Json::str("cycles"))
            .with("mlir", Json::str(text.as_str()))
            .with(
                "targets",
                Json::Arr(vec![Json::str("cycles"), Json::str("xpuutil")]),
            );
        let resp = handle_line(&svc, &req.to_string());
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "got: {resp}");
        let named = resp.get("predictions").expect("predictions object missing");
        let cycles = named.req_f64("cycles").unwrap();
        let util = named.req_f64("xpuutil").unwrap();
        assert!(cycles.is_finite() && util.is_finite());
        // Back-compat scalar answers the primary (first declared) target.
        assert_eq!(resp.req_f64("prediction").unwrap(), cycles);
        assert_eq!(resp.req_str("variant").unwrap(), "fc_ops");
        // ONE model invocation produced both characteristics.
        assert_eq!(svc.stats.batched_queries.load(Ordering::Relaxed), 1);
        // Malformed `targets` shapes fail whole-request.
        for bad in [
            r#"{"id": 2, "target": "cycles", "mlir": "x", "targets": "cycles"}"#,
            r#"{"id": 3, "target": "cycles", "mlir": "x", "targets": ["warp_speed"]}"#,
        ] {
            let resp = handle_line(&svc, bad);
            assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false), "accepted: {bad}");
        }
    }

    /// Requesting a characteristic the serving variants cannot cover is
    /// a clean `targets_not_served` error on the wire — never a silent
    /// partial answer.
    #[test]
    fn unserved_targets_fail_cleanly_on_the_wire() {
        let Some(svc) = service() else { return };
        let text = graph(71, 72);
        let req = Json::obj()
            .with("id", Json::num(1.0))
            .with("target", Json::str("regpressure"))
            .with("mlir", Json::str(text.as_str()))
            .with(
                "targets",
                Json::Arr(vec![Json::str("regpressure"), Json::str("cycles")]),
            );
        let resp = handle_line(&svc, &req.to_string());
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        let msg = resp.req_str("error").unwrap();
        assert!(msg.contains("targets_not_served"), "unexpected error: {msg}");
        assert!(msg.contains("cycles"), "missing characteristic not named: {msg}");
        assert_eq!(svc.stats.targets_not_served.load(Ordering::Relaxed), 1);
        // The same request without the extra requirement succeeds.
        let ok = handle_line(
            &svc,
            &Json::obj()
                .with("id", Json::num(2.0))
                .with("target", Json::str("regpressure"))
                .with("mlir", Json::str(text.as_str()))
                .to_string(),
        );
        assert_eq!(ok.get("ok").and_then(Json::as_bool), Some(true));
        // Single-target responses carry the named object too.
        assert!(
            ok.get("predictions").and_then(|p| p.get("regpressure")).is_some(),
            "single-target response must still name its characteristic"
        );
    }

    /// The typed multi-output client accessor over TCP.
    #[test]
    fn client_predict_multi_over_tcp() {
        let Some(svc) = multi_service() else { return };
        let (addr, stop, server) = spawn_server(svc.clone(), 1);
        let mut client = Client::connect(&addr).unwrap();
        let text = graph(81, 82);
        let pairs = client
            .predict_multi(Target::Cycles, &text, &[Target::Cycles, Target::XpuUtil])
            .unwrap();
        assert_eq!(pairs.len(), 2);
        assert_eq!(pairs[0].0, Target::Cycles);
        assert_eq!(pairs[1].0, Target::XpuUtil);
        assert!(pairs.iter().all(|(_, v)| v.is_finite()));
        // Scalar accessor agrees with the primary characteristic.
        let scalar = client.predict(Target::Cycles, &text).unwrap();
        assert_eq!(scalar, pairs[0].1);
        // Empty requirement list: the client reads back whatever the
        // serving variant declares.
        let all = client.predict_multi(Target::Cycles, &text, &[]).unwrap();
        assert_eq!(all.len(), 2);
        stop.trigger();
        let _ = server.join();
    }

    #[test]
    fn tcp_roundtrip_with_client() {
        let Some(svc) = service() else { return };
        let (addr, stop, server) = spawn_server(svc.clone(), 1);
        let mut client = Client::connect(&addr).unwrap();
        let text = graph(3, 4);
        let v = client.predict(Target::RegPressure, &text).unwrap();
        assert!(v.is_finite());
        // Batch request over the wire: mixed valid/invalid entries.
        let text2 = graph(5, 6);
        let many = client
            .predict_many(Target::RegPressure, &[text.as_str(), "not mlir", text2.as_str()])
            .unwrap();
        assert_eq!(many.len(), 3);
        assert_eq!(many[0].as_ref().unwrap(), &v, "cached value must match");
        assert!(many[1].is_err());
        assert!(many[2].as_ref().unwrap().is_finite());
        let stats = client.stats().unwrap();
        assert!(stats.req_f64("requests").unwrap() >= 4.0);
        assert!(stats.req_f64("batch_requests").unwrap() >= 1.0);
        assert!(stats.req_f64("connections_accepted").unwrap() >= 1.0);
        assert!(stats.req_f64("active_connections").unwrap() >= 1.0);
        assert!(stats.req_f64("epoll_wakeups").unwrap() >= 1.0);
        stop.trigger();
        let _ = server.join();
    }

    /// Regression for the partial-read bug AND the event loop's
    /// reassembly-by-construction: a request that arrives in two TCP
    /// segments with a long pause between them must still be answered.
    /// The pause (300 ms) exceeds the threaded baseline's 200 ms read
    /// timeout, so the old clear-at-loop-top bug would have discarded
    /// the first segment.
    #[test]
    fn split_write_request_reassembled_across_segments() {
        let Some(svc) = service() else { return };
        let (addr, stop, server) = spawn_server(svc.clone(), 1);
        let mut stream = TcpStream::connect(&addr).unwrap();
        stream.set_nodelay(true).unwrap();
        stream.write_all(br#"{"id": 1, "cmd": "pi"#).unwrap();
        stream.flush().unwrap();
        std::thread::sleep(std::time::Duration::from_millis(300));
        stream.write_all(b"ng\"}\n").unwrap();
        stream.flush().unwrap();
        let line = read_response(&stream);
        let resp = parse(&line).unwrap();
        assert_eq!(resp.get("pong").and_then(Json::as_bool), Some(true), "got: {line}");
        stop.trigger();
        let _ = server.join();
    }

    /// Same split-write scenario against the threaded baseline: its read
    /// timeout fires mid-request, and the partial bytes must survive.
    #[test]
    fn split_write_survives_threaded_baseline_timeout() {
        let Some(svc) = service() else { return };
        let stop = Stop::new();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = {
            let stop = stop.clone();
            std::thread::spawn(move || serve_on_threaded(svc, listener, stop))
        };
        let mut stream = TcpStream::connect(&addr).unwrap();
        stream.set_nodelay(true).unwrap();
        stream.write_all(br#"{"id": 2, "cmd": "pi"#).unwrap();
        stream.flush().unwrap();
        // > 200 ms: at least one read timeout fires while the request is
        // half-received.
        std::thread::sleep(std::time::Duration::from_millis(450));
        stream.write_all(b"ng\"}\n").unwrap();
        stream.flush().unwrap();
        let line = read_response(&stream);
        let resp = parse(&line).unwrap();
        assert_eq!(resp.get("pong").and_then(Json::as_bool), Some(true), "got: {line}");
        stop.trigger();
        let _ = server.join();
    }

    /// Two requests in ONE TCP segment: the loop must answer both from a
    /// single readiness event (multiple lines per read buffer).
    #[test]
    fn pipelined_requests_in_one_segment() {
        let Some(svc) = service() else { return };
        let (addr, stop, server) = spawn_server(svc.clone(), 1);
        let stream = TcpStream::connect(&addr).unwrap();
        (&stream)
            .write_all(b"{\"id\": 1, \"cmd\": \"ping\"}\n{\"id\": 2, \"cmd\": \"ping\"}\n")
            .unwrap();
        let mut reader = BufReader::new(&stream);
        for expect_id in [1.0, 2.0] {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let resp = parse(&line).unwrap();
            assert_eq!(resp.req_f64("id").unwrap(), expect_id);
            assert_eq!(resp.get("pong").and_then(Json::as_bool), Some(true));
        }
        stop.trigger();
        let _ = server.join();
    }

    /// The acceptance bar from the issue: ≥256 concurrent connections on
    /// a single IO thread, all answered, with the serving-plane gauges
    /// moving. Thread-per-connection would need 256 OS threads here; the
    /// event loop holds them all in one.
    #[test]
    fn event_loop_holds_256_concurrent_connections_on_one_io_thread() {
        let Some(svc) = service() else { return };
        let (addr, stop, server) = spawn_server(svc.clone(), 1);
        let conns: Vec<TcpStream> =
            (0..256).map(|_| TcpStream::connect(&addr).unwrap()).collect();
        // All connections write before any reads: every socket is
        // simultaneously live on the server.
        for (i, c) in conns.iter().enumerate() {
            (&*c).write_all(format!("{{\"id\": {i}, \"cmd\": \"ping\"}}\n").as_bytes()).unwrap();
        }
        for (i, c) in conns.iter().enumerate() {
            let line = read_response(c);
            let resp = parse(&line).unwrap();
            assert_eq!(resp.req_f64("id").unwrap() as usize, i);
            assert_eq!(resp.get("pong").and_then(Json::as_bool), Some(true));
        }
        // Every connection answered ⇒ every connection is registered.
        assert_eq!(svc.stats.active_connections.load(Ordering::Relaxed), 256);
        assert!(svc.stats.connections_accepted.load(Ordering::Relaxed) >= 256);
        assert!(svc.stats.epoll_wakeups.load(Ordering::Relaxed) > 0);
        drop(conns);
        stop.trigger();
        let _ = server.join();
        // Teardown drains the gauge.
        assert_eq!(svc.stats.active_connections.load(Ordering::Relaxed), 0);
    }

    /// Multi-loop config: connections are dealt round-robin across IO
    /// threads and all of them serve predictions.
    #[test]
    fn multiple_io_threads_share_the_accept_stream() {
        let Some(svc) = service() else { return };
        let (addr, stop, server) = spawn_server(svc.clone(), 3);
        let text = graph(41, 42);
        let mut clients: Vec<Client> =
            (0..9).map(|_| Client::connect(&addr).unwrap()).collect();
        for client in clients.iter_mut() {
            let v = client.predict(Target::RegPressure, &text).unwrap();
            assert!(v.is_finite());
        }
        assert_eq!(svc.stats.active_connections.load(Ordering::Relaxed), 9);
        drop(clients);
        stop.trigger();
        let _ = server.join();
    }

    /// Trigger-before-serve must not hang: the doorbell registration
    /// path signals immediately when the stop already fired.
    #[test]
    fn pre_triggered_stop_exits_immediately() {
        let Some(svc) = service() else { return };
        let stop = Stop::new();
        stop.trigger();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let t0 = Instant::now();
        serve_on(svc, listener, stop).unwrap();
        assert!(t0.elapsed() < std::time::Duration::from_secs(2));
    }

    /// The cluster tier's peer commands: hex-keyed get/put straight
    /// against the local prediction cache, plus the malformed shapes.
    #[test]
    fn cache_get_put_commands() {
        let Some(svc) = service() else { return };
        let key = crate::coordinator::cache::cache_key("fc_ops", &[1, 2, 3]);
        let wire = crate::coordinator::cache::key_to_wire(key);
        // Miss first.
        let miss =
            handle_line(&svc, &format!(r#"{{"id": 1, "cmd": "cache_get", "key": "{wire}"}}"#));
        assert_eq!(miss.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(miss.get("found").and_then(Json::as_bool), Some(false));
        // Put with the LEGACY scalar form (a bare number): still accepted
        // on read for old peers, answered in the new array form.
        let put = handle_line(
            &svc,
            &format!(r#"{{"id": 2, "cmd": "cache_put", "key": "{wire}", "value": 12.5}}"#),
        );
        assert_eq!(put.get("stored").and_then(Json::as_bool), Some(true));
        let hit =
            handle_line(&svc, &format!(r#"{{"id": 3, "cmd": "cache_get", "key": "{wire}"}}"#));
        assert_eq!(hit.get("found").and_then(Json::as_bool), Some(true));
        let got = PredVec::from_json(hit.req("value").unwrap()).unwrap();
        assert_eq!(got, PredVec::scalar(12.5));
        assert!(hit.req_arr("value").is_ok(), "cache_get must answer the array form");
        // Malformed keys and values fail cleanly.
        for bad in [
            r#"{"id": 4, "cmd": "cache_get"}"#,
            r#"{"id": 5, "cmd": "cache_get", "key": "zzz"}"#,
            r#"{"id": 6, "cmd": "cache_put", "key": "00ff"}"#,
        ] {
            let resp = handle_line(&svc, bad);
            assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false), "accepted: {bad}");
        }
    }

    /// Wire-value fidelity for the vector cache protocol: arrays round-
    /// trip bit-exactly across magnitudes, the legacy scalar form stays
    /// readable, and malformed vectors are rejected at the edge.
    #[test]
    fn cache_wire_values_round_trip_vectors() {
        let Some(svc) = service() else { return };
        let key = crate::coordinator::cache::cache_key("fc_ops", &[7, 7, 7]);
        let wire = crate::coordinator::cache::key_to_wire(key);
        // A full vector spanning large and tiny magnitudes.
        let put = handle_line(
            &svc,
            &format!(
                r#"{{"id": 1, "cmd": "cache_put", "key": "{wire}", "value": [1e300, 1e-300, -2.5]}}"#
            ),
        );
        assert_eq!(put.get("stored").and_then(Json::as_bool), Some(true));
        let hit =
            handle_line(&svc, &format!(r#"{{"id": 2, "cmd": "cache_get", "key": "{wire}"}}"#));
        assert_eq!(hit.get("found").and_then(Json::as_bool), Some(true));
        let got = PredVec::from_json(hit.req("value").unwrap()).unwrap();
        assert_eq!(got, PredVec::from_slice(&[1e300, 1e-300, -2.5]));
        // Malformed vector shapes fail whole-request: empty, too wide,
        // non-numeric element, non-finite element, wrong type.
        for bad_value in [
            "[]",
            "[1, 2, 3, 4, 5]",
            r#"[1, "x"]"#,
            r#"[1e999]"#,
            r#""3.5""#,
        ] {
            let resp = handle_line(
                &svc,
                &format!(r#"{{"id": 3, "cmd": "cache_put", "key": "{wire}", "value": {bad_value}}}"#),
            );
            assert_eq!(
                resp.get("ok").and_then(Json::as_bool),
                Some(false),
                "accepted value {bad_value}"
            );
        }
        // The rejects above must not have clobbered the stored vector.
        let again =
            handle_line(&svc, &format!(r#"{{"id": 4, "cmd": "cache_get", "key": "{wire}"}}"#));
        assert_eq!(
            PredVec::from_json(again.req("value").unwrap()).unwrap(),
            PredVec::from_slice(&[1e300, 1e-300, -2.5])
        );
    }

    /// Client cache helpers over the wire: a value put through one
    /// client is visible to another — the exact path peer write-backs
    /// and remote probes ride.
    #[test]
    fn client_cache_roundtrip_over_tcp() {
        let Some(svc) = service() else { return };
        let (addr, stop, server) = spawn_server(svc.clone(), 1);
        let mut a = Client::connect(&addr).unwrap();
        let mut b = Client::connect(&addr).unwrap();
        let key = crate::coordinator::cache::cache_key("fc_ops", &[9, 9]);
        assert_eq!(a.cache_get(key).unwrap(), None);
        a.cache_put(key, PredVec::scalar(3.25)).unwrap();
        assert_eq!(b.cache_get(key).unwrap(), Some(PredVec::scalar(3.25)));
        // Vector values ride the same path.
        let vkey = crate::coordinator::cache::cache_key("fc_ops", &[9, 10]);
        let vec2 = PredVec::from_slice(&[880.0, 61.5]);
        a.cache_put(vkey, vec2).unwrap();
        assert_eq!(b.cache_get(vkey).unwrap(), Some(vec2));
        stop.trigger();
        let _ = server.join();
    }

    /// Client hardening (the peer pool's safety net): a server that
    /// accepts and immediately closes the first connection must cost one
    /// transparent reconnect, not an error.
    #[test]
    fn client_retries_once_over_a_fresh_connection() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            // First connection: accept, then slam the door.
            let (first, _) = listener.accept().unwrap();
            drop(first);
            // Second connection (the retry): answer one ping properly.
            let (second, _) = listener.accept().unwrap();
            let mut writer = second.try_clone().unwrap();
            let mut reader = BufReader::new(second);
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let req = parse(&line).unwrap();
            assert_eq!(req.get("cmd").and_then(Json::as_str), Some("ping"));
            let resp = Json::obj()
                .with("id", req.get("id").cloned().unwrap_or(Json::Null))
                .with("ok", Json::Bool(true))
                .with("pong", Json::Bool(true));
            writer.write_all(resp.to_string().as_bytes()).unwrap();
            writer.write_all(b"\n").unwrap();
        });
        let mut client = Client::connect(&addr).unwrap();
        // Let the server-side drop (and any RST) land before writing.
        std::thread::sleep(std::time::Duration::from_millis(100));
        let id = client.next_id();
        let resp = client
            .roundtrip(
                Json::obj()
                    .with("id", Json::num(id as f64))
                    .with("cmd", Json::str("ping")),
            )
            .expect("roundtrip must survive the dead first connection");
        assert_eq!(resp.get("pong").and_then(Json::as_bool), Some(true));
        server.join().unwrap();
    }

    /// Connecting to a dead address returns promptly (connect timeout /
    /// refused) instead of hanging on the OS default.
    #[test]
    fn connect_timeout_does_not_hang() {
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = l.local_addr().unwrap().to_string();
            drop(l);
            addr
        };
        let t0 = Instant::now();
        let res = Client::connect_timeout(&dead, std::time::Duration::from_millis(300));
        assert!(res.is_err());
        assert!(t0.elapsed() < std::time::Duration::from_secs(5), "connect hung");
    }

    /// Fairness regression: one connection pipelining thousands of
    /// requests in a single burst must not monopolize the IO thread. An
    /// interactive connection keeps getting answers while the flood is
    /// being worked through, the flooder still receives every response
    /// in order, and the budget scheduler visibly engaged
    /// (`fairness_deferrals` > 0 — a 4096-line burst is two orders of
    /// magnitude over FAIR_LINE_BUDGET, so at least one wakeup must
    /// have requeued it).
    #[test]
    fn flooding_connection_does_not_starve_interactive_one() {
        let Some(svc) = service() else { return };
        let (addr, stop, server) = spawn_server(svc.clone(), 1);
        let flood_n: usize = 4096;
        let mut flood = TcpStream::connect(&addr).unwrap();
        flood.set_nodelay(true).unwrap();
        let mut interactive = Client::connect(&addr).unwrap();
        // One giant pipelined burst...
        let mut burst = String::with_capacity(flood_n * 32);
        for i in 0..flood_n {
            burst.push_str(&format!("{{\"id\": {i}, \"cmd\": \"ping\"}}\n"));
        }
        flood.write_all(burst.as_bytes()).unwrap();
        flood.flush().unwrap();
        // ...while the interactive connection keeps conversing.
        for _ in 0..10 {
            let stats = interactive.stats().unwrap();
            assert!(stats.req_f64("requests").unwrap() >= 0.0);
        }
        // The flooder gets all its responses, in order.
        let mut reader = BufReader::new(&flood);
        for i in 0..flood_n {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let resp = parse(&line).unwrap();
            assert_eq!(resp.req_f64("id").unwrap() as usize, i, "flood responses reordered");
        }
        assert!(
            svc.stats.fairness_deferrals.load(Ordering::Relaxed) > 0,
            "the line budget never engaged on a {flood_n}-line burst"
        );
        stop.trigger();
        let _ = server.join();
        // Every line of the burst plus the interactive conversation
        // settled as answered — no quotas configured, nothing shed or
        // dropped, and the ledger balances at quiescence.
        assert!(svc.stats.lines_admitted.load(Ordering::Relaxed) >= flood_n as u64);
        assert_eq!(svc.stats.over_quota.load(Ordering::Relaxed), 0);
        assert_eq!(svc.stats.shed_deadline.load(Ordering::Relaxed), 0);
        assert_eq!(svc.stats.conservation_debt(), 0, "admission ledger out of balance");
    }

    /// Artifact-free stand-in for a model head behind the
    /// [`LineService`] seam: any line containing `"slow"` sleeps for
    /// `delay` (the deliberately slow model execution) and is classified
    /// would-block; everything else echoes immediately.
    struct SlowHead {
        stats: crate::coordinator::stats::ServiceStats,
        delay: std::time::Duration,
    }

    impl SlowHead {
        fn new(delay_ms: u64) -> Arc<SlowHead> {
            Arc::new(SlowHead {
                stats: Default::default(),
                delay: std::time::Duration::from_millis(delay_ms),
            })
        }
    }

    impl LineService for SlowHead {
        fn stats(&self) -> &crate::coordinator::stats::ServiceStats {
            &self.stats
        }

        fn would_block(&self, line: &str) -> bool {
            line.contains("slow")
        }

        fn handle(&self, line: &str) -> Json {
            if line.contains("slow") {
                std::thread::sleep(self.delay);
            }
            Json::obj().with("ok", Json::Bool(true)).with("echo", Json::str(line))
        }
    }

    /// Spawn `serve_loops` over a fake service; returns (addr, stop, join).
    fn spawn_fake(
        svc: Arc<dyn LineService>,
        config: ServerConfig,
    ) -> (String, Arc<Stop>, std::thread::JoinHandle<Result<()>>) {
        let stop = Stop::new();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = {
            let stop = stop.clone();
            std::thread::spawn(move || serve_loops(svc, vec![listener], stop, config))
        };
        (addr, stop, server)
    }

    /// The offload acceptance bar: a deliberately slow model head on one
    /// connection must not delay cache-hit-speed responses on a sibling
    /// connection of the SAME io loop. One loop, one request worker —
    /// without the offload pool the slow line would hold the loop for
    /// its full duration and the sibling's answer would arrive after it.
    #[test]
    fn slow_head_does_not_stall_siblings_on_the_same_loop() {
        let svc = SlowHead::new(500);
        let config = ServerConfig { io_threads: 1, request_workers: 1, ..Default::default() };
        let (addr, stop, server) = spawn_fake(svc.clone(), config);

        let mut slow_conn = TcpStream::connect(&addr).unwrap();
        let mut fast_conn = TcpStream::connect(&addr).unwrap();
        slow_conn.write_all(b"{\"kind\": \"slow\"}\n").unwrap();
        // Give the loop a beat to pick up the slow line and park it on
        // the worker before the sibling's request lands.
        std::thread::sleep(std::time::Duration::from_millis(50));
        let t0 = Instant::now();
        fast_conn.write_all(b"{\"kind\": \"fast\"}\n").unwrap();
        let fast_resp = read_response(&fast_conn);
        let fast_latency = t0.elapsed();
        assert!(fast_resp.contains("fast"));
        // Generous bound: far under the 500 ms the slow head is holding
        // a WORKER for. If the slow line had run on the io thread, this
        // response could not have arrived before it finished.
        assert!(
            fast_latency < std::time::Duration::from_millis(250),
            "sibling stalled {fast_latency:?} behind an offloaded slow line"
        );
        // The slow connection still gets its (correct) answer.
        let slow_resp = read_response(&slow_conn);
        assert!(slow_resp.contains("slow"));
        assert_eq!(svc.stats.offloaded_misses.load(Ordering::Relaxed), 1);
        assert_eq!(svc.stats.io_stall_ns.load(Ordering::Relaxed), 0);
        assert_eq!(svc.stats.offload_queue_depth.load(Ordering::Relaxed), 0);
        stop.trigger();
        let _ = server.join();
        // At quiescence every admitted line settled: one offloaded
        // (answered via its completion), one inline.
        assert_eq!(svc.stats.lines_admitted.load(Ordering::Relaxed), 2);
        assert_eq!(svc.stats.conservation_debt(), 0, "admission ledger out of balance");
    }

    /// Per-connection ordering across the offload boundary: a pipelined
    /// slow-then-fast pair on ONE connection must come back in submit
    /// order — the fast line waits behind the parked slow one even
    /// though it could have been answered inline immediately.
    #[test]
    fn offloaded_line_preserves_per_connection_order() {
        let svc = SlowHead::new(200);
        let config = ServerConfig { io_threads: 1, request_workers: 2, ..Default::default() };
        let (addr, stop, server) = spawn_fake(svc.clone(), config);

        let mut conn = TcpStream::connect(&addr).unwrap();
        conn.write_all(b"{\"a\": \"slow\"}\n{\"b\": \"fast\"}\n").unwrap();
        let mut reader = BufReader::new(&conn);
        let mut first = String::new();
        reader.read_line(&mut first).unwrap();
        let mut second = String::new();
        reader.read_line(&mut second).unwrap();
        assert!(first.contains("slow"), "responses reordered: got {first:?} first");
        assert!(second.contains("fast"));
        stop.trigger();
        let _ = server.join();
        // Both pipelined lines settled in the admission ledger — the
        // offloaded one through its completion.
        assert_eq!(svc.stats.lines_admitted.load(Ordering::Relaxed), 2);
        assert_eq!(svc.stats.conservation_debt(), 0, "admission ledger out of balance");
    }

    /// Deadline shedding end-to-end on the REAL service: with the only
    /// variant's latency EWMA seeded far above a request's `budget_us`,
    /// admission answers a typed `shed_deadline` error before any model
    /// work — and the SAME request without a budget is never shed (the
    /// acceptance bar: no `budget_us`, no shedding).
    #[test]
    fn shed_deadline_fires_only_when_a_budget_is_supplied() {
        let Some(svc) = service() else { return };
        svc.set_variant_ewma_us(Target::RegPressure, "fc_ops", 50_000.0).unwrap();
        let config = ServerConfig { shed_deadlines: true, ..Default::default() };
        let (addr, stop, server) = spawn_fake(svc.clone(), config);
        let mut conn = TcpStream::connect(&addr).unwrap();
        let mlir = graph(1, 1);
        let doomed = Json::obj()
            .with("id", Json::num(1.0))
            .with("target", Json::str("regpressure"))
            .with("mlir", Json::str(&mlir))
            .with("budget_us", Json::num(100.0));
        conn.write_all(format!("{doomed}\n").as_bytes()).unwrap();
        let resp = parse(&read_response(&conn)).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        assert!(
            resp.req_str("error").unwrap().starts_with("shed_deadline"),
            "expected a shed_deadline error, got {resp}"
        );
        // No budget: the request must be handled normally, never shed.
        let plain = Json::obj()
            .with("id", Json::num(2.0))
            .with("target", Json::str("regpressure"))
            .with("mlir", Json::str(&mlir));
        conn.write_all(format!("{plain}\n").as_bytes()).unwrap();
        let resp = parse(&read_response(&conn)).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(svc.stats.shed_deadline.load(Ordering::Relaxed), 1);
        stop.trigger();
        let _ = server.join();
        assert_eq!(svc.stats.conservation_debt(), 0, "admission ledger out of balance");
    }

    /// Accept sharding end-to-end: two reuseport listeners on one
    /// address, each owned by its own io loop, every connection gets
    /// answered no matter which listener the kernel handed it to.
    #[test]
    fn reuseport_sharded_accept_serves_all_connections() {
        let listeners = match bind_reuseport_set("127.0.0.1:0", 2) {
            Ok(l) => l,
            Err(e) => {
                eprintln!("skipping: SO_REUSEPORT unsupported here ({e:#})");
                return;
            }
        };
        let addr = listeners[0].local_addr().unwrap().to_string();
        let svc = SlowHead::new(0);
        let stop = Stop::new();
        let config = ServerConfig {
            io_threads: 2,
            request_workers: 0,
            reuseport: true,
            ..Default::default()
        };
        let server = {
            let stop = stop.clone();
            let svc: Arc<dyn LineService> = svc.clone();
            std::thread::spawn(move || serve_loops(svc, listeners, stop, config))
        };
        for i in 0..8 {
            let mut conn = TcpStream::connect(&addr).unwrap();
            conn.write_all(format!("{{\"i\": {i}}}\n").as_bytes()).unwrap();
            let resp = read_response(&conn);
            assert!(resp.contains(&format!("\\\"i\\\": {i}")) || resp.contains("echo"));
        }
        assert_eq!(svc.stats.connections_accepted.load(Ordering::Relaxed), 8);
        stop.trigger();
        let _ = server.join();
    }

    /// The quota primitive, deterministic via the explicit clock: burst
    /// drains, refill accrues at `rate`, banking caps at `burst`.
    #[test]
    fn token_bucket_refills_at_rate_and_caps_at_burst() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(10.0, 2.0);
        assert!(b.try_take_at(1.0, t0));
        assert!(b.try_take_at(1.0, t0));
        assert!(!b.try_take_at(1.0, t0), "burst exhausted, same instant");
        // 100 ms at 10 tokens/s refills one token — and only one.
        let t1 = t0 + std::time::Duration::from_millis(100);
        assert!(b.try_take_at(1.0, t1));
        assert!(!b.try_take_at(1.0, t1));
        // A long idle stretch banks at most `burst`, not rate × time.
        let t2 = t1 + std::time::Duration::from_secs(3600);
        assert!(b.try_take_at(1.0, t2));
        assert!(b.try_take_at(1.0, t2));
        assert!(!b.try_take_at(1.0, t2), "banked more than the burst");
    }

    /// A clock that does not advance (or an Instant from before the
    /// bucket's creation) must not mint tokens.
    #[test]
    fn token_bucket_never_refills_backwards() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(1000.0, 1.0);
        assert!(b.try_take_at(1.0, t0));
        for _ in 0..100 {
            assert!(!b.try_take_at(1.0, t0));
        }
    }
}

//! TCP front end: newline-delimited JSON, one request per line.
//!
//! Request:  {"id": 7, "target": "regpressure", "mlir": "func.func @f..."}
//!           {"id": 10, "target": "regpressure", "mlir_batch": ["func.func @a...", "func.func @b..."]}
//!           {"id": 8, "cmd": "stats"}
//!           {"id": 9, "cmd": "ping"}
//! Response: {"id": 7, "ok": true, "prediction": 27.4, "us": 812}
//!           {"id": 10, "ok": true, "predictions": [{"ok": true, "prediction": 27.4},
//!                                                  {"ok": false, "error": "..."}], "us": 930}
//!           {"id": 8, "ok": true, "stats": {...}}
//!           {"id": 7, "ok": false, "error": "..."}
//!
//! `mlir_batch` is the batch API: the whole array travels the
//! parse→cache→batcher pipeline in one `Service::predict_many` call (all
//! cache misses enter the batch queue together), and per-entry failures
//! come back in-position without failing the rest. The `stats` command
//! returns the merged service + cache view, including the serving-plane
//! counters `active_connections` / `connections_accepted` /
//! `epoll_wakeups` / `exec_by_batch` next to the pipeline counters from
//! earlier PRs (`coalesced_queries`, `batch_fill_ratio`, `padded_slots`,
//! `frontend_memo_hits`, ...).
//!
//! A DL-compiler links a 30-line client (see `examples/`) and calls this
//! from its pass pipeline. The front end is a readiness-driven event
//! loop over the vendored [`minipoll`] epoll bindings — still no tokio,
//! but no longer a thread per connection either: one (or `--io-threads
//! N`) event-loop thread(s) own every connection as a nonblocking socket
//! with per-connection read/write buffers. Partial request lines are
//! reassembled across TCP segments by construction (bytes accumulate in
//! the connection's read buffer until a `\n` arrives), short writes park
//! the remainder in the write buffer and re-arm `EPOLLOUT`, and shutdown
//! is an eventfd doorbell — no accept polling, no read timeouts, idle
//! connections cost zero CPU. An autotuning fleet can hold hundreds of
//! mostly-idle probe connections open for the price of their buffers.
//!
//! Request *processing* (including a cache-miss model invocation) runs
//! on the IO thread that owns the connection: cache hits and memo hits
//! are microseconds, and miss-heavy concurrent traffic scales across
//! `--io-threads` loops (each loop handles its connections' requests in
//! parallel with the others). Offloading misses to the batch workers
//! without breaking per-connection response order is a noted ROADMAP
//! follow-on.
//!
//! The old thread-per-connection loop survives as
//! [`serve_on_threaded`], kept as the baseline the serving bench
//! (`benches/e3_serving.rs`) compares the event loop against.

use super::Service;
use crate::json::{parse, Json};
use crate::sim::Target;
use anyhow::{anyhow, Context, Result};
use minipoll::{Epoll, EventFd, Events, EPOLLIN, EPOLLOUT, EPOLLRDHUP};
use std::collections::VecDeque;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Shutdown signal shared by the front end's threads: an atomic flag
/// plus the eventfd doorbells of every event loop that must be woken to
/// observe it. `trigger()` is the only way the server stops.
pub struct Stop {
    flag: AtomicBool,
    wakers: Mutex<Vec<Arc<EventFd>>>,
}

impl Stop {
    #[allow(clippy::new_ret_no_self)]
    pub fn new() -> Arc<Stop> {
        Arc::new(Stop { flag: AtomicBool::new(false), wakers: Mutex::new(Vec::new()) })
    }

    /// Flip the flag and ring every registered event loop's doorbell.
    pub fn trigger(&self) {
        self.flag.store(true, Ordering::SeqCst);
        for w in self.wakers.lock().unwrap().iter() {
            w.signal();
        }
    }

    pub fn is_triggered(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }

    /// Attach a loop's doorbell. Signals immediately if the stop already
    /// fired, so registration can never miss a trigger.
    fn register(&self, efd: &Arc<EventFd>) {
        self.wakers.lock().unwrap().push(efd.clone());
        if self.is_triggered() {
            efd.signal();
        }
    }
}

/// Front-end shape knobs (the compute side's knobs live on
/// [`super::ServeOptions`]).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Event-loop threads. Thread 0 accepts and distributes connections
    /// round-robin across all loops (including itself).
    pub io_threads: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { io_threads: 1 }
    }
}

/// Serve until `stop.trigger()` (or forever).
pub fn serve(
    service: Arc<Service>,
    addr: &str,
    stop: Arc<Stop>,
    config: ServerConfig,
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    serve_on_with(service, listener, stop, config)
}

/// Serve on an already-bound listener (lets tests bind port 0) with one
/// IO thread.
pub fn serve_on(service: Arc<Service>, listener: TcpListener, stop: Arc<Stop>) -> Result<()> {
    serve_on_with(service, listener, stop, ServerConfig::default())
}

/// Serve on an already-bound listener with an explicit config. Blocks
/// the calling thread (it becomes IO thread 0, the acceptor) until
/// `stop.trigger()`.
pub fn serve_on_with(
    service: Arc<Service>,
    listener: TcpListener,
    stop: Arc<Stop>,
    config: ServerConfig,
) -> Result<()> {
    listener.set_nonblocking(true)?;
    let n = config.io_threads.max(1);
    eprintln!(
        "[server] cost-model service listening on {} ({n} io thread{})",
        listener.local_addr()?,
        if n == 1 { "" } else { "s" }
    );
    // Every loop gets an inbox (handoff queue + doorbell); doorbells are
    // registered with `stop` up front so a trigger can never race a
    // loop's startup.
    let mut inboxes: Vec<Arc<Inbox>> = Vec::with_capacity(n);
    for _ in 0..n {
        inboxes.push(Arc::new(Inbox::new()?));
    }
    for inbox in &inboxes {
        stop.register(&inbox.doorbell);
    }
    let mut joins = Vec::new();
    for inbox in inboxes.iter().skip(1).cloned() {
        let svc = service.clone();
        let stop = stop.clone();
        joins.push(std::thread::spawn(move || {
            if let Err(e) = io_loop(svc, stop.clone(), inbox, None) {
                // A dead loop would silently strand every connection the
                // acceptor keeps dealing to its inbox — wind the whole
                // front end down instead.
                eprintln!("[server] io thread failed, stopping server: {e:#}");
                stop.trigger();
            }
        }));
    }
    let acceptor = Acceptor { listener, inboxes: inboxes.clone(), next: 0 };
    let res = io_loop(service, stop.clone(), inboxes[0].clone(), Some(acceptor));
    // If thread 0 failed, the sibling loops are still parked in
    // epoll_wait — trigger so the joins below cannot hang, and the
    // startup/run error reaches the caller.
    stop.trigger();
    for j in joins {
        let _ = j.join();
    }
    res
}

/// Cross-thread connection handoff: the acceptor pushes fresh streams
/// here and rings the doorbell; the owning loop drains it on wakeup.
struct Inbox {
    conns: Mutex<VecDeque<TcpStream>>,
    doorbell: Arc<EventFd>,
}

impl Inbox {
    fn new() -> Result<Inbox> {
        Ok(Inbox { conns: Mutex::new(VecDeque::new()), doorbell: Arc::new(EventFd::new()?) })
    }

    fn push(&self, stream: TcpStream) {
        self.conns.lock().unwrap().push_back(stream);
        self.doorbell.signal();
    }

    fn drain(&self) -> VecDeque<TcpStream> {
        std::mem::take(&mut *self.conns.lock().unwrap())
    }
}

/// Thread 0's extra role: own the listener and deal connections out.
struct Acceptor {
    listener: TcpListener,
    inboxes: Vec<Arc<Inbox>>,
    next: usize,
}

// Event-loop tokens: two fixed doorbell/listener slots, then one per
// connection slab slot.
const TOK_DOORBELL: u64 = 0;
const TOK_LISTENER: u64 = 1;
const TOK_CONN_BASE: u64 = 2;

/// Reject a single request line longer than this (a line that long is a
/// protocol violation, not a query) instead of buffering it forever.
const MAX_LINE_BYTES: usize = 32 << 20;

/// Once this much flushed prefix accumulates in a backpressured write
/// buffer, compact it.
const WBUF_COMPACT_BYTES: usize = 64 << 10;

/// Backpressure propagation: once this many response bytes are stuck
/// behind a slow reader, the connection stops reading new requests
/// (EPOLLIN is dropped) and stops answering already-buffered lines until
/// the kernel drains the backlog — a client that never reads cannot grow
/// `wbuf` without bound.
const WBUF_PAUSE_BYTES: usize = 1 << 20;

/// Per-wakeup read budget: a client that streams faster than we answer
/// could otherwise keep the socket readable forever and grow `rbuf`
/// without bound inside ONE event. Level-triggered epoll re-delivers
/// the readable event, so the remainder is picked up next wakeup (and
/// TCP backpressures the sender meanwhile).
const RBUF_READ_BUDGET: usize = 256 << 10;

/// One nonblocking connection owned by an event loop.
struct Conn {
    stream: TcpStream,
    /// Partial-line reassembly: bytes accumulate here across TCP
    /// segments until a `\n` completes a request.
    rbuf: Vec<u8>,
    /// Pending response bytes not yet accepted by the kernel.
    wbuf: Vec<u8>,
    /// How much of `wbuf` is already written.
    wpos: usize,
    /// Interest bits currently armed in epoll.
    interest: u32,
}

impl Conn {
    fn wants_write(&self) -> bool {
        self.wpos < self.wbuf.len()
    }

    /// Push buffered response bytes to the kernel until done or
    /// `WouldBlock`. Returns false when the connection is dead.
    fn flush(&mut self) -> bool {
        while self.wants_write() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => return false,
                Ok(n) => self.wpos += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
        if self.wants_write() {
            if self.wpos >= WBUF_COMPACT_BYTES {
                self.wbuf.drain(..self.wpos);
                self.wpos = 0;
            }
        } else {
            self.wbuf.clear();
            self.wpos = 0;
        }
        true
    }
}

/// The event loop proper: one epoll instance owning a doorbell, the
/// listener (thread 0 only), and a slab of nonblocking connections.
fn io_loop(
    service: Arc<Service>,
    stop: Arc<Stop>,
    inbox: Arc<Inbox>,
    mut acceptor: Option<Acceptor>,
) -> Result<()> {
    let epoll = Epoll::new().context("creating epoll instance")?;
    epoll
        .add(inbox.doorbell.as_raw_fd(), EPOLLIN, TOK_DOORBELL)
        .context("registering doorbell")?;
    if let Some(a) = &acceptor {
        epoll.add(a.listener.as_raw_fd(), EPOLLIN, TOK_LISTENER).context("registering listener")?;
    }
    let mut slab: Vec<Option<Conn>> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut events = Events::with_capacity(512);

    'outer: while !stop.is_triggered() {
        // Block until something is ready — no timeout, no sleep. Idle
        // connections park in the kernel for free.
        epoll.wait(&mut events, -1)?;
        service.stats.epoll_wakeups.fetch_add(1, Ordering::Relaxed);
        for ev in events.iter() {
            match ev.token {
                TOK_DOORBELL => {
                    inbox.doorbell.drain();
                    if stop.is_triggered() {
                        break 'outer;
                    }
                    for stream in inbox.drain() {
                        register_conn(&service, &epoll, &mut slab, &mut free, stream);
                    }
                }
                TOK_LISTENER => {
                    if let Some(a) = &mut acceptor {
                        accept_ready(&service, a);
                    }
                }
                t => {
                    let idx = (t - TOK_CONN_BASE) as usize;
                    conn_event(&service, &epoll, &mut slab, &mut free, idx, ev.events);
                }
            }
        }
    }

    // Teardown: close every connection this loop owns (and any streams
    // handed off but never registered). `close_conn` no-ops on empty
    // slots.
    for idx in 0..slab.len() {
        close_conn(&service, &epoll, &mut slab, &mut free, idx);
    }
    drop(inbox.drain());
    Ok(())
}

/// Accept until the listener runs dry, dealing streams round-robin.
fn accept_ready(service: &Arc<Service>, a: &mut Acceptor) {
    loop {
        match a.listener.accept() {
            Ok((stream, _peer)) => {
                service.stats.connections_accepted.fetch_add(1, Ordering::Relaxed);
                let i = a.next % a.inboxes.len();
                a.next = a.next.wrapping_add(1);
                a.inboxes[i].push(stream);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => {
                // Persistent errors (EMFILE under fd exhaustion, ...)
                // leave the listener readable, so level-triggered epoll
                // would hand the event right back — back off briefly
                // instead of spinning a core on accept→fail cycles.
                eprintln!("[server] accept failed: {e}");
                std::thread::sleep(std::time::Duration::from_millis(10));
                break;
            }
        }
    }
}

fn register_conn(
    service: &Arc<Service>,
    epoll: &Epoll,
    slab: &mut Vec<Option<Conn>>,
    free: &mut Vec<usize>,
    stream: TcpStream,
) {
    if let Err(e) = stream.set_nonblocking(true) {
        eprintln!("[server] could not make connection nonblocking: {e}");
        return;
    }
    // Responses are single small writes; don't let Nagle delay them.
    let _ = stream.set_nodelay(true);
    let idx = free.pop().unwrap_or_else(|| {
        slab.push(None);
        slab.len() - 1
    });
    let interest = EPOLLIN | EPOLLRDHUP;
    if let Err(e) = epoll.add(stream.as_raw_fd(), interest, TOK_CONN_BASE + idx as u64) {
        eprintln!("[server] could not register connection: {e}");
        free.push(idx);
        return;
    }
    slab[idx] = Some(Conn { stream, rbuf: Vec::new(), wbuf: Vec::new(), wpos: 0, interest });
    service.stats.active_connections.fetch_add(1, Ordering::Relaxed);
}

fn close_conn(
    service: &Arc<Service>,
    epoll: &Epoll,
    slab: &mut [Option<Conn>],
    free: &mut Vec<usize>,
    idx: usize,
) {
    if let Some(conn) = slab[idx].take() {
        let _ = epoll.delete(conn.stream.as_raw_fd());
        free.push(idx);
        service.stats.active_connections.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Service one connection's readiness event: flush backpressured
/// writes, drain the socket, answer every completed line, re-arm.
fn conn_event(
    service: &Arc<Service>,
    epoll: &Epoll,
    slab: &mut [Option<Conn>],
    free: &mut Vec<usize>,
    idx: usize,
    bits: u32,
) {
    let Some(conn) = slab.get_mut(idx).and_then(Option::as_mut) else {
        return; // stale event for a slot already closed this wakeup
    };
    let mut alive = true;
    if bits & EPOLLOUT != 0 {
        alive = conn.flush();
    }
    let mut peer_done = false;
    if alive && bits & (EPOLLIN | EPOLLRDHUP | minipoll::EPOLLHUP | minipoll::EPOLLERR) != 0 {
        // Drain the socket up to the per-wakeup budget (level-triggered
        // epoll re-delivers whatever is left).
        let mut chunk = [0u8; 16 * 1024];
        let mut budget = RBUF_READ_BUDGET;
        while budget > 0 {
            let want = budget.min(chunk.len());
            match conn.stream.read(&mut chunk[..want]) {
                Ok(0) => {
                    peer_done = true;
                    break;
                }
                Ok(n) => {
                    conn.rbuf.extend_from_slice(&chunk[..n]);
                    budget -= n;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    alive = false;
                    break;
                }
            }
        }
    }
    // Answer buffered lines (also after a pure EPOLLOUT wakeup: a flush
    // that made room resumes requests deferred by backpressure), then
    // push what the kernel will take.
    if alive {
        alive = respond_to_complete_lines(service, conn);
    }
    if alive && conn.wants_write() {
        alive = conn.flush();
    }
    // A closing peer gets its final responses if the kernel will take
    // them; anything it won't take has nowhere to go.
    if peer_done {
        alive = false;
    }
    if !alive {
        close_conn(service, epoll, slab, free, idx);
        return;
    }
    // Backpressure: past the pause threshold, stop reading (and thus
    // stop generating responses) until the backlog drains.
    let mut want = EPOLLRDHUP | if conn.wants_write() { EPOLLOUT } else { 0 };
    if conn.wbuf.len() - conn.wpos <= WBUF_PAUSE_BYTES {
        want |= EPOLLIN;
    }
    if want != conn.interest {
        if epoll.modify(conn.stream.as_raw_fd(), want, TOK_CONN_BASE + idx as u64).is_ok() {
            conn.interest = want;
        } else {
            close_conn(service, epoll, slab, free, idx);
        }
    }
}

/// Answer every `\n`-terminated request sitting in `rbuf`; leftover
/// partial-line bytes stay buffered for the next segment. Stops early
/// when the write buffer passes the backpressure threshold (the
/// unanswered lines stay in `rbuf` and resume after a flush makes
/// room). Returns false when the connection must close (oversized line).
fn respond_to_complete_lines(service: &Service, conn: &mut Conn) -> bool {
    let mut start = 0;
    while conn.wbuf.len() - conn.wpos <= WBUF_PAUSE_BYTES {
        let Some(nl) = conn.rbuf[start..].iter().position(|&b| b == b'\n') else {
            break;
        };
        let line = &conn.rbuf[start..start + nl];
        start += nl + 1;
        let response = match std::str::from_utf8(line) {
            Ok(text) if text.trim().is_empty() => continue,
            Ok(text) => handle_line(service, text),
            Err(_) => Json::obj()
                .with("ok", Json::Bool(false))
                .with("error", Json::str("request line is not valid UTF-8")),
        };
        // Vec<u8> writes are infallible.
        response.write_to(&mut conn.wbuf).expect("buffer write");
        conn.wbuf.push(b'\n');
    }
    if start > 0 {
        conn.rbuf.drain(..start);
    }
    // Only an oversized SINGLE line (no newline in sight) is a protocol
    // violation; complete lines deferred by write backpressure are fine
    // (their volume is bounded by the read budget + pause cycle).
    conn.rbuf.len() <= MAX_LINE_BYTES || conn.rbuf.contains(&b'\n')
}

/// The legacy thread-per-connection front end, kept as the measured
/// baseline for `benches/e3_serving.rs`: accept polls on a 10 ms sleep
/// and every idle connection wakes on a 200 ms read timeout — the costs
/// the event loop exists to delete. The partial-read handling is shared
/// with the event loop in spirit: a timeout mid-request preserves the
/// bytes already read (see `handle_conn_threaded`).
pub fn serve_on_threaded(
    service: Arc<Service>,
    listener: TcpListener,
    stop: Arc<Stop>,
) -> Result<()> {
    listener.set_nonblocking(true)?;
    let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !stop.is_triggered() {
        // Reap finished connection threads every iteration.
        let mut i = 0;
        while i < handles.len() {
            if handles[i].is_finished() {
                let _ = handles.swap_remove(i).join();
            } else {
                i += 1;
            }
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                service.stats.connections_accepted.fetch_add(1, Ordering::Relaxed);
                let svc = service.clone();
                let stop = stop.clone();
                handles.push(std::thread::spawn(move || {
                    svc.stats.active_connections.fetch_add(1, Ordering::Relaxed);
                    if let Err(e) = handle_conn_threaded(&svc, stream, stop) {
                        eprintln!("[server] connection ended: {e:#}");
                    }
                    svc.stats.active_connections.fetch_sub(1, Ordering::Relaxed);
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

fn handle_conn_threaded(service: &Service, stream: TcpStream, stop: Arc<Stop>) -> Result<()> {
    // Read with a timeout so shutdown can interrupt an idle connection.
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    let mut writer = BufWriter::new(stream.try_clone()?);
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {
                if !line.trim().is_empty() {
                    let response = handle_line(service, &line);
                    response.write_to(&mut writer)?;
                    writer.write_all(b"\n")?;
                    writer.flush()?;
                }
                // Clear only after a COMPLETE line was handled. The old
                // loop cleared at the top of every iteration, so a read
                // timeout that fired mid-request silently discarded the
                // partial bytes `read_line` had already appended.
                line.clear();
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // Timeout tick: `line` keeps any partial request bytes;
                // the next successful read appends the rest.
                if stop.is_triggered() {
                    return Ok(());
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// Process one request line (exposed for tests + in-process clients).
pub fn handle_line(service: &Service, line: &str) -> Json {
    let t0 = Instant::now();
    let req = match parse(line) {
        Ok(r) => r,
        Err(e) => {
            return Json::obj()
                .with("ok", Json::Bool(false))
                .with("error", Json::str(format!("bad json: {e}")))
        }
    };
    let id = req.get("id").cloned().unwrap_or(Json::Null);
    let fail = |msg: String| {
        Json::obj()
            .with("id", id.clone())
            .with("ok", Json::Bool(false))
            .with("error", Json::str(msg))
    };
    if let Some(cmd) = req.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "ping" => Json::obj()
                .with("id", id.clone())
                .with("ok", Json::Bool(true))
                .with("pong", Json::Bool(true)),
            "stats" => Json::obj()
                .with("id", id.clone())
                .with("ok", Json::Bool(true))
                .with("stats", service.stats_json()),
            "targets" => Json::obj().with("id", id.clone()).with("ok", Json::Bool(true)).with(
                "targets",
                Json::Arr(
                    service.targets().iter().map(|t| Json::str(t.name())).collect(),
                ),
            ),
            other => fail(format!("unknown cmd '{other}'")),
        };
    }
    let target = match req.req_str("target").ok().and_then(Target::parse) {
        Some(t) => t,
        None => return fail("missing/invalid 'target'".into()),
    };
    // Batch request: an array of MLIR texts through predict_many.
    if let Some(batch) = req.get("mlir_batch") {
        let Some(items) = batch.as_arr() else {
            return fail("'mlir_batch' must be an array of strings".into());
        };
        let mut texts: Vec<&str> = Vec::with_capacity(items.len());
        for item in items {
            match item.as_str() {
                Some(s) => texts.push(s),
                None => return fail("'mlir_batch' entries must be strings".into()),
            }
        }
        let results = service.predict_many(target, &texts);
        let predictions: Vec<Json> = results
            .into_iter()
            .map(|r| match r {
                Ok(v) => Json::obj()
                    .with("ok", Json::Bool(true))
                    .with("prediction", Json::num(v)),
                Err(e) => Json::obj()
                    .with("ok", Json::Bool(false))
                    .with("error", Json::str(format!("{e:#}"))),
            })
            .collect();
        return Json::obj()
            .with("id", id)
            .with("ok", Json::Bool(true))
            .with("predictions", Json::Arr(predictions))
            .with("us", Json::num(t0.elapsed().as_micros() as f64));
    }
    let mlir = match req.req_str("mlir") {
        Ok(m) => m,
        Err(e) => return fail(e.to_string()),
    };
    match service.predict(target, mlir) {
        Ok(v) => Json::obj()
            .with("id", id)
            .with("ok", Json::Bool(true))
            .with("prediction", Json::num(v))
            .with("us", Json::num(t0.elapsed().as_micros() as f64)),
        Err(e) => fail(format!("{e:#}")),
    }
}

/// Minimal blocking client for the line protocol (used by examples and
/// the serving bench).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
            next_id: 1,
        })
    }

    fn next_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    fn roundtrip(&mut self, req: Json) -> Result<Json> {
        req.write_to(&mut self.writer)?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let resp = parse(&line)?;
        if resp.get("ok").and_then(Json::as_bool) != Some(true) {
            anyhow::bail!(
                "server error: {}",
                resp.get("error").and_then(Json::as_str).unwrap_or("unknown")
            );
        }
        Ok(resp)
    }

    /// Query a prediction.
    pub fn predict(&mut self, target: Target, mlir: &str) -> Result<f64> {
        let id = self.next_id();
        let req = Json::obj()
            .with("id", Json::num(id as f64))
            .with("target", Json::str(target.name()))
            .with("mlir", Json::str(mlir));
        let resp = self.roundtrip(req)?;
        resp.req_f64("prediction")
    }

    /// Query many predictions in one protocol round trip (`mlir_batch`).
    /// Per-entry results mirror `Service::predict_many`.
    pub fn predict_many(&mut self, target: Target, mlirs: &[&str]) -> Result<Vec<Result<f64>>> {
        let id = self.next_id();
        let req = Json::obj()
            .with("id", Json::num(id as f64))
            .with("target", Json::str(target.name()))
            .with(
                "mlir_batch",
                Json::Arr(mlirs.iter().map(|m| Json::str(*m)).collect()),
            );
        let resp = self.roundtrip(req)?;
        let arr = resp.req_arr("predictions")?;
        Ok(arr
            .iter()
            .map(|p| {
                if p.get("ok").and_then(Json::as_bool) == Some(true) {
                    p.req_f64("prediction")
                } else {
                    Err(anyhow!(
                        "{}",
                        p.get("error").and_then(Json::as_str).unwrap_or("unknown error")
                    ))
                }
            })
            .collect())
    }

    /// Fetch server stats.
    pub fn stats(&mut self) -> Result<Json> {
        let id = self.next_id();
        let req = Json::obj()
            .with("id", Json::num(id as f64))
            .with("cmd", Json::str("stats"));
        Ok(self.roundtrip(req)?.req("stats")?.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bundle::Bundle;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::dataset::TargetStats;
    use crate::graphgen::{generate, Family, GraphSpec};
    use crate::mlir::print_function;
    use crate::runtime::Manifest;
    use crate::tokenizer::{Scheme, Vocab};
    use std::path::Path;

    fn service() -> Option<Arc<Service>> {
        let adir = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("artifacts");
        if !adir.join("manifest.json").exists() {
            return None;
        }
        let manifest = Arc::new(Manifest::load(&adir).unwrap());
        let vocab = Vocab::build(vec![vec!["x".to_string()]].iter(), 1);
        let stats = TargetStats { mean: 0.0, std: 1.0, min: 0.0, max: 10.0 };
        let bundle =
            Bundle::untrained(&manifest, "fc_ops", Target::RegPressure, Scheme::OpsOnly, vocab, stats)
                .unwrap();
        Some(Arc::new(
            Service::start(manifest, vec![bundle], BatchPolicy::default(), false).unwrap(),
        ))
    }

    fn graph(structure_seed: u64, shape_seed: u64) -> String {
        let spec = GraphSpec { family: Family::Mlp, structure_seed, shape_seed };
        print_function(&generate(&spec).unwrap())
    }

    /// Spawn the event-loop server on port 0; returns (addr, stop, join).
    fn spawn_server(
        svc: Arc<Service>,
        io_threads: usize,
    ) -> (String, Arc<Stop>, std::thread::JoinHandle<Result<()>>) {
        let stop = Stop::new();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = {
            let stop = stop.clone();
            std::thread::spawn(move || {
                serve_on_with(svc, listener, stop, ServerConfig { io_threads })
            })
        };
        (addr, stop, server)
    }

    /// Read one `\n`-terminated line from a raw stream.
    fn read_response(stream: &TcpStream) -> String {
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        line
    }

    #[test]
    fn line_protocol_handles_commands() {
        let Some(svc) = service() else { return };
        let pong = handle_line(&svc, r#"{"id": 1, "cmd": "ping"}"#);
        assert_eq!(pong.get("pong").and_then(Json::as_bool), Some(true));
        let stats = handle_line(&svc, r#"{"id": 2, "cmd": "stats"}"#);
        assert!(stats.get("stats").is_some());
        // The merged stats view carries the new pipeline counters.
        let inner = stats.get("stats").unwrap();
        assert!(inner.get("coalesced_queries").is_some());
        assert!(inner.get("cache_shard_contention").is_some());
        assert!(inner.get("batch_fill_ratio").is_some());
        assert!(inner.get("padded_slots").is_some());
        assert!(inner.get("frontend_memo_hits").is_some());
        assert!(inner.get("encode_ns").is_some());
        assert!(inner.get("frontend_memo_entries").is_some());
        // ...and the serving-plane counters from the event-loop front end.
        assert!(inner.get("active_connections").is_some());
        assert!(inner.get("connections_accepted").is_some());
        assert!(inner.get("epoll_wakeups").is_some());
        assert!(inner.get("exec_by_batch").is_some());
        let targets = handle_line(&svc, r#"{"id": 3, "cmd": "targets"}"#);
        assert_eq!(targets.req_arr("targets").unwrap().len(), 1);
        let bad = handle_line(&svc, "{nope");
        assert_eq!(bad.get("ok").and_then(Json::as_bool), Some(false));
        let missing = handle_line(&svc, r#"{"id": 4}"#);
        assert_eq!(missing.get("ok").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn batch_request_over_handle_line() {
        let Some(svc) = service() else { return };
        let text = graph(21, 22);
        let req = Json::obj()
            .with("id", Json::num(5.0))
            .with("target", Json::str("regpressure"))
            .with(
                "mlir_batch",
                Json::Arr(vec![
                    Json::str(text.as_str()),
                    Json::str("not mlir"),
                    Json::str(text.as_str()),
                ]),
            );
        let resp = handle_line(&svc, &req.to_string());
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        let preds = resp.req_arr("predictions").unwrap();
        assert_eq!(preds.len(), 3);
        assert_eq!(preds[0].get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(preds[1].get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(preds[2].get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(
            preds[0].req_f64("prediction").unwrap(),
            preds[2].req_f64("prediction").unwrap()
        );
        // Malformed shapes of the batch field fail whole-request.
        let bad =
            handle_line(&svc, r#"{"id": 6, "target": "regpressure", "mlir_batch": "nope"}"#);
        assert_eq!(bad.get("ok").and_then(Json::as_bool), Some(false));
        let bad2 =
            handle_line(&svc, r#"{"id": 7, "target": "regpressure", "mlir_batch": [1, 2]}"#);
        assert_eq!(bad2.get("ok").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn tcp_roundtrip_with_client() {
        let Some(svc) = service() else { return };
        let (addr, stop, server) = spawn_server(svc.clone(), 1);
        let mut client = Client::connect(&addr).unwrap();
        let text = graph(3, 4);
        let v = client.predict(Target::RegPressure, &text).unwrap();
        assert!(v.is_finite());
        // Batch request over the wire: mixed valid/invalid entries.
        let text2 = graph(5, 6);
        let many = client
            .predict_many(Target::RegPressure, &[text.as_str(), "not mlir", text2.as_str()])
            .unwrap();
        assert_eq!(many.len(), 3);
        assert_eq!(many[0].as_ref().unwrap(), &v, "cached value must match");
        assert!(many[1].is_err());
        assert!(many[2].as_ref().unwrap().is_finite());
        let stats = client.stats().unwrap();
        assert!(stats.req_f64("requests").unwrap() >= 4.0);
        assert!(stats.req_f64("batch_requests").unwrap() >= 1.0);
        assert!(stats.req_f64("connections_accepted").unwrap() >= 1.0);
        assert!(stats.req_f64("active_connections").unwrap() >= 1.0);
        assert!(stats.req_f64("epoll_wakeups").unwrap() >= 1.0);
        stop.trigger();
        let _ = server.join();
    }

    /// Regression for the partial-read bug AND the event loop's
    /// reassembly-by-construction: a request that arrives in two TCP
    /// segments with a long pause between them must still be answered.
    /// The pause (300 ms) exceeds the threaded baseline's 200 ms read
    /// timeout, so the old clear-at-loop-top bug would have discarded
    /// the first segment.
    #[test]
    fn split_write_request_reassembled_across_segments() {
        let Some(svc) = service() else { return };
        let (addr, stop, server) = spawn_server(svc.clone(), 1);
        let mut stream = TcpStream::connect(&addr).unwrap();
        stream.set_nodelay(true).unwrap();
        stream.write_all(br#"{"id": 1, "cmd": "pi"#).unwrap();
        stream.flush().unwrap();
        std::thread::sleep(std::time::Duration::from_millis(300));
        stream.write_all(b"ng\"}\n").unwrap();
        stream.flush().unwrap();
        let line = read_response(&stream);
        let resp = parse(&line).unwrap();
        assert_eq!(resp.get("pong").and_then(Json::as_bool), Some(true), "got: {line}");
        stop.trigger();
        let _ = server.join();
    }

    /// Same split-write scenario against the threaded baseline: its read
    /// timeout fires mid-request, and the partial bytes must survive.
    #[test]
    fn split_write_survives_threaded_baseline_timeout() {
        let Some(svc) = service() else { return };
        let stop = Stop::new();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = {
            let stop = stop.clone();
            std::thread::spawn(move || serve_on_threaded(svc, listener, stop))
        };
        let mut stream = TcpStream::connect(&addr).unwrap();
        stream.set_nodelay(true).unwrap();
        stream.write_all(br#"{"id": 2, "cmd": "pi"#).unwrap();
        stream.flush().unwrap();
        // > 200 ms: at least one read timeout fires while the request is
        // half-received.
        std::thread::sleep(std::time::Duration::from_millis(450));
        stream.write_all(b"ng\"}\n").unwrap();
        stream.flush().unwrap();
        let line = read_response(&stream);
        let resp = parse(&line).unwrap();
        assert_eq!(resp.get("pong").and_then(Json::as_bool), Some(true), "got: {line}");
        stop.trigger();
        let _ = server.join();
    }

    /// Two requests in ONE TCP segment: the loop must answer both from a
    /// single readiness event (multiple lines per read buffer).
    #[test]
    fn pipelined_requests_in_one_segment() {
        let Some(svc) = service() else { return };
        let (addr, stop, server) = spawn_server(svc.clone(), 1);
        let stream = TcpStream::connect(&addr).unwrap();
        (&stream)
            .write_all(b"{\"id\": 1, \"cmd\": \"ping\"}\n{\"id\": 2, \"cmd\": \"ping\"}\n")
            .unwrap();
        let mut reader = BufReader::new(&stream);
        for expect_id in [1.0, 2.0] {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let resp = parse(&line).unwrap();
            assert_eq!(resp.req_f64("id").unwrap(), expect_id);
            assert_eq!(resp.get("pong").and_then(Json::as_bool), Some(true));
        }
        stop.trigger();
        let _ = server.join();
    }

    /// The acceptance bar from the issue: ≥256 concurrent connections on
    /// a single IO thread, all answered, with the serving-plane gauges
    /// moving. Thread-per-connection would need 256 OS threads here; the
    /// event loop holds them all in one.
    #[test]
    fn event_loop_holds_256_concurrent_connections_on_one_io_thread() {
        let Some(svc) = service() else { return };
        let (addr, stop, server) = spawn_server(svc.clone(), 1);
        let conns: Vec<TcpStream> =
            (0..256).map(|_| TcpStream::connect(&addr).unwrap()).collect();
        // All connections write before any reads: every socket is
        // simultaneously live on the server.
        for (i, c) in conns.iter().enumerate() {
            (&*c).write_all(format!("{{\"id\": {i}, \"cmd\": \"ping\"}}\n").as_bytes()).unwrap();
        }
        for (i, c) in conns.iter().enumerate() {
            let line = read_response(c);
            let resp = parse(&line).unwrap();
            assert_eq!(resp.req_f64("id").unwrap() as usize, i);
            assert_eq!(resp.get("pong").and_then(Json::as_bool), Some(true));
        }
        // Every connection answered ⇒ every connection is registered.
        assert_eq!(svc.stats.active_connections.load(Ordering::Relaxed), 256);
        assert!(svc.stats.connections_accepted.load(Ordering::Relaxed) >= 256);
        assert!(svc.stats.epoll_wakeups.load(Ordering::Relaxed) > 0);
        drop(conns);
        stop.trigger();
        let _ = server.join();
        // Teardown drains the gauge.
        assert_eq!(svc.stats.active_connections.load(Ordering::Relaxed), 0);
    }

    /// Multi-loop config: connections are dealt round-robin across IO
    /// threads and all of them serve predictions.
    #[test]
    fn multiple_io_threads_share_the_accept_stream() {
        let Some(svc) = service() else { return };
        let (addr, stop, server) = spawn_server(svc.clone(), 3);
        let text = graph(41, 42);
        let mut clients: Vec<Client> =
            (0..9).map(|_| Client::connect(&addr).unwrap()).collect();
        for client in clients.iter_mut() {
            let v = client.predict(Target::RegPressure, &text).unwrap();
            assert!(v.is_finite());
        }
        assert_eq!(svc.stats.active_connections.load(Ordering::Relaxed), 9);
        drop(clients);
        stop.trigger();
        let _ = server.join();
    }

    /// Trigger-before-serve must not hang: the doorbell registration
    /// path signals immediately when the stop already fired.
    #[test]
    fn pre_triggered_stop_exits_immediately() {
        let Some(svc) = service() else { return };
        let stop = Stop::new();
        stop.trigger();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let t0 = Instant::now();
        serve_on(svc, listener, stop).unwrap();
        assert!(t0.elapsed() < std::time::Duration::from_secs(2));
    }
}

//! Serving metrics: counters + latency reservoir, exported over the wire
//! protocol's `stats` command.
//!
//! Batching-health counters added for the batched/sharded serving path:
//! `batch_slots` (executable slots paid for), `padded_slots` (slots that
//! carried padding, i.e. wasted model FLOPs), and `batch_requests`
//! (`predict_many` calls). `batch_fill_ratio()` = useful queries / slots.
//! Front-end counters added with the zero-allocation encode pipeline:
//! `frontend_memo_hits` (queries whose parse/tokenize/encode was skipped
//! by the text-level memo) and `encode_ns` (total nanoseconds spent in
//! the text→ids front end, memo hits included). Serving-plane counters
//! added with the event-driven front end: `active_connections` (gauge of
//! currently-open sockets), `connections_accepted`, `epoll_wakeups`
//! (event-loop `epoll_wait` returns — idle time costs zero of these),
//! and `exec_by_batch` (flush count per compiled batch size, showing the
//! batch-size-aware ladder picking small executables for small flushes).
//! Cluster-tier counters added with the consistent-hash remote cache
//! shards: `forwarded_gets` (remote-owner probes attempted),
//! `remote_hits` (probes the owner answered from its cache),
//! `forwarded_puts` (async write-backs enqueued to owners),
//! `peer_failures` (probes that errored or timed out), and
//! `degraded_fallbacks` (remote-owned keys served by local compute
//! because their owner was Down or failing — degraded, never an error).
//! `fairness_deferrals` counts event-loop round-robin turns where a
//! connection hit its per-wakeup line budget and was requeued — nonzero
//! means the fairness scheduler is actively stopping a pipelining client
//! from monopolizing an IO thread.
//! Routing-tier counters added with the multi-variant router
//! (`super::router`): `budget_downgrades` (queries rerouted off the
//! length-preferred variant because a `budget_us` would have been
//! blown) and `no_covering_variant`
//! (queries longer than every registered variant's `max_len`, rejected
//! with a clean error). `targets_not_served` counts queries whose
//! requested characteristic list no eligible variant serves — the
//! multi-output router refuses partial answers rather than silently
//! returning a subset. Per-variant detail — routed counts and the
//! [`LatencyEwma`] each variant's budget decisions read — lives on the
//! router's variants; `Service::stats_json` merges it in as the
//! `routed_by_variant` / `variants` objects.
//! Offload-tier counters added with the compute offload pool
//! (`super::offload`): `offloaded_misses` (lines handed off an IO
//! thread to the request-worker pool), `io_stall_ns` (nanoseconds IO
//! threads spent executing would-block lines inline — nonzero only
//! with `--request-workers 0` or when the offload queue was full), and
//! `offload_queue_depth` (gauge: jobs currently queued for the pool).
//! Admission-control counters added with the tenancy layer
//! (quotas / weighted-fair offload queueing / deadline shedding in
//! `super::server` + `super::offload`): `lines_admitted` (request
//! lines the event loop dispatched), `lines_answered` (responses
//! produced — inline answers, offload completions delivered, and
//! protocol-error replies alike), `over_quota` (lines rejected by a
//! tenant/connection token bucket), `shed_deadline` (lines rejected at
//! admission because their `budget_us` was already unmeetable),
//! `rejected_overloaded` (would-block lines refused because their
//! tenant hit its offload in-flight cap), and `lines_dropped`
//! (offloaded lines whose connection died before the completion could
//! be written). Together they satisfy the conservation invariant
//! checked by [`ServiceStats::conservation_debt`]: at quiescence every
//! admitted line is accounted for exactly once — no silent drops.
//! Cache-side counters (shard contention, coalesced single-flight
//! queries) live on `PredictionCache`; `Service::stats_json` merges both
//! views (plus the per-peer `cluster` object when clustered) for the
//! wire protocol.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Lock-light metrics for one service.
#[derive(Default)]
pub struct ServiceStats {
    pub requests: AtomicU64,
    pub cache_hits: AtomicU64,
    pub batches: AtomicU64,
    pub batched_queries: AtomicU64,
    /// `predict_many` invocations (each may carry many queries).
    pub batch_requests: AtomicU64,
    /// Total executable slots across all executed batches (chunks ×
    /// compiled batch size).
    pub batch_slots: AtomicU64,
    /// Slots that carried padding instead of a real query.
    pub padded_slots: AtomicU64,
    /// Queries served ids straight from the text-level encode memo
    /// (no parse/tokenize/encode performed).
    pub frontend_memo_hits: AtomicU64,
    /// Total time in the text→ids front end across all queries, in
    /// nanoseconds (memo hits contribute their hash+lookup time).
    pub encode_ns: AtomicU64,
    /// Gauge: sockets currently owned by the front end (event loop or
    /// threaded baseline).
    pub active_connections: AtomicU64,
    /// Connections accepted over the server's lifetime.
    pub connections_accepted: AtomicU64,
    /// `epoll_wait` returns across all IO threads. An idle server adds
    /// zero — the whole point of the readiness-driven front end.
    pub epoll_wakeups: AtomicU64,
    /// Round-robin turns where a connection exhausted its per-wakeup
    /// line budget and went to the back of the ready queue.
    pub fairness_deferrals: AtomicU64,
    /// Request lines the event loop dispatched (complete, non-empty
    /// lines pulled from a connection's read buffer). Every admitted
    /// line settles in exactly one of `lines_answered` / `over_quota` /
    /// `shed_deadline` / `rejected_overloaded` / `lines_dropped` — the
    /// conservation invariant ([`ServiceStats::conservation_debt`]).
    pub lines_admitted: AtomicU64,
    /// Responses produced for admitted lines: inline answers, offload
    /// completions delivered to their connection, and protocol-error
    /// replies (bad JSON, invalid UTF-8) alike.
    pub lines_answered: AtomicU64,
    /// Offloaded lines whose rendered response could not be delivered —
    /// the connection closed (or its slab slot was recycled) while the
    /// job ran, or the server shut down with the completion in flight.
    pub lines_dropped: AtomicU64,
    /// Lines rejected at admission by a per-tenant / per-connection
    /// token bucket (`--quota`), answered with the typed `over_quota`
    /// error instead of being processed.
    pub over_quota: AtomicU64,
    /// Lines rejected at admission because their `budget_us` was
    /// already unmeetable given the fastest variant's latency estimate
    /// and the current offload backlog (`--shed-deadlines`), answered
    /// with the typed `shed_deadline` error instead of queueing doomed
    /// work. Never fires for requests that carry no `budget_us`.
    pub shed_deadline: AtomicU64,
    /// Would-block lines refused because their tenant already had
    /// `--tenant-inflight` jobs queued or executing in the offload
    /// pool, answered with the typed `overloaded` error.
    pub rejected_overloaded: AtomicU64,
    /// Remote-owner cache probes attempted (cluster tier).
    pub forwarded_gets: AtomicU64,
    /// Remote probes the owner answered from its cache.
    pub remote_hits: AtomicU64,
    /// Asynchronous write-backs enqueued to owner nodes.
    pub forwarded_puts: AtomicU64,
    /// Remote probes that errored or timed out.
    pub peer_failures: AtomicU64,
    /// Remote-owned keys served by local compute because the owner was
    /// Down or failing (the cluster's no-error degradation path).
    pub degraded_fallbacks: AtomicU64,
    /// Queries the router rerouted off the length-preferred variant
    /// because the request's `budget_us` would have been blown: onto a
    /// larger covering variant when one fits the budget (no accuracy
    /// loss), else onto a smaller/faster variant over a truncated
    /// encoding — an explicit accuracy-for-latency trade the client
    /// opted into.
    pub budget_downgrades: AtomicU64,
    /// Queries longer than every registered variant's `max_len` for
    /// their target: rejected with a clean error, never truncated
    /// silently and never a panic.
    pub no_covering_variant: AtomicU64,
    /// Queries whose requested characteristic list no eligible variant
    /// serves (heterogeneous per-variant target sets): rejected with a
    /// clean `targets_not_served` error, never a silent partial answer.
    pub targets_not_served: AtomicU64,
    /// Gauge: delta-encoding sessions currently registered
    /// (`session_open` adds, `session_close` and capacity eviction
    /// subtract).
    pub sessions_open: AtomicU64,
    /// `mlir_delta` queries served through the incremental splice path.
    pub delta_requests: AtomicU64,
    /// Line segments whose cached id-span was spliced without re-lexing
    /// (the incremental tier's hit counter).
    pub spans_spliced: AtomicU64,
    /// Line segments that had to be re-lexed into a fresh id-span
    /// (changed lines plus span-table evictions).
    pub spans_reencoded: AtomicU64,
    /// Bytes of MLIR text the delta path actually re-lexed — compare
    /// against full probe sizes to see what the splice tier saves.
    pub delta_bytes_rescanned: AtomicU64,
    /// Lines an IO thread handed to the request-worker pool instead of
    /// executing inline (cache misses, session opens, batch predicts,
    /// cluster peer waits).
    pub offloaded_misses: AtomicU64,
    /// Nanoseconds IO threads spent executing would-block lines inline.
    /// Zero whenever the offload pool absorbed everything; nonzero
    /// means `--request-workers 0` or a full offload queue forced the
    /// IO thread to stall on compute.
    pub io_stall_ns: AtomicU64,
    /// Gauge: jobs currently sitting in the offload pool's queue
    /// (incremented on enqueue, decremented when a worker dequeues).
    pub offload_queue_depth: AtomicU64,
    /// Schedule candidates enumerated by autotune searches probing this
    /// service in-process (the `autotune` subcommand / `ServiceProbe`).
    pub search_candidates: AtomicU64,
    /// Model probes those searches issued (cold and delta both count).
    pub search_probes: AtomicU64,
    /// Search probes that rode the session/delta path.
    pub search_delta_probes: AtomicU64,
    /// Total wall-clock nanoseconds spent inside autotune searches.
    pub search_ns: AtomicU64,
    pub errors: AtomicU64,
    /// Executed flushes per compiled batch size: `exec_by_batch[b]` is
    /// how many chunks ran on the `predict_b{b}` executable. One lock
    /// per model invocation — nowhere near the hot path.
    exec_by_batch: Mutex<BTreeMap<usize, u64>>,
    latencies_us: Mutex<Reservoir>,
}

#[derive(Default)]
struct Reservoir {
    samples: Vec<u64>,
}

const RESERVOIR_CAP: usize = 4096;

/// Smoothing factor for [`LatencyEwma`]: each new sample contributes
/// 20%, so the estimate tracks a shifting latency distribution within
/// ~10 samples while a single outlier moves it by at most a fifth.
const EWMA_ALPHA: f64 = 0.2;

/// Lock-free exponentially-weighted moving average of a latency, in
/// microseconds — the router's per-variant p50 proxy that `budget_us`
/// decisions read on every routed query.
///
/// The value lives in an `AtomicU64` as f64 bits. `observe` is a CAS
/// loop (latency samples arrive once per *model invocation*, nowhere
/// near per-query rates); `get` is a single relaxed load, cheap enough
/// for the routing hot path. A fresh EWMA reads 0.0 — "no evidence this
/// variant is slow" — so a cold variant is never budget-downgraded away
/// from until it has real samples.
#[derive(Default)]
pub struct LatencyEwma {
    bits: AtomicU64,
}

impl LatencyEwma {
    /// Current estimate in microseconds (0.0 until the first sample).
    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }

    /// Fold one observed latency into the estimate. The first sample
    /// seeds the EWMA directly instead of averaging against the 0.0
    /// sentinel (which would under-report by `1 - alpha` forever).
    pub fn observe(&self, us: f64) {
        if !us.is_finite() || us < 0.0 {
            return;
        }
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let prev = f64::from_bits(cur);
            let next = if prev == 0.0 { us } else { prev + EWMA_ALPHA * (us - prev) };
            match self.bits.compare_exchange_weak(
                cur,
                next.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Overwrite the estimate (warm-starting a variant at startup, and
    /// deterministic routing tests).
    pub fn set(&self, us: f64) {
        self.bits.store(us.max(0.0).to_bits(), Ordering::Relaxed);
    }
}

/// Streaming quantile estimator (Jain & Chlamtac's P² algorithm,
/// five markers) — constant memory, no sample buffer, one small mutex.
///
/// The router's per-variant `budget_us` decisions previously read a
/// latency EWMA, which tracks the *mean* — a budget check against the
/// mean admits queries that blow the budget half the time. This sketch
/// maintains a running estimate of one fixed quantile (p95 for
/// routing) by keeping five marker heights and nudging the middle
/// three toward their ideal positions with a piecewise-parabolic fit
/// on every observation.
///
/// `quantile()` returns 0.0 until five samples have arrived (the
/// markers aren't meaningful yet); callers that need an estimate
/// earlier should fall back to the EWMA — `Variant` does exactly that,
/// so warm-started and freshly-spawned variants keep routing sensibly
/// before real traffic exists. Samples arrive once per *model
/// invocation* (not per query), so the mutex is nowhere near any hot
/// path; `quantile()` on the routing path is a lock + two loads.
pub struct QuantileSketch {
    state: Mutex<P2State>,
}

struct P2State {
    /// Marker heights (sorted observations until seeded, then the P²
    /// estimates for min / q/2 / q / (1+q)/2 / max).
    heights: [f64; 5],
    /// Actual marker positions, 1-indexed as in the paper.
    pos: [f64; 5],
    /// Desired marker positions.
    want: [f64; 5],
    /// Per-observation increments of the desired positions.
    dwant: [f64; 5],
    count: u64,
}

impl QuantileSketch {
    /// A sketch tracking quantile `q` in (0, 1), e.g. 0.95 for p95.
    pub fn new(q: f64) -> QuantileSketch {
        let q = q.clamp(0.001, 0.999);
        QuantileSketch {
            state: Mutex::new(P2State {
                heights: [0.0; 5],
                pos: [1.0, 2.0, 3.0, 4.0, 5.0],
                want: [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0],
                dwant: [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0],
                count: 0,
            }),
        }
    }

    /// Samples observed so far (garbage samples excluded).
    pub fn count(&self) -> u64 {
        self.state.lock().unwrap().count
    }

    /// Current estimate of the tracked quantile, 0.0 until five
    /// samples have seeded the markers.
    pub fn quantile(&self) -> f64 {
        let st = self.state.lock().unwrap();
        if st.count < 5 {
            0.0
        } else {
            st.heights[2]
        }
    }

    /// Fold one observation into the sketch.
    pub fn observe(&self, x: f64) {
        if !x.is_finite() || x < 0.0 {
            return;
        }
        let mut st = self.state.lock().unwrap();
        if st.count < 5 {
            let n = st.count as usize;
            st.heights[n] = x;
            st.count += 1;
            if st.count == 5 {
                st.heights.sort_by(|a, b| a.partial_cmp(b).unwrap());
            }
            return;
        }
        st.count += 1;

        // Cell k holds the new observation; extreme markers absorb
        // out-of-range values directly.
        let k = if x < st.heights[0] {
            st.heights[0] = x;
            0
        } else if x >= st.heights[4] {
            st.heights[4] = x;
            3
        } else {
            // heights[k] <= x < heights[k+1]
            (1..4).find(|&i| x < st.heights[i]).unwrap_or(4) - 1
        };

        for i in (k + 1)..5 {
            st.pos[i] += 1.0;
        }
        for i in 0..5 {
            st.want[i] += st.dwant[i];
        }

        // Nudge interior markers toward their desired positions.
        for i in 1..4 {
            let d = st.want[i] - st.pos[i];
            if (d >= 1.0 && st.pos[i + 1] - st.pos[i] > 1.0)
                || (d <= -1.0 && st.pos[i - 1] - st.pos[i] < -1.0)
            {
                let d = d.signum();
                let parabolic = st.heights[i]
                    + d / (st.pos[i + 1] - st.pos[i - 1])
                        * ((st.pos[i] - st.pos[i - 1] + d)
                            * (st.heights[i + 1] - st.heights[i])
                            / (st.pos[i + 1] - st.pos[i])
                            + (st.pos[i + 1] - st.pos[i] - d)
                                * (st.heights[i] - st.heights[i - 1])
                                / (st.pos[i] - st.pos[i - 1]));
                st.heights[i] = if st.heights[i - 1] < parabolic && parabolic < st.heights[i + 1] {
                    parabolic
                } else {
                    // Parabolic fit left the bracket — fall back to the
                    // linear form, which preserves marker monotonicity.
                    let j = if d > 0.0 { i + 1 } else { i - 1 };
                    st.heights[i]
                        + d * (st.heights[j] - st.heights[i]) / (st.pos[j] - st.pos[i])
                };
                st.pos[i] += d;
            }
        }
    }
}

impl ServiceStats {
    /// The admission conservation invariant, as a signed debt:
    /// `lines_admitted − (lines_answered + over_quota + shed_deadline +
    /// rejected_overloaded + lines_dropped)`. Positive means admitted
    /// lines are still in flight (offloaded jobs running) — or, at a
    /// quiescent point, that a request was silently dropped. Tests
    /// assert 0 at quiescence so any future drop path fails loudly.
    pub fn conservation_debt(&self) -> i64 {
        let settled = self.lines_answered.load(Ordering::Relaxed)
            + self.over_quota.load(Ordering::Relaxed)
            + self.shed_deadline.load(Ordering::Relaxed)
            + self.rejected_overloaded.load(Ordering::Relaxed)
            + self.lines_dropped.load(Ordering::Relaxed);
        self.lines_admitted.load(Ordering::Relaxed) as i64 - settled as i64
    }

    /// Record one executed chunk on the `batch`-sized executable.
    pub fn record_exec(&self, batch: usize) {
        *self.exec_by_batch.lock().unwrap().entry(batch).or_insert(0) += 1;
    }

    /// Snapshot of flush counts per compiled batch size.
    pub fn exec_by_batch(&self) -> BTreeMap<usize, u64> {
        self.exec_by_batch.lock().unwrap().clone()
    }

    pub fn record_latency_us(&self, us: u64) {
        let mut r = self.latencies_us.lock().unwrap();
        if r.samples.len() < RESERVOIR_CAP {
            r.samples.push(us);
        } else {
            // Simple overwrite ring.
            let idx = (self.requests.load(Ordering::Relaxed) as usize) % RESERVOIR_CAP;
            r.samples[idx] = us;
        }
    }

    /// (p50, p95, p99, mean) request latency in microseconds.
    pub fn latency_summary_us(&self) -> (u64, u64, u64, f64) {
        let r = self.latencies_us.lock().unwrap();
        if r.samples.is_empty() {
            return (0, 0, 0, 0.0);
        }
        let mut s = r.samples.clone();
        s.sort_unstable();
        let pct = |p: f64| s[((s.len() as f64 * p) as usize).min(s.len() - 1)];
        let mean = s.iter().sum::<u64>() as f64 / s.len() as f64;
        (pct(0.50), pct(0.95), pct(0.99), mean)
    }

    /// Mean queries per executed batch (batching effectiveness).
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_queries.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Fraction of paid executable slots that carried a real query
    /// (1.0 = perfectly packed batches, 0.0 = nothing executed yet).
    pub fn batch_fill_ratio(&self) -> f64 {
        let slots = self.batch_slots.load(Ordering::Relaxed);
        if slots == 0 {
            0.0
        } else {
            self.batched_queries.load(Ordering::Relaxed) as f64 / slots as f64
        }
    }

    pub fn to_json(&self) -> crate::json::Json {
        use crate::json::Json;
        let (p50, p95, p99, mean) = self.latency_summary_us();
        Json::obj()
            .with("requests", Json::num(self.requests.load(Ordering::Relaxed) as f64))
            .with("cache_hits", Json::num(self.cache_hits.load(Ordering::Relaxed) as f64))
            .with("batches", Json::num(self.batches.load(Ordering::Relaxed) as f64))
            .with("mean_batch_size", Json::num(self.mean_batch_size()))
            .with(
                "batch_requests",
                Json::num(self.batch_requests.load(Ordering::Relaxed) as f64),
            )
            .with("batch_fill_ratio", Json::num(self.batch_fill_ratio()))
            .with(
                "batch_slots",
                Json::num(self.batch_slots.load(Ordering::Relaxed) as f64),
            )
            .with(
                "padded_slots",
                Json::num(self.padded_slots.load(Ordering::Relaxed) as f64),
            )
            .with(
                "frontend_memo_hits",
                Json::num(self.frontend_memo_hits.load(Ordering::Relaxed) as f64),
            )
            .with("encode_ns", Json::num(self.encode_ns.load(Ordering::Relaxed) as f64))
            .with(
                "active_connections",
                Json::num(self.active_connections.load(Ordering::Relaxed) as f64),
            )
            .with(
                "connections_accepted",
                Json::num(self.connections_accepted.load(Ordering::Relaxed) as f64),
            )
            .with(
                "epoll_wakeups",
                Json::num(self.epoll_wakeups.load(Ordering::Relaxed) as f64),
            )
            .with(
                "fairness_deferrals",
                Json::num(self.fairness_deferrals.load(Ordering::Relaxed) as f64),
            )
            .with(
                "lines_admitted",
                Json::num(self.lines_admitted.load(Ordering::Relaxed) as f64),
            )
            .with(
                "lines_answered",
                Json::num(self.lines_answered.load(Ordering::Relaxed) as f64),
            )
            .with(
                "lines_dropped",
                Json::num(self.lines_dropped.load(Ordering::Relaxed) as f64),
            )
            .with("over_quota", Json::num(self.over_quota.load(Ordering::Relaxed) as f64))
            .with(
                "shed_deadline",
                Json::num(self.shed_deadline.load(Ordering::Relaxed) as f64),
            )
            .with(
                "rejected_overloaded",
                Json::num(self.rejected_overloaded.load(Ordering::Relaxed) as f64),
            )
            .with(
                "forwarded_gets",
                Json::num(self.forwarded_gets.load(Ordering::Relaxed) as f64),
            )
            .with("remote_hits", Json::num(self.remote_hits.load(Ordering::Relaxed) as f64))
            .with(
                "forwarded_puts",
                Json::num(self.forwarded_puts.load(Ordering::Relaxed) as f64),
            )
            .with(
                "peer_failures",
                Json::num(self.peer_failures.load(Ordering::Relaxed) as f64),
            )
            .with(
                "degraded_fallbacks",
                Json::num(self.degraded_fallbacks.load(Ordering::Relaxed) as f64),
            )
            .with(
                "budget_downgrades",
                Json::num(self.budget_downgrades.load(Ordering::Relaxed) as f64),
            )
            .with(
                "no_covering_variant",
                Json::num(self.no_covering_variant.load(Ordering::Relaxed) as f64),
            )
            .with(
                "targets_not_served",
                Json::num(self.targets_not_served.load(Ordering::Relaxed) as f64),
            )
            .with(
                "sessions_open",
                Json::num(self.sessions_open.load(Ordering::Relaxed) as f64),
            )
            .with(
                "delta_requests",
                Json::num(self.delta_requests.load(Ordering::Relaxed) as f64),
            )
            .with(
                "spans_spliced",
                Json::num(self.spans_spliced.load(Ordering::Relaxed) as f64),
            )
            .with(
                "spans_reencoded",
                Json::num(self.spans_reencoded.load(Ordering::Relaxed) as f64),
            )
            .with(
                "delta_bytes_rescanned",
                Json::num(self.delta_bytes_rescanned.load(Ordering::Relaxed) as f64),
            )
            .with(
                "offloaded_misses",
                Json::num(self.offloaded_misses.load(Ordering::Relaxed) as f64),
            )
            .with("io_stall_ns", Json::num(self.io_stall_ns.load(Ordering::Relaxed) as f64))
            .with(
                "offload_queue_depth",
                Json::num(self.offload_queue_depth.load(Ordering::Relaxed) as f64),
            )
            .with(
                "search_candidates",
                Json::num(self.search_candidates.load(Ordering::Relaxed) as f64),
            )
            .with(
                "search_probes",
                Json::num(self.search_probes.load(Ordering::Relaxed) as f64),
            )
            .with(
                "search_delta_probes",
                Json::num(self.search_delta_probes.load(Ordering::Relaxed) as f64),
            )
            .with("search_ns", Json::num(self.search_ns.load(Ordering::Relaxed) as f64))
            .with("exec_by_batch", {
                let mut by_batch = Json::obj();
                for (b, count) in self.exec_by_batch() {
                    by_batch = by_batch.with(&b.to_string(), Json::num(count as f64));
                }
                by_batch
            })
            .with("errors", Json::num(self.errors.load(Ordering::Relaxed) as f64))
            .with("latency_p50_us", Json::num(p50 as f64))
            .with("latency_p95_us", Json::num(p95 as f64))
            .with("latency_p99_us", Json::num(p99 as f64))
            .with("latency_mean_us", Json::num(mean))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles() {
        let s = ServiceStats::default();
        for us in 1..=100u64 {
            s.requests.fetch_add(1, Ordering::Relaxed);
            s.record_latency_us(us);
        }
        let (p50, p95, p99, mean) = s.latency_summary_us();
        assert!((45..=55).contains(&p50), "p50 {p50}");
        assert!((93..=98).contains(&p95), "p95 {p95}");
        assert!(p99 >= p95);
        assert!((mean - 50.5).abs() < 1.0);
    }

    #[test]
    fn batch_effectiveness() {
        let s = ServiceStats::default();
        s.batches.fetch_add(2, Ordering::Relaxed);
        s.batched_queries.fetch_add(10, Ordering::Relaxed);
        assert_eq!(s.mean_batch_size(), 5.0);
    }

    #[test]
    fn fill_ratio_tracks_padding_waste() {
        let s = ServiceStats::default();
        assert_eq!(s.batch_fill_ratio(), 0.0);
        // Two executed chunks of a batch-8 executable carrying 10 queries:
        // 16 slots paid, 6 padded.
        s.batched_queries.fetch_add(10, Ordering::Relaxed);
        s.batch_slots.fetch_add(16, Ordering::Relaxed);
        s.padded_slots.fetch_add(6, Ordering::Relaxed);
        assert!((s.batch_fill_ratio() - 0.625).abs() < 1e-12);
    }

    #[test]
    fn json_export() {
        let s = ServiceStats::default();
        s.requests.fetch_add(3, Ordering::Relaxed);
        s.frontend_memo_hits.fetch_add(2, Ordering::Relaxed);
        s.encode_ns.fetch_add(1500, Ordering::Relaxed);
        s.active_connections.fetch_add(4, Ordering::Relaxed);
        s.connections_accepted.fetch_add(9, Ordering::Relaxed);
        s.epoll_wakeups.fetch_add(17, Ordering::Relaxed);
        s.forwarded_gets.fetch_add(6, Ordering::Relaxed);
        s.remote_hits.fetch_add(5, Ordering::Relaxed);
        s.forwarded_puts.fetch_add(1, Ordering::Relaxed);
        s.peer_failures.fetch_add(2, Ordering::Relaxed);
        s.degraded_fallbacks.fetch_add(2, Ordering::Relaxed);
        s.fairness_deferrals.fetch_add(3, Ordering::Relaxed);
        let j = s.to_json();
        assert_eq!(j.req_f64("requests").unwrap(), 3.0);
        assert_eq!(j.req_f64("batch_fill_ratio").unwrap(), 0.0);
        assert_eq!(j.req_f64("batch_slots").unwrap(), 0.0);
        assert_eq!(j.req_f64("padded_slots").unwrap(), 0.0);
        assert_eq!(j.req_f64("frontend_memo_hits").unwrap(), 2.0);
        assert_eq!(j.req_f64("encode_ns").unwrap(), 1500.0);
        assert_eq!(j.req_f64("active_connections").unwrap(), 4.0);
        assert_eq!(j.req_f64("connections_accepted").unwrap(), 9.0);
        assert_eq!(j.req_f64("epoll_wakeups").unwrap(), 17.0);
        assert_eq!(j.req_f64("forwarded_gets").unwrap(), 6.0);
        assert_eq!(j.req_f64("remote_hits").unwrap(), 5.0);
        assert_eq!(j.req_f64("forwarded_puts").unwrap(), 1.0);
        assert_eq!(j.req_f64("peer_failures").unwrap(), 2.0);
        assert_eq!(j.req_f64("degraded_fallbacks").unwrap(), 2.0);
        assert_eq!(j.req_f64("fairness_deferrals").unwrap(), 3.0);
        // Routing-tier counters are present (zero) even before any
        // multi-variant routing happens — dashboards can rely on them.
        assert_eq!(j.req_f64("budget_downgrades").unwrap(), 0.0);
        assert_eq!(j.req_f64("no_covering_variant").unwrap(), 0.0);
        assert_eq!(j.req_f64("targets_not_served").unwrap(), 0.0);
        // Session-tier counters are present (zero) before any session
        // opens, so dashboards can rely on them.
        assert_eq!(j.req_f64("sessions_open").unwrap(), 0.0);
        assert_eq!(j.req_f64("delta_requests").unwrap(), 0.0);
        assert_eq!(j.req_f64("spans_spliced").unwrap(), 0.0);
        assert_eq!(j.req_f64("spans_reencoded").unwrap(), 0.0);
        assert_eq!(j.req_f64("delta_bytes_rescanned").unwrap(), 0.0);
        // Offload-tier counters are present (zero) even when serving
        // runs fully inline — dashboards can rely on them.
        assert_eq!(j.req_f64("offloaded_misses").unwrap(), 0.0);
        assert_eq!(j.req_f64("io_stall_ns").unwrap(), 0.0);
        assert_eq!(j.req_f64("offload_queue_depth").unwrap(), 0.0);
        // Admission-tier counters are present (zero) before any quotas
        // or shedding are configured — dashboards can rely on them.
        assert_eq!(j.req_f64("lines_admitted").unwrap(), 0.0);
        assert_eq!(j.req_f64("lines_answered").unwrap(), 0.0);
        assert_eq!(j.req_f64("lines_dropped").unwrap(), 0.0);
        assert_eq!(j.req_f64("over_quota").unwrap(), 0.0);
        assert_eq!(j.req_f64("shed_deadline").unwrap(), 0.0);
        assert_eq!(j.req_f64("rejected_overloaded").unwrap(), 0.0);
        // Autotune-search counters are present (zero) before any search
        // probes this service — dashboards can rely on them.
        assert_eq!(j.req_f64("search_candidates").unwrap(), 0.0);
        assert_eq!(j.req_f64("search_probes").unwrap(), 0.0);
        assert_eq!(j.req_f64("search_delta_probes").unwrap(), 0.0);
        assert_eq!(j.req_f64("search_ns").unwrap(), 0.0);
        assert!(j.get("exec_by_batch").is_some());
    }

    #[test]
    fn conservation_debt_balances_every_outcome() {
        let s = ServiceStats::default();
        assert_eq!(s.conservation_debt(), 0, "fresh stats owe nothing");
        s.lines_admitted.fetch_add(10, Ordering::Relaxed);
        assert_eq!(s.conservation_debt(), 10, "admitted lines are in flight");
        s.lines_answered.fetch_add(5, Ordering::Relaxed);
        s.over_quota.fetch_add(2, Ordering::Relaxed);
        s.shed_deadline.fetch_add(1, Ordering::Relaxed);
        s.rejected_overloaded.fetch_add(1, Ordering::Relaxed);
        s.lines_dropped.fetch_add(1, Ordering::Relaxed);
        assert_eq!(s.conservation_debt(), 0, "every outcome settles one admission");
        // Over-settling (a double count) goes negative, not modular.
        s.lines_answered.fetch_add(1, Ordering::Relaxed);
        assert_eq!(s.conservation_debt(), -1);
    }

    #[test]
    fn quantile_sketch_cold_reads_zero() {
        let q = QuantileSketch::new(0.95);
        assert_eq!(q.quantile(), 0.0);
        assert_eq!(q.count(), 0);
        for x in [10.0, 20.0, 30.0, 40.0] {
            q.observe(x);
        }
        // Four samples: markers not seeded yet.
        assert_eq!(q.quantile(), 0.0, "needs five samples to seed");
        q.observe(50.0);
        assert_eq!(q.count(), 5);
        assert!(q.quantile() > 0.0);
    }

    #[test]
    fn quantile_sketch_ignores_garbage() {
        let q = QuantileSketch::new(0.95);
        q.observe(f64::NAN);
        q.observe(f64::INFINITY);
        q.observe(-3.0);
        assert_eq!(q.count(), 0, "garbage samples must not seed markers");
    }

    #[test]
    fn quantile_sketch_tracks_uniform_p95() {
        let q = QuantileSketch::new(0.95);
        // Uniform 1..=1000 in a scrambled but deterministic order
        // (stride 37 is coprime with 1000, so every value appears once).
        for i in 0..1000u64 {
            q.observe(((i * 37) % 1000 + 1) as f64);
        }
        let est = q.quantile();
        assert!(
            (850.0..=1000.0).contains(&est),
            "p95 of uniform[1,1000] ≈ 950, sketch said {est}"
        );
    }

    #[test]
    fn quantile_sketch_separates_tail_from_mean() {
        // 9-in-10 fast samples at 100us, 1-in-10 slow at 2000us: the
        // mean (an EWMA's target) sits near 290us, the p95 must land in
        // the slow mode — the whole reason routing switched to a sketch.
        let q = QuantileSketch::new(0.95);
        for i in 0..2000u64 {
            q.observe(if i % 10 == 9 { 2000.0 } else { 100.0 });
        }
        let est = q.quantile();
        assert!(est > 500.0, "p95 must see the slow mode, got {est}");
    }

    #[test]
    fn quantile_sketch_median_of_known_sequence() {
        let q = QuantileSketch::new(0.5);
        for i in 1..=101u64 {
            q.observe(i as f64);
        }
        let est = q.quantile();
        assert!((40.0..=62.0).contains(&est), "median of 1..=101 ≈ 51, got {est}");
    }

    #[test]
    fn quantile_sketch_concurrent_observes_stay_in_range() {
        let q = std::sync::Arc::new(QuantileSketch::new(0.95));
        let mut handles = Vec::new();
        for t in 0..4u32 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..500u32 {
                    q.observe(100.0 + ((t * 500 + i) % 100) as f64);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(q.count(), 2000);
        let v = q.quantile();
        assert!((100.0..=200.0).contains(&v), "sketch left the sample range: {v}");
    }

    #[test]
    fn ewma_seeds_on_first_sample_then_smooths() {
        let e = LatencyEwma::default();
        assert_eq!(e.get(), 0.0);
        e.observe(1000.0);
        assert_eq!(e.get(), 1000.0, "first sample must seed, not average vs 0");
        e.observe(2000.0);
        // 1000 + 0.2 * (2000 - 1000) = 1200.
        assert!((e.get() - 1200.0).abs() < 1e-9, "got {}", e.get());
        // Converges toward a sustained level.
        for _ in 0..64 {
            e.observe(500.0);
        }
        assert!((e.get() - 500.0).abs() < 1.0, "got {}", e.get());
    }

    #[test]
    fn ewma_ignores_garbage_and_allows_seeding() {
        let e = LatencyEwma::default();
        e.observe(f64::NAN);
        e.observe(f64::INFINITY);
        e.observe(-5.0);
        assert_eq!(e.get(), 0.0, "garbage samples must not move the estimate");
        e.set(750.0);
        assert_eq!(e.get(), 750.0);
        e.set(-1.0);
        assert_eq!(e.get(), 0.0, "set clamps below zero");
    }

    #[test]
    fn ewma_concurrent_observes_stay_in_range() {
        let e = std::sync::Arc::new(LatencyEwma::default());
        let mut handles = Vec::new();
        for t in 0..8u32 {
            let e = e.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u32 {
                    e.observe(100.0 + ((t * 1000 + i) % 100) as f64);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let v = e.get();
        assert!((100.0..=200.0).contains(&v), "EWMA left the sample range: {v}");
    }

    #[test]
    fn exec_by_batch_tracks_ladder_selection() {
        let s = ServiceStats::default();
        s.record_exec(8);
        s.record_exec(8);
        s.record_exec(32);
        let by_batch = s.exec_by_batch();
        assert_eq!(by_batch.get(&8), Some(&2));
        assert_eq!(by_batch.get(&32), Some(&1));
        let j = s.to_json();
        let obj = j.get("exec_by_batch").unwrap();
        assert_eq!(obj.req_f64("8").unwrap(), 2.0);
        assert_eq!(obj.req_f64("32").unwrap(), 1.0);
    }
}

//! The routing tier: one serving [`crate::sim::Target`] backed by
//! *several* registered model variants, each with its own bundle
//! (vocab/max_len/params), batch queue, and worker pool.
//!
//! The paper's cost model is explicitly multi-target, and real
//! deployments serve a *family* of model variants behind one query
//! interface — a short probe should pay for a `max_len=128` FC model,
//! not a `max_len=512` conv stack (compare Tiramisu's learned cost model
//! and the SambaNova placement model, which both pick a variant by input
//! size and latency budget). This module is that router:
//!
//! - **Route by length.** Every query's unpadded token count (one
//!   counting tokenizer pass, memoized per text in `LenMemo`) selects
//!   the *cheapest* variant whose `max_len` covers it — variants are
//!   kept sorted by `max_len` ascending, so "cheapest covering" is the
//!   first cover in the list. A query longer than every variant's
//!   `max_len` is rejected with a clean error (`no_covering_variant` in
//!   the stats), never silently truncated and never a panic.
//! - **Route by budget.** A request may carry `budget_us`. When the
//!   length-preferred variant's observed latency (a per-variant p95
//!   from a [`QuantileSketch`], falling back to the [`LatencyEwma`]
//!   until five real samples exist — see
//!   [`Variant::latency_estimate_us`]) would
//!   blow the budget, the router reroutes: first to the cheapest
//!   *larger covering* variant whose estimate fits (no accuracy loss),
//!   otherwise *down* to the largest smaller/faster variant whose
//!   estimate fits — an explicit accuracy-for-latency trade (the
//!   encoding is truncated to the smaller `max_len`). Either reroute
//!   is counted in `budget_downgrades`. If nothing fits the budget,
//!   the preferred covering variant serves anyway: an unsatisfiable
//!   budget must not degrade accuracy for free.
//! - **Isolate per variant.** Each variant owns its batch queue and
//!   worker pool, and both the frontend memo and the prediction cache
//!   key on the variant ([`super::cache::cache_namespace`]), so two
//!   variants can never cross-serve encodings or cached values.
//!
//! Construction-time invariants (checked by `Router::build`): at
//! least one variant per target, unique variant names within a target,
//! and one tokenization scheme per target (the routing length is
//! measured once per text under that scheme; mixed schemes would give
//! each variant a different notion of "length").

use super::batcher::{BatchQueue, PolicyController};
use super::frontend::ShardedMemo;
use super::stats::{LatencyEwma, QuantileSketch};
use crate::bundle::Bundle;
use crate::sim::Target;
use crate::tokenizer::span::IdSpan;
use crate::tokenizer::Scheme;
use anyhow::{anyhow, bail, Result};
use fxhash::FxHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::AtomicU64;
use std::sync::Arc;
use std::thread::JoinHandle;

/// What `mlir-cost serve --variants` (or a library caller) registers:
/// a named serving variant. The target and scheme come from the bundle.
pub struct VariantSpec {
    /// Name the router, the stats, and wire responses use. Must be
    /// unique within the bundle's target.
    pub name: String,
    pub bundle: Bundle,
}

/// One registered model variant: bundle + batch queue + worker pool +
/// routing telemetry. Built by `Service::start_variants`.
pub(crate) struct Variant {
    pub(crate) name: Arc<str>,
    pub(crate) bundle: Bundle,
    /// `target/variant/model` — the prediction-cache key namespace
    /// ([`super::cache::cache_namespace`]).
    pub(crate) cache_ns: String,
    pub(crate) queue: Arc<BatchQueue>,
    pub(crate) workers: Vec<JoinHandle<()>>,
    /// Queries routed to this variant (preferred or downgraded-into).
    pub(crate) routed: AtomicU64,
    /// Queries that arrived here via a `budget_us` downgrade.
    pub(crate) budget_downgrades: AtomicU64,
    /// Observed model-invocation latency (queue wait + PJRT execute).
    /// Shared with the variant's worker pool, which observes each
    /// completed request's `submitted.elapsed()` — per-request accurate
    /// regardless of how callers collect results. Cache hits don't feed
    /// it — a hit costs the same on every variant. Kept (and exported)
    /// for back-compat and as the cold-start fallback; `budget_us`
    /// decisions now read [`Variant::latency_estimate_us`].
    pub(crate) ewma_us: Arc<LatencyEwma>,
    /// Observed model-invocation latency p95 (same samples as
    /// `ewma_us`, folded into a P² sketch). This is the estimate
    /// `budget_us` routing reads once five samples exist: a budget
    /// check against a mean admits queries that blow the budget half
    /// the time; the p95 is the honest version of that promise.
    pub(crate) p95_us: Arc<QuantileSketch>,
    /// This variant's live batch-policy controller: retunes the
    /// queue's `max_batch`/`max_wait_us` from observed flush fill and
    /// execute latency (`--batch-policy adaptive`), or sits inert
    /// (`static`). Always present so `policy_*` stats export
    /// unconditionally.
    pub(crate) policy: Arc<PolicyController>,
    /// The incremental tier's segment cache: `FxHash(line bytes)` →
    /// that line's [`IdSpan`] under THIS variant's vocab/op-table
    /// (spans embed vocabulary ids, so the table is per-variant by
    /// construction — no salt needed in the key). `session_open` warms
    /// it for the routed variant; `mlir_delta` splices hits and
    /// re-lexes only misses (`spans_spliced` / `spans_reencoded`).
    pub(crate) span_table: ShardedMemo<IdSpan>,
}

/// Samples the p95 sketch needs before routing trusts it over the
/// EWMA (the sketch's five P² markers must be seeded).
const P95_MIN_SAMPLES: u64 = 5;

impl Variant {
    /// The latency estimate `budget_us` decisions read: the sketch's
    /// p95 once it has real samples, else the EWMA — so warm-started
    /// variants (`set_variant_ewma_us`, manifest `ewma_us` keys) and
    /// cold variants keep routing sensibly before traffic exists.
    pub(crate) fn latency_estimate_us(&self) -> f64 {
        if self.p95_us.count() >= P95_MIN_SAMPLES {
            self.p95_us.quantile()
        } else {
            self.ewma_us.get()
        }
    }
}

/// All variants serving one target, sorted by `(max_len, name)`
/// ascending — so "the cheapest covering variant" is simply the first
/// one in the list whose `max_len` covers the query.
pub(crate) struct TargetRoutes {
    pub(crate) scheme: Scheme,
    pub(crate) variants: Vec<Variant>,
}

impl TargetRoutes {
    /// Pick a variant for a query of `token_len` tokens that serves
    /// every characteristic in `required` (empty = any variant of this
    /// target qualifies). See [`choose_variant`] for the decision rule.
    /// `None` = no eligible variant covers the length.
    pub(crate) fn choose(
        &self,
        token_len: usize,
        budget_us: Option<u64>,
        required: &[Target],
    ) -> Option<(usize, bool)> {
        choose_variant(
            self.variants.len(),
            |i| {
                let v = &self.variants[i];
                (v.bundle.max_len, v.latency_estimate_us(), v.bundle.serves_all(required))
            },
            token_len,
            budget_us,
        )
    }

    /// Does ANY variant (eligible or not) cover this token length? Used
    /// to tell a length failure (`no_covering_variant`) apart from a
    /// characteristic-coverage failure (`targets_not_served`).
    pub(crate) fn covers_len(&self, token_len: usize) -> bool {
        self.variants.iter().any(|v| v.bundle.max_len >= token_len)
    }

    /// The requested characteristics no variant of this target serves
    /// at all (for the `targets_not_served` error message).
    pub(crate) fn unserved(&self, required: &[Target]) -> Vec<Target> {
        required
            .iter()
            .copied()
            .filter(|&t| !self.variants.iter().any(|v| v.bundle.targets.contains(&t)))
            .collect()
    }

    /// The fastest *credible* latency estimate across this target's
    /// variants — the admission tier's optimistic bound for deadline
    /// shedding: if even this cannot meet a request's `budget_us`, no
    /// routing decision can. Cold variants (estimate 0.0 = "no
    /// evidence yet") are excluded, and `None` comes back when every
    /// variant is cold — a service with no latency evidence must never
    /// shed.
    pub(crate) fn min_latency_estimate_us(&self) -> Option<f64> {
        self.variants
            .iter()
            .map(Variant::latency_estimate_us)
            .filter(|e| *e > 0.0)
            .min_by(f64::total_cmp)
    }

    /// The largest registered `max_len` (error messages).
    pub(crate) fn largest_max_len(&self) -> usize {
        self.variants.last().map(|v| v.bundle.max_len).unwrap_or(0)
    }

    pub(crate) fn find(&self, name: &str) -> Option<&Variant> {
        self.variants.iter().find(|v| &*v.name == name)
    }
}

/// The routing decision, shared by the stateful router and the pure
/// unit tests. `meta(i)` returns `(max_len, ewma_us, eligible)` for
/// variant `i` of a `(max_len, name)`-ascending list — `eligible` is
/// false when the variant does not serve every requested
/// characteristic, and such variants are invisible to every step of
/// the rule (preferred pick and both budget scans): a query must never
/// receive a silent partial answer. Returns
/// `(chosen index, rerouted-by-budget)`; `None` when no eligible
/// variant covers `token_len`.
///
/// Rule: the *preferred* variant is the first (cheapest) cover. With a
/// budget, if the preferred estimate exceeds it:
///
/// 1. scan **upward** through the larger covering variants for the
///    cheapest one whose estimate fits — they cover the query, so a
///    faster-but-bigger sibling costs no accuracy at all (rare shape,
///    but real: a small LSTM can be slower than a wide FC);
/// 2. otherwise scan **downward** for the *largest* smaller variant
///    whose estimate fits — largest, because a downgrade truncates the
///    encoding to the smaller `max_len` and the router should shed as
///    little of the query as the budget allows;
/// 3. if nothing fits the budget, the preferred cover serves anyway
///    (an unsatisfiable budget should cost latency honesty, not
///    accuracy).
///
/// A cold variant's estimate reads 0.0 and therefore fits any budget.
pub(crate) fn choose_variant<F>(
    n: usize,
    meta: F,
    token_len: usize,
    budget_us: Option<u64>,
) -> Option<(usize, bool)>
where
    F: Fn(usize) -> (usize, f64, bool),
{
    let preferred = (0..n).find(|&i| {
        let (max_len, _, eligible) = meta(i);
        eligible && max_len >= token_len
    })?;
    if let Some(budget) = budget_us {
        let budget = budget as f64;
        if meta(preferred).1 > budget {
            for i in (preferred + 1)..n {
                let (_, ewma, eligible) = meta(i);
                if eligible && ewma <= budget {
                    return Some((i, true));
                }
            }
            for i in (0..preferred).rev() {
                let (_, ewma, eligible) = meta(i);
                if eligible && ewma <= budget {
                    return Some((i, true));
                }
            }
        }
    }
    Some((preferred, false))
}

/// Entries each variant's span table holds. A span is one *line's* ids
/// (a handful of u32s), so even ops_operands affine bodies keep this
/// under ~2 MB per variant; clear-on-full re-warms in one delta.
pub(crate) const SPAN_TABLE_CAPACITY: usize = 32768;

/// Shard count for the span table (power of two, mirroring the other
/// serving-path memos).
pub(crate) const SPAN_TABLE_SHARDS: usize = 16;

/// Entries the token-length memo holds (12 bytes each — a routing
/// probe on a duplicate text costs one text hash + one shard lookup,
/// no tokenizer pass).
const LEN_MEMO_CAPACITY: usize = 16384;

/// Shard count for [`LenMemo`] (power of two, mirroring the prediction
/// cache's layout).
const LEN_MEMO_SHARDS: usize = 16;

/// Sharded `FxHash(target, text)` → unpadded-token-count memo: the
/// router's half of the duplicate-query fast path (the per-variant
/// encode memo is the other half). An instance of the same generic
/// [`ShardedMemo`] the encode memo uses — this thin wrapper only owns
/// the key derivation and the `u32` clamp that keeps entries at 12
/// bytes.
pub(crate) struct LenMemo {
    memo: ShardedMemo<u32>,
}

impl LenMemo {
    fn new(capacity: usize) -> LenMemo {
        LenMemo { memo: ShardedMemo::with_shards(capacity, LEN_MEMO_SHARDS) }
    }

    /// Memo key over `(target, text)` — hashes the full text; the hot
    /// path uses [`LenMemo::key_from_hash`] with the digest the front
    /// end already computed.
    pub(crate) fn key(target: &str, text: &str) -> u64 {
        LenMemo::key_from_hash(target, super::frontend::FrontendMemo::text_hash(text))
    }

    /// Memo key from a precomputed text digest (hashes only the short
    /// target salt).
    pub(crate) fn key_from_hash(target: &str, text_hash: u64) -> u64 {
        let mut h = FxHasher::default();
        target.hash(&mut h);
        text_hash.hash(&mut h);
        h.finish()
    }

    pub(crate) fn get(&self, key: u64) -> Option<usize> {
        self.memo.get(key).map(|n| n as usize)
    }

    pub(crate) fn insert(&self, key: u64, token_len: usize) {
        self.memo.insert(key, token_len.min(u32::MAX as usize) as u32);
    }

    pub(crate) fn len(&self) -> usize {
        self.memo.len()
    }
}

/// The per-target variant tables plus the routing-length memo.
pub(crate) struct Router {
    routes: HashMap<Target, TargetRoutes>,
    pub(crate) len_memo: LenMemo,
}

/// The construction invariants, checkable from bare `(target, name,
/// scheme)` triples — `Service::start_variants` runs this BEFORE
/// spawning any worker pool, so a rejected variant set cannot leak
/// workers parked on orphaned queues.
pub(crate) fn validate_variant_set<'a>(
    items: impl Iterator<Item = (Target, &'a str, Scheme)>,
) -> Result<()> {
    let mut seen: Vec<(Target, &'a str, Scheme)> = Vec::new();
    for (target, name, scheme) in items {
        if seen.iter().any(|&(t, n, _)| t == target && n == name) {
            bail!("duplicate variant name '{name}' for target '{}'", target.name());
        }
        if let Some(&(_, _, s)) = seen.iter().find(|&&(t, _, _)| t == target) {
            if s != scheme {
                bail!(
                    "variants of target '{}' mix tokenization schemes ({} vs {}): \
                     routing measures one length per query, so a target's variants \
                     must share a scheme",
                    target.name(),
                    s.name(),
                    scheme.name(),
                );
            }
        }
        seen.push((target, name, scheme));
    }
    Ok(())
}

impl Router {
    /// Organize constructed variants into per-target routing tables,
    /// re-checking the construction invariants (≥1 variant per
    /// requested target comes free — targets only exist here because a
    /// variant named them).
    pub(crate) fn build(variants: Vec<(Target, Variant)>) -> Result<Router> {
        validate_variant_set(
            variants.iter().map(|(t, v)| (*t, &*v.name, v.bundle.scheme)),
        )?;
        let mut routes: HashMap<Target, TargetRoutes> = HashMap::new();
        for (target, v) in variants {
            routes
                .entry(target)
                .or_insert_with(|| TargetRoutes { scheme: v.bundle.scheme, variants: Vec::new() })
                .variants
                .push(v);
        }
        for tr in routes.values_mut() {
            tr.variants.sort_by(|a, b| {
                a.bundle.max_len.cmp(&b.bundle.max_len).then_with(|| a.name.cmp(&b.name))
            });
        }
        Ok(Router { routes, len_memo: LenMemo::new(LEN_MEMO_CAPACITY) })
    }

    pub(crate) fn routes(&self, target: Target) -> Result<&TargetRoutes> {
        self.routes
            .get(&target)
            .ok_or_else(|| anyhow!("no model serving target '{}'", target.name()))
    }

    pub(crate) fn targets(&self) -> Vec<Target> {
        self.routes.keys().copied().collect()
    }

    pub(crate) fn iter(&self) -> impl Iterator<Item = (&Target, &TargetRoutes)> {
        self.routes.iter()
    }

    pub(crate) fn iter_mut(&mut self) -> impl Iterator<Item = (&Target, &mut TargetRoutes)> {
        self.routes.iter_mut()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Slice-backed wrapper for the pure decision rule: `meta[i]` is
    /// `(max_len, ewma_us, eligible)`, max_len ascending.
    fn pick(
        meta: &[(usize, f64, bool)],
        len: usize,
        budget: Option<u64>,
    ) -> Option<(usize, bool)> {
        choose_variant(meta.len(), |i| meta[i], len, budget)
    }

    const LADDER: &[(usize, f64, bool)] =
        &[(128, 300.0, true), (128, 900.0, true), (512, 5_000.0, true)];

    #[test]
    fn cheapest_covering_variant_wins_without_budget() {
        assert_eq!(pick(LADDER, 1, None), Some((0, false)));
        assert_eq!(pick(LADDER, 128, None), Some((0, false)), "boundary is inclusive");
        assert_eq!(pick(LADDER, 129, None), Some((2, false)));
        assert_eq!(pick(LADDER, 512, None), Some((2, false)));
    }

    #[test]
    fn query_longer_than_every_variant_has_no_route() {
        assert_eq!(pick(LADDER, 513, None), None);
        assert_eq!(pick(LADDER, 513, Some(1)), None, "budget cannot rescue an uncovered query");
        assert_eq!(pick(&[], 1, None), None, "empty variant list routes nowhere");
    }

    #[test]
    fn budget_met_by_preferred_variant_does_not_downgrade() {
        // Long query prefers the 512 variant (ewma 5000); a generous
        // budget keeps it there.
        assert_eq!(pick(LADDER, 200, Some(10_000)), Some((2, false)));
        // Exact fit is still a fit.
        assert_eq!(pick(LADDER, 200, Some(5_000)), Some((2, false)));
    }

    #[test]
    fn blown_budget_downgrades_to_largest_fitting_smaller_variant() {
        // 512-variant (5000us) blows a 1000us budget; both 128 variants
        // are smaller. The LARGEST fitting one wins — index 1 (900us),
        // not index 0 — so the truncation sheds as little as possible.
        assert_eq!(pick(LADDER, 200, Some(1_000)), Some((1, true)));
        // A tighter budget (500us) only the small variant fits.
        assert_eq!(pick(LADDER, 200, Some(500)), Some((0, true)));
    }

    #[test]
    fn budget_below_every_ewma_keeps_smallest_covering_variant() {
        // Nothing fits 10us: the preferred (smallest covering) variant
        // serves, and it is NOT counted as a downgrade.
        assert_eq!(pick(LADDER, 200, Some(10)), Some((2, false)));
        assert_eq!(pick(LADDER, 1, Some(10)), Some((0, false)));
    }

    #[test]
    fn cold_variant_fits_any_budget() {
        // ewma 0.0 = no evidence of slowness: it qualifies as a
        // downgrade landing spot...
        let meta = [(128usize, 0.0, true), (512, 5_000.0, true)];
        assert_eq!(pick(&meta, 200, Some(1_000)), Some((0, true)));
        // ...and as a preferred variant it never triggers a downgrade.
        let cold = [(128usize, 0.0, true), (512, 0.0, true)];
        assert_eq!(pick(&cold, 200, Some(1)), Some((1, false)));
    }

    #[test]
    fn blown_budget_prefers_larger_covering_variant_over_truncation() {
        // The small variant is the slow one (e.g. LSTM) and the big one
        // is fast (wide FC): a blown budget reroutes UP to the larger
        // covering variant — zero accuracy loss — before considering
        // any truncating downgrade.
        let meta = [(128usize, 5_000.0, true), (512, 300.0, true)];
        assert_eq!(pick(&meta, 50, Some(1_000)), Some((1, true)));
        // Even when a smaller truncating variant also fits the budget,
        // the covering sibling wins.
        let meta3 = [(64usize, 100.0, true), (128, 5_000.0, true), (512, 300.0, true)];
        assert_eq!(pick(&meta3, 100, Some(1_000)), Some((2, true)));
    }

    #[test]
    fn preferred_at_index_zero_with_unsatisfiable_budget_stays_put() {
        // Preferred blows the 1us budget and no sibling (larger or
        // smaller) fits either: serve preferred, count no reroute.
        assert_eq!(pick(LADDER, 50, Some(1)), Some((0, false)));
    }

    #[test]
    fn ineligible_variants_are_invisible_to_every_step() {
        // Preferred pick skips an ineligible cheaper cover: the query
        // requires characteristics only the bigger variant serves.
        let meta = [(128usize, 300.0, false), (512, 5_000.0, true)];
        assert_eq!(pick(&meta, 50, None), Some((1, false)));
        // All covers ineligible → no route, even with slack budget.
        let none = [(128usize, 300.0, false), (512, 5_000.0, false)];
        assert_eq!(pick(&none, 50, None), None);
        assert_eq!(pick(&none, 50, Some(100_000)), None);
        // A blown budget must not downgrade INTO an ineligible variant:
        // the only budget-fitting smaller sibling is ineligible, so the
        // preferred eligible cover serves anyway (honest latency, never
        // a partial answer).
        let trap = [(128usize, 100.0, false), (256, 200.0, true), (512, 5_000.0, true)];
        assert_eq!(pick(&trap, 300, Some(1_000)), Some((2, false)));
        // Upward budget rescue also respects eligibility.
        let up = [(128usize, 5_000.0, true), (256, 100.0, false), (512, 300.0, true)];
        assert_eq!(pick(&up, 50, Some(1_000)), Some((2, true)));
    }

    #[test]
    fn len_memo_roundtrip_and_bound() {
        let m = LenMemo::new(64);
        let k = LenMemo::key("regpressure", "func.func @f() { return }");
        assert_eq!(m.get(k), None);
        m.insert(k, 7);
        assert_eq!(m.get(k), Some(7));
        // Distinct targets measure distinct keys for the same text.
        assert_ne!(k, LenMemo::key("cycles", "func.func @f() { return }"));
        for i in 0..1000u64 {
            m.insert(LenMemo::key("t", &format!("text {i}")), i as usize);
        }
        assert!(m.len() <= 64, "len memo grew past capacity: {}", m.len());
    }

    #[test]
    fn len_memo_reinsert_at_cap_does_not_clear() {
        // Same clear-on-full subtlety FrontendMemo pins: refreshing an
        // existing key at capacity must not wipe the shard.
        let m = LenMemo::new(1);
        let k = LenMemo::key("t", "x");
        m.insert(k, 5);
        m.insert(k, 6);
        assert_eq!(m.get(k), Some(6));
        assert_eq!(m.len(), 1);
    }
}

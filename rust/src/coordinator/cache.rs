//! Prediction cache: compilers re-query the same subgraphs constantly
//! (every pass, every heuristic probe), so a small exact-match cache keyed
//! by the encoded token sequence removes most model invocations.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::Mutex;

/// Bounded FIFO-evicting exact-match cache.
pub struct PredictionCache {
    map: Mutex<Inner>,
    capacity: usize,
}

struct Inner {
    entries: HashMap<u64, f64>,
    order: std::collections::VecDeque<u64>,
    hits: u64,
    misses: u64,
}

/// Key = hash of (model name, encoded ids).
pub fn cache_key(model: &str, ids: &[u32]) -> u64 {
    let mut h = DefaultHasher::new();
    model.hash(&mut h);
    ids.hash(&mut h);
    h.finish()
}

impl PredictionCache {
    pub fn new(capacity: usize) -> Self {
        PredictionCache {
            map: Mutex::new(Inner {
                entries: HashMap::new(),
                order: std::collections::VecDeque::new(),
                hits: 0,
                misses: 0,
            }),
            capacity: capacity.max(1),
        }
    }

    pub fn get(&self, key: u64) -> Option<f64> {
        let mut inner = self.map.lock().unwrap();
        match inner.entries.get(&key).copied() {
            Some(v) => {
                inner.hits += 1;
                Some(v)
            }
            None => {
                inner.misses += 1;
                None
            }
        }
    }

    pub fn put(&self, key: u64, value: f64) {
        let mut inner = self.map.lock().unwrap();
        if inner.entries.len() >= self.capacity && !inner.entries.contains_key(&key) {
            if let Some(old) = inner.order.pop_front() {
                inner.entries.remove(&old);
            }
        }
        if inner.entries.insert(key, value).is_none() {
            inner.order.push_back(key);
        }
    }

    /// (hits, misses) so far.
    pub fn stats(&self) -> (u64, u64) {
        let inner = self.map.lock().unwrap();
        (inner.hits, inner.misses)
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_miss_accounting() {
        let c = PredictionCache::new(8);
        let k = cache_key("m", &[1, 2, 3]);
        assert_eq!(c.get(k), None);
        c.put(k, 7.5);
        assert_eq!(c.get(k), Some(7.5));
        assert_eq!(c.stats(), (1, 1));
    }

    #[test]
    fn distinct_keys() {
        assert_ne!(cache_key("a", &[1, 2]), cache_key("b", &[1, 2]));
        assert_ne!(cache_key("a", &[1, 2]), cache_key("a", &[2, 1]));
    }

    #[test]
    fn eviction_respects_capacity() {
        let c = PredictionCache::new(3);
        for i in 0..10u32 {
            c.put(cache_key("m", &[i]), i as f64);
        }
        assert_eq!(c.len(), 3);
        // The newest entries survive.
        assert_eq!(c.get(cache_key("m", &[9])), Some(9.0));
        assert_eq!(c.get(cache_key("m", &[0])), None);
    }

    #[test]
    fn put_same_key_updates_without_growth() {
        let c = PredictionCache::new(2);
        let k = cache_key("m", &[5]);
        c.put(k, 1.0);
        c.put(k, 2.0);
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(k), Some(2.0));
    }
}

//! Prediction cache: compilers re-query the same subgraphs constantly
//! (every pass, every heuristic probe), so an exact-match cache keyed by
//! the encoded token sequence removes most model invocations.
//!
//! Two properties matter at serving scale and both live here:
//!
//! - **N-way sharding.** Entries are spread over `N` shards selected by
//!   the key's high bits, each behind its own `Mutex`, so concurrent
//!   compiler threads rarely collide on a lock. Each shard is an LRU: a
//!   hit re-stamps the entry and pushes a fresh `(key, stamp)` pair onto
//!   the recency queue (stale pairs are skipped lazily at eviction time),
//!   so promotion stays O(1).
//! - **Single-flight misses.** Autotuning probes fire thousands of
//!   near-simultaneous identical queries. The first miss for a key becomes
//!   the *leader* (it pays the model invocation); concurrent misses for
//!   the same key become *followers* that park on a per-key waiter list
//!   and receive the leader's answer — they never occupy a batch slot.
//!
//! Contention (`lock would have blocked`) and coalesced-follower counts
//! are exported through the service `stats` command.

use crate::pred::PredVec;
use fxhash::{FxHashMap, FxHasher};
use std::collections::VecDeque;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Mutex, MutexGuard, TryLockError};

/// Key = FxHash of (model name, encoded ids). This runs once per query —
/// over a `max_len`-sized id row — so the hasher choice is measurable;
/// FxHash is ~an order of magnitude cheaper than SipHash here and the
/// keys are compiler-internal (no DoS surface).
pub fn cache_key(model: &str, ids: &[u32]) -> u64 {
    let mut h = FxHasher::default();
    model.hash(&mut h);
    ids.hash(&mut h);
    h.finish()
}

/// The string every serving variant hashes into its cache keys:
/// `target/variant/model`. Including the target and the variant name —
/// not just the model architecture — means two variants (or two targets)
/// that happen to share a model name can never cross-serve each other's
/// cached predictions: their keys live in disjoint namespaces. The
/// namespace is derived deterministically from configuration, so every
/// node of a cluster serving the same variant set computes identical
/// keys (the consistent-hash ring depends on that).
pub fn cache_namespace(target: &str, variant: &str, model: &str) -> String {
    format!("{target}/{variant}/{model}")
}

/// Default shard count for the serving path (power of two).
pub const DEFAULT_SHARDS: usize = 16;

/// Wire form of a cache key for the cluster tier's `cache_get` /
/// `cache_put` commands: fixed-width hex. JSON numbers are f64 and lose
/// u64 precision above 2^53, so keys cross node boundaries as strings.
pub fn key_to_wire(key: u64) -> String {
    format!("{key:016x}")
}

/// Parse a wire-form cache key (any hex u64; case-insensitive).
pub fn key_from_wire(s: &str) -> Option<u64> {
    u64::from_str_radix(s, 16).ok()
}

/// Shard selection shared by the prediction cache and the front-end
/// memo: the key's high bits pick the shard (FxHash's final multiply
/// diffuses into the high bits), leaving the low bits for the in-shard
/// map's buckets. The cluster tier's consistent-hash ring
/// (`crate::cluster::ring`) is the cross-process extension of the same
/// owner-partition idea — keys spread by their hash, ownership decided
/// without coordination.
pub fn shard_index(key: u64, shard_bits: u32) -> usize {
    if shard_bits == 0 {
        0
    } else {
        (key >> (64 - shard_bits)) as usize
    }
}

struct Entry {
    /// The full prediction bundle for this key — every declared
    /// characteristic from the one forward pass that computed it.
    /// `PredVec` is `Copy` and inline, so entries stay uniform-size:
    /// capacity accounting is still a plain entry count, with each
    /// entry a fixed `size_of::<Entry>()` regardless of how many
    /// characteristics the bundle declares.
    value: PredVec,
    /// Stamp of this entry's newest pair in `order`; older pairs for the
    /// same key are stale and skipped during eviction.
    stamp: u64,
}

struct Shard {
    entries: FxHashMap<u64, Entry>,
    /// Lazy LRU recency queue of `(key, stamp)`; front is oldest.
    order: VecDeque<(u64, u64)>,
    stamp: u64,
    /// Keys with a model invocation in flight → waiters to notify.
    inflight: FxHashMap<u64, Vec<Sender<Option<PredVec>>>>,
}

impl Shard {
    fn new() -> Shard {
        Shard {
            entries: FxHashMap::default(),
            order: VecDeque::new(),
            stamp: 0,
            inflight: FxHashMap::default(),
        }
    }

    /// Re-stamp `key` as most recently used; returns its value if present.
    /// One hash probe serves both the hit test and the promotion.
    fn promote(&mut self, key: u64) -> Option<PredVec> {
        let e = self.entries.get_mut(&key)?;
        self.stamp += 1;
        e.stamp = self.stamp;
        let value = e.value;
        self.push_order(key);
        Some(value)
    }

    /// Record `(key, current stamp)` in the lazy recency queue. The queue
    /// holds one pair per (re)use; compact when stale pairs dominate so
    /// memory stays proportional to live entries — on every path that
    /// pushes, or reuse-heavy workloads (get-promotes *and* put-refreshes)
    /// would grow it without bound.
    fn push_order(&mut self, key: u64) {
        self.order.push_back((key, self.stamp));
        if self.order.len() > self.entries.len() * 4 + 16 {
            self.compact();
        }
    }

    fn compact(&mut self) {
        let entries = &self.entries;
        self.order.retain(|(k, s)| entries.get(k).map(|e| e.stamp) == Some(*s));
    }

    /// Insert (or refresh) an entry, evicting the least-recently-used
    /// genuine entries down to `cap`.
    fn insert(&mut self, key: u64, value: PredVec, cap: usize) {
        self.stamp += 1;
        let stamp = self.stamp;
        if self.entries.insert(key, Entry { value, stamp }).is_none() {
            while self.entries.len() > cap {
                match self.order.pop_front() {
                    Some((k, s)) => {
                        if self.entries.get(&k).map(|e| e.stamp) == Some(s) {
                            self.entries.remove(&k);
                        }
                        // Stale pair (entry was promoted since): skip.
                    }
                    None => break,
                }
            }
        }
        self.push_order(key);
    }
}

/// Result of a cache lookup on the serving path.
pub enum Lookup<'a> {
    /// Cached value, promoted to most-recently-used.
    Hit(PredVec),
    /// Another thread is already computing this key; park on the receiver
    /// for its denormalized value (`None` = the leader failed).
    Wait(Receiver<Option<PredVec>>),
    /// This thread is the leader: it must run the model and then
    /// [`FlightGuard::complete`]. Dropping the guard without completing
    /// signals failure to any followers.
    Miss(FlightGuard<'a>),
}

/// Leader token for a single-flight miss. Exactly one exists per key at a
/// time; completing it publishes the value to the cache and to every
/// coalesced follower.
pub struct FlightGuard<'a> {
    cache: &'a PredictionCache,
    key: u64,
    done: bool,
}

impl FlightGuard<'_> {
    pub fn key(&self) -> u64 {
        self.key
    }

    /// Publish the computed value: insert into the cache and wake all
    /// followers with `Some(value)`.
    pub fn complete(mut self, value: PredVec) {
        self.done = true;
        self.cache.fulfill(self.key, Some(value));
    }
}

impl Drop for FlightGuard<'_> {
    fn drop(&mut self) {
        if !self.done {
            // Leader failed: wake followers with None so they error out
            // instead of waiting forever.
            self.cache.fulfill(self.key, None);
        }
    }
}

/// Bounded, sharded, LRU-evicting exact-match cache with single-flight
/// miss coalescing.
pub struct PredictionCache {
    shards: Vec<Mutex<Shard>>,
    shard_bits: u32,
    per_shard_cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    coalesced: AtomicU64,
    contended: AtomicU64,
}

impl PredictionCache {
    /// `DEFAULT_SHARDS`-way cache holding ~`capacity` entries total.
    pub fn new(capacity: usize) -> Self {
        Self::with_shards(capacity, DEFAULT_SHARDS)
    }

    /// Explicit shard count (rounded up to a power of two; tests use 1 for
    /// deterministic eviction order). The shard count is clamped so a
    /// small capacity is not silently multiplied: each shard holds at
    /// least one entry, so the worst-case total is
    /// `max(capacity, shard_count)` rounded up to the shard granularity.
    pub fn with_shards(capacity: usize, shards: usize) -> Self {
        let n = shards
            .max(1)
            .next_power_of_two()
            .min(capacity.max(1).next_power_of_two());
        PredictionCache {
            shards: (0..n).map(|_| Mutex::new(Shard::new())).collect(),
            shard_bits: n.trailing_zeros(),
            per_shard_cap: (capacity / n).max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            contended: AtomicU64::new(0),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn lock_shard(&self, key: u64) -> MutexGuard<'_, Shard> {
        let m = &self.shards[shard_index(key, self.shard_bits)];
        match m.try_lock() {
            Ok(g) => g,
            Err(TryLockError::WouldBlock) => {
                self.contended.fetch_add(1, Ordering::Relaxed);
                m.lock().unwrap()
            }
            Err(TryLockError::Poisoned(p)) => p.into_inner(),
        }
    }

    /// Serving-path lookup with single-flight semantics.
    pub fn lookup(&self, key: u64) -> Lookup<'_> {
        let mut shard = self.lock_shard(key);
        if let Some(v) = shard.promote(key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Lookup::Hit(v);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        if let Some(waiters) = shard.inflight.get_mut(&key) {
            let (tx, rx) = channel();
            waiters.push(tx);
            self.coalesced.fetch_add(1, Ordering::Relaxed);
            return Lookup::Wait(rx);
        }
        shard.inflight.insert(key, Vec::new());
        Lookup::Miss(FlightGuard { cache: self, key, done: false })
    }

    /// Resolve an in-flight key: cache the value (if any) and notify all
    /// waiters outside the lock.
    fn fulfill(&self, key: u64, value: Option<PredVec>) {
        let waiters = {
            let mut shard = self.lock_shard(key);
            let waiters = shard.inflight.remove(&key).unwrap_or_default();
            if let Some(v) = value {
                shard.insert(key, v, self.per_shard_cap);
            }
            waiters
        };
        for w in waiters {
            let _ = w.send(value);
        }
    }

    /// Plain get (promotes on hit); bypasses single-flight bookkeeping.
    pub fn get(&self, key: u64) -> Option<PredVec> {
        let v = self.lock_shard(key).promote(key);
        match v {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        v
    }

    /// Silent probe: is `key` resident right now? No hit/miss counters,
    /// no LRU promotion, no single-flight registration — the IO thread's
    /// offload classifier uses this to decide *where* a line runs
    /// (inline for warm hits, worker pool otherwise) without perturbing
    /// any statistic the real serving path will count moments later.
    /// Advisory by nature: an entry can be evicted (or land) between
    /// this probe and the real lookup, which costs one misclassified
    /// line, never a wrong answer.
    pub fn peek(&self, key: u64) -> Option<PredVec> {
        self.lock_shard(key).entries.get(&key).map(|e| e.value)
    }

    /// Plain insert; bypasses single-flight bookkeeping.
    pub fn put(&self, key: u64, value: PredVec) {
        let mut shard = self.lock_shard(key);
        let cap = self.per_shard_cap;
        shard.insert(key, value, cap);
    }

    /// (hits, misses) so far.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Queries that coalesced onto another thread's in-flight invocation.
    pub fn coalesced(&self) -> u64 {
        self.coalesced.load(Ordering::Relaxed)
    }

    /// Lock acquisitions that found their shard already held.
    pub fn contended(&self) -> u64 {
        self.contended.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().entries.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::{Arc, Barrier};
    use std::time::Duration;

    #[test]
    fn hit_miss_accounting() {
        let c = PredictionCache::new(8);
        let k = cache_key("m", &[1, 2, 3]);
        assert_eq!(c.get(k), None);
        c.put(k, PredVec::scalar(7.5));
        assert_eq!(c.get(k), Some(PredVec::scalar(7.5)));
        assert_eq!(c.stats(), (1, 1));
    }

    #[test]
    fn peek_is_silent_and_does_not_promote() {
        // Counters: peek must move neither hits nor misses.
        let c = PredictionCache::new(8);
        let k = cache_key("m", &[1, 2, 3]);
        assert_eq!(c.peek(k), None);
        c.put(k, PredVec::scalar(7.5));
        assert_eq!(c.peek(k), Some(PredVec::scalar(7.5)));
        assert_eq!(c.stats(), (0, 0), "peek must not count hits or misses");

        // LRU: a peeked entry stays cold and evicts first.
        let c = PredictionCache::with_shards(3, 1);
        let (ka, kb, kc, kd) = (
            cache_key("m", &[1]),
            cache_key("m", &[2]),
            cache_key("m", &[3]),
            cache_key("m", &[4]),
        );
        c.put(ka, PredVec::scalar(1.0));
        c.put(kb, PredVec::scalar(2.0));
        c.put(kc, PredVec::scalar(3.0));
        assert_eq!(c.peek(ka), Some(PredVec::scalar(1.0)));
        c.put(kd, PredVec::scalar(4.0));
        assert_eq!(c.peek(ka), None, "peek must not have promoted ka");
        assert_eq!(c.peek(kd), Some(PredVec::scalar(4.0)));
    }

    #[test]
    fn distinct_keys() {
        assert_ne!(cache_key("a", &[1, 2]), cache_key("b", &[1, 2]));
        assert_ne!(cache_key("a", &[1, 2]), cache_key("a", &[2, 1]));
    }

    #[test]
    fn namespaces_split_targets_and_variants() {
        let ids = [1u32, 2, 3];
        // Same model architecture behind two variants or two targets:
        // the namespaces — and therefore the cache keys — must differ.
        let a = cache_key(&cache_namespace("regpressure", "fc_small", "fc_ops"), &ids);
        let b = cache_key(&cache_namespace("regpressure", "fc_wide", "fc_ops"), &ids);
        let c = cache_key(&cache_namespace("cycles", "fc_small", "fc_ops"), &ids);
        assert_ne!(a, b, "variants cross-serve");
        assert_ne!(a, c, "targets cross-serve");
        // Deterministic: every cluster node derives the same namespace.
        assert_eq!(
            cache_namespace("regpressure", "fc_small", "fc_ops"),
            "regpressure/fc_small/fc_ops"
        );
    }

    #[test]
    fn wire_key_roundtrips_losslessly() {
        for key in [0u64, 1, (1 << 53) + 1, u64::MAX, cache_key("m", &[1, 2, 3])] {
            let wire = key_to_wire(key);
            assert_eq!(wire.len(), 16, "fixed-width hex: {wire}");
            assert_eq!(key_from_wire(&wire), Some(key));
        }
        assert_eq!(key_from_wire("nope"), None);
        assert_eq!(key_from_wire(""), None);
    }

    #[test]
    fn eviction_respects_capacity() {
        // Single shard: deterministic global eviction order.
        let c = PredictionCache::with_shards(3, 1);
        for i in 0..10u32 {
            c.put(cache_key("m", &[i]), PredVec::scalar(i as f64));
        }
        assert_eq!(c.len(), 3);
        // The newest entries survive.
        assert_eq!(c.get(cache_key("m", &[9])), Some(PredVec::scalar(9.0)));
        assert_eq!(c.get(cache_key("m", &[0])), None);
    }

    #[test]
    fn sharded_capacity_is_bounded() {
        let c = PredictionCache::new(64);
        assert_eq!(c.shard_count(), DEFAULT_SHARDS);
        for i in 0..1000u32 {
            c.put(cache_key("m", &[i]), PredVec::scalar(i as f64));
        }
        assert!(c.len() <= 64, "len {} exceeds capacity", c.len());
        assert!(c.len() >= DEFAULT_SHARDS, "len {} suspiciously small", c.len());
    }

    #[test]
    fn put_same_key_updates_without_growth() {
        let c = PredictionCache::with_shards(2, 1);
        let k = cache_key("m", &[5]);
        c.put(k, PredVec::scalar(1.0));
        c.put(k, PredVec::scalar(2.0));
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(k), Some(PredVec::scalar(2.0)));
    }

    #[test]
    fn lru_promotion_on_hit() {
        let c = PredictionCache::with_shards(3, 1);
        let (ka, kb, kc, kd) = (
            cache_key("m", &[1]),
            cache_key("m", &[2]),
            cache_key("m", &[3]),
            cache_key("m", &[4]),
        );
        c.put(ka, PredVec::scalar(1.0));
        c.put(kb, PredVec::scalar(2.0));
        c.put(kc, PredVec::scalar(3.0));
        // Touch the oldest entry: it must now outlive kb under pressure.
        assert_eq!(c.get(ka), Some(PredVec::scalar(1.0)));
        c.put(kd, PredVec::scalar(4.0));
        assert_eq!(c.len(), 3);
        assert_eq!(c.get(ka), Some(PredVec::scalar(1.0)), "promoted entry was evicted");
        assert_eq!(c.get(kb), None, "LRU entry survived eviction");
        assert_eq!(c.get(kc), Some(PredVec::scalar(3.0)));
        assert_eq!(c.get(kd), Some(PredVec::scalar(4.0)));
    }

    #[test]
    fn heavy_reuse_does_not_leak_order_queue() {
        let c = PredictionCache::with_shards(4, 1);
        let k = cache_key("m", &[1]);
        c.put(k, PredVec::scalar(1.0));
        for _ in 0..10_000 {
            assert_eq!(c.get(k), Some(PredVec::scalar(1.0)));
        }
        let shard = c.shards[0].lock().unwrap();
        assert!(
            shard.order.len() <= shard.entries.len() * 4 + 16,
            "lazy LRU queue grew unboundedly: {}",
            shard.order.len()
        );
    }

    #[test]
    fn put_refresh_does_not_leak_order_queue() {
        let c = PredictionCache::with_shards(4, 1);
        let k = cache_key("m", &[1]);
        for i in 0..10_000 {
            c.put(k, PredVec::scalar(i as f64));
        }
        let shard = c.shards[0].lock().unwrap();
        assert!(
            shard.order.len() <= shard.entries.len() * 4 + 16,
            "refresh-heavy puts grew the lazy LRU queue unboundedly: {}",
            shard.order.len()
        );
    }

    #[test]
    fn tiny_capacity_clamps_shard_count() {
        let c = PredictionCache::new(4);
        assert!(c.shard_count() <= 4, "shards {} exceed capacity 4", c.shard_count());
        for i in 0..100u32 {
            c.put(cache_key("m", &[i]), PredVec::scalar(i as f64));
        }
        assert!(c.len() <= 4, "len {} exceeds tiny capacity", c.len());
    }

    #[test]
    fn single_flight_one_leader_32_threads() {
        let c = Arc::new(PredictionCache::with_shards(64, 8));
        let key = cache_key("m", &[42]);
        let leaders = Arc::new(AtomicUsize::new(0));
        let barrier = Arc::new(Barrier::new(32));
        let mut handles = Vec::new();
        for _ in 0..32 {
            let c = c.clone();
            let leaders = leaders.clone();
            let barrier = barrier.clone();
            handles.push(std::thread::spawn(move || {
                barrier.wait();
                // The flight carries the FULL characteristic vector; a
                // follower receives every element, not just the primary.
                let vec = PredVec::from_slice(&[7.25, 93.0]);
                match c.lookup(key) {
                    Lookup::Hit(v) => v,
                    Lookup::Wait(rx) => rx.recv().unwrap().expect("leader failed"),
                    Lookup::Miss(guard) => {
                        leaders.fetch_add(1, Ordering::SeqCst);
                        // Simulate the model invocation all followers
                        // coalesce onto.
                        std::thread::sleep(Duration::from_millis(30));
                        guard.complete(vec);
                        vec
                    }
                }
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), PredVec::from_slice(&[7.25, 93.0]));
        }
        assert_eq!(leaders.load(Ordering::SeqCst), 1, "exactly one model invocation");
        // Everyone else either coalesced onto the flight or hit the cache
        // after the leader published.
        let (hits, _) = c.stats();
        assert_eq!(c.coalesced() + hits + 1, 32);
        assert_eq!(c.get(key), Some(PredVec::from_slice(&[7.25, 93.0])));
    }

    #[test]
    fn failed_leader_wakes_followers_with_none() {
        let c = Arc::new(PredictionCache::with_shards(8, 1));
        let key = cache_key("m", &[9]);
        let Lookup::Miss(guard) = c.lookup(key) else {
            panic!("first lookup must be the leader")
        };
        let Lookup::Wait(rx) = c.lookup(key) else {
            panic!("second lookup must coalesce")
        };
        drop(guard); // leader "fails"
        assert_eq!(rx.recv().unwrap(), None);
        // The key is no longer in flight: a retry becomes a fresh leader.
        assert!(matches!(c.lookup(key), Lookup::Miss(_)));
    }

    #[test]
    fn contention_counter_moves_under_load() {
        let c = Arc::new(PredictionCache::with_shards(1024, 1)); // 1 shard: force collisions
        let barrier = Arc::new(Barrier::new(8));
        let mut handles = Vec::new();
        for t in 0..8u32 {
            let c = c.clone();
            let barrier = barrier.clone();
            handles.push(std::thread::spawn(move || {
                barrier.wait();
                for i in 0..2000u32 {
                    c.put(cache_key("m", &[t, i]), PredVec::scalar(i as f64));
                    c.get(cache_key("m", &[t, i]));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Not asserting a count (timing-dependent) — just that the counter
        // is wired and non-panicking; under 8 threads on one shard it is
        // overwhelmingly likely to be nonzero.
        let _ = c.contended();
    }
}

//! The serving coordinator: the "deploy the model which the DL-compiler
//! can invoke while compiling" half of the paper, built like a production
//! inference router — per-target heads, dynamic batching, prediction
//! cache, metrics, and a line-protocol TCP front end.
//!
//! Python is never here: predictions run through the AOT-compiled HLO
//! executables via PJRT.

pub mod batcher;
pub mod cache;
pub mod server;
pub mod stats;

use crate::bundle::Bundle;
use crate::mlir::parse_function;
use crate::runtime::{Executable, Manifest, Runtime, Tensor};
use crate::sim::Target;
use crate::tokenizer::{encode, tokenize};
use anyhow::{anyhow, Result};
use batcher::{BatchPolicy, BatchQueue, Pending};
use cache::{cache_key, PredictionCache};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// One target's serving head: bundle + batch queue + worker thread.
struct Head {
    bundle: Bundle,
    queue: Arc<BatchQueue>,
    worker: Option<JoinHandle<()>>,
}

/// The cost-model service a DL-compiler connects to.
pub struct Service {
    heads: HashMap<Target, Head>,
    pub cache: Arc<PredictionCache>,
    pub stats: Arc<stats::ServiceStats>,
}

impl Service {
    /// Spin up one worker per bundle. `use_pallas` selects the
    /// Pallas-kernel predict executables for conv models.
    ///
    /// Each worker owns its own PJRT client: the `xla` crate's handles are
    /// deliberately `!Send` (non-atomic refcounts around the C API), so
    /// the executable is compiled inside the worker thread it serves from.
    pub fn start(
        manifest: Arc<Manifest>,
        bundles: Vec<Bundle>,
        policy: BatchPolicy,
        use_pallas: bool,
    ) -> Result<Service> {
        let cache = Arc::new(PredictionCache::new(65536));
        let stats = Arc::new(stats::ServiceStats::default());
        let mut heads = HashMap::new();
        for bundle in bundles {
            let mm = manifest.model(&bundle.model)?;
            let (key, batch) = mm.predict_key_for(policy.max_batch, use_pallas);
            let key = if use_pallas && mm.files.get(&key).is_none() {
                // Non-conv models have no pallas variant; fall back.
                mm.predict_key_for(policy.max_batch, false).0
            } else {
                key
            };
            let path = manifest.path_of(mm.file(&key)?);
            let queue = BatchQueue::new(policy.clone());
            let worker = spawn_worker(
                path,
                bundle.params.clone(),
                bundle.max_len,
                batch,
                queue.clone(),
                stats.clone(),
            );
            heads.insert(
                bundle.target,
                Head { bundle, queue, worker: Some(worker) },
            );
        }
        Ok(Service { heads, cache, stats })
    }

    pub fn targets(&self) -> Vec<Target> {
        self.heads.keys().copied().collect()
    }

    /// Predict a hardware characteristic for a raw MLIR function text.
    /// This is the full request path: parse → tokenize → encode → cache →
    /// batch → PJRT → denormalize.
    pub fn predict(&self, target: Target, mlir_text: &str) -> Result<f64> {
        let t0 = Instant::now();
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        let head = self
            .heads
            .get(&target)
            .ok_or_else(|| anyhow!("no model serving target '{}'", target.name()))?;
        let func = parse_function(mlir_text)?;
        let toks = tokenize(&func, head.bundle.scheme);
        let ids = encode(&toks, &head.bundle.vocab, head.bundle.max_len);
        let key = cache_key(&head.bundle.model, &ids);
        if let Some(v) = self.cache.get(key) {
            self.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
            self.stats.record_latency_us(t0.elapsed().as_micros() as u64);
            return Ok(v);
        }
        let rx = head.queue.submit(ids);
        let norm = rx.recv().map_err(|_| anyhow!("prediction worker gone"))?;
        let value = head.bundle.stats.denormalize(norm);
        self.cache.put(key, value);
        self.stats.record_latency_us(t0.elapsed().as_micros() as u64);
        Ok(value)
    }

    /// Shut down workers (drains in-flight batches).
    pub fn shutdown(&mut self) {
        for head in self.heads.values_mut() {
            head.queue.close();
            if let Some(w) = head.worker.take() {
                let _ = w.join();
            }
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn spawn_worker(
    path: PathBuf,
    params: Vec<Tensor>,
    max_len: usize,
    batch: usize,
    queue: Arc<BatchQueue>,
    stats: Arc<stats::ServiceStats>,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        // Per-thread PJRT client + compile (see Service::start docs).
        let rt = match Runtime::cpu() {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("[coordinator] worker failed to create PJRT client: {e:#}");
                return;
            }
        };
        let exe = match rt.load(&path) {
            Ok(exe) => exe,
            Err(e) => {
                eprintln!("[coordinator] worker failed to compile {path:?}: {e:#}");
                return;
            }
        };
        eprintln!(
            "[coordinator] worker ready: {} compiled in {:.1} ms",
            exe.path, exe.compile_ms
        );
        while let Some(pending) = queue.next_batch() {
            if pending.is_empty() {
                continue;
            }
            match run_batch(&exe, &params, max_len, batch, &pending) {
                Ok(values) => {
                    stats.batches.fetch_add(1, Ordering::Relaxed);
                    stats
                        .batched_queries
                        .fetch_add(pending.len() as u64, Ordering::Relaxed);
                    for (p, v) in pending.iter().zip(values) {
                        let _ = p.respond.send(v);
                    }
                }
                Err(e) => {
                    stats.errors.fetch_add(1, Ordering::Relaxed);
                    eprintln!("[coordinator] batch failed: {e:#}");
                    // Drop senders → receivers see disconnect.
                }
            }
        }
    })
}

fn run_batch(
    exe: &Executable,
    params: &[Tensor],
    max_len: usize,
    batch: usize,
    pending: &[Pending],
) -> Result<Vec<f64>> {
    let mut out = Vec::with_capacity(pending.len());
    for chunk in pending.chunks(batch) {
        let mut ids: Vec<i32> = Vec::with_capacity(batch * max_len);
        for p in chunk {
            ids.extend(p.ids.iter().map(|&x| x as i32));
        }
        ids.resize(batch * max_len, 0);
        let mut inputs = params.to_vec();
        inputs.push(Tensor::i32(vec![batch as i64, max_len as i64], ids)?);
        let res = exe.run(&inputs)?;
        let vals = res[0].as_f32()?;
        out.extend(vals[..chunk.len()].iter().map(|&v| v as f64));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::TargetStats;
    use crate::graphgen::{generate, Family, GraphSpec};
    use crate::mlir::print_function;
    use crate::tokenizer::{Scheme, Vocab};
    use std::path::{Path, PathBuf};

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("artifacts")
    }

    fn test_service() -> Option<Service> {
        let adir = artifacts_dir();
        if !adir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let manifest = Arc::new(Manifest::load(&adir).unwrap());
        let streams = vec![vec!["xpu.matmul".to_string()]];
        let vocab = Vocab::build(streams.iter(), 1);
        let stats = TargetStats { mean: 20.0, std: 5.0, min: 4.0, max: 60.0 };
        let bundle = Bundle::untrained(
            &manifest,
            "fc_ops",
            Target::RegPressure,
            Scheme::OpsOnly,
            vocab,
            stats,
        )
        .unwrap();
        Some(
            Service::start(manifest, vec![bundle], BatchPolicy::default(), false).unwrap(),
        )
    }

    #[test]
    fn end_to_end_predict() {
        let Some(svc) = test_service() else { return };
        let spec = GraphSpec { family: Family::Mlp, structure_seed: 1, shape_seed: 2 };
        let text = print_function(&generate(&spec).unwrap());
        let v = svc.predict(Target::RegPressure, &text).unwrap();
        assert!(v.is_finite());
        // Same query → cache hit, identical answer.
        let v2 = svc.predict(Target::RegPressure, &text).unwrap();
        assert_eq!(v, v2);
        let (hits, _) = svc.cache.stats();
        assert_eq!(hits, 1);
    }

    #[test]
    fn unknown_target_is_error() {
        let Some(svc) = test_service() else { return };
        let spec = GraphSpec { family: Family::Mlp, structure_seed: 1, shape_seed: 2 };
        let text = print_function(&generate(&spec).unwrap());
        assert!(svc.predict(Target::Cycles, &text).is_err());
    }

    #[test]
    fn concurrent_queries_batch_together() {
        let Some(svc) = test_service() else { return };
        let svc = Arc::new(svc);
        let texts: Vec<String> = (0..24)
            .map(|i| {
                let spec = GraphSpec {
                    family: Family::ALL[i % 7],
                    structure_seed: i as u64,
                    shape_seed: 1000 + i as u64,
                };
                print_function(&generate(&spec).unwrap())
            })
            .collect();
        let mut handles = Vec::new();
        for t in texts {
            let svc = svc.clone();
            handles.push(std::thread::spawn(move || {
                svc.predict(Target::RegPressure, &t).unwrap()
            }));
        }
        for h in handles {
            assert!(h.join().unwrap().is_finite());
        }
        assert!(svc.stats.mean_batch_size() > 1.0, "no batching happened");
    }

    #[test]
    fn malformed_mlir_is_rejected() {
        let Some(svc) = test_service() else { return };
        assert!(svc.predict(Target::RegPressure, "not mlir at all").is_err());
    }
}
